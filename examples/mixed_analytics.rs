//! Mixed analytics (Table II generalized as a library client): run a
//! four-class mix — BFS, Figure-2 connected components, delta-stepping
//! SSSP and 2-hop neighborhoods — concurrently vs sequentially through the
//! open `Analysis` API, then drill into what the classes do to the machine:
//! BFS/k-hop are read-and-remote-write heavy, CC and SSSP hammer the
//! memory-side processors with `remote_min`, and the §IV-C counters show it.
//!
//! ```bash
//! cargo run --release --example mixed_analytics -- \
//!     [--scale 14] [--bfs 40] [--cc 10] [--sssp 10] [--khop 20]
//! ```

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{planner, Coordinator, Policy, QueryRequest};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::cli::Args;
use pathfinder_queries::util::stats::improvement_pct;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale: u32 = args.opt_parse_or("scale", 14)?;
    let bfs: usize = args.opt_parse_or("bfs", 40)?;
    let cc: usize = args.opt_parse_or("cc", 10)?;
    let sssp: usize = args.opt_parse_or("sssp", 10)?;
    let khop: usize = args.opt_parse_or("khop", 20)?;

    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let machine = Machine::new(MachineConfig::pathfinder_8());
    let coordinator = Coordinator::new(&g, machine);

    println!(
        "mix: {bfs} bfs + {cc} cc + {sssp} sssp + {khop} khop on {} \
         ({} vertices, {} directed edges)",
        coordinator.machine().cfg.name,
        g.n(),
        g.m_directed()
    );

    // Concurrent: the four classes interleaved into one stream, all at once.
    let classes: Vec<Vec<QueryRequest>> = vec![
        planner::bfs_queries(&g, bfs, 0xBF5),
        planner::cc_queries(cc),
        planner::sssp_queries(&g, sssp, 0xBF5 ^ 0x55),
        planner::khop_queries(&g, khop, 2, 0xBF5 ^ 0xAA),
    ];
    let queries = planner::interleave_classes(classes);
    let conc = coordinator.run(&queries, Policy::Concurrent)?;
    // Sequential: the paper's arm generalized — whole classes back to back.
    let seq_order = planner::sequential_mix_order(&queries);
    let seq = coordinator.run(&seq_order, Policy::Sequential)?;

    println!("concurrent: {:.4} s", conc.makespan_s);
    println!("sequential: {:.4} s", seq.makespan_s);
    println!(
        "improvement: {:.1}% (paper Table II: ~70% for the 80/20 two-class mix)",
        improvement_pct(seq.makespan_s, conc.makespan_s)
    );

    // Per-class latency, p50/p95/p99 included.
    for (label, q) in conc.per_class_quantiles() {
        println!("  {label:>5} latency: {}", q.latency_line());
    }

    // The §IV-C machine story, from the simulated hardware counters.
    let totals = conc.counters.totals();
    println!("\nhardware counters (concurrent run):");
    println!("  channel ops     {:>14.0}", totals.channel_ops);
    println!("  MSP remote_min  {:>14.0}  <- CC hook + SSSP relaxation traffic", totals.msp_ops);
    println!("  migrations      {:>14.0}", totals.migrations);
    println!("  fabric bytes    {:>14.0}", totals.fabric_bytes);
    println!("  channel util    {:>13.0}%", conc.mean_channel_utilization * 100.0);
    println!(
        "  msp share of channel traffic: {:.0}% — mixing read-heavy traversals \
         with remote_min-heavy analyses is what stresses the §IV-C read/write balance",
        100.0 * totals.msp_ops / totals.channel_ops
    );
    Ok(())
}
