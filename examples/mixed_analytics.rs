//! Mixed analytics (Table II as a library client): run an 80/20 mix of BFS
//! and Figure-2 connected components concurrently vs sequentially, then
//! drill into what the two algorithms do to the machine — BFS is
//! read-and-remote-write heavy, CC hammers the memory-side processors with
//! `remote_min`, and the §IV-C counters show it.
//!
//! ```bash
//! cargo run --release --example mixed_analytics -- [--scale 14] [--bfs 40] [--cc 10]
//! ```

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::MixPoint;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{planner, Coordinator, Policy};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::cli::Args;
use pathfinder_queries::util::stats::improvement_pct;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale: u32 = args.opt_parse_or("scale", 14)?;
    let mix = MixPoint {
        bfs: args.opt_parse_or("bfs", 40)?,
        cc: args.opt_parse_or("cc", 10)?,
    };

    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let machine = Machine::new(MachineConfig::pathfinder_8());
    let coordinator = Coordinator::new(&g, machine);

    println!(
        "mix: {} BFS + {} CC on {} ({} vertices, {} directed edges)",
        mix.bfs,
        mix.cc,
        coordinator.machine().cfg.name,
        g.n(),
        g.m_directed()
    );

    // Concurrent: the interleaved stream, all at once.
    let queries = planner::mix_queries(&g, mix, 0xBF5);
    let conc = coordinator.run(&queries, Policy::Concurrent)?;
    // Sequential: the paper's arm — all BFS, then all CC (§IV-C).
    let seq_order = planner::sequential_mix_order(&queries);
    let seq = coordinator.run(&seq_order, Policy::Sequential)?;

    println!("concurrent: {:.4} s", conc.makespan_s);
    println!("sequential: {:.4} s", seq.makespan_s);
    println!(
        "improvement: {:.1}% (paper Table II: ~70% on the single chassis)",
        improvement_pct(seq.makespan_s, conc.makespan_s)
    );

    // Per-class latency.
    for label in ["bfs", "cc"] {
        if let Some(q) = conc.latency_quantiles(Some(label)) {
            println!(
                "  {label:>3} latency: min {:.4}s  median {:.4}s  max {:.4}s",
                q.q0, q.q50, q.q100
            );
        }
    }

    // The §IV-C machine story, from the simulated hardware counters.
    let totals = conc.counters.totals();
    println!("\nhardware counters (concurrent run):");
    println!("  channel ops     {:>14.0}", totals.channel_ops);
    println!("  MSP remote_min  {:>14.0}  <- the CC hook traffic", totals.msp_ops);
    println!("  migrations      {:>14.0}", totals.migrations);
    println!("  fabric bytes    {:>14.0}", totals.fabric_bytes);
    println!("  channel util    {:>13.0}%", conc.mean_channel_utilization * 100.0);
    println!(
        "  msp share of channel traffic: {:.0}% — mixing read-heavy BFS with \
         remote_min-heavy CC is what stresses the §IV-C read/write balance",
        100.0 * totals.msp_ops / totals.channel_ops
    );
    Ok(())
}
