//! A sharded multi-chassis fleet serving one graph (DESIGN.md §Fleet):
//! the Pathfinder scaled past a single chassis by partitioning the graph
//! across N shards, replicating each shard R times, and pricing every
//! cross-shard frontier exchange on the fleet interconnect.
//!
//! The sweep below serves the same saturating mixed workload on a single
//! chassis and on 2/4/8-shard fleets (hash and degree-balanced edge-cut
//! partitions), then adds read replicas and finally live edge ingest —
//! where each update batch fans out through one ordered log so every
//! replica of a shard applies the same batches in the same order and all
//! copies agree per epoch. The summary's `fleet:` lines show the edge-cut
//! fraction, total interconnect traffic, and per-shard channel
//! utilization: a hash partition of a skewed graph leaves shards
//! unevenly loaded, which the balanced partitioner visibly narrows.
//!
//! The closest CLI invocation to the 4-shard sweep point:
//!
//! ```bash
//! cargo run --release -- serve --scale 13 --queries 200 --rate 2000 \
//!     --fleet nodes=4,partition=balanced
//! ```
//!
//! ```bash
//! cargo run --release --example fleet_service -- [--scale 13] [--machine pathfinder-8]
//! ```

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{
    FleetConfig, GraphService, MutationConfig, ServiceConfig, WorkloadSpec,
};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::flow::OnFull;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale: u32 = args.opt_parse_or("scale", 13)?;
    let preset = args.opt_or("machine", "pathfinder-8");

    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let mcfg = MachineConfig::preset(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset:?}"))?;
    let service = GraphService::new(&g, Machine::new(mcfg));

    println!(
        "fleet service on {preset} chassis: {} vertices, {} directed arcs\n",
        g.n(),
        g.m_directed()
    );

    let base = ServiceConfig {
        queries: 200,
        arrival_rate_per_s: 2000.0,
        workload: WorkloadSpec::four_class(),
        on_full: OnFull::Queue,
        seed: 0x5E21,
        ..Default::default()
    };

    // Scale-out sweep: the same burst on one chassis, then on fleets of
    // 2/4/8 shards. More shards add channel capacity but also turn more
    // edges into cross-shard frontier exchanges — the `interconnect`
    // figure in the fleet line is that traffic, priced by the flow engine
    // as a per-node interconnect resource alongside the five on-chassis
    // lanes.
    for spec in ["nodes=2", "nodes=4", "nodes=8"] {
        for strategy in ["hash", "balanced"] {
            let cfg = ServiceConfig {
                fleet: Some(FleetConfig::parse(&format!("{spec},partition={strategy}"))?),
                ..base.clone()
            };
            let rep = service.serve(&cfg)?;
            println!("--fleet {spec},partition={strategy}:");
            println!("{}", indent(&rep.summary()));
        }
    }
    println!("single chassis, same burst (for comparison):");
    let rep = service.serve(&base)?;
    println!("{}", indent(&rep.summary()));

    // Read replicas: each shard served by 2 copies; rooted traversals
    // route to a replica by query id while every replica still holds its
    // shard, doubling read bandwidth for the same cut.
    println!("4 shards x 2 read replicas:");
    let cfg = ServiceConfig {
        fleet: Some(FleetConfig::parse("nodes=4,replicas=2,partition=balanced")?),
        ..base.clone()
    };
    let rep = service.serve(&cfg)?;
    println!("{}", indent(&rep.summary()));

    // Live ingest on the fleet: update batches fan out through one
    // ordered log — the primary applies each batch, then streams it to
    // every replica as explicit interconnect traffic, so all copies of a
    // shard agree per epoch (the equivalence property pinned in
    // rust/tests/prop_tests.rs). Compactions surface as Batch-class
    // `compact` work, one fold per replica's copy of the base.
    println!("4 shards x 2 replicas with live edge ingest (--mutate):");
    let cfg = ServiceConfig {
        queries: 200,
        arrival_rate_per_s: 1000.0,
        workload: WorkloadSpec::four_class(),
        on_full: OnFull::Queue,
        mutation: Some(MutationConfig {
            rate_batches_per_s: 200.0,
            batch: 64,
            delete_fraction: 0.1,
            compact_every: 4,
        }),
        fleet: Some(FleetConfig::parse("nodes=4,replicas=2,partition=balanced")?),
        seed: 0x5E21,
        ..Default::default()
    };
    let rep = service.serve(&cfg)?;
    println!("{}", indent(&rep.summary()));
    Ok(())
}

fn indent(s: &str) -> String {
    s.lines().map(|l| format!("    {l}\n")).collect()
}
