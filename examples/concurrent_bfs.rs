//! The Figure-3/4 experiment as a library client: sweep the number of
//! concurrent BFS queries on both Pathfinder configurations and plot (as
//! text) total time and improvement, including the §IV-B observations —
//! linear growth in query count, sub-linear 8→32-node scaling, and the
//! thread-context wall at 256 queries on 8 nodes.
//!
//! ```bash
//! cargo run --release --example concurrent_bfs -- [--scale 14] [--counts 1,8,32,128]
//! ```

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{planner, Coordinator, Policy};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::cli::Args;
use pathfinder_queries::util::format::{fmt_pct, fmt_s, TextTable};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale: u32 = args.opt_parse_or("scale", 14)?;
    let counts: Vec<usize> = args
        .opt_list("counts")?
        .unwrap_or_else(|| vec![1, 8, 16, 32, 64, 128]);

    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    eprintln!("graph: {} vertices, {} directed edges", g.n(), g.m_directed());

    let max_q = counts.iter().copied().max().unwrap_or(1);
    let mut table = TextTable::new(vec![
        "machine", "queries", "concurrent", "sequential", "improvement",
    ]);
    let mut t128 = Vec::new(); // (machine, conc_s, seq_s) at the largest count

    for preset in ["pathfinder-8", "pathfinder-32"] {
        let machine = Machine::new(MachineConfig::preset(preset).unwrap());
        let coordinator = Coordinator::new(&g, machine);
        let queries = planner::bfs_queries(&g, max_q.min(coordinator.capacity()), 0xBF5);
        let specs = coordinator.prepare(&queries);

        for &k in counts.iter().filter(|&&k| k <= queries.len()) {
            let conc =
                coordinator.run_specs(&queries[..k], &specs[..k], Policy::Concurrent)?;
            let seq =
                coordinator.run_specs(&queries[..k], &specs[..k], Policy::Sequential)?;
            let impr = (seq.makespan_s / conc.makespan_s - 1.0) * 100.0;
            table.row(vec![
                preset.to_string(),
                k.to_string(),
                fmt_s(conc.makespan_s),
                fmt_s(seq.makespan_s),
                fmt_pct(impr),
            ]);
            if k == max_q {
                t128.push((preset, conc.makespan_s, seq.makespan_s));
            }
        }
    }
    println!("{}", table.render());

    if let [(_, c8, s8), (_, c32, s32)] = t128[..] {
        println!(
            "8->32-node speed-up at q={max_q}: {:.2}x concurrent, {:.2}x sequential \
             (paper: 2.69x / 3.24x)",
            c8 / c32,
            s8 / s32
        );
    }

    // The §IV-B wall: 256 concurrent queries exhaust 8-node context memory.
    let coordinator =
        Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let too_many = planner::bfs_queries(&g, coordinator.capacity() + 1, 0xBF5);
    match coordinator.run(&too_many, Policy::Concurrent) {
        Err(e) => println!("\n{} queries on pathfinder-8: {e}", too_many.len()),
        Ok(_) => unreachable!("over-capacity run must fail"),
    }
    Ok(())
}
