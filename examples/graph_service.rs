//! A web-accessible graph database deployment (the paper's §I motivation):
//! the Pathfinder as a long-running service behind admission control.
//!
//! Queries arrive as a Poisson stream drawn from a declarative
//! `WorkloadSpec` — here the full six-analysis catalog: BFS, k-hop
//! neighborhoods, SSSP, connected components, PageRank and triangle
//! counting (the two analytic kernels run as Batch-class background
//! work). Thread-context memory bounds in-flight work (the §IV-B
//! exhaustion becomes queueing or rejection); the operator report shows
//! per-class p50/p95/p99 latency with SLO verdicts, throughput and
//! channel utilization. Sweeping the offered load shows the service
//! saturating exactly where the concurrency experiments say it should.
//!
//! The closest CLI invocation to the first sweep point (the shape the
//! README quotes). One caveat: a `--mix` parsed from the CLI files every
//! class as Standard priority, while this example's `six_class()`
//! catalog files khop as Interactive and cc/pagerank/tricount as Batch —
//! so under priority-aware admission or `--weights`, per-class latencies
//! differ between the two:
//!
//! ```bash
//! cargo run --release -- serve --scale 13 --queries 300 --rate 200 \
//!     --mix bfs=0.35,khop=0.25,sssp=0.15,cc=0.1,pagerank=0.1,tricount=0.05 \
//!     --slo khop=0.05,bfs=0.5
//! ```
//!
//! ```bash
//! cargo run --release --example graph_service -- [--scale 13] [--machine pathfinder-8]
//! ```

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{
    GraphService, MutationConfig, PreemptPolicy, PriorityMix, ServiceConfig, ShareWeights,
    WorkloadSpec,
};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::flow::OnFull;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let scale: u32 = args.opt_parse_or("scale", 13)?;
    let preset = args.opt_or("machine", "pathfinder-8");

    let gcfg = GraphConfig::with_scale(scale);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    let mcfg = MachineConfig::preset(&preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset:?}"))?;
    let service = GraphService::new(&g, Machine::new(mcfg));

    println!(
        "graph service on {preset}: {} vertices, capacity {} concurrent queries\n",
        g.n(),
        service.coordinator().capacity()
    );

    // Sweep the offered load from idle to overload, serving all six
    // analysis classes (k-hop and BFS carry p99 SLOs the summary checks;
    // PageRank and triangle counting ride as Batch-class background work).
    for rate in [50.0, 200.0, 1000.0, 5000.0, 20000.0] {
        let cfg = ServiceConfig {
            queries: 300,
            arrival_rate_per_s: rate,
            workload: WorkloadSpec::six_class(),
            on_full: OnFull::Queue,
            seed: 0x5E21,
            ..Default::default()
        };
        let rep = service.serve(&cfg)?;
        println!("offered {rate:>7.0} q/s:");
        println!("{}", indent(&rep.summary()));
    }

    // Overload with rejection instead of queueing.
    println!("same burst with admission control set to REJECT:");
    let cfg = ServiceConfig {
        queries: 300,
        arrival_rate_per_s: 20000.0,
        workload: WorkloadSpec::six_class(),
        on_full: OnFull::Reject,
        seed: 0x5E21,
        ..Default::default()
    };
    let rep = service.serve(&cfg)?;
    println!("{}", indent(&rep.summary()));

    // Overload with a bounded wait queue and an explicit priority mix:
    // Batch work is shed first, Interactive survives with the best p99.
    println!("same burst with a bounded queue (SHED) and 20/60/20 priorities:");
    let cfg = ServiceConfig {
        queries: 300,
        arrival_rate_per_s: 20000.0,
        workload: WorkloadSpec::six_class(),
        on_full: OnFull::Shed { max_waiting: 32 },
        priority_mix: Some(PriorityMix { interactive: 0.2, standard: 0.6, batch: 0.2 }),
        seed: 0x5E21,
        ..Default::default()
    };
    let rep = service.serve(&cfg)?;
    println!("{}", indent(&rep.summary()));

    // Weighted fair share + checkpoint preemption: running queries split
    // saturated bandwidth 4:2:1 by class, and Batch work parks at phase
    // boundaries whenever a queued Interactive query needs its context
    // bytes — compare the interactive p99 lines against the run above.
    println!("same burst with 4:2:1 fair-share weights and checkpoint preemption:");
    let cfg = ServiceConfig {
        queries: 300,
        arrival_rate_per_s: 20000.0,
        workload: WorkloadSpec::six_class(),
        on_full: OnFull::Queue,
        priority_mix: Some(PriorityMix { interactive: 0.2, standard: 0.6, batch: 0.2 }),
        weights: ShareWeights::priority_weighted(),
        preempt: Some(PreemptPolicy::default()),
        mutation: None,
        fleet: None,
        seed: 0x5E21,
    };
    let rep = service.serve(&cfg)?;
    println!("{}", indent(&rep.summary()));

    // Live graph: edges stream in while the service runs. Update batches
    // are Batch-class work sharing channel bandwidth with queries; each
    // query pins the epoch current at its admission, and the store
    // compacts drained overlays back into a flat base (the summary's
    // mutation line shows epochs / compactions / update throughput).
    println!("moderate load with live edge ingest (serve --mutate):");
    let cfg = ServiceConfig {
        queries: 300,
        arrival_rate_per_s: 1000.0,
        workload: WorkloadSpec::six_class(),
        on_full: OnFull::Queue,
        mutation: Some(MutationConfig {
            rate_batches_per_s: 250.0,
            batch: 64,
            delete_fraction: 0.1,
            compact_every: 4,
        }),
        seed: 0x5E21,
        ..Default::default()
    };
    let rep = service.serve(&cfg)?;
    println!("{}", indent(&rep.summary()));
    Ok(())
}

fn indent(s: &str) -> String {
    s.lines().map(|l| format!("    {l}\n")).collect()
}
