//! Quickstart: the library in ~40 lines.
//!
//! Generate a Graph500-style R-MAT graph, stand up a coordinator on the
//! simulated 8-node Pathfinder, run the same 32 BFS queries sequentially
//! and concurrently, and print the paper's headline comparison.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::{planner, Coordinator, ImprovementRow, Policy};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::sim::machine::Machine;

fn main() -> anyhow::Result<()> {
    // 1. A scale-14 R-MAT graph (16k vertices, ~400k directed edges).
    let gcfg = GraphConfig::with_scale(14);
    let g = build_undirected_csr(gcfg.n_vertices() as usize, &Rmat::new(gcfg).edges());
    println!("graph: {} vertices, {} directed edges", g.n(), g.m_directed());

    // 2. A coordinator on the single-chassis, 8-node Pathfinder.
    let machine = Machine::new(MachineConfig::pathfinder_8());
    let coordinator = Coordinator::new(&g, machine);

    // 3. 32 BFS queries from unique pseudorandom sources (§IV-A).
    let queries = planner::bfs_queries(&g, 32, 0xBF5);

    // 4. Run them both ways.
    let concurrent = coordinator.run(&queries, Policy::Concurrent)?;
    let sequential = coordinator.run(&queries, Policy::Sequential)?;

    // 5. The paper's comparison.
    let row = ImprovementRow::from_reports(&concurrent, &sequential);
    println!("concurrent: {:.4} s  (channel utilization {:.0}%)",
        concurrent.makespan_s, concurrent.mean_channel_utilization * 100.0);
    println!("sequential: {:.4} s  (channel utilization {:.0}%)",
        sequential.makespan_s, sequential.mean_channel_utilization * 100.0);
    println!(
        "improvement: {:.1}%  ({:.2}x) — the paper reports >100% on this machine",
        row.improvement_pct(),
        row.speedup()
    );
    Ok(())
}
