"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) -> HLO text.

Nothing in this package runs at query time. ``compile.aot`` is invoked once
by ``make artifacts``; the rust coordinator loads the resulting HLO text
through PJRT (see rust/src/runtime/).
"""
