"""AOT export: lower the L2 step functions to HLO *text* + a variant manifest.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --outdir ../artifacts

Interchange format is HLO text, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``). The HLO *text* parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md). Lowering uses ``return_tuple=True`` so the
rust side unwraps a single tuple result.

Variants: one HLO module per (kind, batch, n) — PJRT executables are
shape-monomorphic, and the rust dynamic batcher picks the smallest variant
that fits the batch it formed. The manifest (artifacts/manifest.json) tells
the rust runtime what exists without it having to parse HLO.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

DEFAULT_N = 1024
DEFAULT_BATCHES = (1, 8, 32, 128)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bfs_step(batch: int, n: int) -> str:
    specs = model.bfs_step_specs(batch, n)
    return to_hlo_text(jax.jit(model.bfs_step).lower(*specs))


def lower_cc_step(n: int) -> str:
    specs = model.cc_step_specs(n)
    return to_hlo_text(jax.jit(model.cc_step).lower(*specs))


def export_all(outdir: str, n: int, batches) -> dict:
    os.makedirs(outdir, exist_ok=True)
    entries = []

    def emit(name: str, kind: str, batch: int, text: str, outputs):
        path = f"{name}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": kind,
                "batch": batch,
                "n": n,
                "path": path,
                "outputs": outputs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"  wrote {path} ({len(text)} chars)")

    for b in batches:
        emit(
            f"bfs_step_b{b}_n{n}",
            "bfs_step",
            b,
            lower_bfs_step(b, n),
            ["next_frontier", "visited", "levels", "active"],
        )
    emit(f"cc_step_n{n}", "cc_step", 0, lower_cc_step(n), ["labels", "changed"])

    manifest = {"version": 1, "n": n, "entries": entries}
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(entries)} variants)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    ap.add_argument(
        "--batches",
        type=lambda s: tuple(int(x) for x in s.split(",")),
        default=DEFAULT_BATCHES,
    )
    args = ap.parse_args()
    print(f"AOT export -> {args.outdir} (n={args.n}, batches={args.batches})")
    export_all(args.outdir, args.n, args.batches)


if __name__ == "__main__":
    main()
