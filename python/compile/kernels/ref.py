"""Pure-jnp oracles for the Pallas kernels.

Used by pytest as the correctness ground truth and kept deliberately
one-line-obvious: any divergence between a kernel and its oracle is a kernel
bug, never an oracle bug.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def frontier_expand_ref(frontier: jax.Array, adj: jax.Array, visited: jax.Array) -> jax.Array:
    """Oracle for :func:`compile.kernels.frontier.frontier_expand`."""
    hits = jnp.minimum(frontier @ adj, 1.0)
    return hits * (1.0 - visited)


def min_hook_ref(labels: jax.Array, adj: jax.Array) -> jax.Array:
    """Oracle for :func:`compile.kernels.minhook.min_hook`."""
    contrib = jnp.where(adj > 0.0, labels.reshape(-1, 1), jnp.inf)
    return jnp.minimum(labels, contrib.min(axis=0))
