"""Shiloach-Vishkin "hook" step as a blocked masked-min Pallas kernel.

This is the linear-algebra form of the paper's Figure 2, line 1: on the
Pathfinder every edge (u, v) issues ``remote_min(&C[v], C[u])`` at the
memory-side processor. On the GraphBLAS baseline the same step is a min-plus
(tropical) masked reduction over the adjacency matrix:

    C'[v] = min(C[v], min_{u : A[u,v] = 1} C[u])

The kernel tiles ``adj`` into (bk, bn) VMEM blocks; each output block keeps a
running minimum across the K grid dimension, seeded from the vertex's own
label, with non-edges contributing +inf.

Labels are carried as f32; component labels are vertex ids < 2**24 so every
value is exactly representable and the min is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hook_kernel(c_ref, cself_ref, a_ref, o_ref):
    """One (1, bn) output block of new labels; grid dim 1 iterates K blocks."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = cself_ref[...]

    # contrib[u, v] = C[u] where there is an edge u -> v, else +inf.
    contrib = jnp.where(a_ref[...] > 0.0, c_ref[...].reshape(-1, 1), float("inf"))
    o_ref[...] = jnp.minimum(o_ref[...], contrib.min(axis=0, keepdims=True))


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def min_hook(
    labels: jax.Array,
    adj: jax.Array,
    *,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """One SV hook sweep: push the minimum label across every edge.

    Args:
      labels: (N,) f32 — current tentative component label per vertex.
      adj:    (N, N) f32 0/1 — directed representation of the undirected
              graph (both (i,j) and (j,i) present), as in the paper §IV-A.

    Returns:
      (N,) f32 updated labels (monotonically non-increasing).
    """
    (n,) = labels.shape
    assert adj.shape == (n, n)
    block_n = min(block_n, n)
    block_k = min(block_k, n)
    assert n % block_n == 0 and n % block_k == 0

    labels2 = labels.reshape(1, n)
    grid = (n // block_n, n // block_k)
    out = pl.pallas_call(
        _hook_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_k), lambda jn, kk: (0, kk)),
            pl.BlockSpec((1, block_n), lambda jn, kk: (0, jn)),
            pl.BlockSpec((block_k, block_n), lambda jn, kk: (kk, jn)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda jn, kk: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=True,  # CPU-PJRT execution; see module docstring.
    )(labels2, labels2, adj)
    return out.reshape(n)
