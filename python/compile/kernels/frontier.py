"""BFS frontier expansion as a blocked, masked boolean mat-mul Pallas kernel.

GraphBLAS semantics (what RedisGraph's ``algo.BFS`` executes underneath):

    next_frontier = (frontier (any.and) A) .* (not visited)

over the boolean semiring. We emulate the boolean semiring on the MXU with
f32 arithmetic: the 0/1 matmul accumulates edge multiplicities, and the fused
epilogue saturates at 1 and applies the complement mask. All values stay
exactly representable in f32 (accumulated counts are bounded by N < 2**24),
so the emulation is exact, not approximate.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles HBM-resident
``adj`` into (bk, bn) VMEM blocks streamed through BlockSpec; the output
block is the VMEM accumulator that lives across the K-loop (innermost grid
dimension); the epilogue (saturate + mask) runs on the VPU on the final K
step, avoiding a second pass over the output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expand_kernel(f_ref, a_ref, v_ref, o_ref, *, k_blocks: int):
    """One (b, n) output block; grid dim 2 iterates K blocks (innermost)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Boolean semiring "any.and" emulated as f32 matmul; exact for 0/1 data.
    o_ref[...] += jnp.dot(f_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_blocks - 1)
    def _epilogue():
        # Saturate multiplicities to {0,1} and mask out visited vertices.
        hit = jnp.minimum(o_ref[...], 1.0)
        o_ref[...] = hit * (1.0 - v_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "block_k"))
def frontier_expand(
    frontier: jax.Array,
    adj: jax.Array,
    visited: jax.Array,
    *,
    block_b: int = 8,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Expand a batch of BFS frontiers one level.

    Args:
      frontier: (B, N) f32 0/1 — one row per concurrent BFS query.
      adj:      (N, N) f32 0/1 — adj[i, j] == 1 iff edge i -> j.
      visited:  (B, N) f32 0/1 — vertices already discovered per query.
      block_*:  VMEM tile sizes; 128 matches the MXU systolic array edge.

    Returns:
      (B, N) f32 0/1 next frontier: reachable-in-one-hop and not visited.
    """
    b, n = frontier.shape
    assert adj.shape == (n, n), (adj.shape, n)
    assert visited.shape == (b, n)
    block_b = min(block_b, b)
    block_n = min(block_n, n)
    block_k = min(block_k, n)
    assert b % block_b == 0 and n % block_n == 0 and n % block_k == 0
    k_blocks = n // block_k

    grid = (b // block_b, n // block_n, k_blocks)
    return pl.pallas_call(
        functools.partial(_expand_kernel, k_blocks=k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda ib, jn, kk: (ib, kk)),
            pl.BlockSpec((block_k, block_n), lambda ib, jn, kk: (kk, jn)),
            pl.BlockSpec((block_b, block_n), lambda ib, jn, kk: (ib, jn)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda ib, jn, kk: (ib, jn)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,  # CPU-PJRT execution; see module docstring.
    )(frontier, adj, visited)
