"""Layer-1 Pallas kernels for the GraphBLAS-semantics baseline engine.

These implement the hot spots of the RedisGraph comparison platform from the
paper (Section IV-D): RedisGraph's BFS is a masked boolean matrix-vector
product on GraphBLAS; its connectivity primitive is a masked min reduction.

All kernels are lowered with ``interpret=True`` so the HLO runs on the CPU
PJRT client (real-TPU Mosaic custom-calls are not loadable there). Kernels
are validated against the pure-jnp oracles in :mod:`compile.kernels.ref`.
"""

from compile.kernels.frontier import frontier_expand
from compile.kernels.minhook import min_hook

__all__ = ["frontier_expand", "min_hook"]
