"""Layer-2 JAX compute graphs for the GraphBLAS baseline engine.

These are the *whole-step* computations the rust runtime executes per BFS
level / per SV iteration; the Pallas kernels from :mod:`compile.kernels` are
the hot spots inside them, so kernel + epilogue lower into one HLO module
(one PJRT executable per (kind, batch, n) variant — see :mod:`compile.aot`).

Everything is f32: levels and labels are small integers, exactly
representable; keeping a single dtype keeps the rust Literal plumbing simple.

Step functions, not whole-query loops, are exported: BFS depth is
data-dependent, and the rust coordinator owns the convergence loop (it also
owns batching, admission and timing — the L3 contribution). Each step
returns a cheap scalar the coordinator uses to decide termination without
scanning the full output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.frontier import frontier_expand
from compile.kernels.minhook import min_hook


def bfs_step(adj, frontier, visited, levels, depth):
    """One level-synchronous BFS step for a batch of concurrent queries.

    Args:
      adj:      (N, N) f32 0/1 adjacency.
      frontier: (B, N) f32 0/1 current frontier per query.
      visited:  (B, N) f32 0/1 discovered set per query (includes frontier).
      levels:   (B, N) f32 — BFS level per vertex, -1 for undiscovered.
      depth:    scalar f32 — the level being assigned this step.

    Returns:
      (next_frontier, visited', levels', active) where active is a (B,)
      vector of next-frontier population counts (0 => that query finished).
    """
    nxt = frontier_expand(frontier, adj, visited)
    visited = jnp.minimum(visited + nxt, 1.0)
    levels = jnp.where(nxt > 0.0, depth, levels)
    active = jnp.sum(nxt, axis=1)
    return nxt, visited, levels, active


def cc_step(adj, labels):
    """One Shiloach-Vishkin iteration: hook sweep + full pointer-jump compress.

    Mirrors the paper's Figure 2 loop body on GraphBLAS semantics: the hook
    is the masked-min product (remote_min analogue); the compress phase
    pointer-jumps labels until every label is a root. ceil(log2 N) jumps
    fully flatten any min-tree, so a fixed fori_loop keeps the HLO static.

    Args:
      adj:    (N, N) f32 0/1 adjacency (directed representation).
      labels: (N,) f32 tentative component labels.

    Returns:
      (labels', changed) — changed is a scalar count of vertices whose label
      shrank this iteration (0 => converged), the paper's `changed` flag.
    """
    (n,) = labels.shape
    hooked = min_hook(labels, adj)

    jumps = max(1, int(n).bit_length())

    def jump(_, lab):
        return jnp.minimum(lab, lab[lab.astype(jnp.int32)])

    compressed = jax.lax.fori_loop(0, jumps, jump, hooked)
    changed = jnp.sum((compressed != labels).astype(jnp.float32))
    return compressed, changed


def bfs_step_specs(batch: int, n: int):
    """Input ShapeDtypeStructs for lowering `bfs_step` at a fixed variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),      # adj
        jax.ShapeDtypeStruct((batch, n), f32),  # frontier
        jax.ShapeDtypeStruct((batch, n), f32),  # visited
        jax.ShapeDtypeStruct((batch, n), f32),  # levels
        jax.ShapeDtypeStruct((), f32),          # depth
    )


def cc_step_specs(n: int):
    """Input ShapeDtypeStructs for lowering `cc_step` at a fixed variant."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, n), f32),  # adj
        jax.ShapeDtypeStruct((n,), f32),    # labels
    )
