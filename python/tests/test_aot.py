"""AOT export smoke tests: HLO text must be parseable interchange.

These do not execute through PJRT-from-rust (cargo tests do that); they check
the text artifact invariants the rust loader depends on.
"""

import json

import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_manifest(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export_all(str(outdir), n=128, batches=(1, 2))
    return outdir, manifest


def test_manifest_entries(small_manifest):
    _, manifest = small_manifest
    kinds = [(e["kind"], e["batch"]) for e in manifest["entries"]]
    assert ("bfs_step", 1) in kinds
    assert ("bfs_step", 2) in kinds
    assert ("cc_step", 0) in kinds


def test_hlo_text_shape(small_manifest):
    outdir, manifest = small_manifest
    for entry in manifest["entries"]:
        text = (outdir / entry["path"]).read_text()
        assert "ENTRY" in text, entry["name"]
        assert "HloModule" in text, entry["name"]
        # Tuple return (return_tuple=True) is what the rust side unwraps.
        assert "tuple" in text.lower(), entry["name"]


def test_manifest_json_round_trip(small_manifest):
    outdir, manifest = small_manifest
    on_disk = json.loads((outdir / "manifest.json").read_text())
    assert on_disk == manifest


def test_bfs_step_io_arity(small_manifest):
    _, manifest = small_manifest
    for entry in manifest["entries"]:
        if entry["kind"] == "bfs_step":
            assert entry["outputs"] == ["next_frontier", "visited", "levels", "active"]
        else:
            assert entry["outputs"] == ["labels", "changed"]


def test_no_custom_calls(small_manifest):
    """interpret=True must lower to plain HLO (no Mosaic custom-calls)."""
    outdir, manifest = small_manifest
    for entry in manifest["entries"]:
        text = (outdir / entry["path"]).read_text()
        assert "custom-call" not in text, entry["name"]
