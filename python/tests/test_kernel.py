"""L1 correctness: frontier_expand Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal for the baseline engine's hot spot —
exact equality is required (the boolean-semiring emulation is exact in f32).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_array_equal

from compile.kernels.frontier import frontier_expand
from compile.kernels.ref import frontier_expand_ref


def random_instance(rng, b, n, density=0.05):
    adj = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    frontier = (rng.random((b, n)) < 0.1).astype(np.float32)
    visited = np.maximum(frontier, (rng.random((b, n)) < 0.2).astype(np.float32))
    return frontier, adj, visited


@pytest.mark.parametrize("b", [1, 2, 8])
@pytest.mark.parametrize("n", [128, 256])
def test_matches_ref(b, n):
    rng = np.random.default_rng(b * 1000 + n)
    frontier, adj, visited = random_instance(rng, b, n)
    got = np.asarray(frontier_expand(frontier, adj, visited))
    want = np.asarray(frontier_expand_ref(frontier, adj, visited))
    assert_array_equal(got, want)


@pytest.mark.parametrize(
    "block_b,block_n,block_k",
    [(1, 128, 128), (2, 64, 128), (4, 128, 64), (8, 32, 32), (8, 256, 256)],
)
def test_block_shapes(block_b, block_n, block_k):
    """Tiling must never change the result."""
    rng = np.random.default_rng(7)
    frontier, adj, visited = random_instance(rng, 8, 256)
    got = np.asarray(
        frontier_expand(
            frontier, adj, visited, block_b=block_b, block_n=block_n, block_k=block_k
        )
    )
    want = np.asarray(frontier_expand_ref(frontier, adj, visited))
    assert_array_equal(got, want)


def test_empty_frontier_stays_empty():
    n = 128
    rng = np.random.default_rng(3)
    _, adj, visited = random_instance(rng, 2, n)
    frontier = np.zeros((2, n), np.float32)
    out = np.asarray(frontier_expand(frontier, adj, visited))
    assert_array_equal(out, np.zeros_like(out))


def test_all_visited_blocks_everything():
    n = 128
    rng = np.random.default_rng(4)
    frontier, adj, _ = random_instance(rng, 2, n)
    visited = np.ones((2, n), np.float32)
    out = np.asarray(frontier_expand(frontier, adj, visited))
    assert_array_equal(out, np.zeros_like(out))


def test_dense_adjacency_saturates_to_one():
    """High-multiplicity hits must clamp to exactly 1.0 (boolean semiring)."""
    n, b = 128, 2
    adj = np.ones((n, n), np.float32)
    frontier = np.ones((b, n), np.float32)
    visited = np.zeros((b, n), np.float32)
    out = np.asarray(frontier_expand(frontier, adj, visited))
    assert_array_equal(out, np.ones_like(out))


def test_output_is_binary():
    rng = np.random.default_rng(5)
    frontier, adj, visited = random_instance(rng, 4, 256, density=0.3)
    out = np.asarray(frontier_expand(frontier, adj, visited))
    assert set(np.unique(out)).issubset({0.0, 1.0})


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    b=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([128, 256]),
    density=st.floats(0.0, 0.5),
)
def test_hypothesis_sweep(seed, b, n, density):
    """Property: kernel == oracle for arbitrary binary instances."""
    rng = np.random.default_rng(seed)
    frontier, adj, visited = random_instance(rng, b, n, density)
    got = np.asarray(frontier_expand(frontier, adj, visited))
    want = np.asarray(frontier_expand_ref(frontier, adj, visited))
    assert_array_equal(got, want)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_hypothesis_monotone_visited(seed):
    """Property: next frontier never intersects visited."""
    rng = np.random.default_rng(seed)
    frontier, adj, visited = random_instance(rng, 2, 128, 0.2)
    out = np.asarray(frontier_expand(frontier, adj, visited))
    assert np.all(out * visited == 0.0)
