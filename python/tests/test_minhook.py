"""L1 correctness: min_hook Pallas kernel vs the pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_array_equal

from compile.kernels.minhook import min_hook
from compile.kernels.ref import min_hook_ref


def random_instance(rng, n, density=0.05):
    adj = (rng.random((n, n)) < density).astype(np.float32)
    adj = np.maximum(adj, adj.T)  # undirected => symmetric directed rep
    np.fill_diagonal(adj, 0.0)
    labels = np.arange(n, dtype=np.float32)
    rng.shuffle(labels)
    return labels, adj


@pytest.mark.parametrize("n", [128, 256, 512])
def test_matches_ref(n):
    rng = np.random.default_rng(n)
    labels, adj = random_instance(rng, n)
    got = np.asarray(min_hook(labels, adj))
    want = np.asarray(min_hook_ref(labels, adj))
    assert_array_equal(got, want)


@pytest.mark.parametrize("block_n,block_k", [(64, 64), (128, 64), (64, 128), (256, 256)])
def test_block_shapes(block_n, block_k):
    rng = np.random.default_rng(11)
    labels, adj = random_instance(rng, 256)
    got = np.asarray(min_hook(labels, adj, block_n=block_n, block_k=block_k))
    want = np.asarray(min_hook_ref(labels, adj))
    assert_array_equal(got, want)


def test_isolated_vertices_keep_label():
    n = 128
    labels = np.arange(n, dtype=np.float32)
    adj = np.zeros((n, n), np.float32)
    out = np.asarray(min_hook(labels, adj))
    assert_array_equal(out, labels)


def test_single_edge_pushes_min_both_ways():
    n = 128
    labels = np.arange(n, dtype=np.float32)
    adj = np.zeros((n, n), np.float32)
    adj[3, 77] = adj[77, 3] = 1.0
    out = np.asarray(min_hook(labels, adj))
    want = labels.copy()
    want[77] = 3.0
    assert_array_equal(out, want)


def test_monotone_nonincreasing():
    rng = np.random.default_rng(13)
    labels, adj = random_instance(rng, 256, 0.1)
    out = np.asarray(min_hook(labels, adj))
    assert np.all(out <= labels)


def test_star_graph_center_min():
    """Star with center holding the min label floods it to all leaves."""
    n = 128
    labels = np.arange(n, dtype=np.float32)
    adj = np.zeros((n, n), np.float32)
    adj[0, 1:] = 1.0
    adj[1:, 0] = 1.0
    out = np.asarray(min_hook(labels, adj))
    assert_array_equal(out, np.zeros(n, np.float32))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n=st.sampled_from([128, 256]),
    density=st.floats(0.0, 0.3),
)
def test_hypothesis_sweep(seed, n, density):
    rng = np.random.default_rng(seed)
    labels, adj = random_instance(rng, n, density)
    got = np.asarray(min_hook(labels, adj))
    want = np.asarray(min_hook_ref(labels, adj))
    assert_array_equal(got, want)
