"""L2 correctness: whole-step functions vs plain-python graph oracles.

The oracles here are textbook BFS (adjacency-list queue) and union-find —
independent of jnp — so the whole kernel+epilogue stack is checked
end-to-end, not just the kernels in isolation.
"""

import collections

import numpy as np
import pytest
from numpy.testing import assert_array_equal

from compile import model


def rmat_like(rng, n, avg_deg=8):
    """Skewed random graph (rough R-MAT stand-in) as a symmetric 0/1 matrix."""
    m = n * avg_deg // 2
    # Skew endpoints toward low ids, like R-MAT's recursive bias.
    u = np.minimum(rng.integers(0, n, m), rng.integers(0, n, m))
    v = rng.integers(0, n, m)
    adj = np.zeros((n, n), np.float32)
    adj[u, v] = 1.0
    adj[v, u] = 1.0
    np.fill_diagonal(adj, 0.0)
    return adj


def bfs_oracle(adj, src):
    n = adj.shape[0]
    lev = np.full(n, -1.0, np.float32)
    lev[src] = 0.0
    q = collections.deque([src])
    nbrs = [np.nonzero(adj[i])[0] for i in range(n)]
    while q:
        u = q.popleft()
        for w in nbrs[u]:
            if lev[w] < 0:
                lev[w] = lev[u] + 1
                q.append(w)
    return lev


def cc_oracle(adj):
    n = adj.shape[0]
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in zip(*np.nonzero(adj)):
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    # Canonical label = min vertex id in the component.
    return np.array([find(i) for i in range(n)], np.float32)


def run_bfs_via_steps(adj, sources):
    """Drive model.bfs_step to convergence exactly as the rust runtime does."""
    b, n = len(sources), adj.shape[0]
    frontier = np.zeros((b, n), np.float32)
    frontier[np.arange(b), sources] = 1.0
    visited = frontier.copy()
    levels = np.full((b, n), -1.0, np.float32)
    levels[np.arange(b), sources] = 0.0
    depth = 1.0
    while True:
        frontier, visited, levels, active = (
            np.asarray(x) for x in model.bfs_step(adj, frontier, visited, levels, depth)
        )
        if active.sum() == 0:
            return levels
        depth += 1.0


def run_cc_via_steps(adj, max_iter=64):
    n = adj.shape[0]
    labels = np.arange(n, dtype=np.float32)
    for _ in range(max_iter):
        labels, changed = (np.asarray(x) for x in model.cc_step(adj, labels))
        if changed == 0:
            return labels
    raise AssertionError("cc did not converge")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bfs_levels_match_oracle(seed):
    rng = np.random.default_rng(seed)
    adj = rmat_like(rng, 256)
    sources = rng.choice(256, size=4, replace=False)
    levels = run_bfs_via_steps(adj, sources)
    for i, s in enumerate(sources):
        assert_array_equal(levels[i], bfs_oracle(adj, s))


def test_bfs_batch_independence():
    """Each batch lane must behave exactly as if run alone."""
    rng = np.random.default_rng(9)
    adj = rmat_like(rng, 128)
    srcs = [5, 17, 99]
    batched = run_bfs_via_steps(adj, np.array(srcs))
    for i, s in enumerate(srcs):
        solo = run_bfs_via_steps(adj, np.array([s]))
        assert_array_equal(batched[i], solo[0])


def test_bfs_disconnected_vertex():
    n = 128
    adj = np.zeros((n, n), np.float32)
    adj[0, 1] = adj[1, 0] = 1.0
    levels = run_bfs_via_steps(adj, np.array([0]))
    want = np.full(n, -1.0, np.float32)
    want[0], want[1] = 0.0, 1.0
    assert_array_equal(levels[0], want)


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_cc_labels_match_oracle(seed):
    rng = np.random.default_rng(seed)
    adj = rmat_like(rng, 256, avg_deg=4)
    labels = run_cc_via_steps(adj)
    assert_array_equal(labels, cc_oracle(adj))


def test_cc_all_isolated():
    n = 128
    adj = np.zeros((n, n), np.float32)
    labels = run_cc_via_steps(adj)
    assert_array_equal(labels, np.arange(n, dtype=np.float32))


def test_cc_single_component_path():
    n = 128
    adj = np.zeros((n, n), np.float32)
    idx = np.arange(n - 1)
    adj[idx, idx + 1] = 1.0
    adj[idx + 1, idx] = 1.0
    labels = run_cc_via_steps(adj)
    assert_array_equal(labels, np.zeros(n, np.float32))


def test_cc_converges_in_log_iterations():
    """SV with full compress converges in O(log n) hook rounds."""
    rng = np.random.default_rng(21)
    adj = rmat_like(rng, 256, avg_deg=4)
    labels = np.arange(256, dtype=np.float32)
    iters = 0
    while True:
        labels, changed = (np.asarray(x) for x in model.cc_step(adj, labels))
        iters += 1
        if changed == 0:
            break
        assert iters <= 16, "too many SV iterations"
    assert iters <= 16
