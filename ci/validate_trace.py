#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file emitted by `--trace`.

Stdlib-only (CI runs it with a bare python3). Checks the structural
contract that Perfetto / chrome://tracing relies on and that
DESIGN.md #Observability promises:

  * top level: an object with a non-empty "traceEvents" array;
  * every event has a string "name", integer "pid"/"tid", and a phase
    "ph" in {B, E, i, C, M};
  * every non-metadata event has a finite, non-negative numeric "ts"
    (microseconds), and the array is sorted by "ts" (the exporter
    emits a stable global sort);
  * B/E spans balance as a LIFO per (pid, tid) track, with matching
    names, and no E without an open B;
  * at least one counter ("C") event and at least one instant ("i")
    or span event exist (a trace with only metadata is vacuous).

Usage: validate_trace.py <trace.json>
Exit status 0 iff the file validates; problems go to stderr.
"""

import json
import math
import sys

VALID_PH = {"B", "E", "i", "C", "M"}


def fail(msg):
    print(f"validate_trace: {msg}", file=sys.stderr)
    return 1


def validate(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return fail(f"cannot parse {path}: {e}")

    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return fail("top level must be an object with a traceEvents array")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        return fail("traceEvents must be a non-empty array")

    errors = 0
    last_ts = -math.inf
    open_spans = {}  # (pid, tid) -> stack of B names
    counts = {}
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors += fail(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in VALID_PH:
            errors += fail(f"{where}: bad ph {ph!r}")
            continue
        counts[ph] = counts.get(ph, 0) + 1
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errors += fail(f"{where}: missing/empty name")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            errors += fail(f"{where}: pid/tid must be integers")
        if ph == "M":
            continue  # metadata carries no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts) or ts < 0:
            errors += fail(f"{where}: ts must be a finite non-negative number, got {ts!r}")
            continue
        if ts < last_ts:
            errors += fail(f"{where}: ts {ts} < previous {last_ts} (not sorted)")
        last_ts = ts
        track = (ev["pid"], ev["tid"]) if isinstance(ev.get("pid"), int) else None
        if ph == "B" and track is not None:
            open_spans.setdefault(track, []).append(name)
        elif ph == "E" and track is not None:
            stack = open_spans.get(track, [])
            if not stack:
                errors += fail(f"{where}: E {name!r} on track {track} with no open B")
            else:
                opened = stack.pop()
                if opened != name:
                    errors += fail(
                        f"{where}: E {name!r} closes B {opened!r} on track {track} "
                        "(spans must nest)"
                    )

    for track, stack in open_spans.items():
        if stack:
            errors += fail(f"track {track}: {len(stack)} unclosed B span(s): {stack}")

    if counts.get("C", 0) == 0:
        errors += fail("no counter (C) events — telemetry series missing")
    if counts.get("B", 0) == 0 and counts.get("i", 0) == 0:
        errors += fail("no span (B/E) or instant (i) events — trace is vacuous")

    total = sum(counts.values())
    by_ph = ", ".join(f"{ph}={counts[ph]}" for ph in sorted(counts))
    print(f"validate_trace: {path}: {total} events ({by_ph}) — " + ("FAIL" if errors else "OK"))
    return 1 if errors else 0


if __name__ == "__main__":
    if len(sys.argv) != 2:
        sys.exit(fail("usage: validate_trace.py <trace.json>"))
    sys.exit(validate(sys.argv[1]))
