//! End-to-end: the full harness at scale 15 — the smallest scale where the calibrated model
//! reproduces the paper's *shape* — these are the acceptance criteria from
//! DESIGN.md §5, asserted programmatically. Slower than the unit tests;
//! everything shares one Harness build.

use pathfinder_queries::bench_harness::{fig3, fig4, scaling, table1, table2, table3, Harness};
use pathfinder_queries::config::experiment::ExperimentConfig;
use pathfinder_queries::config::workload::{GraphConfig, MixPoint};

fn harness() -> Harness {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.graph = GraphConfig::with_scale(15);
    cfg.workload.query_counts = vec![1, 8, 32, 128];
    cfg.workload.mixes = vec![
        MixPoint { bfs: 136, cc: 34 },  // Table II row 1 (8 nodes, 80/20)
        MixPoint { bfs: 560, cc: 140 }, // Table II row 3 (32 nodes, 80/20)
    ];
    cfg.results_dir = std::env::temp_dir().join("pfq-e2e-results");
    Harness::new(cfg).unwrap()
}

#[test]
fn paper_shape_acceptance() {
    let h = harness();

    // ---- Fig. 3 / Fig. 4: concurrency wins, in the paper's bands. ----
    let f4 = fig4::run(&h).unwrap();
    let (lo8, hi8) = f4.improvement_range("pathfinder-8", 8).unwrap();
    let (lo32, hi32) = f4.improvement_range("pathfinder-32", 8).unwrap();
    assert!(lo8 > 100.0, "8-node: >2x always (paper); got {lo8:.0}%");
    assert!(hi8 < 200.0, "8-node improvement implausibly high: {hi8:.0}%");
    assert!(
        lo32 > 70.0 && hi32 < 115.0,
        "32-node band {lo32:.0}%..{hi32:.0}% vs paper 81..97%"
    );
    assert!(hi32 < lo8, "degraded 32-node must trail the single chassis");

    // Times grow ~linearly with query count (paper §IV-B).
    assert!(f4.fig3.linearity_deviation("pathfinder-8", 8) < 0.25);
    assert!(f4.fig3.linearity_deviation("pathfinder-32", 8) < 0.25);

    // ---- Table I: per-query averages faster on 32 nodes. ----
    let t1 = table1::run(&h).unwrap();
    assert_eq!(t1.rows.len(), 2);
    let q8 = &t1.rows[0].quantiles;
    let q32 = &t1.rows[1].quantiles;
    assert!(q32.q50 < q8.q50, "paper: 0.94s median vs 2.85s");
    // Paper ratio ~3.0; accept a broad band.
    let ratio = q8.q50 / q32.q50;
    assert!((1.5..=6.0).contains(&ratio), "median ratio {ratio:.2}");

    // ---- §IV-B scaling at 128 queries: sub-linear 8->32. ----
    let sc = scaling::run(&h, 128).unwrap();
    let (conc_sp, seq_sp) = sc.speedups.unwrap();
    assert!(
        (1.8..=4.0).contains(&conc_sp),
        "conc 8->32 speedup {conc_sp:.2} (paper 2.69)"
    );
    assert!(
        (1.8..=4.2).contains(&seq_sp),
        "seq 8->32 speedup {seq_sp:.2} (paper 3.24)"
    );
    assert!(conc_sp < 4.0 && seq_sp < 4.0, "must be sub-linear in node count");
    // Context exhaustion at capacity+1 on the 8-node machine.
    let (attempt, cap, err, _) = sc.exhaustion.unwrap();
    assert_eq!(attempt, cap + 1);
    assert!(err.contains("thread-context memory"));

    // ---- Table II: mixes improve, less than pure BFS, 8 > 32. ----
    let t2 = table2::run(&h).unwrap();
    assert_eq!(t2.rows.len(), 2);
    assert_eq!(t2.rows[0].machine, "pathfinder-8");
    assert_eq!(t2.rows[1].machine, "pathfinder-32");
    let i8 = t2.rows[0].improvement_pct();
    let i32_ = t2.rows[1].improvement_pct();
    assert!(i8 > 50.0 && i8 < 150.0, "8-node mix improvement {i8:.0}% (paper ~70%)");
    assert!(i32_ > 30.0 && i32_ < 110.0, "32-node mix improvement {i32_:.0}%");
    assert!(i32_ < i8, "32-node mix must trail 8-node (paper 38-47 vs 70)");

    // ---- Table III: adjusted speed-ups grow with concurrency. ----
    let t3 = table3::run(&h, None).unwrap();
    let s1 = t3.speedup("pathfinder-32", 1).unwrap();
    let s16 = t3.speedup("pathfinder-32", 16).unwrap();
    let s128 = t3.speedup("pathfinder-32", 128).unwrap();
    assert!(s1 < 1.2, "single query: RedisGraph competitive (paper 0.83), got {s1:.2}");
    assert!((4.0..=18.0).contains(&s16), "paper ~9x at 16, got {s16:.1}");
    assert!((10.0..=35.0).contains(&s128), "paper ~19x at 128, got {s128:.1}");
    assert!(s1 < s16 && s16 < s128, "speed-up must grow with concurrency");
}

#[test]
fn results_csvs_written() {
    let h = harness();
    let data = fig3::report(&h).unwrap();
    assert!(!data.rows.is_empty());
    let csv = h.cfg.results_dir.join("fig3_bfs_conc_vs_seq.csv");
    let text = std::fs::read_to_string(&csv).unwrap();
    assert!(text.lines().count() > data.rows.len());
    assert!(text.starts_with("machine,queries,"));
}
