//! Coordinator integration tests: policies, admission, mixed workloads,
//! metrics, and the serving facade — the paper's experimental arms driven
//! through the public API.

use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::{GraphConfig, MixPoint};
use pathfinder_queries::coordinator::{
    planner, Coordinator, GraphService, ImprovementRow, Policy, PreemptPolicy, ServiceConfig,
    ShareWeights, WorkloadSpec,
};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::sim::flow::OnFull;
use pathfinder_queries::sim::machine::Machine;

fn rmat(scale: u32) -> Csr {
    let cfg = GraphConfig::with_scale(scale);
    build_undirected_csr(1 << scale, &pathfinder_queries::graph::rmat::Rmat::new(cfg).edges())
}

#[test]
fn paper_arms_end_to_end_8_nodes() {
    let g = rmat(13);
    let coord = Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let queries = planner::bfs_queries(&g, 64, 0xBF5);
    let conc = coord.run(&queries, Policy::Concurrent).unwrap();
    let seq = coord.run(&queries, Policy::Sequential).unwrap();

    let row = ImprovementRow::from_reports(&conc, &seq);
    assert!(row.speedup() > 2.0, "paper: >2x on the single chassis, got {:.2}", row.speedup());
    assert_eq!(conc.completed(), 64);
    assert_eq!(seq.completed(), 64);
    // Concurrency trades per-query latency for makespan: an individual
    // concurrent query takes longer than its solo service time, but the
    // batch finishes sooner.
    let mean_service = seq.makespan_s / 64.0;
    assert!(conc.mean_latency_s().expect("all completed") > mean_service);
    assert!(conc.makespan_s < seq.makespan_s);
}

#[test]
fn deterministic_given_same_inputs() {
    let g = rmat(11);
    let coord = Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let queries = planner::bfs_queries(&g, 16, 9);
    let a = coord.run(&queries, Policy::Concurrent).unwrap();
    let b = coord.run(&queries, Policy::Concurrent).unwrap();
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(
        a.records.iter().map(|r| r.latency_s).collect::<Vec<_>>(),
        b.records.iter().map(|r| r.latency_s).collect::<Vec<_>>()
    );
}

#[test]
fn admission_matches_ledger_capacity() {
    let g = rmat(10);
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 64 << 20; // capacity 32
    let coord = Coordinator::new(&g, Machine::new(cfg));
    assert_eq!(coord.capacity(), 32);

    let queries = planner::bfs_queries(&g, 40, 1);
    // Unadmitted: the paper's crash, surfaced as an error.
    assert!(coord.run(&queries, Policy::Concurrent).is_err());
    // Queue: everything completes, peak bounded.
    let q = coord.run(&queries, Policy::admitted(OnFull::Queue)).unwrap();
    assert_eq!(q.completed(), 40);
    assert!(q.peak_concurrency <= 32);
    // Reject: 8 rejections.
    let r = coord.run(&queries, Policy::admitted(OnFull::Reject)).unwrap();
    assert_eq!(r.rejections(), 8);
}

#[test]
fn queueing_costs_less_than_sequential() {
    let g = rmat(11);
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 64 << 20; // capacity 32
    let coord = Coordinator::new(&g, Machine::new(cfg));
    let queries = planner::bfs_queries(&g, 64, 2);
    let queued = coord.run(&queries, Policy::admitted(OnFull::Queue)).unwrap();
    let seq = coord.run(&queries, Policy::Sequential).unwrap();
    assert!(queued.makespan_s < seq.makespan_s);
}

#[test]
fn mix_improvement_smaller_than_pure_bfs() {
    // Table II's improvements sit below Fig. 4's pure-BFS ones.
    let g = rmat(13);
    let coord = Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));

    let pure = planner::bfs_queries(&g, 40, 5);
    let pure_row = ImprovementRow::from_reports(
        &coord.run(&pure, Policy::Concurrent).unwrap(),
        &coord.run(&pure, Policy::Sequential).unwrap(),
    );

    let mixed = planner::mix_queries(&g, MixPoint { bfs: 32, cc: 8 }, 5);
    let mixed_seq = planner::sequential_mix_order(&mixed);
    let mixed_row = ImprovementRow::from_reports(
        &coord.run(&mixed, Policy::Concurrent).unwrap(),
        &coord.run(&mixed_seq, Policy::Sequential).unwrap(),
    );

    assert!(mixed_row.improvement_pct() > 30.0);
    assert!(
        mixed_row.improvement_pct() < pure_row.improvement_pct(),
        "mixed {:.0}% should trail pure {:.0}%",
        mixed_row.improvement_pct(),
        pure_row.improvement_pct()
    );
}

#[test]
fn metrics_quantiles_match_latencies() {
    let g = rmat(11);
    let coord = Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let queries = planner::bfs_queries(&g, 12, 3);
    let rep = coord.run(&queries, Policy::Sequential).unwrap();
    let q = rep.latency_quantiles(Some("bfs")).unwrap();
    let lats = rep.latencies(Some("bfs"));
    assert_eq!(q.q0, lats.iter().copied().fold(f64::INFINITY, f64::min));
    assert_eq!(q.q100, lats.iter().copied().fold(0.0, f64::max));
    assert!(rep.throughput_qps() > 0.0);
}

#[test]
fn service_latency_grows_with_load() {
    let g = rmat(12);
    let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let mut medians = Vec::new();
    for rate in [100.0, 10_000.0, 100_000.0] {
        let rep = svc
            .serve(&ServiceConfig {
                queries: 120,
                arrival_rate_per_s: rate,
                workload: WorkloadSpec::bfs_cc(0.0),
                on_full: OnFull::Queue,
                seed: 4,
                ..Default::default()
            })
            .unwrap();
        medians.push(rep.class("bfs").unwrap().q50);
    }
    assert!(
        medians[2] > medians[0],
        "overloaded median {:.4}s should exceed idle {:.4}s",
        medians[2],
        medians[0]
    );
}

#[test]
fn arrival_spacing_reduces_contention() {
    let g = rmat(12);
    let coord = Coordinator::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let queries = planner::bfs_queries(&g, 32, 8);
    // Burst: all at once.
    let burst = coord.run(&queries, Policy::Concurrent).unwrap();
    // Spread: arrivals far apart (each runs alone).
    let arrivals: Vec<f64> = (0..32).map(|i| i as f64 * 1e9).collect();
    let mut spaced = queries.clone();
    planner::assign_arrivals(&mut spaced, &arrivals);
    let spread = coord.run(&spaced, Policy::Concurrent).unwrap();
    assert!(
        spread.mean_latency_s().expect("spread completed")
            < burst.mean_latency_s().expect("burst completed")
    );
    assert_eq!(spread.peak_concurrency, 1);
}

/// Acceptance: a mixed four-class concurrent run completes end-to-end via
/// `GraphService`, with per-class p50/p95/p99 reported for every class.
#[test]
fn four_class_mix_end_to_end_with_tail_quantiles() {
    let g = rmat(12);
    let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let rep = svc
        .serve(&ServiceConfig {
            queries: 96,
            arrival_rate_per_s: 500.0,
            workload: WorkloadSpec::four_class(),
            on_full: OnFull::Queue,
            seed: 0x4C1A,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(rep.served, 96);
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.class_latency.len(), 4, "all four classes must complete");
    for label in ["bfs", "khop", "sssp", "cc"] {
        let q = rep.class(label).unwrap_or_else(|| panic!("missing class {label}"));
        assert!(q.q50 > 0.0);
        assert!(q.q50 <= q.q95 && q.q95 <= q.q99 && q.q99 <= q.q100, "{label}");
    }
    // CC touches every vertex; the interactive k-hop class is the cheapest.
    assert!(rep.class("cc").unwrap().q50 > rep.class("khop").unwrap().q50);
    let s = rep.summary();
    assert!(s.contains("p95") && s.contains("p99"), "{s}");
}

/// Acceptance (six-analysis registry): a mixed run over ALL six shipped
/// analyses — the four traversal-shaped kernels plus PageRank and
/// triangle counting — completes end-to-end through `GraphService` (the
/// `serve --mix bfs=..,pagerank=..,tricount=..` path), with per-class
/// p50/p95/p99 for every class and SLO verdicts in the summary.
#[test]
fn six_class_mix_end_to_end_with_tail_quantiles() {
    let g = rmat(12);
    let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let reg = pathfinder_queries::alg::AnalysisRegistry::builtin();
    // An even-ish explicit mix (what the CLI's --mix flag parses), with a
    // generous whole-run SLO on the two new analytic kernels.
    let mut workload = WorkloadSpec::parse(
        "bfs=0.25, khop=0.2, sssp=0.15, cc=0.1, pagerank=0.15, tricount=0.15",
        &reg,
    )
    .unwrap();
    for class in workload.classes.iter_mut() {
        if class.label == "pagerank" || class.label == "tricount" {
            class.slo_p99_s = Some(1e6);
        }
    }
    let rep = svc
        .serve(&ServiceConfig {
            queries: 120,
            arrival_rate_per_s: 500.0,
            workload,
            on_full: OnFull::Queue,
            seed: 0x6C1A,
            ..Default::default()
        })
        .unwrap();
    assert_eq!(rep.served, 120);
    assert_eq!(rep.rejected, 0);
    let classes: Vec<&str> = rep.class_latency.iter().map(|(l, _)| l.as_str()).collect();
    assert_eq!(classes.len(), 6, "all six classes must complete: {classes:?}");
    for label in ["bfs", "khop", "sssp", "cc", "pagerank", "tricount"] {
        let q = rep.class(label).unwrap_or_else(|| panic!("missing class {label}"));
        assert!(q.q50 > 0.0);
        assert!(q.q50 <= q.q95 && q.q95 <= q.q99 && q.q99 <= q.q100, "{label}");
    }
    // The iterative whole-graph kernel dwarfs the interactive k-hop class.
    assert!(rep.class("pagerank").unwrap().q50 > rep.class("khop").unwrap().q50);
    assert!(rep.slo_of("pagerank").unwrap().pass && rep.slo_of("tricount").unwrap().pass);
    let s = rep.summary();
    assert!(s.contains("pagerank") && s.contains("tricount"), "{s}");
}

/// The shipped six-class catalog spec is well-formed: six registry-backed
/// classes, analytic kernels filed as Batch work, SLOs on the latency-
/// sensitive classes.
#[test]
fn six_class_catalog_spec_is_well_formed() {
    use pathfinder_queries::coordinator::Priority;

    let spec = WorkloadSpec::six_class();
    spec.validate().unwrap();
    assert_eq!(spec.classes.len(), 6);
    let by_label = |l: &str| spec.classes.iter().find(|c| c.label == l).unwrap();
    for heavy in ["cc", "pagerank", "tricount"] {
        assert_eq!(by_label(heavy).priority, Priority::Batch, "{heavy}");
    }
    assert!(by_label("khop").slo_p99_s.is_some());
    assert!((spec.total_weight() - 1.0).abs() < 1e-12);
}

/// Acceptance (priority-aware admission): under an over-capacity
/// mixed-priority workload, admitted runs serve Interactive work first —
/// its p99 latency is strictly better than Batch's — and overload
/// shedding drops Batch work first: zero Interactive sheds while Batch
/// work remained to shed.
#[test]
fn mixed_priority_overload_orders_and_sheds_by_class() {
    use pathfinder_queries::coordinator::Priority;

    let g = rmat(11);
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 16 << 20; // capacity: 8 concurrent queries
    let coord = Coordinator::new(&g, Machine::new(cfg));

    // 48 identical-cost queries, priorities round-robin, arriving in a
    // burst far above capacity.
    let mut queries = planner::bfs_queries(&g, 48, 0xB5);
    planner::assign_round_robin_priorities(&mut queries);
    let arrivals: Vec<f64> = (0..48).map(|i| i as f64 * 1e3).collect();
    planner::assign_arrivals(&mut queries, &arrivals);

    // Queueing: everyone completes, but Interactive waits least, so its
    // p99 is strictly better than Batch's.
    let queued = coord.run(&queries, Policy::admitted(OnFull::Queue)).unwrap();
    assert_eq!(queued.completed(), 48);
    let p99 = |rep: &pathfinder_queries::coordinator::RunReport, p: Priority| {
        rep.priority_class(p).unwrap().latency.as_ref().unwrap().q99
    };
    assert!(
        p99(&queued, Priority::Interactive) < p99(&queued, Priority::Batch),
        "interactive p99 {} must beat batch p99 {}",
        p99(&queued, Priority::Interactive),
        p99(&queued, Priority::Batch)
    );
    assert!(p99(&queued, Priority::Interactive) <= p99(&queued, Priority::Standard));
    // Interactive also waited the least on average.
    let wait = |p: Priority| queued.priority_class(p).unwrap().mean_wait_s;
    assert!(wait(Priority::Interactive) < wait(Priority::Batch));

    // Shedding: with a bounded wait queue, Batch is dropped first and no
    // Interactive query is shed while Batch work remains.
    let shed = coord
        .run(&queries, Policy::admitted(OnFull::Shed { max_waiting: 16 }))
        .unwrap();
    let stats = |p: Priority| shed.priority_class(p).unwrap();
    assert!(shed.sheds() > 0, "overload must shed");
    assert_eq!(stats(Priority::Interactive).shed, 0, "no interactive sheds");
    assert!(stats(Priority::Batch).shed > 0, "batch is dropped first");
    assert!(
        stats(Priority::Batch).shed >= stats(Priority::Standard).shed,
        "batch shed at least as much as standard"
    );
    assert_eq!(shed.completed() + shed.sheds() + shed.rejections(), 48);
}

/// Acceptance (weighted fair share + checkpoint preemption): under a
/// saturating mixed workload — Batch work occupying every thread-context
/// slot when Interactive queries arrive — enabling 8:2:1 weights plus
/// preemption makes the Interactive p99 *strictly* lower than PR 2's
/// unweighted sharing, with zero Interactive deadline misses while Batch
/// work is still in flight.
#[test]
fn weighted_preemption_beats_unweighted_sharing_for_interactive() {
    use pathfinder_queries::coordinator::{Priority, QueryRequest, RunReport};

    let g = rmat(11);
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 16 << 20; // capacity: 8 concurrent queries
    let coord = Coordinator::new(&g, Machine::new(cfg));

    // 32 Batch queries burst in first and fill every slot; 8 Interactive
    // queries arrive just behind them and — under PR 2 — can only wait.
    let build = |interactive_deadline_ns: Option<f64>| -> Vec<QueryRequest> {
        let mut queries = planner::bfs_queries(&g, 40, 0x1D3);
        for (i, q) in queries.iter_mut().enumerate() {
            *q = q.clone().with_priority(Priority::Batch).at(i as f64 * 1e3);
        }
        for (i, q) in queries.iter_mut().rev().take(8).enumerate() {
            *q = q.clone().with_priority(Priority::Interactive).at(1e4 + i as f64 * 1e3);
            if let Some(d) = interactive_deadline_ns {
                *q = q.clone().with_deadline_ns(d);
            }
        }
        queries
    };
    let weighted_policy = Policy::ConcurrentAdmitted {
        on_full: OnFull::Queue,
        weights: ShareWeights { interactive: 8.0, standard: 2.0, batch: 1.0 },
        preempt: Some(PreemptPolicy::default()),
    };
    let int_p99 = |rep: &RunReport| {
        rep.priority_class(Priority::Interactive).unwrap().latency.as_ref().unwrap().q99
    };

    // Arm 1: PR 2's unweighted max-min with plain queueing.
    let baseline = coord.run(&build(None), Policy::admitted(OnFull::Queue)).unwrap();
    assert_eq!(baseline.completed(), 40);
    assert_eq!(baseline.preempted(), 0);

    // Arm 2: weighted shares + checkpoint preemption.
    let treated = coord.run(&build(None), weighted_policy).unwrap();
    assert_eq!(treated.completed(), 40, "preemption must not lose work");
    assert!(
        int_p99(&treated) < int_p99(&baseline),
        "interactive p99 must strictly improve: weighted+preempt {} vs unweighted {}",
        int_p99(&treated),
        int_p99(&baseline)
    );
    assert!(treated.preempted() > 0, "batch work must actually park");
    // Only Batch was parked, and Batch work was still in flight when the
    // last Interactive query completed.
    let stats = |rep: &RunReport, p: Priority| rep.priority_class(p).unwrap();
    assert_eq!(stats(&treated, Priority::Interactive).preempted, 0);
    let last_interactive_finish = treated
        .records
        .iter()
        .filter(|r| r.priority == Priority::Interactive)
        .map(|r| r.finish_s)
        .fold(0.0, f64::max);
    let last_batch_finish = treated
        .records
        .iter()
        .filter(|r| r.priority == Priority::Batch)
        .map(|r| r.finish_s)
        .fold(0.0, f64::max);
    assert!(
        last_batch_finish > last_interactive_finish,
        "batch work must remain in flight past the interactive tail"
    );

    // Arm 3: give Interactive queries the unweighted p99 as a deadline.
    // Under weights+preemption every one of them beats it: zero misses,
    // zero deadline sheds.
    let deadline_ns = int_p99(&baseline) * 1e9;
    let with_deadlines = coord.run(&build(Some(deadline_ns)), weighted_policy).unwrap();
    assert_eq!(with_deadlines.completed(), 40);
    assert_eq!(
        with_deadlines.deadline_misses(),
        0,
        "interactive deadlines at the unweighted p99 must all be met"
    );
    assert_eq!(stats(&with_deadlines, Priority::Interactive).shed, 0);
}

/// Batching satellite (DESIGN.md §Batching): when a fused batch is SHED,
/// every member request's own `QueryRecord` reports `Outcome::Shed` with a
/// NaN latency, and the per-member dispositions still partition the
/// original request list exactly — fusion never loses or double-counts a
/// member.
#[test]
fn shed_batch_disposes_every_member_and_partitions_exactly() {
    use pathfinder_queries::coordinator::{BatchConfig, Outcome};

    let g = rmat(11);
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 16 << 20; // capacity: 8 default footprints
    let coord = Coordinator::new(&g, Machine::new(cfg));
    let batch = BatchConfig { width: 8, window_ns: 1e9 };

    // Three width-8 groups of same-epoch BFS (arrival order = index
    // order, so group g covers originals 8g..8g+8). Each fused batch
    // reserves Σ member footprints = the WHOLE context budget: group 0
    // runs, group 1 waits, and with max_waiting=1 group 2 overflows the
    // wait queue and is shed whole.
    let mut queries = planner::bfs_queries(&g, 24, 0x5ED);
    let arrivals: Vec<f64> = (0..24).map(|i| i as f64 * 1e3).collect();
    planner::assign_arrivals(&mut queries, &arrivals);
    let rep = coord
        .submit_batched(
            queries,
            Policy::admitted(OnFull::Shed { max_waiting: 1 }),
            &batch,
        )
        .unwrap();

    assert_eq!(rep.records.len(), 24, "one record per ORIGINAL request");
    assert_eq!(
        rep.completed() + rep.sheds() + rep.rejections(),
        24,
        "member dispositions must partition the batch"
    );
    assert_eq!(rep.sheds(), 8, "a shed batch sheds every member, exactly once");
    for r in &rep.records[16..24] {
        assert_eq!(r.outcome, Outcome::Shed, "q{}", r.id);
        assert!(r.latency_s.is_nan(), "q{}: a shed member never ran", r.id);
    }
    // Completed members: per-source latency = fused finish − OWN arrival,
    // so a group shares one finish and its latencies differ by exactly
    // the members' arrival spread.
    for group in [&rep.records[0..8], &rep.records[8..16]] {
        for r in group {
            assert_eq!(r.outcome, Outcome::Completed, "q{}", r.id);
            assert!(
                (r.finish_s - r.arrival_s - r.latency_s).abs() < 1e-12,
                "q{}: latency must be fused finish minus member arrival",
                r.id
            );
            assert_eq!(
                r.finish_s.to_bits(),
                group[0].finish_s.to_bits(),
                "q{}: one fused query, one finish",
                r.id
            );
        }
        let spread = group[0].latency_s - group[7].latency_s;
        assert!(
            (spread - 7e3 * 1e-9).abs() < 1e-12,
            "latency spread {spread} must equal the arrival spread"
        );
    }
}

/// Batching satellite, preemption arm: a fused Batch-class group parked
/// by checkpoint preemption marks EVERY member `Preempted { resumed }`
/// — all complete, latencies still fan out per-member from the one fused
/// timing, and the interactive query that forced the park is untouched.
#[test]
fn preempted_batch_marks_every_member_and_completes() {
    use pathfinder_queries::coordinator::{BatchConfig, Outcome, Priority};

    let g = rmat(11);
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 16 << 20; // capacity: 8 default footprints
    let coord = Coordinator::new(&g, Machine::new(cfg));
    let batch = BatchConfig { width: 8, window_ns: 1e9 };

    // 8 Batch-class BFS fuse into one group holding the whole budget; an
    // Interactive BFS arrives behind it (its group is full, so it rides
    // alone) and can only start if the fused batch parks.
    let mut queries = planner::bfs_queries(&g, 9, 0x9E);
    for (i, q) in queries.iter_mut().enumerate() {
        *q = q.clone().with_priority(Priority::Batch).at(i as f64 * 1e3);
    }
    queries[8] = queries[8].clone().with_priority(Priority::Interactive).at(2e4);
    let policy = Policy::ConcurrentAdmitted {
        on_full: OnFull::Queue,
        weights: ShareWeights::flat(),
        preempt: Some(PreemptPolicy::default()),
    };
    let rep = coord.submit_batched(queries, policy, &batch).unwrap();

    assert_eq!(rep.records.len(), 9);
    assert_eq!(rep.completed(), 9, "preemption must not lose fused work");
    assert_eq!(rep.preempted(), 8, "every member of the parked batch is preempted");
    assert_eq!(
        rep.records[8].outcome,
        Outcome::Completed,
        "the interactive trigger is never parked"
    );
    for r in &rep.records[0..8] {
        assert_eq!(r.outcome, Outcome::Preempted { resumed: true }, "q{}", r.id);
        assert_eq!(
            r.finish_s.to_bits(),
            rep.records[0].finish_s.to_bits(),
            "q{}: one fused timing serves the whole group",
            r.id
        );
        assert!(
            (r.finish_s - r.arrival_s - r.latency_s).abs() < 1e-12,
            "q{}: latency fans out from the member's own arrival",
            r.id
        );
    }
    // The park actually bought the interactive query its slot: it
    // finished while the batch was still in flight.
    assert!(rep.records[8].finish_s < rep.records[0].finish_s);
}
