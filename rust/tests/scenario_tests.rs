//! Scenario-harness regression suite: the open-loop/determinism property
//! test, the overload-ramp acceptance test (shed + preempt in one run),
//! golden-trace and telemetry reconciliation against the PR 9 artifact
//! schemas, stream-seed order independence, and catalog/builtin parity.

use std::collections::BTreeMap;

use pathfinder_queries::alg::AnalysisRegistry;
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::scenario::{ArrivalProcess, ScenarioSpec, StreamSpec};
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::coordinator::scenario::{stream_seed, ScenarioTimeline};
use pathfinder_queries::coordinator::telemetry::telemetry_path;
use pathfinder_queries::coordinator::{
    compile_scenario, planner, Coordinator, GraphService, Policy, PreemptPolicy, Priority,
    ServiceConfig, ShareWeights, TraceSpec,
};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::sim::flow::OnFull;
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::util::json::Json;
use pathfinder_queries::util::rng::SplitMix64;

fn rmat(scale: u32) -> Csr {
    let cfg = GraphConfig::with_scale(scale);
    build_undirected_csr(1 << scale, &pathfinder_queries::graph::rmat::Rmat::new(cfg).edges())
}

/// Pathfinder-8 with thread-context memory cut to 8 in-flight queries:
/// small enough that the catalog's overload shapes actually overload.
fn capacity8_machine() -> Machine {
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.ctx_mem_per_node_bytes = 16 << 20;
    Machine::new(cfg)
}

fn repo_path(rel: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(rel)
}

/// A deliberately tiny scenario for serve-path tests (~40 arrivals).
fn mini_spec() -> ScenarioSpec {
    ScenarioSpec::new(
        "mini",
        1.0,
        vec![
            StreamSpec::new(
                "fast",
                ArrivalProcess::Constant { rate_per_s: 25.0 },
                vec![("khop".into(), 1.0)],
            )
            .with_priority(Priority::Interactive)
            .with_slo_p99_s(5.0),
            StreamSpec::new(
                "bulk",
                ArrivalProcess::Constant { rate_per_s: 15.0 },
                vec![("bfs".into(), 1.0)],
            )
            .with_priority(Priority::Batch),
        ],
    )
}

/// The structural contract `ci/validate_trace.py` enforces, mirrored in
/// Rust so the suite guards it even where python3 is unavailable.
fn assert_trace_contract(doc: &Json) {
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "traceEvents must be non-empty");
    let mut last_ts = f64::NEG_INFINITY;
    let mut stacks: BTreeMap<(u64, u64), Vec<String>> = BTreeMap::new();
    let (mut counters, mut spans_or_instants) = (0usize, 0usize);
    for ev in events {
        let ph = ev.str_of("ph").unwrap();
        assert!(matches!(ph.as_str(), "B" | "E" | "i" | "C" | "M"), "bad ph {ph:?}");
        assert!(!ev.str_of("name").unwrap().is_empty(), "empty event name");
        let pid = ev.get("pid").unwrap().as_u64().unwrap();
        let tid = ev.get("tid").unwrap().as_u64().unwrap();
        match ph.as_str() {
            "C" => counters += 1,
            "B" | "i" => spans_or_instants += 1,
            _ => {}
        }
        if ph == "M" {
            continue; // metadata carries no timestamp
        }
        let ts = ev.get("ts").unwrap().as_f64().unwrap();
        assert!(ts.is_finite() && ts >= 0.0, "ts must be finite and non-negative, got {ts}");
        assert!(ts >= last_ts, "events must be globally sorted by ts ({ts} < {last_ts})");
        last_ts = ts;
        if ph == "B" {
            stacks.entry((pid, tid)).or_default().push(ev.str_of("name").unwrap());
        } else if ph == "E" {
            let name = ev.str_of("name").unwrap();
            let opened = stacks.get_mut(&(pid, tid)).and_then(|s| s.pop());
            assert_eq!(
                opened.as_deref(),
                Some(name.as_str()),
                "B/E spans must nest LIFO per (pid, tid) track"
            );
        }
    }
    assert!(stacks.values().all(|s| s.is_empty()), "unclosed B spans: {stacks:?}");
    assert!(counters > 0, "need at least one counter (C) event");
    assert!(spans_or_instants > 0, "need at least one span (B) or instant (i) event");
}

/// Run `ci/validate_trace.py` on a trace if python3 exists on this
/// machine; None = interpreter unavailable, skip silently.
fn validate_with_python(path: &std::path::Path) -> Option<bool> {
    std::process::Command::new("python3")
        .arg(repo_path("ci/validate_trace.py"))
        .arg(path)
        .output()
        .ok()
        .map(|out| out.status.success())
}

/// Satellite 1 — the tentpole's core properties:
/// (a) same seed compiles to a bit-identical merged timeline;
/// (b) arrival instants are open-loop: the engine records the same
///     arrivals under wildly different serving policies, so completions
///     can't feed back into the generator;
/// (c) per-stream sampled counts track each process's closed-form
///     expectation.
#[test]
fn prop_scenario_streams_are_open_loop_and_deterministic() {
    let g = rmat(10);
    let reg = AnalysisRegistry::builtin();

    // Probe-calibrate the engine runs: compress each catalog spec so its
    // nominal mid-load (200/s units) sits at this machine's measured
    // drain rate — guaranteeing real contention whatever the absolute
    // speed of the simulated machine is.
    let coord = Coordinator::new(&g, capacity8_machine());
    let probe = coord
        .run(&planner::bfs_queries(&g, 32, 0xCAFE), Policy::admitted(OnFull::Queue))
        .unwrap();
    let f = (32.0 / probe.makespan_s) / 200.0;

    // (a) + (b) on two catalog entries that together cover all four
    // arrival processes (constant/diurnal/bursty + ramp).
    for name in ["multi-tenant-contention", "overload-ramp"] {
        let spec = ScenarioSpec::builtin(name).unwrap().time_compressed(f).unwrap();
        let a = compile_scenario(&g, &reg, &spec, 0xD1CE).unwrap();
        let b = compile_scenario(&g, &reg, &spec, 0xD1CE).unwrap();
        assert_eq!(a.arrivals.len(), b.arrivals.len(), "{name}: same seed, same count");
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}: bit-identical merged timeline");
        }
        assert_eq!(a.map, b.map, "{name}: same stream attribution");

        let queue = coord.run(&a.requests, Policy::admitted(OnFull::Queue)).unwrap();
        let shed = coord
            .run(&a.requests, Policy::admitted(OnFull::Shed { max_waiting: 1 }))
            .unwrap();
        assert_eq!(queue.records.len(), a.requests.len());
        assert_eq!(shed.records.len(), a.requests.len());
        assert!(
            shed.records.iter().filter(|r| r.shed()).count() > 0,
            "{name}: a one-slot queue must shed under catalog load"
        );
        // The two runs dispose of queries very differently, yet every
        // arrival instant is identical — the open-loop contract.
        for (q, s) in queue.records.iter().zip(&shed.records) {
            assert_eq!(
                q.arrival_s.to_bits(),
                s.arrival_s.to_bits(),
                "{name}: arrivals must not depend on the serving policy"
            );
        }
        // And they are exactly the compiled instants.
        for (r, &t_ns) in queue.records.iter().zip(&a.arrivals) {
            assert!(
                (r.arrival_s - t_ns * 1e-9).abs() < 1e-12,
                "{name}: engine arrival {} != compiled {}",
                r.arrival_s,
                t_ns * 1e-9
            );
        }
    }

    // (c) closed-form expectations: the sampled count, averaged over a
    // fixed seed set, lands near E[N] for every catalog stream. The
    // bursty streams are doubly stochastic (dwell modulation adds
    // variance beyond Poisson), hence the generous 20% band; 64 seeds
    // put the mean's spread at a quarter of that or less.
    for spec in ScenarioSpec::catalog() {
        for stream in &spec.streams {
            let expected = stream.process.expected_arrivals(spec.duration_s);
            let mut mean = 0.0;
            const SEEDS: u64 = 64;
            for s in 0..SEEDS {
                let mut rng = SplitMix64::new(stream_seed(s, &stream.name));
                mean +=
                    stream.process.sample_arrivals_ns(spec.duration_s, &mut rng).len() as f64;
            }
            mean /= SEEDS as f64;
            let tol = (0.2 * expected).max(15.0);
            assert!(
                (mean - expected).abs() < tol,
                "{}/{}: mean sampled count {mean:.1} vs closed-form {expected:.1} (tol {tol:.1})",
                spec.name,
                stream.name
            );
        }
    }
}

/// Satellite 4 — per-stream seeds derive from the stream *name*, so
/// reordering the streams of a spec changes nothing about any stream's
/// arrivals or draws.
#[test]
fn stream_seeds_are_independent_of_stream_order() {
    let g = rmat(10);
    let reg = AnalysisRegistry::builtin();
    let spec = ScenarioSpec::builtin("steady").unwrap();
    let mut rev = spec.clone();
    rev.streams.reverse();

    let a = compile_scenario(&g, &reg, &spec, 42).unwrap();
    let b = compile_scenario(&g, &reg, &rev, 42).unwrap();

    // Group arrivals per stream name (merged order differs, content must not).
    let by_name = |tl: &ScenarioTimeline| -> BTreeMap<String, (u64, Vec<u64>)> {
        let mut m: BTreeMap<String, (u64, Vec<u64>)> = tl
            .map
            .streams
            .iter()
            .map(|cs| (cs.name.clone(), (cs.seed, Vec::new())))
            .collect();
        for (&t, &si) in tl.arrivals.iter().zip(&tl.map.stream_of) {
            m.get_mut(&tl.map.streams[si].name).unwrap().1.push(t.to_bits());
        }
        m
    };
    assert_eq!(by_name(&a), by_name(&b), "reordering streams must not move any arrival");
    // The merged timelines are therefore identical too.
    let bits = |tl: &ScenarioTimeline| tl.arrivals.iter().map(|t| t.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a), bits(&b));
    // And the seeds are exactly the documented name-derived values.
    for cs in &a.map.streams {
        assert_eq!(cs.seed, stream_seed(42, &cs.name));
    }
}

/// Satellite 4 (report half) — the service report surfaces each stream's
/// seed, stream counts partition the run, and the JSON form keeps u64
/// seeds precise as hex strings.
#[test]
fn report_surfaces_per_stream_seeds_and_partition() {
    let g = rmat(10);
    let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let cfg = ServiceConfig {
        scenario: Some(mini_spec()),
        seed: 0xFEED_FACE_CAFE_BEEF,
        ..Default::default()
    };
    let rep = svc.serve(&cfg).unwrap();
    let sc = rep.scenario.as_ref().expect("scenario runs carry a scenario section");
    assert_eq!(sc.name, "mini");
    for st in &sc.streams {
        assert_eq!(st.seed, stream_seed(cfg.seed, &st.name), "stream {}", st.name);
        assert_eq!(
            st.completed + st.rejected + st.shed,
            st.arrivals,
            "stream {} outcome partition",
            st.name
        );
    }
    let arrivals: usize = sc.streams.iter().map(|s| s.arrivals).sum();
    assert_eq!(arrivals, rep.served + rep.rejected + rep.shed);

    let s = rep.summary();
    assert!(
        s.contains(&format!("{:#018x}", sc.streams[0].seed)),
        "summary must print per-stream seeds:\n{s}"
    );
    assert!(s.contains("SLO"), "summary must carry the stream SLO verdict:\n{s}");

    // JSON: seeds as hex strings (Json numbers are f64 — u64 seeds would
    // silently lose bits), class_matrix keyed by scenario name.
    let j = rep.to_json();
    let streams = j.get("scenario").unwrap().get("streams").unwrap().as_arr().unwrap();
    assert_eq!(streams.len(), 2);
    for st in streams {
        let hex = st.str_of("seed").unwrap();
        assert!(hex.starts_with("0x"), "seed must serialize as hex, got {hex:?}");
        let parsed = u64::from_str_radix(hex.trim_start_matches("0x"), 16).unwrap();
        assert_eq!(parsed, stream_seed(cfg.seed, &st.str_of("name").unwrap()));
    }
    assert!(j.get("class_matrix").unwrap().get("serve/mini").is_ok(), "BENCH row key");
}

/// Catalog parity — every checked-in `ci/scenarios/*.json` parses to
/// exactly its builtin (so docs, CLI names and files can't drift), and
/// `ScenarioSpec::load` resolves names before paths.
#[test]
fn catalog_files_match_builtins() {
    for name in ScenarioSpec::catalog_names() {
        let path = repo_path(&format!("ci/scenarios/{name}.json"));
        let spec =
            ScenarioSpec::parse_file(&path).unwrap_or_else(|e| panic!("{path:?}: {e:#}"));
        assert_eq!(
            spec,
            ScenarioSpec::builtin(name).unwrap(),
            "checked-in {name}.json must equal the builtin"
        );
        assert_eq!(ScenarioSpec::load(name).unwrap(), spec, "load({name}) resolves the builtin");
    }
    assert!(ScenarioSpec::load("no-such-scenario").is_err());
}

/// Satellite 3 (golden trace) — the checked-in fixture passes both the
/// Rust mirror of the validator contract and, where python3 exists, the
/// real `ci/validate_trace.py`. Guards the PR 9 trace schema against
/// drift: if the exporter's shape changes, regenerate the fixture
/// deliberately.
#[test]
fn golden_trace_fixture_passes_the_validator() {
    let path = repo_path("ci/fixtures/scenario_golden_trace.json");
    let doc = Json::parse_file(&path).unwrap();
    assert_eq!(doc.str_of("displayTimeUnit").unwrap(), "ns");
    assert_trace_contract(&doc);
    if let Some(ok) = validate_with_python(&path) {
        assert!(ok, "ci/validate_trace.py must accept the golden fixture");
    }
}

/// Satellite 3 (reconciliation) — a traced scenario run's telemetry
/// sidecar must agree with the `ServiceReport`: event counts equal the
/// report's served/shed/rejected partition, and the Chrome trace passes
/// the validator contract.
#[test]
fn traced_scenario_run_reconciles_with_telemetry() {
    let g = rmat(10);
    let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
    let dir = std::env::temp_dir().join("pfq-scenario-tests");
    let trace_file = dir.join("mini.trace.json");
    let cfg = ServiceConfig {
        scenario: Some(mini_spec()),
        trace: Some(TraceSpec::new(trace_file.clone())),
        seed: 0x7ACE,
        ..Default::default()
    };
    let rep = svc.serve(&cfg).unwrap();

    let doc = Json::parse_file(&trace_file).unwrap();
    assert_eq!(doc.str_of("displayTimeUnit").unwrap(), "ns");
    assert_trace_contract(&doc);
    if let Some(ok) = validate_with_python(&trace_file) {
        assert!(ok, "ci/validate_trace.py must accept a live scenario trace");
    }

    let tel = Json::parse_file(&telemetry_path(&trace_file)).unwrap();
    assert_eq!(tel.str_of("schema").unwrap(), "pfq-telemetry-v1");
    let counts = tel.get("event_counts").unwrap();
    let count = |k: &str| {
        counts.get_opt(k).map(|v| v.as_f64().unwrap() as usize).unwrap_or(0)
    };
    let total = rep.served + rep.rejected + rep.shed;
    assert_eq!(count("arrival"), total, "every compiled request must emit an arrival event");
    assert_eq!(count("finish"), rep.served, "finish events reconcile with served");
    assert_eq!(count("shed"), rep.shed, "shed events reconcile");
    assert_eq!(count("reject"), rep.rejected, "reject events reconcile");
}

/// Satellite 2 — the acceptance test: on the overload-ramp scenario with
/// shed + preempt enabled, Batch work sheds strictly before Interactive,
/// no Interactive arrival before the hand-derived ramp knee misses its
/// SLO, and Completed/Rejected/Shed partition the run exactly — with
/// both shedding AND preemption demonstrably firing in the same run.
#[test]
fn overload_ramp_sheds_batch_before_interactive() {
    let g = rmat(10);
    let machine = capacity8_machine();
    let coord = Coordinator::new(&g, machine.clone());

    // Probe this machine's sustained drain rate: a saturating 32-query
    // bfs burst (arrivals at t=0) drains in makespan M, so mu ~= 32/M
    // queries/s is the capacity the ramp must cross.
    let burst = planner::bfs_queries(&g, 32, 0xCAFE);
    let probe = coord.run(&burst, Policy::admitted(OnFull::Queue)).unwrap();
    let mu = 32.0 / probe.makespan_s;
    assert!(mu.is_finite() && mu > 0.0);

    // Anchor the interactive latency scale: solo khop service time.
    let solo = coord
        .run(&planner::khop_queries(&g, 4, 2, 0xBEEF), Policy::Sequential)
        .unwrap();
    let solo_khop_s = solo.latencies(None).into_iter().fold(0.0f64, f64::max);
    assert!(solo_khop_s > 0.0);

    // Retarget the catalog ramp at this machine: the builtin is sized in
    // nominal units (mean total rate 345/s against the CI smoke box);
    // compress so its mid-ramp rate sits at the measured capacity. After
    // compression the offered load is (50 + 590u) * mu/200 for ramp
    // fraction u — it crosses mu at the knee u* = 150/590, and ends at
    // 3.2*mu: deep, sustained overload in the back half.
    let f = mu / 200.0;
    let mut spec =
        ScenarioSpec::builtin("overload-ramp").unwrap().time_compressed(f).unwrap();
    // The catalog's 0.25 s SLO is sized for the CI smoke machine; on this
    // probe-calibrated run the target is anchored to measured solo
    // latency so the assertion is about *scheduling*, not machine speed.
    let slo_s = 25.0 * solo_khop_s;
    for s in &mut spec.streams {
        if s.name == "interactive-frontend" {
            s.slo_p99_s = Some(slo_s);
        }
    }

    let on_full = OnFull::Shed { max_waiting: 32 };
    let weights = ShareWeights::priority_weighted();
    let svc = GraphService::new(&g, machine.clone());
    let cfg = ServiceConfig {
        scenario: Some(spec.clone()),
        on_full,
        weights,
        preempt: Some(PreemptPolicy::default()),
        seed: 9,
        ..Default::default()
    };
    let rep = svc.serve(&cfg).unwrap();

    // Both overload mechanisms fire in ONE run (the PR acceptance bar).
    assert!(rep.shed > 0, "the ramp must shed: {}", rep.summary());
    assert!(rep.preempted > 0, "interactive pressure must preempt batch: {}", rep.summary());

    let sc = rep.scenario.as_ref().expect("scenario section");
    let inter = sc.stream("interactive-frontend").expect("interactive stream");
    let batch = sc.stream("batch-ingest-ramp").expect("batch stream");
    assert!(batch.shed > 0, "overload lands on the Batch stream");
    assert_eq!(
        inter.shed + inter.rejected,
        0,
        "interactive work is never dropped while batch waiters exist"
    );
    for st in &sc.streams {
        assert_eq!(
            st.completed + st.rejected + st.shed,
            st.arrivals,
            "stream {}: Completed/Rejected/Shed must partition arrivals exactly",
            st.name
        );
    }
    assert_eq!(rep.served + rep.rejected + rep.shed, inter.arrivals + batch.arrivals);

    // Record-level assertions: replay the identical compiled timeline
    // through the coordinator (serve's own engine path) for per-query
    // outcomes and times.
    let tl = compile_scenario(&g, &AnalysisRegistry::builtin(), &spec, cfg.seed).unwrap();
    let run = coord
        .run(
            &tl.requests,
            Policy::ConcurrentAdmitted {
                on_full,
                weights,
                preempt: Some(PreemptPolicy::default()),
            },
        )
        .unwrap();
    assert_eq!(run.records.len(), tl.requests.len());
    for r in &run.records {
        let outcomes = [r.completed(), r.rejected(), r.shed()];
        assert_eq!(
            outcomes.iter().filter(|&&x| x).count(),
            1,
            "query {} must land in exactly one outcome bucket",
            r.id
        );
    }
    // serve() and the raw coordinator agree on the same timeline+policy.
    assert_eq!(run.records.iter().filter(|r| r.shed()).count(), rep.shed);

    // Batch sheds strictly before Interactive (vacuously if Interactive
    // never sheds — which the stream assertion above already pinned).
    let first_shed_arrival = |p: Priority| {
        run.records
            .iter()
            .filter(|r| r.shed() && r.priority == p)
            .map(|r| r.arrival_s)
            .fold(f64::INFINITY, f64::min)
    };
    let first_batch = first_shed_arrival(Priority::Batch);
    assert!(first_batch.is_finite(), "batch work must shed");
    assert!(
        first_batch < first_shed_arrival(Priority::Interactive),
        "batch must shed strictly before any interactive shed"
    );

    // Hand-derived knee: offered load (50 + 590u)*mu/200 crosses mu at
    // u* = 150/590 ~ 0.254. Before *half* the knee the machine has ~40%
    // headroom, so every Interactive arrival there must complete within
    // the anchored SLO — zero misses until the knee.
    let knee_u = 150.0 / 590.0;
    let cutoff_s = 0.5 * knee_u * spec.duration_s;
    let mut pre_knee = 0usize;
    for (r, &si) in run.records.iter().zip(&tl.map.stream_of) {
        if spec.streams[si].name != "interactive-frontend" || r.arrival_s >= cutoff_s {
            continue;
        }
        pre_knee += 1;
        assert!(
            r.completed(),
            "pre-knee interactive arrival at {:.4}s must complete",
            r.arrival_s
        );
        assert!(
            r.latency_s <= slo_s,
            "pre-knee interactive at {:.4}s missed SLO: {:.4}s > {:.4}s",
            r.arrival_s,
            r.latency_s,
            slo_s
        );
    }
    assert!(pre_knee > 0, "compression left no interactive arrivals before the knee");
}
