//! Runtime integration tests: the AOT artifact contract between
//! `python/compile/aot.py` and the rust PJRT engine. These run against the
//! real artifacts (`make artifacts`) and skip gracefully when absent.

use pathfinder_queries::runtime::artifact::{default_artifacts_dir, ArtifactManifest};
use pathfinder_queries::runtime::Engine;

fn manifest() -> Option<ArtifactManifest> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ArtifactManifest::load(&dir).unwrap())
}

fn engine(m: ArtifactManifest) -> Option<Engine> {
    match Engine::new(m) {
        Ok(eng) => Some(eng),
        // Built without the `pjrt` feature: the stub engine refuses to
        // construct; skip exactly like missing artifacts.
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

#[test]
fn manifest_covers_both_kinds_with_batches() {
    let Some(m) = manifest() else { return };
    let batches = m.bfs_batches();
    assert!(batches.len() >= 2, "need multiple BFS batch variants, got {batches:?}");
    assert!(batches.windows(2).all(|w| w[0] < w[1]));
    assert!(m.cc_variant().is_some());
    // Every entry's file exists and carries a sha256.
    for e in &m.entries {
        assert!(m.hlo_path(e).exists());
        assert_eq!(e.sha256.len(), 64);
    }
}

#[test]
fn sha256_integrity_matches_files() {
    // The manifest hash must describe the actual HLO text on disk —
    // guards against stale artifacts after editing the python side.
    let Some(m) = manifest() else { return };
    for e in &m.entries {
        let text = std::fs::read(m.hlo_path(e)).unwrap();
        let got = sha256_hex(&text);
        assert_eq!(got, e.sha256, "stale artifact {}: rerun `make artifacts`", e.name);
    }
}

/// Minimal SHA-256 (FIPS 180-4) so the integrity check needs no deps.
fn sha256_hex(data: &[u8]) -> String {
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let mut msg = data.to_vec();
    let bitlen = (data.len() as u64) * 8;
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());
    for block in msg.chunks(64) {
        let mut w = [0u32; 64];
        for (i, c) in block.chunks(4).enumerate() {
            w[i] = u32::from_be_bytes(c.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ ((!e) & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[test]
fn sha256_known_answer() {
    assert_eq!(
        sha256_hex(b"abc"),
        "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
    );
    assert_eq!(
        sha256_hex(b""),
        "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
    );
}

#[test]
fn engine_compiles_all_variants_once() {
    let Some(m) = manifest() else { return };
    let Some(eng) = engine(m) else { return };
    assert_eq!(eng.compiled_count(), 0, "compilation is lazy");
    let times = eng.compile_all().unwrap();
    assert_eq!(times.len(), eng.manifest().entries.len());
    assert_eq!(eng.compiled_count(), times.len());
    // Recompiling is a cache hit (fast, count unchanged).
    let again = eng.compile_all().unwrap();
    assert_eq!(eng.compiled_count(), times.len());
    assert!(again.iter().all(|(_, s)| *s < 0.5), "cache hits should be instant");
}

#[test]
fn bfs_step_batch_lanes_are_independent() {
    let Some(m) = manifest() else { return };
    let Some(eng) = engine(m) else { return };
    let e = eng.manifest().bfs_variant_for(2).unwrap().clone();
    if e.batch < 2 {
        return;
    }
    let (b, n) = (e.batch, eng.manifest().n);
    // Two queries in different lanes of one batch; disjoint edges.
    let mut adj = vec![0.0f32; n * n];
    for (u, v) in [(0usize, 1usize), (1, 0), (4, 5), (5, 4)] {
        adj[u * n + v] = 1.0;
    }
    let mut frontier = vec![0.0f32; b * n];
    let mut visited = vec![0.0f32; b * n];
    let levels = vec![-1.0f32; b * n];
    frontier[0] = 1.0; // lane 0 at vertex 0
    visited[0] = 1.0;
    frontier[n + 4] = 1.0; // lane 1 at vertex 4
    visited[n + 4] = 1.0;
    let out = eng
        .execute_f32(
            &e.name,
            &[
                (&adj, &[n as i64, n as i64]),
                (&frontier, &[b as i64, n as i64]),
                (&visited, &[b as i64, n as i64]),
                (&levels, &[b as i64, n as i64]),
                (&[1.0f32], &[]),
            ],
        )
        .unwrap();
    let next = &out[0];
    assert_eq!(next[1], 1.0, "lane 0 discovers vertex 1");
    assert_eq!(next[5], 0.0, "lane 0 does not see lane 1's frontier");
    assert_eq!(next[n + 5], 1.0, "lane 1 discovers vertex 5");
    assert_eq!(next[n + 1], 0.0, "lane 1 does not see lane 0's frontier");
}

#[test]
fn unknown_variant_is_clean_error() {
    let Some(m) = manifest() else { return };
    let Some(eng) = engine(m) else { return };
    let err = eng.execute_f32("nope_b9_n9", &[]).unwrap_err();
    assert!(err.to_string().contains("unknown artifact variant"));
}
