//! Baseline integration tests: the GraphBLAS/PJRT engine against oracles
//! and the sim algorithms, plus the Xeon/RedisGraph model against the
//! paper's published Table III.

use pathfinder_queries::alg::{self, oracle};
use pathfinder_queries::baseline::redisgraph::{adjusted_speedup, ClientOverhead};
use pathfinder_queries::baseline::{GraphBlasEngine, XeonModel};
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::runtime::artifact::default_artifacts_dir;
use pathfinder_queries::runtime::Engine;
use pathfinder_queries::sim::machine::Machine;

fn engine() -> Option<Engine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match Engine::from_dir(&dir) {
        Ok(eng) => Some(eng),
        // Built without the `pjrt` feature: the stub engine refuses to
        // construct; skip exactly like missing artifacts.
        Err(e) => {
            eprintln!("skipping: {e}");
            None
        }
    }
}

fn fitting_rmat(eng: &Engine, seed: u64) -> Csr {
    let scale = (eng.manifest().n as f64).log2() as u32;
    let mut cfg = GraphConfig::with_scale(scale);
    cfg.seed = seed;
    build_undirected_csr(1 << scale, &pathfinder_queries::graph::rmat::Rmat::new(cfg).edges())
}

/// The three implementations of BFS — host oracle, Pathfinder-sim
/// functional execution, PJRT GraphBLAS engine — agree vertex for vertex.
#[test]
fn three_way_bfs_agreement() {
    let Some(eng) = engine() else { return };
    let g = fitting_rmat(&eng, 42);
    let m = Machine::new(MachineConfig::pathfinder_8());
    let gb = GraphBlasEngine::new(&eng, &g).unwrap();
    let sources = pathfinder_queries::graph::sample::bfs_sources(&g, 4, 5);
    let res = gb.bfs(&sources).unwrap();
    for (i, &src) in sources.iter().enumerate() {
        let truth = oracle::bfs_levels(&g, src);
        let sim = alg::bfs_run(&g, &m, src).levels;
        assert_eq!(sim, truth, "sim vs oracle, src {src}");
        assert_eq!(res.levels[i], truth, "pjrt vs oracle, src {src}");
    }
}

#[test]
fn three_way_cc_agreement() {
    let Some(eng) = engine() else { return };
    let g = fitting_rmat(&eng, 43);
    let m = Machine::new(MachineConfig::pathfinder_8());
    let gb = GraphBlasEngine::new(&eng, &g).unwrap();
    let truth = oracle::cc_labels(&g);
    assert_eq!(alg::cc_run(&g, &m).labels, truth, "sim vs oracle");
    assert_eq!(gb.cc().unwrap().labels, truth, "pjrt vs oracle");
}

#[test]
fn engine_handles_edge_case_graphs() {
    let Some(eng) = engine() else { return };
    // Empty graph: BFS reaches only the source; CC is all-distinct.
    let empty = build_undirected_csr(8, &[]);
    let gb = GraphBlasEngine::new(&eng, &empty).unwrap();
    let r = gb.bfs(&[3]).unwrap();
    assert_eq!(r.levels[0][3], 0);
    assert!(r.levels[0].iter().enumerate().all(|(v, &l)| (v == 3) == (l == 0.0 as i64)));
    let cc = gb.cc().unwrap();
    assert_eq!(cc.labels, (0..8).collect::<Vec<i64>>());
    // Complete bipartite-ish tiny graph.
    let k = build_undirected_csr(6, &[(0, 3), (0, 4), (1, 3), (2, 5), (4, 5)]);
    let gb = GraphBlasEngine::new(&eng, &k).unwrap();
    oracle::check_cc(&k, &gb.cc().unwrap().labels).unwrap();
    oracle::check_bfs(&k, 0, &gb.bfs(&[0]).unwrap().levels[0]).unwrap();
}

#[test]
fn bfs_steps_equal_eccentricity_plus_one() {
    let Some(eng) = engine() else { return };
    // A path graph: depth from one end is n-1 levels; engine should stop
    // right after the frontier empties.
    let n = 12usize;
    let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
    let g = build_undirected_csr(n, &edges);
    let gb = GraphBlasEngine::new(&eng, &g).unwrap();
    let r = gb.bfs(&[0]).unwrap();
    assert_eq!(r.levels[0][n - 1], (n - 1) as i64);
    // One expanding step per depth plus the final empty check.
    assert_eq!(r.steps, n, "level steps");
}

// ---------------- Xeon / RedisGraph model ----------------

#[test]
fn xeon_model_reproduces_published_table3() {
    let m = XeonModel::paper();
    for (q, expect) in [(1, 5.0), (8, 40.0), (16, 139.0), (32, 276.0), (64, 610.0), (128, 1707.0)]
    {
        let got = m.total_s(q);
        assert!((got - expect).abs() / expect < 0.02, "q={q}: {got:.1} vs {expect}");
    }
}

#[test]
fn adjusted_speedups_match_paper_rows() {
    let ov = ClientOverhead::from_single_query(5.0);
    // (rg_s, pf_s, expected) from Table III.
    let rows = [
        (5.0, 3.47, 0.590),
        (40.0, 14.88, 2.01),
        (139.0, 10.29, 9.09),
        (276.0, 19.61, 11.2),
        (1707.0, 84.04, 19.2),
    ];
    for (rg, pf, expect) in rows {
        let got = adjusted_speedup(rg, pf, ov);
        assert!((got - expect).abs() / expect < 0.02, "{got:.3} vs {expect}");
    }
}

#[test]
fn oversubscription_kicks_in_past_hw_threads() {
    let m = XeonModel::paper();
    // Per-query cost at 256 queries is much worse than at 64 (the paper
    // could not measure past 128; the model extrapolates preemption).
    assert!(m.per_query_s(256) > 1.8 * m.per_query_s(64));
    // But below 8 queries, concurrency is free.
    assert!((m.per_query_s(4) - m.per_query_s(1)).abs() < 1e-9);
}
