//! Integration tests for the graph substrate: generation, hygiene,
//! layout, I/O — the paper's §IV-A dataset recipe end to end.

use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::graph::builder::{build_undirected_csr, undirected_edge_count};
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::graph::layout::StripedLayout;
use pathfinder_queries::graph::rmat::Rmat;
use pathfinder_queries::graph::sample::bfs_sources;
use pathfinder_queries::graph::{io, validate};

fn rmat(scale: u32, seed: u64) -> Csr {
    let mut cfg = GraphConfig::with_scale(scale);
    cfg.seed = seed;
    build_undirected_csr(1 << scale, &Rmat::new(cfg).edges())
}

#[test]
fn generator_is_deterministic_across_runs() {
    let a = rmat(12, 7);
    let b = rmat(12, 7);
    assert_eq!(a, b);
    let c = rmat(12, 8);
    assert_ne!(a, c, "different seeds must give different graphs");
}

#[test]
fn paper_dataset_hygiene() {
    // §IV-A: undirected closure, no duplicates, no self loops.
    let g = rmat(13, 1);
    validate::check_invariants(&g).expect("invariants");
    // Both (i,j) and (j,i) present: m_directed is exactly 2x undirected.
    assert_eq!(g.m_directed(), 2 * undirected_edge_count(&g));
}

#[test]
fn rmat_has_graph500_shape() {
    let g = rmat(14, 3);
    let r = validate::report(&g);
    // Skewed degrees: the max degree dwarfs the mean.
    assert!(r.max_degree as f64 > 20.0 * r.mean_degree, "{r:?}");
    // A giant component holding most non-isolated vertices.
    assert!(r.largest_component > g.n() / 2, "{r:?}");
    // Dedup keeps it below the raw target of n*ef directed pairs.
    assert!(g.m_directed() < (1 << 14) * 16 * 2);
    // Isolated vertices exist at this scale (R-MAT leaves gaps).
    assert!(r.isolated_vertices > 0);
}

#[test]
fn edge_factor_scales_edge_count() {
    let mut cfg = GraphConfig::with_scale(12);
    cfg.edge_factor = 4;
    let sparse = build_undirected_csr(1 << 12, &Rmat::new(cfg.clone()).edges());
    cfg.edge_factor = 16;
    let dense = build_undirected_csr(1 << 12, &Rmat::new(cfg).edges());
    assert!(dense.m_directed() > 3 * sparse.m_directed());
}

#[test]
fn io_round_trip() {
    let g = rmat(11, 5);
    let path = std::env::temp_dir().join("pfq_io_roundtrip.csr");
    io::save_csr(&g, &path).unwrap();
    let back = io::load_csr(&path).unwrap();
    assert_eq!(g, back);
    std::fs::remove_file(&path).ok();
}

#[test]
fn io_rejects_garbage() {
    let path = std::env::temp_dir().join("pfq_io_garbage.csr");
    std::fs::write(&path, b"not a graph").unwrap();
    assert!(io::load_csr(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn sources_unique_nonisolated_reproducible() {
    let g = rmat(12, 2);
    let s1 = bfs_sources(&g, 100, 42);
    let s2 = bfs_sources(&g, 100, 42);
    assert_eq!(s1, s2);
    let mut sorted = s1.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), 100, "sources must be unique");
    assert!(s1.iter().all(|&v| g.degree(v) > 0), "no isolated sources");
}

#[test]
fn striped_layout_covers_graph() {
    // Every vertex maps to a valid (node, channel); both views agree with
    // the paper's "vertex 0 on node 0, vertex 1 on node 1" striping.
    let g = rmat(10, 1);
    let l = StripedLayout::new(8, 8);
    for v in 0..g.n() as u32 {
        assert_eq!(l.node_of(v), v as usize % 8);
        assert!(l.channel_of(v) < 8);
        assert!(l.edge_block_channel(v) < 8);
    }
}

#[test]
fn degree_sum_equals_directed_edges() {
    let g = rmat(12, 9);
    let sum: usize = (0..g.n() as u32).map(|v| g.degree(v)).sum();
    assert_eq!(sum, g.m_directed());
}
