//! Property-based tests (in-tree generator — the offline environment has no
//! proptest): randomized inputs driven by `SplitMix64`, checking invariants
//! rather than examples. Each property runs CASES seeded cases, so failures
//! print the seed for replay.

use pathfinder_queries::alg::{self, oracle, Analysis, AnalysisRegistry};
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::coordinator::{planner, Coordinator, Policy, QueryRequest};
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::sim::demand::{DemandBuilder, PhaseDemand};
use pathfinder_queries::sim::flow::{
    Admission, FlowReport, FlowSim, OnFull, Priority, QuerySpec, ShareWeights, SolverMode,
};
use pathfinder_queries::sim::machine::Machine;
use pathfinder_queries::sim::preempt::PreemptPolicy;
use pathfinder_queries::util::rng::SplitMix64;
use pathfinder_queries::util::stats::Quantiles;

const CASES: u64 = 24;

/// Random sparse graph: n in [2, 200], ~2n random edges.
fn random_graph(rng: &mut SplitMix64) -> Csr {
    let n = 2 + rng.gen_range(199) as usize;
    let m = n * (1 + rng.gen_range(3) as usize);
    let edges: Vec<(u32, u32)> = (0..m)
        .map(|_| (rng.gen_range(n as u64) as u32, rng.gen_range(n as u64) as u32))
        .collect();
    build_undirected_csr(n, &edges)
}

fn m8() -> Machine {
    Machine::new(MachineConfig::pathfinder_8())
}

#[test]
fn prop_bfs_levels_are_shortest_paths() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed);
        let g = random_graph(&mut rng);
        let src = rng.gen_range(g.n() as u64) as u32;
        let run = alg::bfs_run(&g, &m8(), src);
        // Against the oracle.
        oracle::check_bfs(&g, src, &run.levels).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // Edge relaxation: adjacent levels differ by at most 1, and an
        // unreached vertex has no reached neighbor.
        for (u, v) in g.edges() {
            let (lu, lv) = (run.levels[u as usize], run.levels[v as usize]);
            match (lu, lv) {
                (-1, -1) => {}
                (-1, _) | (_, -1) => panic!("seed {seed}: edge ({u},{v}) half-reached"),
                (a, b) => assert!((a - b).abs() <= 1, "seed {seed}: edge ({u},{v}) {a}/{b}"),
            }
        }
    }
}

#[test]
fn prop_cc_labels_are_component_minima() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xCC);
        let g = random_graph(&mut rng);
        let run = alg::cc_run(&g, &m8());
        oracle::check_cc(&g, &run.labels).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // Fixpoint: endpoints agree, labels self-referential, label <= id.
        for (u, v) in g.edges() {
            assert_eq!(run.labels[u as usize], run.labels[v as usize], "seed {seed}");
        }
        for v in 0..g.n() {
            let l = run.labels[v] as usize;
            assert!(l <= v, "seed {seed}: label above vertex id");
            assert_eq!(run.labels[l], l as i64, "seed {seed}: label not a root");
        }
    }
}

/// PageRank (tentpole property): on random graphs the fixed-point-scaled
/// ranks conserve mass (sum to 1) and match the independent pull-based
/// oracle within tolerance — and the kernel computed over a mutated
/// `GraphView` equals the kernel over the materialized CSR, per-value and
/// per-phase (PR 4's overlay-equivalence pattern).
#[test]
fn prop_pagerank_ranks_sum_to_one_and_match_oracle() {
    use pathfinder_queries::alg::pagerank::{ORACLE_TOL, RANK_SCALE};
    use pathfinder_queries::graph::delta::random_batch;
    use pathfinder_queries::graph::store::GraphStore;

    let m = m8();
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x9A6E);
        let g = random_graph(&mut rng);
        let run = alg::pagerank_run(&g, &m);
        // Mass conservation in scaled units (rounding + tolerance slack).
        let sum: i64 = run.ranks.iter().sum();
        let mass_tol = g.n() as i64 + (ORACLE_TOL * RANK_SCALE) as i64;
        assert!(
            (sum - RANK_SCALE as i64).abs() <= mass_tol,
            "seed {seed}: ranks sum to {sum}"
        );
        oracle::check_pagerank(&g, &run.ranks).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(run.phases.len(), 2 * run.rounds, "seed {seed}");

        // Overlay equivalence: same ranks and demand on a mutated view.
        let mut store = GraphStore::new(&g);
        for _ in 0..2 {
            let batch = random_batch(store.view(), 10, 0.3, &mut rng);
            store.apply_batch(&batch);
        }
        let view = store.view();
        let over = alg::pagerank_run(view, &m);
        let flat = alg::pagerank_run(&view.to_csr(), &m);
        assert_eq!(over.ranks, flat.ranks, "seed {seed}: overlay vs materialized");
        assert_eq!(over.phases.len(), flat.phases.len(), "seed {seed}");
        oracle::check_pagerank(view, &over.ranks).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

/// Triangle counting (tentpole property): the degree-ordered merge-
/// intersection kernel matches the brute-force hash-set oracle exactly on
/// random graphs, and a mutated `GraphView` counts exactly what its
/// materialized CSR counts.
#[test]
fn prop_tricount_matches_bruteforce_oracle() {
    use pathfinder_queries::graph::delta::random_batch;
    use pathfinder_queries::graph::store::GraphStore;

    let m = m8();
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x741C);
        let g = random_graph(&mut rng);
        let run = alg::tricount_run(&g, &m);
        assert_eq!(
            run.triangles,
            oracle::triangle_total(&g),
            "seed {seed}: kernel vs brute force"
        );
        // One oriented edge per undirected edge, independent of skew.
        assert_eq!(run.ordered_edges, g.m_directed() / 2, "seed {seed}");
        oracle::check_tricount(&g, &[run.triangles as i64])
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        // Overlay equivalence (inserts can create triangles, deletes can
        // break them; the pinned view must count its exact edge set).
        let mut store = GraphStore::new(&g);
        let batch = random_batch(store.view(), 12, 0.4, &mut rng);
        store.apply_batch(&batch);
        let view = store.view();
        let over = alg::tricount_run(view, &m);
        let flat = alg::tricount_run(&view.to_csr(), &m);
        assert_eq!(over.triangles, flat.triangles, "seed {seed}: overlay vs materialized");
        assert_eq!(over.triangles, oracle::triangle_total(view), "seed {seed}");
    }
}

#[test]
fn prop_demand_builder_consistency() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xDE);
        let nodes = 1 + rng.gen_range(16) as usize;
        let chans = 1 + rng.gen_range(8) as usize;
        let mut b = DemandBuilder::new(nodes, chans);
        let mut expect_total = 0.0;
        for _ in 0..rng.gen_range(200) {
            let node = rng.gen_range(nodes as u64) as usize;
            let chan = rng.gen_range(chans as u64) as usize;
            let count = (1 + rng.gen_range(5)) as f64;
            if rng.next_f64() < 0.3 {
                b.msp_op(node, chan, count);
            } else {
                b.channel_op(node, chan, count);
            }
            expect_total += count;
        }
        let d = b.finish();
        assert!((d.total_channel_ops() - expect_total).abs() < 1e-9, "seed {seed}");
        for node in 0..nodes {
            // Hottest channel bounded by node total and >= mean.
            assert!(d.max_channel_ops[node] <= d.channel_ops[node] + 1e-9);
            assert!(
                d.max_channel_ops[node] * chans as f64 >= d.channel_ops[node] - 1e-9,
                "seed {seed}: hottest below mean"
            );
            // MSP ops are a subset of channel ops.
            assert!(d.msp_ops[node] <= d.channel_ops[node] + 1e-9);
            // Per-channel rows sum to node totals.
            let row: f64 = d.per_channel_ops[node * chans..(node + 1) * chans].iter().sum();
            assert!((row - d.channel_ops[node]).abs() < 1e-9);
        }
    }
}

#[test]
fn prop_rotation_preserves_everything_but_placement() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x20);
        let chans = 2 + rng.gen_range(7) as usize;
        let mut b = DemandBuilder::new(4, chans);
        for _ in 0..50 {
            b.channel_op(
                rng.gen_range(4) as usize,
                rng.gen_range(chans as u64) as usize,
                1.0,
            );
        }
        let d = b.finish();
        let off = rng.gen_range(17) as usize;
        let r = d.rotate_channels(off);
        assert_eq!(r.channel_ops, d.channel_ops, "seed {seed}");
        assert_eq!(r.max_channel_ops, d.max_channel_ops, "seed {seed}");
        assert_eq!(
            r.per_channel_ops.iter().sum::<f64>(),
            d.per_channel_ops.iter().sum::<f64>()
        );
        // Full-cycle rotation is the identity.
        assert_eq!(d.rotate_channels(chans), d, "seed {seed}");
    }
}

/// Random phase mixes through the flow engine: the fundamental ordering
/// makespan(conc) in [max solo, sum solo] and work conservation.
#[test]
fn prop_flow_bounds_random_workloads() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xF1);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let nq = 1 + rng.gen_range(12) as usize;
        let specs: Vec<QuerySpec> = (0..nq)
            .map(|id| {
                let phases = (1 + rng.gen_range(4) as usize..=4)
                    .map(|_| {
                        let mut p = PhaseDemand::zero(8, 8);
                        for node in 0..8 {
                            for c in 0..8 {
                                let ops = rng.next_f64() * 1e4;
                                p.per_channel_ops[node * 8 + c] = ops;
                                p.channel_ops[node] += ops;
                                p.max_channel_ops[node] =
                                    p.max_channel_ops[node].max(ops);
                            }
                            p.instructions[node] = rng.next_f64() * 1e6;
                        }
                        p.parallelism = 1.0 + rng.next_f64() * 1e4;
                        p
                    })
                    .collect();
                QuerySpec::new(id, "rand", phases, 0.0)
            })
            .collect();
        let conc = sim.run(&specs);
        let seq = sim.run_sequential(&specs);
        let max_solo = specs.iter().map(|s| s.solo_ns(&m)).fold(0.0, f64::max);
        let sum_solo: f64 = specs.iter().map(|s| s.solo_ns(&m)).sum();
        assert!(
            conc.makespan_ns <= sum_solo * (1.0 + 1e-9),
            "seed {seed}: conc above sequential bound"
        );
        assert!(
            conc.makespan_ns >= max_solo * (1.0 - 1e-9),
            "seed {seed}: conc beat the longest query"
        );
        assert!((seq.makespan_ns - sum_solo).abs() / sum_solo < 1e-9, "seed {seed}");
        assert!(
            (conc.counters.totals().channel_ops - seq.counters.totals().channel_ops).abs()
                < 1e-6,
            "seed {seed}: work not conserved"
        );
        // Every query finished.
        assert!(conc.timings.iter().all(|t| t.finish_ns.is_finite()), "seed {seed}");
    }
}

#[test]
fn prop_admission_partitions_queries() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xAD);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let nq = 1 + rng.gen_range(20) as usize;
        let cap = 1 + rng.gen_range(nq as u64) as usize;
        let specs: Vec<QuerySpec> = (0..nq)
            .map(|id| {
                let mut p = PhaseDemand::zero(8, 8);
                p.channel_ops[0] = 1e4;
                p.per_channel_ops[0] = 1e4;
                p.max_channel_ops[0] = 1e4;
                p.parallelism = 100.0;
                QuerySpec::new(id, "rand", vec![p], rng.next_f64() * 1e6)
            })
            .collect();
        for on_full in [OnFull::Queue, OnFull::Reject] {
            let rep = sim.run_admitted(&specs, Admission::capped(cap, on_full));
            assert!(rep.peak_concurrency <= cap, "seed {seed}");
            let done = rep.timings.iter().filter(|t| t.finish_ns.is_finite()).count();
            match on_full {
                OnFull::Queue => {
                    assert_eq!(done, nq, "seed {seed}: queue must serve all");
                    assert!(rep.rejected.is_empty());
                }
                OnFull::Reject => {
                    assert_eq!(done + rep.rejected.len(), nq, "seed {seed}");
                }
            }
        }
    }
}

#[test]
fn prop_quantiles_are_order_statistics() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x9A);
        let n = 1 + rng.gen_range(100) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64() * 1e3).collect();
        let q = Quantiles::from_samples(&xs);
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(q.q0, sorted[0], "seed {seed}");
        assert_eq!(q.q100, *sorted.last().unwrap(), "seed {seed}");
        assert!(q.q0 <= q.q25 && q.q25 <= q.q50 && q.q50 <= q.q75);
        assert!(q.q75 <= q.q95 && q.q95 <= q.q99 && q.q99 <= q.q100);
        assert!(q.spread() >= 0.0);
    }
}

#[test]
fn prop_machine_config_json_round_trip() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x11);
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.nodes = 8 * (1 + rng.gen_range(4) as usize);
        cfg.channel_random_op_ns = 10.0 + rng.next_f64() * 200.0;
        cfg.msp_write_priority = 0.5 + rng.next_f64();
        cfg.spawn_efficiency = 0.05 + rng.next_f64() * 0.9;
        cfg.degrade_factor = 0.2 + rng.next_f64() * 0.8;
        if rng.next_f64() < 0.5 {
            cfg.degraded_chassis = vec![rng.gen_range(cfg.nodes as u64 / 8) as usize];
        }
        let json = cfg.to_json().render_pretty();
        let back = MachineConfig::from_json(
            &pathfinder_queries::util::json::Json::parse(&json).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg, back, "seed {seed}");
    }
}

/// Property (API satellite): every analysis registered with the builtin
/// registry — randomly instantiated on random graphs — validates against
/// its host oracle when scheduled through the coordinator under both
/// `Sequential` and `ConcurrentAdmitted` policies, and both policies
/// complete the whole batch.
#[test]
fn prop_registered_analyses_validate_under_both_policies() {
    let registry = AnalysisRegistry::builtin();
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(seed ^ 0xA11A);
        let g = random_graph(&mut rng);
        let coord = Coordinator::new(&g, m8());
        // One instance of every registered class, random sources.
        let requests: Vec<QueryRequest> = registry
            .labels()
            .into_iter()
            .map(|label| {
                let src = rng.gen_range(g.n() as u64) as u32;
                QueryRequest::from_arc(registry.build(label, src).unwrap())
            })
            .collect();
        for policy in [Policy::Sequential, Policy::admitted(OnFull::Queue)] {
            let rep = coord.run(&requests, policy).unwrap();
            assert_eq!(rep.completed(), requests.len(), "seed {seed} {policy:?}");
        }
        // Policies share one functional execution path; validate it at
        // every stripe offset the batch would use.
        for (i, req) in requests.iter().enumerate() {
            let out = req.analysis.run_offset(g.view(), coord.machine(), i);
            req.analysis
                .validate(g.view(), &out.values)
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", req.analysis.describe()));
        }
    }
}

/// Coordinator-level: sequential makespan is permutation-invariant in
/// total, concurrent is order-independent for identical arrival times.
#[test]
fn prop_coordinator_order_invariance() {
    for seed in 0..6 {
        let mut rng = SplitMix64::new(seed ^ 0x0D);
        let g = random_graph(&mut rng);
        let coord = Coordinator::new(&g, m8());
        let k = 2 + rng.gen_range(6) as usize;
        let queries = planner::bfs_queries(&g, k.min(g.n() / 2).max(1), seed);
        let base = coord.run(&queries, Policy::Sequential).unwrap();
        let mut shuffled = queries.clone();
        rng.shuffle(&mut shuffled);
        let perm = coord.run(&shuffled, Policy::Sequential).unwrap();
        // Same total work, same makespan (stripe offsets permute with the
        // queries, but rotation never changes node totals).
        assert!(
            (base.makespan_s - perm.makespan_s).abs() / base.makespan_s < 1e-9,
            "seed {seed}: {} vs {}",
            base.makespan_s,
            perm.makespan_s
        );
    }
}

/// Tentpole property (priority-aware admission): under a queueing policy
/// with aging disabled, no query ever starts while a strictly
/// higher-priority query is waiting — in particular, no Standard query
/// starts while an Interactive one waits.
#[test]
fn prop_no_lower_class_starts_while_higher_class_waits() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x9107);
        let sim = FlowSim::new(m8());
        let nq = 4 + rng.gen_range(16) as usize;
        let cap = 1 + rng.gen_range(3) as usize;
        let specs: Vec<QuerySpec> = (0..nq)
            .map(|id| {
                let mut p = PhaseDemand::zero(8, 8);
                p.channel_ops[0] = 1e4 + rng.next_f64() * 1e4;
                p.per_channel_ops[0] = p.channel_ops[0];
                p.max_channel_ops[0] = p.channel_ops[0];
                p.parallelism = 100.0;
                let priority = match rng.gen_range(3) {
                    0 => Priority::Interactive,
                    1 => Priority::Standard,
                    _ => Priority::Batch,
                };
                QuerySpec::new(id, "rand", vec![p], rng.next_f64() * 1e5)
                    .with_priority(priority)
            })
            .collect();
        let adm = Admission::capped(cap, OnFull::Queue).with_age_promote_ns(f64::INFINITY);
        let rep = sim.run_admitted(&specs, adm);
        // Every query completes; the order respects strict priority: a
        // query must not start while a higher-priority one that had
        // already arrived is still waiting to start.
        for s in &specs {
            assert!(rep.timings[s.id].finish_ns.is_finite(), "seed {seed}");
        }
        for lo in &specs {
            for hi in &specs {
                if hi.priority >= lo.priority {
                    continue; // hi must be a strictly better class
                }
                let lo_start = rep.timings[lo.id].start_ns;
                let hi_start = rep.timings[hi.id].start_ns;
                assert!(
                    !(hi.arrival_ns <= lo_start && hi_start > lo_start),
                    "seed {seed}: {:?} q{} started at {lo_start} while {:?} q{} \
                     (arrived {}, started {hi_start}) was waiting",
                    lo.priority,
                    lo.id,
                    hi.priority,
                    hi.id,
                    hi.arrival_ns,
                );
            }
        }
    }
}

/// Aging bound: with `age_promote_ns = A`, a queued Batch query's wait is
/// bounded by A plus the work already in service plus the backlog that
/// enqueued *before* it — the later Interactive stream cannot push it back
/// indefinitely once it has aged (under strict priority it would go last).
#[test]
fn prop_aging_bounds_batch_wait() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xA9E);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut specs: Vec<QuerySpec> = Vec::new();
        // id 0: a Standard query in service; id 1: the Batch query stuck
        // behind it; ids 2..: a stream of Interactive arrivals that would
        // starve Batch under strict priority.
        for id in 0..12 {
            let mut p = PhaseDemand::zero(8, 8);
            p.channel_ops[0] = 1e4;
            p.per_channel_ops[0] = 1e4;
            p.max_channel_ops[0] = 1e4;
            p.parallelism = 100.0;
            let (priority, arrival) = match id {
                0 => (Priority::Standard, 0.0),
                1 => (Priority::Batch, 0.0),
                _ => (Priority::Interactive, rng.next_f64() * 4e5),
            };
            specs.push(
                QuerySpec::new(id, "rand", vec![p], arrival).with_priority(priority),
            );
        }
        let service_ns = specs[0].solo_ns(&m); // identical service times
        let age = 2e5;
        let rep = sim.run_admitted(
            &specs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(age),
        );
        let batch_wait = rep.timings[1].start_ns - specs[1].arrival_ns;
        // Once promoted (after `age`), the batch query is FIFO-first among
        // the promoted/Interactive class (earliest enqueue), so it starts
        // at the next completion: at most `age` plus the in-service query
        // plus one more that slipped in before promotion.
        let bound = age + 2.0 * service_ns + 1.0;
        assert!(
            batch_wait <= bound,
            "seed {seed}: batch waited {batch_wait} ns, bound {bound}"
        );
    }
}

/// A latency-bound phase consuming `frac` of every channel uniformly —
/// uniformity makes saturated completion times closed-form (see the
/// weighted-shares property below).
fn uniform_phase(m: &Machine, frac: f64, total_ns: f64) -> PhaseDemand {
    PhaseDemand::uniform_channel_load(m, frac, total_ns)
}

/// Tentpole property (weighted fair share): under saturation, realized
/// per-class bandwidth follows the configured weights. With `n_c`
/// identical single-phase queries per class `c`, each with per-channel
/// drain `D = frac x total_ns`, progressive filling gives every class the
/// rate `w_c x level` until the heaviest class completes — so the heaviest
/// class finishes at exactly `Σ_c n_c w_c x D / w_max` (solo time cancels),
/// and mean latencies order inversely to the weights.
#[test]
fn prop_weighted_shares_converge_to_configured_weights() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x3E1);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        // Strictly ordered random weights and random per-class counts.
        let wb = 1.0 + rng.gen_range(3) as f64;
        let ws = wb + 1.0 + rng.gen_range(3) as f64;
        let wi = ws + 1.0 + rng.gen_range(3) as f64;
        let counts = [
            4 + rng.gen_range(4) as usize,
            4 + rng.gen_range(4) as usize,
            4 + rng.gen_range(4) as usize,
        ];
        let drain_ns = 0.5e6; // frac x total_ns per channel per query
        let mut specs = Vec::new();
        for (ci, &class) in Priority::ALL.iter().enumerate() {
            for _ in 0..counts[ci] {
                let id = specs.len();
                specs.push(
                    QuerySpec::new(id, "w", vec![uniform_phase(&m, 0.5, 1e6)], 0.0)
                        .with_priority(class),
                );
            }
        }
        let weights = ShareWeights { interactive: wi, standard: ws, batch: wb };
        let rep = sim.run_admitted(&specs, Admission::unlimited().with_weights(weights));
        assert!(rep.timings.iter().all(|t| t.completed()), "seed {seed}");
        // Closed form for the heaviest class's completion time.
        let denom = (counts[0] as f64 * wi + counts[1] as f64 * ws + counts[2] as f64 * wb)
            * drain_ns;
        let expect_int_ns = denom / wi;
        let got_int_s =
            rep.class_mean_latency_s(Priority::Interactive).expect("interactive completed");
        assert!(
            (got_int_s * 1e9 - expect_int_ns).abs() / expect_int_ns < 0.02,
            "seed {seed}: interactive latency {got_int_s}s vs closed form {expect_int_ns}ns \
             (weights {wi}:{ws}:{wb}, counts {counts:?})"
        );
        // Realized service orders inversely to the weights, strictly.
        let mean = |p: Priority| rep.class_mean_latency_s(p).expect("class completed");
        assert!(
            mean(Priority::Interactive) < mean(Priority::Standard)
                && mean(Priority::Standard) < mean(Priority::Batch),
            "seed {seed}: means must order by weight: {} / {} / {}",
            mean(Priority::Interactive),
            mean(Priority::Standard),
            mean(Priority::Batch)
        );
    }
}

/// Preemption keeps every invariant admission already had: dispositions
/// still partition the batch, parked work always resumes and completes,
/// only victim-class queries are ever parked, and the byte ledger's
/// high-water mark respects the budget throughout.
#[test]
fn prop_preemption_preserves_partition_and_ledger_bounds() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x9A2E);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let nq = 2 + rng.gen_range(14) as usize;
        let byte_cap = 120u64;
        let specs: Vec<QuerySpec> = (0..nq)
            .map(|id| {
                let phases = (0..1 + rng.gen_range(3) as usize)
                    .map(|_| {
                        uniform_phase(&m, 0.2 + rng.next_f64() * 0.4, 2e5 + rng.next_f64() * 8e5)
                    })
                    .collect();
                let mut q = QuerySpec::new(id, "p", phases, rng.next_f64() * 2e6)
                    .with_ctx_bytes(20 + rng.gen_range(60))
                    .with_priority(match rng.gen_range(3) {
                        0 => Priority::Interactive,
                        1 => Priority::Standard,
                        _ => Priority::Batch,
                    });
                if rng.gen_range(3) == 0 {
                    q = q.with_deadline_ns(rng.next_f64() * 5e6);
                }
                q
            })
            .collect();
        let adm = Admission::byte_budget(byte_cap, OnFull::Queue)
            .with_preempt(PreemptPolicy::default());
        let rep = sim.run_admitted(&specs, adm);
        let done = rep.timings.iter().filter(|t| t.completed()).count();
        assert_eq!(
            done + rep.rejected.len() + rep.shed.len(),
            nq,
            "seed {seed}: dispositions must partition"
        );
        assert!(rep.peak_ctx_bytes <= byte_cap, "seed {seed}");
        assert_eq!(rep.parks, rep.resumes, "seed {seed}: every park must resume");
        for &id in &rep.preempted {
            assert!(rep.timings[id].completed(), "seed {seed}: parked work must complete");
            assert_eq!(
                specs[id].priority,
                Priority::Batch,
                "seed {seed}: only the victim class may be parked"
            );
        }
        assert!(
            rep.mean_latency_s().is_none_or(|s| s.is_finite()),
            "seed {seed}"
        );
    }
}

/// Admission partitions queries across all three dispositions: completed +
/// rejected + shed = submitted, with byte budgets and deadlines active.
#[test]
fn prop_admission_dispositions_partition_queries() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xD15);
        let sim = FlowSim::new(m8());
        let nq = 1 + rng.gen_range(20) as usize;
        let byte_cap = 100u64;
        let specs: Vec<QuerySpec> = (0..nq)
            .map(|id| {
                let mut p = PhaseDemand::zero(8, 8);
                p.channel_ops[0] = 1e4;
                p.per_channel_ops[0] = 1e4;
                p.max_channel_ops[0] = 1e4;
                p.parallelism = 100.0;
                let mut q = QuerySpec::new(id, "rand", vec![p], rng.next_f64() * 1e6)
                    .with_ctx_bytes(20 + rng.gen_range(120))
                    .with_priority(match rng.gen_range(3) {
                        0 => Priority::Interactive,
                        1 => Priority::Standard,
                        _ => Priority::Batch,
                    });
                if rng.gen_range(2) == 0 {
                    q = q.with_deadline_ns(rng.next_f64() * 2e5);
                }
                q
            })
            .collect();
        for on_full in
            [OnFull::Queue, OnFull::Reject, OnFull::Shed { max_waiting: 1 + seed as usize % 4 }]
        {
            let rep = sim.run_admitted(&specs, Admission::byte_budget(byte_cap, on_full));
            let done = rep.timings.iter().filter(|t| t.completed()).count();
            assert_eq!(
                done + rep.rejected.len() + rep.shed.len(),
                nq,
                "seed {seed} {on_full:?}: dispositions must partition"
            );
            // Oversized specs are always rejected, never run or queued.
            for s in specs.iter().filter(|s| s.ctx_bytes > byte_cap) {
                assert!(rep.rejected.contains(&s.id), "seed {seed} {on_full:?}");
            }
            // NaN-free aggregate stats even with rejections/sheds present
            // (None, not a fake 0.0, when nothing completed at all).
            assert!(
                rep.mean_latency_s().is_none_or(|s| s.is_finite()),
                "seed {seed} {on_full:?}"
            );
            assert!(rep.latencies_s().iter().all(|l| l.is_finite()));
        }
    }
}

/// Random traced-engine workload shared by the observability properties:
/// mixed classes, multi-phase, jittered arrivals, context footprints, and
/// an occasional deadline — the same shape the preemption property uses.
fn trace_prop_specs(rng: &mut SplitMix64, m: &Machine) -> Vec<QuerySpec> {
    let nq = 2 + rng.gen_range(14) as usize;
    (0..nq)
        .map(|id| {
            let phases = (0..1 + rng.gen_range(3) as usize)
                .map(|_| {
                    uniform_phase(m, 0.2 + rng.next_f64() * 0.4, 2e5 + rng.next_f64() * 8e5)
                })
                .collect();
            let mut q = QuerySpec::new(id, "t", phases, rng.next_f64() * 2e6)
                .with_ctx_bytes(20 + rng.gen_range(60))
                .with_priority(Priority::ALL[rng.gen_range(3) as usize]);
            if rng.gen_range(3) == 0 {
                q = q.with_deadline_ns(rng.next_f64() * 5e6);
            }
            q
        })
        .collect()
}

/// Every number in two [`FlowReport`]s compared exactly — f64s via
/// `to_bits`, so even a NaN-for-NaN or -0.0/+0.0 swap is a failure.
fn assert_reports_bit_identical(a: &FlowReport, b: &FlowReport, seed: u64) {
    assert_eq!(a.timings.len(), b.timings.len(), "seed {seed}: timing count");
    for (x, y) in a.timings.iter().zip(&b.timings) {
        assert_eq!(x.id, y.id, "seed {seed}");
        assert_eq!(x.label, y.label, "seed {seed}: label of {}", x.id);
        assert_eq!(
            x.arrival_ns.to_bits(),
            y.arrival_ns.to_bits(),
            "seed {seed}: arrival of {}",
            x.id
        );
        assert_eq!(
            x.start_ns.to_bits(),
            y.start_ns.to_bits(),
            "seed {seed}: start of {}",
            x.id
        );
        assert_eq!(
            x.finish_ns.to_bits(),
            y.finish_ns.to_bits(),
            "seed {seed}: finish of {}",
            x.id
        );
        assert_eq!(x.phases, y.phases, "seed {seed}");
        assert_eq!(x.priority, y.priority, "seed {seed}");
        assert_eq!(x.admitted_as, y.admitted_as, "seed {seed}: admitted_as of {}", x.id);
    }
    assert_eq!(
        a.makespan_ns.to_bits(),
        b.makespan_ns.to_bits(),
        "seed {seed}: makespan"
    );
    assert_eq!(a.counters, b.counters, "seed {seed}: counters");
    assert_eq!(a.peak_concurrency, b.peak_concurrency, "seed {seed}");
    assert_eq!(a.rejected, b.rejected, "seed {seed}: rejected ids");
    assert_eq!(a.shed, b.shed, "seed {seed}: shed ids");
    assert_eq!(a.peak_ctx_bytes, b.peak_ctx_bytes, "seed {seed}");
    assert_eq!(a.preempted, b.preempted, "seed {seed}: preempted ids");
    assert_eq!(a.parks, b.parks, "seed {seed}");
    assert_eq!(a.resumes, b.resumes, "seed {seed}");
    assert_eq!(a.weights, b.weights, "seed {seed}");
    assert_eq!(a.events, b.events, "seed {seed}: event count");
}

/// The load-bearing observability invariant (DESIGN.md §Observability):
/// tracing is observation only. A run recording into a [`TraceBuffer`]
/// must produce a [`FlowReport`] bit-identical to the same run on the
/// zero-cost `NullSink` default — across random workloads exercising byte
/// budgets, weights, preemption, deadlines, and all three overflow modes.
#[test]
fn prop_traced_run_is_bit_identical_to_untraced() {
    use pathfinder_queries::sim::trace::TraceBuffer;
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x7ACE);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let specs = trace_prop_specs(&mut rng, &m);
        for on_full in
            [OnFull::Queue, OnFull::Reject, OnFull::Shed { max_waiting: 1 + seed as usize % 4 }]
        {
            let adm = Admission::byte_budget(120, on_full)
                .with_weights(ShareWeights::priority_weighted())
                .with_preempt(PreemptPolicy::default());
            let plain = sim.run_admitted(&specs, adm);
            let mut buf = TraceBuffer::new();
            let traced = sim.run_admitted_traced(&specs, adm, &mut buf);
            assert_reports_bit_identical(&plain, &traced, seed);
            assert!(!buf.events.is_empty(), "seed {seed}: traced run must record events");
        }
        // The sequential baseline path is traced too.
        let plain = sim.run_sequential(&specs);
        let mut buf = TraceBuffer::new();
        let traced = sim.run_sequential_traced(&specs, &mut buf);
        assert_reports_bit_identical(&plain, &traced, seed);
    }
}

/// Trace↔report reconciliation: the event stream and the [`FlowReport`]
/// are two views of one run, so they must agree exactly — the `events`
/// counter decomposes into admits + phase retirements + parks + resumes,
/// shed/rejected id sequences equal the event stream's, every query
/// reaches exactly one terminal event (finish, shed, or reject) matching
/// its report disposition, and the preempted set is exactly the ids that
/// parked.
#[test]
fn prop_trace_reconciles_with_flow_report() {
    use pathfinder_queries::sim::trace::TraceBuffer;
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x0B5);
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let specs = trace_prop_specs(&mut rng, &m);
        for on_full in
            [OnFull::Queue, OnFull::Reject, OnFull::Shed { max_waiting: 1 + seed as usize % 4 }]
        {
            let adm = Admission::byte_budget(120, on_full)
                .with_preempt(PreemptPolicy::default());
            let mut buf = TraceBuffer::new();
            let rep = sim.run_admitted_traced(&specs, adm, &mut buf);
            let counts = buf.counts_by_kind();
            let n =
                |k: &str| counts.iter().find(|&&(kk, _)| kk == k).map_or(0, |&(_, c)| c);
            assert_eq!(
                n("arrival"),
                specs.len(),
                "seed {seed} {on_full:?}: one arrival per submitted query"
            );
            assert_eq!(
                rep.events,
                n("admit") + n("phase_end") + n("park") + n("resume"),
                "seed {seed} {on_full:?}: events counter must decompose over the trace"
            );
            assert_eq!(rep.parks, n("park"), "seed {seed} {on_full:?}");
            assert_eq!(rep.resumes, n("resume"), "seed {seed} {on_full:?}");
            let ids_of = |kind: &str| -> Vec<usize> {
                buf.events
                    .iter()
                    .filter(|e| e.kind() == kind)
                    .filter_map(|e| e.query_id())
                    .collect()
            };
            // Shed/rejected report sequences ARE the event sequences.
            assert_eq!(rep.shed, ids_of("shed"), "seed {seed} {on_full:?}: shed ids");
            assert_eq!(
                rep.rejected,
                ids_of("reject"),
                "seed {seed} {on_full:?}: rejected ids"
            );
            // Exactly one terminal event per query, agreeing with the
            // report's disposition.
            let mut terminal = vec![0usize; specs.len()];
            let mut finished = vec![false; specs.len()];
            for e in &buf.events {
                match e.kind() {
                    "finish" => {
                        let id = e.query_id().unwrap();
                        terminal[id] += 1;
                        finished[id] = true;
                    }
                    "shed" | "reject" => terminal[e.query_id().unwrap()] += 1,
                    _ => {}
                }
            }
            for (id, &t) in terminal.iter().enumerate() {
                assert_eq!(
                    t, 1,
                    "seed {seed} {on_full:?}: query {id} must reach exactly one terminal event"
                );
                assert_eq!(
                    rep.timings[id].completed(),
                    finished[id],
                    "seed {seed} {on_full:?}: disposition of query {id}"
                );
            }
            // The preempted set is exactly the ids that parked.
            let mut parked = ids_of("park");
            parked.sort_unstable();
            parked.dedup();
            let mut preempted = rep.preempted.clone();
            preempted.sort_unstable();
            assert_eq!(preempted, parked, "seed {seed} {on_full:?}: preempted ids");
        }
    }
}

/// Snapshot isolation (DESIGN.md §Mutation): a query pinned to epoch *e*
/// computes — and validates against its host oracle — on epoch *e*'s exact
/// edge set, while later batches apply and compaction runs underneath it.
/// The reference edge set is maintained independently by replaying the
/// update stream, so the store, the overlay fold, and compaction are all
/// checked against ground truth.
#[test]
fn prop_pinned_epoch_queries_are_snapshot_isolated() {
    use pathfinder_queries::graph::delta::{random_batch, UpdateOp};
    use pathfinder_queries::graph::store::GraphStore;

    let m = m8();
    for seed in 0..CASES / 2 {
        let mut rng = SplitMix64::new(seed ^ 0x5A9);
        let g = random_graph(&mut rng);
        let mut store = GraphStore::new(&g);
        // Ground truth per epoch: replayed undirected edge sets.
        let mut edges: std::collections::BTreeSet<(u32, u32)> = (0..g.n() as u32)
            .flat_map(|u| g.neighbors(u).iter().map(move |&v| (u.min(v), u.max(v))))
            .collect();
        let mut truth = vec![build_undirected_csr(g.n(), &edges.iter().copied().collect::<Vec<_>>())];

        // Pin epoch 0 as a long-running query would.
        let pinned_epoch = store.pin();
        let src = rng.gen_range(g.n() as u64) as u32;
        let out_before = alg::Bfs { src }.run(store.view_at(pinned_epoch).unwrap(), &m);

        for _ in 0..5 {
            let batch = random_batch(store.view(), 12, 0.4, &mut rng);
            for upd in &batch {
                let key = upd.normalized();
                match upd.op {
                    UpdateOp::Insert => edges.insert(key),
                    UpdateOp::Delete => edges.remove(&key),
                };
            }
            store.apply_batch(&batch);
            truth.push(build_undirected_csr(g.n(), &edges.iter().copied().collect::<Vec<_>>()));
            // Compaction may run at any time; it must not disturb the pin.
            store.compact();
        }
        assert_eq!(store.base_epoch(), 0, "seed {seed}: pinned epoch survived compaction");

        // Every still-viewable epoch matches its replayed ground truth.
        for (e, expect) in truth.iter().enumerate() {
            let view = store.view_at(e as u64).unwrap_or_else(|err| panic!("seed {seed}: {err}"));
            assert_eq!(&view.to_csr(), expect, "seed {seed} epoch {e}");
        }

        // The pinned query's world is frozen: same BFS result, and it
        // validates against the oracle run on the pinned epoch's edge set
        // — even though 5 batches landed since.
        let pinned_view = store.view_at(pinned_epoch).unwrap();
        let out_after = alg::Bfs { src }.run(pinned_view, &m);
        assert_eq!(out_before.values, out_after.values, "seed {seed}");
        alg::Bfs { src }
            .validate(pinned_view, &out_after.values)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        oracle::check_bfs(&truth[0], src, &out_after.values)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        // Release the pin: compaction now folds everything, the newest
        // epoch still matches truth, and the pinned epoch is retired.
        store.unpin(pinned_epoch);
        let c = store.compact();
        assert_eq!(c.drained, 5, "seed {seed}");
        assert_eq!(&store.view().to_csr(), truth.last().unwrap(), "seed {seed}");
        assert!(store.view_at(pinned_epoch).is_err() || pinned_epoch == store.base_epoch());
    }
}

/// Partition invariants (DESIGN.md §Fleet): on random graphs, for both
/// strategies and a range of shard counts, every vertex has exactly one
/// owner, owned arcs are conserved across shards, cut accounting is
/// self-consistent — and the degree-balanced strategy's max−min owned-arc
/// spread is bounded by the maximum degree (the LPT greedy bound, since
/// each placement moves one vertex's degree).
#[test]
fn prop_partition_invariants_hold_on_random_graphs() {
    use pathfinder_queries::graph::partition::{Partition, PartitionStrategy};

    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x9A27);
        let g = random_graph(&mut rng);
        for shards in [1usize, 2, 3, 5, 8] {
            for strategy in [PartitionStrategy::Hash, PartitionStrategy::Balanced] {
                let p = Partition::build(&g, shards, strategy);
                p.check_invariants(&g)
                    .unwrap_or_else(|e| panic!("seed {seed} {shards}x{strategy:?}: {e}"));
                // Exactly one owner per vertex, in range.
                for v in 0..g.n() as u32 {
                    assert!(p.owner_of(v) < shards, "seed {seed}: owner out of range");
                }
                // Arcs conserved: every directed arc owned exactly once.
                let owned: usize = (0..shards).map(|s| p.shard_arcs(s)).sum();
                assert_eq!(owned, g.m_directed(), "seed {seed} {shards}x{strategy:?}");
                // Cut arcs are symmetric in total: each cut arc (u,v) has
                // a mirror (v,u) that is also cut, so the sum is even.
                let cut: usize = (0..shards).map(|s| p.cut_arcs(s)).sum();
                assert_eq!(cut % 2, 0, "seed {seed}: cut arcs must mirror");
                assert!(p.cut_fraction() >= 0.0 && p.cut_fraction() <= 1.0);
                if shards == 1 {
                    assert_eq!(cut, 0, "seed {seed}: one shard cuts nothing");
                }
                if strategy == PartitionStrategy::Balanced {
                    assert!(
                        p.arc_spread() <= g.max_degree(),
                        "seed {seed} shards {shards}: spread {} above max degree {}",
                        p.arc_spread(),
                        g.max_degree()
                    );
                }
            }
        }
    }
}

/// Fleet-vs-single-node equivalence (DESIGN.md §Fleet): identical batch
/// sequences applied to a [`ReplicaSet`] (every replica of every shard fed
/// by one ordered log) and to the global single-node store produce the
/// same epoch numbering, the same materialized graph at every epoch from
/// every replica — and therefore the same analysis answers bound to the
/// same snapshot id, regardless of shard count, replica count or strategy.
#[test]
fn prop_fleet_replicas_track_the_global_store() {
    use pathfinder_queries::coordinator::ReplicaSet;
    use pathfinder_queries::graph::delta::random_batch;
    use pathfinder_queries::graph::partition::{Partition, PartitionStrategy};
    use pathfinder_queries::graph::store::GraphStore;

    let m = m8();
    for seed in 0..CASES / 4 {
        let mut rng = SplitMix64::new(seed ^ 0xF1EE);
        let g = random_graph(&mut rng);
        for (shards, replicas) in [(1usize, 1usize), (2, 2), (3, 1), (5, 2)] {
            for strategy in [PartitionStrategy::Hash, PartitionStrategy::Balanced] {
                let part = Partition::build(&g, shards, strategy);
                let mut rs = ReplicaSet::new(part, replicas);
                let mut global = GraphStore::new(&g);
                let batches: Vec<Vec<_>> = (0..3)
                    .map(|_| random_batch(global.view(), 8, 0.3, &mut rng))
                    .collect();
                for b in &batches {
                    let fleet_epoch = rs.apply_batch(b);
                    let global_epoch = global.apply_batch(b).epoch;
                    assert_eq!(
                        fleet_epoch, global_epoch,
                        "seed {seed} {shards}x{replicas} {strategy:?}: log out of step"
                    );
                }
                for epoch in 0..=batches.len() as u64 {
                    let want = global.view_at(epoch).unwrap().to_csr();
                    for r in 0..replicas {
                        let got = rs.materialize(epoch, r).unwrap();
                        assert_eq!(
                            got, want,
                            "seed {seed} {shards}x{replicas} {strategy:?} epoch {epoch} \
                             replica {r}"
                        );
                        // Same snapshot, same answers: a query served by
                        // any replica at this epoch returns the global
                        // store's result.
                        let src = rng.gen_range(g.n() as u64) as u32;
                        let a = alg::Bfs { src };
                        assert_eq!(
                            a.run(got.view(), &m).values,
                            a.run(want.view(), &m).values,
                            "seed {seed} epoch {epoch} replica {r}"
                        );
                    }
                }
            }
        }
    }
}

/// Delete-heavy mutation stress (DESIGN.md §Mutation): a store fed mostly
/// deletions piles up tombstone overlays, keeps every epoch's view equal
/// to an independently replayed edge set, fully empties a targeted vertex
/// (the oracle still validates a traversal rooted there), and compaction
/// folds the tombstones away without changing the visible graph.
#[test]
fn prop_delete_heavy_mutation_keeps_views_exact() {
    use pathfinder_queries::graph::delta::{random_batch, EdgeUpdate, UpdateOp};
    use pathfinder_queries::graph::store::GraphStore;

    let m = m8();
    for seed in 0..CASES / 2 {
        let mut rng = SplitMix64::new(seed ^ 0xDE1E);
        let g = random_graph(&mut rng);
        let mut store = GraphStore::new(&g);
        let mut edges: std::collections::BTreeSet<(u32, u32)> = (0..g.n() as u32)
            .flat_map(|u| g.neighbors(u).iter().map(move |&v| (u.min(v), u.max(v))))
            .collect();

        // Delete-heavy stream: 90% deletions, replayed into ground truth.
        let mut batches = 0usize;
        for _ in 0..6 {
            let batch = random_batch(store.view(), 10, 0.9, &mut rng);
            for upd in &batch {
                let key = upd.normalized();
                match upd.op {
                    UpdateOp::Insert => edges.insert(key),
                    UpdateOp::Delete => edges.remove(&key),
                };
            }
            store.apply_batch(&batch);
            batches += 1;
        }
        // Fully empty the heaviest vertex with explicit tombstones.
        let hub = (0..g.n() as u32)
            .max_by_key(|&v| store.view().degree(v))
            .unwrap();
        let kill: Vec<EdgeUpdate> = {
            let mut scratch = pathfinder_queries::graph::view::NeighborScratch::default();
            store
                .view()
                .neighbors(hub, &mut scratch)
                .iter()
                .map(|&v| EdgeUpdate::delete(hub, v))
                .collect()
        };
        for upd in &kill {
            edges.remove(&upd.normalized());
        }
        store.apply_batch(&kill);
        batches += 1;

        // Tombstones pile up as live overlays until compaction.
        assert_eq!(store.live_overlays(), batches, "seed {seed}");
        let view = store.view();
        assert_eq!(view.degree(hub), 0, "seed {seed}: hub must be fully emptied");
        let expect =
            build_undirected_csr(g.n(), &edges.iter().copied().collect::<Vec<_>>());
        assert_eq!(view.to_csr(), expect, "seed {seed}: overlay view vs replayed truth");

        // The oracle covers the fully-emptied vertex: a BFS rooted there
        // reaches exactly itself, on the overlay view and after the fold.
        let out = alg::Bfs { src: hub }.run(view, &m);
        alg::Bfs { src: hub }
            .validate(view, &out.values)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(out.values.iter().filter(|&&l| l >= 0).count(), 1, "seed {seed}");

        let c = store.compact();
        assert_eq!(c.drained, batches, "seed {seed}: every tombstone overlay folds");
        assert_eq!(store.live_overlays(), 0, "seed {seed}");
        assert_eq!(store.view().to_csr(), expect, "seed {seed}: fold changed the graph");
        let after = alg::Bfs { src: hub }.run(store.view(), &m);
        assert_eq!(out.values, after.values, "seed {seed}: answers survive the fold");
    }
}

/// Field-by-field BITWISE comparison of two flow reports — the PR 7
/// equivalence tolerance is zero, not epsilon: the incremental solver
/// must produce the exact f64s the dense reference produces.
fn assert_reports_bitwise_equal(a: &FlowReport, b: &FlowReport, ctx: &str) {
    assert_eq!(a.makespan_ns.to_bits(), b.makespan_ns.to_bits(), "{ctx}: makespan");
    assert_eq!(a.events, b.events, "{ctx}: events");
    assert_eq!(a.peak_concurrency, b.peak_concurrency, "{ctx}: peak concurrency");
    assert_eq!(a.peak_ctx_bytes, b.peak_ctx_bytes, "{ctx}: peak ctx bytes");
    assert_eq!(a.rejected, b.rejected, "{ctx}: rejected");
    assert_eq!(a.shed, b.shed, "{ctx}: shed");
    assert_eq!(a.preempted, b.preempted, "{ctx}: preempted");
    assert_eq!(a.parks, b.parks, "{ctx}: parks");
    assert_eq!(a.resumes, b.resumes, "{ctx}: resumes");
    assert_eq!(a.timings.len(), b.timings.len(), "{ctx}: timing count");
    for (ta, tb) in a.timings.iter().zip(&b.timings) {
        assert_eq!(ta.id, tb.id, "{ctx}");
        assert_eq!(
            ta.arrival_ns.to_bits(),
            tb.arrival_ns.to_bits(),
            "{ctx}: q{} arrival",
            ta.id
        );
        assert_eq!(ta.start_ns.to_bits(), tb.start_ns.to_bits(), "{ctx}: q{} start", ta.id);
        assert_eq!(ta.finish_ns.to_bits(), tb.finish_ns.to_bits(), "{ctx}: q{} finish", ta.id);
        assert_eq!(ta.phases, tb.phases, "{ctx}: q{} phases", ta.id);
        assert_eq!(ta.priority, tb.priority, "{ctx}: q{} priority", ta.id);
        assert_eq!(ta.admitted_as, tb.admitted_as, "{ctx}: q{} admitted_as", ta.id);
    }
    let ca = &a.counters;
    let cb = &b.counters;
    for (xs, ys, name) in [
        (&ca.channel_ops, &cb.channel_ops, "channel_ops"),
        (&ca.stream_bytes, &cb.stream_bytes, "stream_bytes"),
        (&ca.instructions, &cb.instructions, "instructions"),
        (&ca.fabric_bytes, &cb.fabric_bytes, "fabric_bytes"),
        (&ca.migrations, &cb.migrations, "migrations"),
        (&ca.msp_ops, &cb.msp_ops, "msp_ops"),
    ] {
        assert_eq!(xs.len(), ys.len(), "{ctx}: {name} length");
        for (i, (x, y)) in xs.iter().zip(ys.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}[{i}]");
        }
    }
    assert_eq!(ca.elapsed_ns.to_bits(), cb.elapsed_ns.to_bits(), "{ctx}: elapsed");
}

/// Random admission-trace scenario exercising every engine feature the
/// solvers must agree on: mixed weights, byte budgets, deadlines,
/// checkpoint preemption, shedding, aging, channel skew, and (on half the
/// cases) a flattened 2-chassis fleet so the interconnect — the sixth
/// resource kind — is in play.
fn random_admission_scenario(
    rng: &mut SplitMix64,
) -> (Machine, Vec<QuerySpec>, Admission) {
    use pathfinder_queries::sim::cluster::Cluster;

    let fleet = rng.gen_range(2) == 0;
    let m = if fleet {
        Cluster::new(&MachineConfig::pathfinder_8(), 2, 1).machine().clone()
    } else {
        m8()
    };
    let nq = 4 + rng.gen_range(16) as usize;
    let specs: Vec<QuerySpec> = (0..nq)
        .map(|id| {
            let phases = (0..1 + rng.gen_range(3) as usize)
                .map(|_| {
                    let frac = 0.2 + rng.next_f64() * 0.5;
                    let total = 2e5 + rng.next_f64() * 8e5;
                    let p = if fleet && rng.gen_range(3) == 0 {
                        PhaseDemand::uniform_fleet_load(&m, frac, total, total)
                    } else {
                        PhaseDemand::uniform_channel_load(&m, frac, total)
                    };
                    // Skew so the hottest-channel resource can bind.
                    p.rotate_channels(rng.gen_range(8) as usize)
                })
                .collect();
            let mut q = QuerySpec::new(id, "eq", phases, rng.next_f64() * 2e6)
                .with_ctx_bytes(20 + rng.gen_range(60))
                .with_priority(Priority::ALL[rng.gen_range(3) as usize]);
            if rng.gen_range(4) == 0 {
                q = q.with_deadline_ns(rng.next_f64() * 5e6);
            }
            q
        })
        .collect();
    let adm = match rng.gen_range(4) {
        0 => Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
        1 => Admission::byte_budget(120, OnFull::Queue)
            .with_weights(ShareWeights::priority_weighted())
            .with_preempt(PreemptPolicy::default()),
        2 => Admission::byte_budget(
            150,
            OnFull::Shed { max_waiting: 1 + rng.gen_range(4) as usize },
        ),
        _ => Admission::capped(1 + rng.gen_range(4) as usize, OnFull::Queue)
            .with_age_promote_ns(1e5 + rng.next_f64() * 1e6),
    };
    (m, specs, adm)
}

/// Tentpole property (PR 7 equivalence satellite): the event-scoped
/// incremental solver and the dense per-component reference produce
/// IDENTICAL reports — every timing, counter, and disposition, compared
/// bit-for-bit with tolerance zero — across randomized admit / finish /
/// park / resume / shed traces. The two modes share one component solve;
/// the incremental mode merely *skips* components no event touched, so
/// any divergence means the event-scoping missed a rate change.
#[test]
fn prop_incremental_matches_dense_reference_exactly() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0x1DE7);
        let (m, specs, adm) = random_admission_scenario(&mut rng);
        let inc = FlowSim::new(m.clone()).run_admitted(&specs, adm);
        let dense = FlowSim::new(m.clone())
            .with_solver_mode(SolverMode::Dense)
            .run_admitted(&specs, adm);
        assert_reports_bitwise_equal(&inc, &dense, &format!("seed {seed}"));
        // The trace must actually exercise the engine: at least one query
        // completes in every scenario.
        assert!(inc.timings.iter().any(|t| t.completed()), "seed {seed}: dead scenario");
    }
}

/// Determinism satellite (PR 7): repeat runs of the same scenario are
/// bit-identical — the solver iterates indexed vectors (never a
/// HashMap), so there is no iteration-order nondeterminism to leak into
/// rates, timings, or counters.
#[test]
fn prop_flow_runs_are_deterministic() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xDE7E);
        let (m, specs, adm) = random_admission_scenario(&mut rng);
        let sim = FlowSim::new(m);
        let first = sim.run_admitted(&specs, adm);
        for round in 1..3 {
            let again = sim.run_admitted(&specs, adm);
            assert_reports_bitwise_equal(
                &first,
                &again,
                &format!("seed {seed} repeat {round}"),
            );
        }
    }
}

/// Batching satellite (DESIGN.md §Batching): fused multi-source BFS
/// levels bit-match k independent single-source runs — on random R-MAT
/// graphs and on mutated overlay views — at random batch widths (1..=64)
/// and stripe offsets. The fused sweep shares migrations and edge scans
/// across the batch, but each member's answer must be EXACTLY what it
/// would have computed alone.
#[test]
fn prop_msbfs_bit_matches_independent_single_source_runs() {
    use pathfinder_queries::alg::msbfs_run_offset;
    use pathfinder_queries::config::workload::GraphConfig;
    use pathfinder_queries::graph::delta::random_batch;
    use pathfinder_queries::graph::rmat::Rmat;
    use pathfinder_queries::graph::store::GraphStore;

    let m = m8();
    for seed in 0..CASES / 2 {
        let mut rng = SplitMix64::new(seed ^ 0xB47C);
        let mut cfg = GraphConfig::with_scale(9);
        cfg.seed = seed;
        let g = build_undirected_csr(1 << 9, &Rmat::new(cfg).edges());
        let k = 1 + rng.gen_range(64) as usize;
        let sources: Vec<u32> =
            (0..k).map(|_| rng.gen_range(g.n() as u64) as u32).collect();
        let offset = rng.gen_range(16) as usize;

        let fused = msbfs_run_offset(&g, &m, &sources, offset);
        assert_eq!(fused.levels.len(), k, "seed {seed}");
        for (s, &src) in sources.iter().enumerate() {
            let solo = alg::bfs_run(&g, &m, src);
            assert_eq!(
                fused.levels[s], solo.levels,
                "seed {seed} width {k} src {src}: fused vs independent run"
            );
        }

        // Overlaid views (same-epoch batches run on a pinned snapshot):
        // the fused sweep over a mutated view must bit-match the
        // single-source oracle on that exact edge set.
        let mut store = GraphStore::new(&g);
        for _ in 0..2 {
            let batch = random_batch(store.view(), 12, 0.4, &mut rng);
            store.apply_batch(&batch);
        }
        let view = store.view();
        let over = msbfs_run_offset(view, &m, &sources, offset);
        for (s, &src) in sources.iter().enumerate() {
            assert_eq!(
                over.levels[s],
                oracle::bfs_levels(view, src),
                "seed {seed} src {src}: fused vs oracle on the overlay view"
            );
        }
    }
}

/// Epoch refcounting: compaction never retires an overlay any pin still
/// needs, under randomized interleavings of pin/unpin/apply/compact.
#[test]
fn prop_compaction_never_retires_a_pinned_overlay() {
    use pathfinder_queries::graph::delta::random_batch;
    use pathfinder_queries::graph::store::GraphStore;

    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed ^ 0xEC0);
        let g = random_graph(&mut rng);
        let mut store = GraphStore::new(&g);
        let mut pins: Vec<u64> = Vec::new();
        let mut snapshots: Vec<(u64, Csr)> = Vec::new();
        for _ in 0..24 {
            match rng.gen_range(4) {
                0 => {
                    let batch = random_batch(store.view(), 6, 0.3, &mut rng);
                    store.apply_batch(&batch);
                }
                1 => {
                    let e = store.pin();
                    pins.push(e);
                    snapshots.push((e, store.view_at(e).unwrap().to_csr()));
                }
                2 if !pins.is_empty() => {
                    let i = rng.gen_range(pins.len() as u64) as usize;
                    let e = pins.swap_remove(i);
                    store.unpin(e);
                    snapshots.retain(|(se, _)| *se != e || pins.contains(&e));
                }
                _ => {
                    store.compact();
                }
            }
            // Invariant: every pinned epoch is still viewable and reads
            // exactly the snapshot taken when it was pinned.
            if let Some(min_pin) = pins.iter().min() {
                assert!(
                    store.base_epoch() <= *min_pin,
                    "seed {seed}: base {} passed pin {min_pin}",
                    store.base_epoch()
                );
            }
            for (e, snap) in &snapshots {
                let v = store.view_at(*e).unwrap_or_else(|err| panic!("seed {seed}: {err}"));
                assert_eq!(&v.to_csr(), snap, "seed {seed} epoch {e}");
            }
        }
    }
}
