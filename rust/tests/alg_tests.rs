//! Algorithm integration tests: functional correctness against oracles on
//! a spread of graph shapes, plus the demand-accounting contracts the
//! simulator relies on.

use pathfinder_queries::alg::{self, oracle, Analysis, Bfs, Cc, KHop, PageRank, Sssp, TriCount};
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::sim::machine::Machine;

fn m8() -> Machine {
    Machine::new(MachineConfig::pathfinder_8())
}

fn m32() -> Machine {
    Machine::new(MachineConfig::pathfinder_32())
}

fn rmat(scale: u32, seed: u64) -> Csr {
    let mut cfg = GraphConfig::with_scale(scale);
    cfg.seed = seed;
    build_undirected_csr(1 << scale, &pathfinder_queries::graph::rmat::Rmat::new(cfg).edges())
}

/// Graph shapes that stress different algorithm paths.
fn zoo() -> Vec<(&'static str, Csr)> {
    let path: Vec<(u32, u32)> = (0..99u32).map(|i| (i, i + 1)).collect();
    let star: Vec<(u32, u32)> = (1..=64u32).map(|v| (0, v)).collect();
    let cycle: Vec<(u32, u32)> = (0..64u32).map(|i| (i, (i + 1) % 64)).collect();
    let clique: Vec<(u32, u32)> =
        (0..16u32).flat_map(|i| (i + 1..16).map(move |j| (i, j))).collect();
    let forest: Vec<(u32, u32)> = vec![(0, 1), (1, 2), (4, 5), (7, 8), (8, 9), (9, 10)];
    vec![
        ("path", build_undirected_csr(100, &path)),
        ("star", build_undirected_csr(65, &star)),
        ("cycle", build_undirected_csr(64, &cycle)),
        ("clique", build_undirected_csr(16, &clique)),
        ("forest", build_undirected_csr(12, &forest)),
        ("rmat", rmat(11, 77)),
        ("empty", build_undirected_csr(8, &[])),
    ]
}

#[test]
fn bfs_matches_oracle_on_zoo() {
    for m in [m8(), m32()] {
        for (name, g) in zoo() {
            for src in [0u32, (g.n() as u32 - 1) / 2] {
                let run = alg::bfs_run(&g, &m, src);
                oracle::check_bfs(&g, src, &run.levels)
                    .unwrap_or_else(|e| panic!("{name} src {src}: {e}"));
            }
        }
    }
}

#[test]
fn cc_matches_oracle_on_zoo() {
    for m in [m8(), m32()] {
        for (name, g) in zoo() {
            let run = alg::cc_run(&g, &m);
            oracle::check_cc(&g, &run.labels).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}

#[test]
fn bfs_offsets_do_not_change_results_or_totals() {
    let g = rmat(11, 3);
    let m = m8();
    let base = alg::bfs_run_offset(&g, &m, 7, 0);
    for offset in [1usize, 3, 9] {
        let run = alg::bfs_run_offset(&g, &m, 7, offset);
        assert_eq!(run.levels, base.levels);
        // Node totals identical; only channel placement rotates.
        for (a, b) in run.phases.iter().zip(&base.phases) {
            assert_eq!(a.channel_ops, b.channel_ops);
            assert_eq!(a.instructions, b.instructions);
        }
    }
}

#[test]
fn bfs_frontier_accounting() {
    let g = rmat(11, 5);
    let m = m8();
    let run = alg::bfs_run(&g, &m, 3);
    // Frontier sizes sum to reached vertices; level edges sum to the
    // degrees of reached vertices.
    let total_frontier: usize = run.frontier_sizes.iter().sum();
    assert_eq!(total_frontier, run.reached());
    let total_edges: usize = run.level_edges.iter().sum();
    let expect: usize = (0..g.n() as u32)
        .filter(|&v| run.levels[v as usize] != -1)
        .map(|v| g.degree(v))
        .sum();
    assert_eq!(total_edges, expect);
    // R-MAT frontier sizes rise then fall (the paper's "size varies
    // widely" observation needs a bulge).
    let peak = run.frontier_sizes.iter().copied().max().unwrap();
    assert!(peak > run.frontier_sizes[0]);
    assert!(peak > *run.frontier_sizes.last().unwrap());
}

#[test]
fn cc_demand_scales_with_iterations() {
    let g = rmat(10, 9);
    let m = m8();
    let run = alg::cc_run(&g, &m);
    // Every hook sweep charges exactly one MSP op per directed edge;
    // nothing else charges MSP ops.
    let msp: f64 = run.phases.iter().flat_map(|p| p.msp_ops.iter()).sum();
    assert_eq!(msp, (g.m_directed() * run.iterations) as f64);
    // Total label state converged.
    assert_eq!(run.components(), oracle::component_count(&oracle::cc_labels(&g)));
}

#[test]
fn analysis_api_round_trips_for_all_six_classes() {
    let g = rmat(10, 2);
    let m = m8();
    let analyses: Vec<Box<dyn Analysis>> = vec![
        Box::new(Bfs { src: 5 }),
        Box::new(Cc),
        Box::new(Sssp { src: 5 }),
        Box::new(KHop::new(5, 2)),
        Box::new(PageRank),
        Box::new(TriCount),
    ];
    for a in analyses {
        let out = a.run(g.view(), &m);
        a.validate(g.view(), &out.values).unwrap_or_else(|e| panic!("{}: {e}", a.describe()));
        assert_eq!(out.label, a.label());
        assert!(!out.phases.is_empty());
        assert!(out.solo_ns(&m) > 0.0);
    }
}

#[test]
fn sssp_matches_oracle_on_zoo() {
    for m in [m8(), m32()] {
        for (name, g) in zoo() {
            for src in [0u32, (g.n() as u32 - 1) / 2] {
                let run = alg::sssp_run(&g, &m, src);
                oracle::check_sssp(&g, src, &run.dist)
                    .unwrap_or_else(|e| panic!("{name} src {src}: {e}"));
            }
        }
    }
}

#[test]
fn khop_matches_oracle_on_zoo() {
    for m in [m8(), m32()] {
        for (name, g) in zoo() {
            for k in [1u32, 2, 5] {
                let run = alg::khop_run(&g, &m, 0, k);
                oracle::check_khop(&g, 0, k, &run.levels)
                    .unwrap_or_else(|e| panic!("{name} k {k}: {e}"));
            }
        }
    }
}

#[test]
fn pagerank_and_tricount_match_oracles_on_zoo() {
    let m = m8();
    for (name, g) in zoo() {
        let pr = alg::pagerank_run(&g, &m);
        oracle::check_pagerank(&g, &pr.ranks).unwrap_or_else(|e| panic!("{name}: {e}"));
        let tc = alg::tricount_run(&g, &m);
        assert_eq!(tc.triangles, oracle::triangle_total(&g), "{name}");
        match name {
            // Triangle-free shapes.
            "path" | "star" | "cycle" | "forest" => assert_eq!(tc.triangles, 0, "{name}"),
            // K16 holds C(16,3) triangles.
            "clique" => assert_eq!(tc.triangles, 560, "{name}"),
            _ => {}
        }
    }
}

#[test]
fn sssp_distances_dominate_hop_counts() {
    // Every edge weighs at least 1, so the weighted distance is bounded
    // below by the BFS level, and both agree on reachability.
    let g = rmat(10, 6);
    let m = m8();
    let bfs = alg::bfs_run(&g, &m, 9);
    let sssp = alg::sssp_run(&g, &m, 9);
    for v in 0..g.n() {
        assert_eq!(bfs.levels[v] == -1, sssp.dist[v] == -1, "vertex {v}");
        if bfs.levels[v] >= 0 {
            assert!(sssp.dist[v] >= bfs.levels[v], "vertex {v}");
        }
    }
}

#[test]
fn cc_on_32_nodes_has_longer_reduction_chain() {
    // The view-0 changed reduction is serial in node count (Fig. 2).
    let g = rmat(9, 4);
    let hops8 = alg::cc_run(&g, &m8()).phases[1].serial_hops;
    let hops32 = alg::cc_run(&g, &m32()).phases[1].serial_hops;
    assert_eq!(hops8, 7.0);
    assert_eq!(hops32, 31.0);
}

#[test]
fn unreachable_sources_are_cheap() {
    // An isolated vertex's BFS is a single tiny level.
    let g = build_undirected_csr(10, &[(1, 2), (2, 3)]);
    let m = m8();
    let run = alg::bfs_run(&g, &m, 0);
    assert_eq!(run.reached(), 1);
    assert_eq!(run.phases.len(), 1);
    let big = alg::bfs_run(&g, &m, 1);
    let t_small: f64 = run.phases.iter().map(|p| p.solo_ns(&m)).sum();
    let t_big: f64 = big.phases.iter().map(|p| p.solo_ns(&m)).sum();
    assert!(t_small < t_big);
}
