//! Simulator integration tests, most importantly the **cross-validation of
//! the flow (fluid) engine against the discrete-event engine** — the two
//! independent timing models must agree on single-query structure before
//! the flow engine's concurrency results can be trusted.

use pathfinder_queries::alg;
use pathfinder_queries::config::machine::MachineConfig;
use pathfinder_queries::config::workload::GraphConfig;
use pathfinder_queries::graph::builder::build_undirected_csr;
use pathfinder_queries::graph::csr::Csr;
use pathfinder_queries::sim::event::EventSim;
use pathfinder_queries::sim::flow::{FlowSim, QuerySpec};
use pathfinder_queries::sim::machine::Machine;

fn rmat(scale: u32, seed: u64) -> Csr {
    let mut cfg = GraphConfig::with_scale(scale);
    cfg.seed = seed;
    build_undirected_csr(1 << scale, &pathfinder_queries::graph::rmat::Rmat::new(cfg).edges())
}

fn m8() -> Machine {
    Machine::new(MachineConfig::pathfinder_8())
}

/// Flow solo BFS time vs the discrete-event engine on the same graph:
/// the two models were built independently (fluid demand vectors vs
/// explicit per-access queueing), so agreement within a small factor
/// validates both.
#[test]
fn flow_vs_event_bfs_within_factor() {
    let m = m8();
    let flow = FlowSim::new(m.clone());
    let mut event = EventSim::new(m.clone());
    for (scale, seed) in [(10u32, 3u64), (11, 5), (12, 9)] {
        let g = rmat(scale, seed);
        let src = pathfinder_queries::graph::sample::bfs_sources(&g, 1, 1)[0];
        let run = alg::bfs_run(&g, &m, src);
        let spec = QuerySpec::new(0, "bfs", run.phases, 0.0);
        let t_flow = flow.run(std::slice::from_ref(&spec)).makespan_ns;
        let ev = event.bfs(&g, src);
        assert_eq!(ev.values, run.levels, "functional agreement");
        let ratio = ev.elapsed_ns / t_flow;
        assert!(
            (0.2..=5.0).contains(&ratio),
            "scale {scale}: event {:.3e} ns vs flow {:.3e} ns (ratio {ratio:.2})",
            ev.elapsed_ns,
            t_flow
        );
    }
}

#[test]
fn flow_vs_event_cc_within_factor() {
    let m = m8();
    let flow = FlowSim::new(m.clone());
    let mut event = EventSim::new(m.clone());
    let g = rmat(10, 21);
    let run = alg::cc_run(&g, &m);
    let spec = QuerySpec::new(0, "cc", run.phases, 0.0);
    let t_flow = flow.run(std::slice::from_ref(&spec)).makespan_ns;
    let ev = event.cc(&g);
    assert_eq!(ev.values, run.labels, "functional agreement");
    let ratio = ev.elapsed_ns / t_flow;
    assert!(
        (0.2..=5.0).contains(&ratio),
        "event {:.3e} vs flow {:.3e} (ratio {ratio:.2})",
        ev.elapsed_ns,
        t_flow
    );
}

/// Both engines must agree that the event-sim's serialized channels make a
/// bigger graph proportionally slower.
#[test]
fn engines_scale_together() {
    let m = m8();
    let flow = FlowSim::new(m.clone());
    let mut event = EventSim::new(m.clone());
    let (small, big) = (rmat(10, 4), rmat(13, 4));
    let spec = |g: &Csr| {
        let run = alg::bfs_run(g, &m, pathfinder_queries::graph::sample::bfs_sources(g, 1, 2)[0]);
        QuerySpec::new(0, "bfs", run.phases, 0.0)
    };
    let f_ratio = flow.run(&[spec(&big)]).makespan_ns / flow.run(&[spec(&small)]).makespan_ns;
    let e_ratio = {
        let s = event.bfs(&small, 1).elapsed_ns;
        let b = event.bfs(&big, 1).elapsed_ns;
        b / s
    };
    assert!(f_ratio > 1.5 && e_ratio > 1.5, "flow {f_ratio:.2} event {e_ratio:.2}");
    assert!((f_ratio / e_ratio - 1.0).abs() < 1.5, "flow {f_ratio:.2} vs event {e_ratio:.2}");
}

/// Degraded chassis slow both engines down.
#[test]
fn degraded_machine_slower_in_both_engines() {
    let g = rmat(11, 6);
    let healthy = Machine::new(MachineConfig::pathfinder_32_healthy());
    let degraded = Machine::new(MachineConfig::pathfinder_32());
    let src = 5u32;

    let solo = |m: &Machine| {
        let run = alg::bfs_run(&g, m, src);
        let spec = QuerySpec::new(0, "bfs", run.phases, 0.0);
        FlowSim::new(m.clone()).run(&[spec]).makespan_ns
    };
    assert!(solo(&degraded) > solo(&healthy));

    let ev = |m: &Machine| EventSim::new(m.clone()).bfs(&g, src).elapsed_ns;
    assert!(ev(&degraded) > ev(&healthy));
}

/// The flow engine's fundamental inequalities on real BFS workloads.
#[test]
fn flow_bounds_on_real_workload() {
    let g = rmat(12, 13);
    let m = m8();
    let flow = FlowSim::new(m.clone());
    let sources = pathfinder_queries::graph::sample::bfs_sources(&g, 24, 3);
    let specs: Vec<QuerySpec> = sources
        .iter()
        .enumerate()
        .map(|(i, &s)| QuerySpec::new(i, "bfs", alg::bfs_run(&g, &m, s).phases, 0.0))
        .collect();
    let conc = flow.run(&specs);
    let seq = flow.run_sequential(&specs);
    // Sequential >= concurrent >= longest single query.
    let longest = specs.iter().map(|s| s.solo_ns(&m)).fold(0.0, f64::max);
    assert!(seq.makespan_ns >= conc.makespan_ns);
    assert!(conc.makespan_ns >= longest * (1.0 - 1e-9));
    // Work conservation: identical counters either way.
    assert_eq!(
        conc.counters.totals().channel_ops,
        seq.counters.totals().channel_ops
    );
    // Concurrency must raise utilization.
    assert!(
        conc.counters.mean_channel_utilization(&m)
            > seq.counters.mean_channel_utilization(&m)
    );
}

/// Event engine respects the context-slot ceiling: a frontier wider than
/// the node's thread contexts processes in waves.
#[test]
fn event_sim_context_waves() {
    let mut cfg = MachineConfig::pathfinder_8();
    cfg.cores_per_node = 1;
    cfg.threads_per_core = 4; // 4 slots per node
    let m_small = Machine::new(cfg);
    let m_big = m8();
    // Star of 64 leaves: level 1 has 64 concurrent threads.
    let edges: Vec<(u32, u32)> = (1..=64u32).map(|v| (0, v)).collect();
    let g = build_undirected_csr(65, &edges);
    let t_small = EventSim::new(m_small).bfs(&g, 0).elapsed_ns;
    let t_big = EventSim::new(m_big).bfs(&g, 0).elapsed_ns;
    assert!(t_small > t_big, "fewer contexts must be slower: {t_small} vs {t_big}");
}
