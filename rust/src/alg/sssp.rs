//! Single-source shortest paths by delta-stepping on the MSP `remote_min`
//! hook — the natural sibling of Figure 2's connected components.
//!
//! The Pathfinder's memory-side processors give distance relaxation the
//! same shape CC's hook sweep has: `remote_min(&D[v], D[u] + w(u,v))` is a
//! read-modify-write cycle at `v`'s home channel, no thread migration, the
//! issuing core keeps running (§III). Delta-stepping organizes relaxations
//! into buckets of width Δ so the demand phases mirror the algorithm's
//! synchronous structure:
//!
//! * **light rounds** — for the current bucket's frontier, each vertex's
//!   worker is launched on its home node (migration + spawn), reads its
//!   own distance record, streams its edge block, and issues one MSP
//!   `remote_min` per *light* edge (w ≤ Δ). Re-inserted vertices trigger
//!   further rounds until the bucket drains;
//! * **one heavy round** — the bucket's settled set relaxes its *heavy*
//!   edges (w > Δ) once, targeting strictly later buckets.
//!
//! The graph is unweighted on disk; weights are synthesized per edge by a
//! deterministic symmetric hash ([`edge_weight`], 1..=[`MAX_WEIGHT`]), so
//! the sim execution and the Dijkstra oracle
//! ([`crate::alg::oracle::sssp_dist`]) always agree on the instance.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::alg::oracle;
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::demand::{DemandBuilder, PhaseDemand};
use crate::sim::machine::Machine;
use std::collections::BTreeMap;

/// Largest synthesized edge weight (weights are 1..=MAX_WEIGHT).
pub const MAX_WEIGHT: u64 = 8;

/// Delta-stepping bucket width. Edges with w ≤ DELTA are "light".
pub const DELTA: u64 = 4;

/// Deterministic symmetric weight of edge (u, v): a SplitMix64-style hash
/// of the unordered endpoint pair, mapped to 1..=[`MAX_WEIGHT`]. Both
/// directions of an undirected edge get the same weight, and the oracle
/// uses this exact function.
pub fn edge_weight(u: u32, v: u32) -> u64 {
    let (a, b) = if u <= v { (u, v) } else { (v, u) };
    let mut x = ((a as u64) << 32) | b as u64;
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    1 + (x % MAX_WEIGHT)
}

/// Single-source shortest paths from `src`, as a schedulable [`Analysis`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sssp {
    /// Source vertex.
    pub src: u32,
}

impl Analysis for Sssp {
    fn label(&self) -> &'static str {
        "sssp"
    }

    fn describe(&self) -> String {
        format!("sssp(src={})", self.src)
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = sssp_run_offset(g, m, self.src, stripe_offset);
        QueryOutput { label: self.label(), values: run.dist, phases: run.phases }
    }

    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        oracle::check_sssp(g, self.src, values)
    }

    fn source_vertex(&self) -> Option<u32> {
        Some(self.src)
    }
}

/// Result of one functional+demand delta-stepping execution.
#[derive(Debug, Clone)]
pub struct SsspRun {
    /// Per-vertex shortest distance from the source, -1 if unreachable.
    pub dist: Vec<i64>,
    /// One demand vector per relaxation round (light rounds + heavy
    /// rounds, in execution order).
    pub phases: Vec<PhaseDemand>,
    /// Number of buckets processed.
    pub buckets: usize,
    /// Total edge relaxations issued (light + heavy).
    pub relaxations: usize,
}

/// Run delta-stepping from `src` at the canonical placement.
pub fn sssp_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine, src: u32) -> SsspRun {
    sssp_run_offset(g, m, src, 0)
}

/// Run delta-stepping with an explicit stripe offset for the query's own
/// distance array (see [`crate::alg::bfs::bfs_run_offset`]). Accepts a
/// `&Csr` or any epoch's [`GraphView`].
pub fn sssp_run_offset<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    src: u32,
    stripe_offset: usize,
) -> SsspRun {
    let g: GraphView<'a> = g.into();
    let layout = m.layout;
    let nodes = m.nodes();
    let channels = m.cfg.channels_per_node;
    let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
    let cfg = &m.cfg;
    let n = g.n();
    let mut scratch = NeighborScratch::default();

    const UNREACHED: i64 = i64::MAX;
    let mut dist = vec![UNREACHED; n];
    dist[src as usize] = 0;

    // Buckets keyed by dist / DELTA; processed in ascending order. Light
    // relaxations from bucket i can only target buckets >= i, heavy ones
    // strictly > i, so no earlier bucket is ever refilled.
    let mut buckets: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
    buckets.insert(0, vec![src]);

    let mut phases = Vec::new();
    let mut buckets_done = 0usize;
    let mut relaxations = 0usize;

    while let Some((&bi, _)) = buckets.iter().next() {
        buckets_done += 1;
        // Every vertex removed from bucket bi; relaxes heavy edges once.
        let mut settled: Vec<u32> = Vec::new();

        // --- Light rounds: drain bucket bi. ---
        loop {
            let Some(mut frontier) = buckets.remove(&bi) else { break };
            // Keep only vertices whose final distance still lands in this
            // bucket (stale insertions are re-bucketed copies).
            frontier.retain(|&v| {
                dist[v as usize] != UNREACHED && dist[v as usize] as u64 / DELTA == bi
            });
            frontier.sort_unstable();
            frontier.dedup();
            if frontier.is_empty() {
                break;
            }

            let mut b = DemandBuilder::new(nodes, channels);
            let mut ops = 0.0f64;
            for &u in &frontier {
                settled.push(u);
                let un = layout.node_of(u);
                // Worker launch on u's home node.
                b.migration(un, 1.0);
                b.fabric_bytes(un, 64.0);
                b.instructions(un, cfg.spawn_instr);
                // Own distance record read.
                b.channel_op(un, (layout.channel_of(u) + stripe_offset) % channels, 1.0);
                ops += 1.0;
                let nbrs = g.neighbors(u, &mut scratch);
                // Edge block stream (co-located with the vertex, §IV-A).
                b.stream_bytes(un, GraphView::edge_block_bytes_for(nbrs.len()) as f64);
                b.instructions(un, nbrs.len() as f64 * cfg.instr_per_edge);
                let du = dist[u as usize];
                for &v in nbrs {
                    let w = edge_weight(u, v);
                    if w > DELTA {
                        continue; // heavy edge: relaxed after the bucket drains
                    }
                    // remote_min at v's home channel (MSP RMW, no migration).
                    let vn = layout.node_of(v);
                    b.msp_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                    ops += 1.0;
                    relaxations += 1;
                    if vn != un {
                        b.fabric_bytes(un, 16.0);
                    }
                    let nd = du + w as i64;
                    if nd < dist[v as usize] {
                        dist[v as usize] = nd;
                        buckets.entry(nd as u64 / DELTA).or_default().push(v);
                    }
                }
            }
            b.parallelism(ops.min(contexts_total));
            phases.push(b.finish());
        }

        // --- One heavy round over the bucket's settled set. ---
        settled.sort_unstable();
        settled.dedup();
        let mut b = DemandBuilder::new(nodes, channels);
        let mut ops = 0.0f64;
        for &u in &settled {
            let un = layout.node_of(u);
            let du = dist[u as usize];
            let mut touched = false;
            let nbrs = g.neighbors(u, &mut scratch);
            for &v in nbrs {
                let w = edge_weight(u, v);
                if w <= DELTA {
                    continue;
                }
                if !touched {
                    // Re-visit u's record + edge block for the heavy pass.
                    b.channel_op(un, (layout.channel_of(u) + stripe_offset) % channels, 1.0);
                    b.stream_bytes(un, GraphView::edge_block_bytes_for(nbrs.len()) as f64);
                    ops += 1.0;
                    touched = true;
                }
                let vn = layout.node_of(v);
                b.msp_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                b.instructions(un, cfg.instr_per_edge);
                ops += 1.0;
                relaxations += 1;
                if vn != un {
                    b.fabric_bytes(un, 16.0);
                }
                let nd = du + w as i64;
                if nd < dist[v as usize] {
                    dist[v as usize] = nd;
                    buckets.entry(nd as u64 / DELTA).or_default().push(v);
                }
            }
        }
        if ops > 0.0 {
            b.parallelism(ops.min(contexts_total));
            phases.push(b.finish());
        }
    }

    let dist = dist.into_iter().map(|d| if d == UNREACHED { -1 } else { d }).collect();
    SsspRun { dist, phases, buckets: buckets_done, relaxations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn weights_symmetric_and_bounded() {
        for (u, v) in [(0u32, 1u32), (5, 2), (100, 100), (7, 1000)] {
            let w = edge_weight(u, v);
            assert_eq!(w, edge_weight(v, u));
            assert!((1..=MAX_WEIGHT).contains(&w), "w({u},{v}) = {w}");
        }
        // Not all weights equal (the hash actually varies).
        let ws: std::collections::HashSet<u64> =
            (0..64u32).map(|v| edge_weight(v, v + 1)).collect();
        assert!(ws.len() > 1);
    }

    #[test]
    fn distances_match_dijkstra_on_rmat() {
        let g = rmat(10, 7);
        let m = m8();
        for src in [0u32, 13, 500] {
            let run = sssp_run(&g, &m, src);
            oracle::check_sssp(&g, src, &run.dist).unwrap();
        }
    }

    #[test]
    fn distances_match_dijkstra_on_path_and_star() {
        let path: Vec<(u32, u32)> = (0..49u32).map(|i| (i, i + 1)).collect();
        let star: Vec<(u32, u32)> = (1..=32u32).map(|v| (0, v)).collect();
        let m = m8();
        for (n, edges) in [(50usize, path), (33, star)] {
            let g = build_undirected_csr(n, &edges);
            let run = sssp_run(&g, &m, 0);
            oracle::check_sssp(&g, 0, &run.dist).unwrap();
        }
    }

    #[test]
    fn unreachable_vertices_are_minus_one() {
        let g = build_undirected_csr(6, &[(0, 1), (3, 4)]);
        let run = sssp_run(&g, &m8(), 0);
        assert_eq!(run.dist[0], 0);
        assert_eq!(run.dist[1], edge_weight(0, 1) as i64);
        assert_eq!(run.dist[2], -1);
        assert_eq!(run.dist[3], -1);
    }

    #[test]
    fn sssp_costs_more_than_bfs_and_uses_msp() {
        // Same traversal structure as BFS but every relaxation is an MSP
        // RMW, and buckets add rounds — SSSP should be the dearer query.
        let g = rmat(10, 3);
        let m = m8();
        let sssp = sssp_run(&g, &m, 5);
        let bfs = crate::alg::bfs::bfs_run(&g, &m, 5);
        let t_sssp: f64 = sssp.phases.iter().map(|p| p.solo_ns(&m)).sum();
        let t_bfs: f64 = bfs.phases.iter().map(|p| p.solo_ns(&m)).sum();
        assert!(t_sssp > t_bfs, "sssp {t_sssp} vs bfs {t_bfs}");
        let msp: f64 = sssp.phases.iter().flat_map(|p| p.msp_ops.iter()).sum();
        assert!(msp > 0.0, "relaxations must be MSP remote_min ops");
    }

    #[test]
    fn offsets_do_not_change_results() {
        let g = rmat(9, 11);
        let m = m8();
        let base = sssp_run_offset(&g, &m, 2, 0);
        for offset in [1usize, 5] {
            let run = sssp_run_offset(&g, &m, 2, offset);
            assert_eq!(run.dist, base.dist);
            for (a, b) in run.phases.iter().zip(&base.phases) {
                assert_eq!(a.channel_ops, b.channel_ops);
            }
        }
    }
}
