//! The query abstraction the coordinator schedules: a BFS from a source
//! vertex or a whole-graph connected components evaluation, with uniform
//! access to execution (functional result + demand phases) and validation.

use super::{bfs, cc, oracle};
use crate::graph::csr::Csr;
use crate::sim::demand::PhaseDemand;
use crate::sim::machine::Machine;

/// One analysis query (paper §IV: BFS from unique sources, CC, and mixes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Query {
    /// Breadth-first search from a source vertex.
    Bfs { src: u32 },
    /// Whole-graph connected components (Figure 2).
    Cc,
}

impl Query {
    /// Short label used in reports and timings.
    pub fn label(&self) -> &'static str {
        match self {
            Query::Bfs { .. } => "bfs",
            Query::Cc => "cc",
        }
    }

    /// Execute functionally on `g` for machine `m`, producing the result
    /// values and the per-phase demand vectors. `stripe_offset` is the
    /// query's own-array placement offset (usually its index within the
    /// batch — see [`bfs::bfs_run_offset`]).
    pub fn run_offset(&self, g: &Csr, m: &Machine, stripe_offset: usize) -> QueryOutput {
        match *self {
            Query::Bfs { src } => {
                let run = bfs::bfs_run_offset(g, m, src, stripe_offset);
                QueryOutput { query: *self, values: run.levels, phases: run.phases }
            }
            Query::Cc => {
                let run = cc::cc_run_offset(g, m, stripe_offset);
                QueryOutput { query: *self, values: run.labels, phases: run.phases }
            }
        }
    }

    /// [`Query::run_offset`] at the canonical placement.
    pub fn run(&self, g: &Csr, m: &Machine) -> QueryOutput {
        self.run_offset(g, m, 0)
    }

    /// Demand phases only (skips retaining the value vector).
    pub fn phases(&self, g: &Csr, m: &Machine, stripe_offset: usize) -> Vec<PhaseDemand> {
        self.run_offset(g, m, stripe_offset).phases
    }
}

impl std::fmt::Display for Query {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Query::Bfs { src } => write!(f, "bfs(src={src})"),
            Query::Cc => write!(f, "cc"),
        }
    }
}

/// Functional result + demand of one executed query.
#[derive(Debug, Clone)]
pub struct QueryOutput {
    pub query: Query,
    /// BFS levels or CC labels.
    pub values: Vec<i64>,
    /// Per-phase resource demand.
    pub phases: Vec<PhaseDemand>,
}

impl QueryOutput {
    /// Check the functional result against the host oracle.
    pub fn validate(&self, g: &Csr) -> anyhow::Result<()> {
        match self.query {
            Query::Bfs { src } => oracle::check_bfs(g, src, &self.values),
            Query::Cc => oracle::check_cc(g, &self.values),
        }
    }

    /// Total solo duration of all phases (ns) on machine `m`.
    pub fn solo_ns(&self, m: &Machine) -> f64 {
        self.phases.iter().map(|p| p.solo_ns(m)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat10() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    #[test]
    fn bfs_query_validates() {
        let g = rmat10();
        let m = m8();
        let out = Query::Bfs { src: 3 }.run(&g, &m);
        out.validate(&g).unwrap();
        assert!(out.solo_ns(&m) > 0.0);
        assert_eq!(out.query.label(), "bfs");
    }

    #[test]
    fn cc_query_validates() {
        let g = rmat10();
        let m = m8();
        let out = Query::Cc.run(&g, &m);
        out.validate(&g).unwrap();
        assert_eq!(out.query.label(), "cc");
    }

    #[test]
    fn display_forms() {
        assert_eq!(Query::Bfs { src: 42 }.to_string(), "bfs(src=42)");
        assert_eq!(Query::Cc.to_string(), "cc");
    }

    #[test]
    fn validate_catches_corruption() {
        let g = rmat10();
        let mut out = Query::Bfs { src: 3 }.run(&g, &m8());
        out.values[10] = 999;
        assert!(out.validate(&g).is_err());
    }
}
