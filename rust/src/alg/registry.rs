//! The analysis registry: label → factory, the extension point that makes
//! the coordinator workload-open.
//!
//! The CLI (`--mix bfs=0.8,sssp=0.2`), the service's
//! [`crate::coordinator::service::WorkloadSpec`] parser, and the property
//! tests all resolve analysis classes by label through a registry instead
//! of matching on a closed type. [`AnalysisRegistry::builtin`] registers
//! the six shipped analyses; embedders add their own with
//! [`AnalysisRegistry::register`] and every layer above picks them up.
//! docs/ANALYSES.md is the authoring guide for doing exactly that.

use crate::alg::analysis::Analysis;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Builds one analysis instance rooted at a source vertex. Source-free
/// analyses (CC, PageRank, triangle counting) ignore the argument.
pub type AnalysisFactory = Arc<dyn Fn(u32) -> Arc<dyn Analysis> + Send + Sync>;

/// Label-keyed analysis factories.
///
/// Resolving and building through the registry is all a caller ever needs
/// — the returned [`Analysis`] is schedulable, servable and reportable
/// with no other wiring:
///
/// ```
/// use pathfinder_queries::alg::{Analysis, AnalysisRegistry};
///
/// let registry = AnalysisRegistry::builtin();
/// assert_eq!(
///     registry.labels(),
///     vec!["bfs", "cc", "khop", "pagerank", "sssp", "tricount"],
/// );
///
/// // Sourced analyses root at the given vertex; source-free ones ignore it.
/// let bfs = registry.build("bfs", 42).unwrap();
/// assert_eq!(bfs.describe(), "bfs(src=42)");
/// let pr = registry.build("pagerank", 42).unwrap();
/// assert_eq!(pr.describe(), "pagerank");
///
/// // Parameter-free kinds advertise a demand-cache key the coordinator
/// // uses to compute their (expensive) demand once on the static graph.
/// assert_eq!(pr.cacheable_demand().as_deref(), Some("pagerank"));
/// assert!(bfs.cacheable_demand().is_none());
/// ```
#[derive(Clone)]
pub struct AnalysisRegistry {
    entries: BTreeMap<&'static str, AnalysisFactory>,
}

impl AnalysisRegistry {
    /// An empty registry (embedders composing their own catalog).
    pub fn empty() -> Self {
        AnalysisRegistry { entries: BTreeMap::new() }
    }

    /// The six shipped analyses: `bfs`, `cc`, `sssp`, `khop` (2-hop
    /// neighborhoods by default), `pagerank`, and `tricount`.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register("bfs", Arc::new(|src| -> Arc<dyn Analysis> {
            Arc::new(super::bfs::Bfs { src })
        }));
        r.register("cc", Arc::new(|_src| -> Arc<dyn Analysis> { Arc::new(super::cc::Cc) }));
        r.register("sssp", Arc::new(|src| -> Arc<dyn Analysis> {
            Arc::new(super::sssp::Sssp { src })
        }));
        r.register("khop", Arc::new(|src| -> Arc<dyn Analysis> {
            Arc::new(super::khop::KHop::new(src, 2))
        }));
        r.register("pagerank", Arc::new(|_src| -> Arc<dyn Analysis> {
            Arc::new(super::pagerank::PageRank)
        }));
        r.register("tricount", Arc::new(|_src| -> Arc<dyn Analysis> {
            Arc::new(super::tricount::TriCount)
        }));
        r
    }

    /// Register (or replace) a factory under `label`.
    pub fn register(&mut self, label: &'static str, factory: AnalysisFactory) {
        self.entries.insert(label, factory);
    }

    /// Build an instance of class `label` rooted at `src`.
    pub fn build(&self, label: &str, src: u32) -> anyhow::Result<Arc<dyn Analysis>> {
        match self.entries.get(label) {
            Some(f) => Ok(f(src)),
            None => anyhow::bail!(
                "unknown analysis {label:?} (registered: {})",
                self.labels().join(", ")
            ),
        }
    }

    /// The factory registered under `label`, if any.
    pub fn factory(&self, label: &str) -> Option<(&'static str, AnalysisFactory)> {
        self.entries.get_key_value(label).map(|(k, v)| (*k, v.clone()))
    }

    /// Registered labels, sorted.
    pub fn labels(&self) -> Vec<&'static str> {
        self.entries.keys().copied().collect()
    }

    pub fn contains(&self, label: &str) -> bool {
        self.entries.contains_key(label)
    }
}

impl std::fmt::Debug for AnalysisRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AnalysisRegistry").field("labels", &self.labels()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::khop::KHop;

    #[test]
    fn builtin_covers_six_classes() {
        let r = AnalysisRegistry::builtin();
        assert_eq!(r.labels(), vec!["bfs", "cc", "khop", "pagerank", "sssp", "tricount"]);
        for label in r.labels() {
            let a = r.build(label, 7).unwrap();
            assert_eq!(a.label(), label);
        }
    }

    #[test]
    fn unknown_label_names_the_catalog() {
        let r = AnalysisRegistry::builtin();
        let err = r.build("betweenness", 0).unwrap_err().to_string();
        assert!(err.contains("betweenness") && err.contains("bfs"), "{err}");
    }

    #[test]
    fn registration_is_open() {
        let mut r = AnalysisRegistry::empty();
        assert!(!r.contains("khop5"));
        r.register(
            "khop5",
            Arc::new(|src| -> Arc<dyn crate::alg::Analysis> { Arc::new(KHop::new(src, 5)) }),
        );
        let a = r.build("khop5", 3).unwrap();
        assert_eq!(a.describe(), "khop(src=3,k=5)");
    }
}
