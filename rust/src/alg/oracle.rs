//! Host reference implementations ("the obviously correct versions").
//!
//! These never touch the simulator; they exist so every Pathfinder (and
//! baseline-engine) result can be checked against an independent
//! implementation: plain queue BFS, union-find connected components,
//! binary-heap Dijkstra (over the synthesized [`crate::alg::sssp`]
//! weights), truncated-BFS k-hop levels, pull-based power-iteration
//! PageRank, and hash-set triangle counting.
//!
//! All oracles read through [`GraphView`], so a result computed on a
//! pinned epoch snapshot is checked against an oracle run on *that exact
//! edge set* — the snapshot-isolation contract of DESIGN.md §Mutation.
//! A plain `&Csr` converts to the no-overlay fast path, so existing call
//! sites are unchanged.

use crate::graph::view::{GraphView, NeighborScratch};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Plain FIFO breadth-first search. Returns per-vertex levels, -1 where
/// unreachable from `src`.
pub fn bfs_levels<'a>(g: impl Into<GraphView<'a>>, src: u32) -> Vec<i64> {
    let g: GraphView<'a> = g.into();
    let mut scratch = NeighborScratch::default();
    let mut levels = vec![-1i64; g.n()];
    levels[src as usize] = 0;
    let mut q = VecDeque::new();
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        let next = levels[u as usize] + 1;
        for &v in g.neighbors(u, &mut scratch) {
            if levels[v as usize] == -1 {
                levels[v as usize] = next;
                q.push_back(v);
            }
        }
    }
    levels
}

/// Union-find with path halving + union by label minimum: every vertex ends
/// labeled with the smallest vertex id of its component (the same labeling
/// Shiloach-Vishkin with min-hooks converges to).
pub fn cc_labels<'a>(g: impl Into<GraphView<'a>>) -> Vec<i64> {
    let g: GraphView<'a> = g.into();
    let n = g.n();
    let mut parent: Vec<u32> = (0..n as u32).collect();

    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize]; // halve
            x = parent[x as usize];
        }
        x
    }

    let mut scratch = NeighborScratch::default();
    for u in 0..n as u32 {
        for &v in g.neighbors(u, &mut scratch) {
            let ru = find(&mut parent, u);
            let rv = find(&mut parent, v);
            if ru != rv {
                // Union by minimum label so roots are component minima.
                let (lo, hi) = if ru < rv { (ru, rv) } else { (rv, ru) };
                parent[hi as usize] = lo;
            }
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v) as i64).collect()
}

/// Number of connected components implied by a label vector.
pub fn component_count(labels: &[i64]) -> usize {
    let mut roots: Vec<i64> = labels.to_vec();
    roots.sort_unstable();
    roots.dedup();
    roots.len()
}

/// Check that `levels` is a valid BFS level assignment from `src`:
/// reachable vertices get the true shortest unweighted distance.
pub fn check_bfs<'a>(g: impl Into<GraphView<'a>>, src: u32, levels: &[i64]) -> anyhow::Result<()> {
    let g: GraphView<'a> = g.into();
    anyhow::ensure!(levels.len() == g.n(), "levels length mismatch");
    let truth = bfs_levels(g, src);
    for v in 0..g.n() {
        anyhow::ensure!(
            levels[v] == truth[v],
            "vertex {v}: level {} but oracle says {}",
            levels[v],
            truth[v]
        );
    }
    Ok(())
}

/// Plain binary-heap Dijkstra over the synthesized edge weights
/// ([`crate::alg::sssp::edge_weight`]). Returns per-vertex shortest
/// distances, -1 where unreachable from `src`.
pub fn sssp_dist<'a>(g: impl Into<GraphView<'a>>, src: u32) -> Vec<i64> {
    let g: GraphView<'a> = g.into();
    let n = g.n();
    let mut dist = vec![i64::MAX; n];
    dist[src as usize] = 0;
    let mut heap = BinaryHeap::new();
    heap.push(Reverse((0i64, src)));
    let mut scratch = NeighborScratch::default();
    while let Some(Reverse((d, u))) = heap.pop() {
        if d > dist[u as usize] {
            continue; // stale heap entry
        }
        for &v in g.neighbors(u, &mut scratch) {
            let nd = d + crate::alg::sssp::edge_weight(u, v) as i64;
            if nd < dist[v as usize] {
                dist[v as usize] = nd;
                heap.push(Reverse((nd, v)));
            }
        }
    }
    dist.into_iter().map(|d| if d == i64::MAX { -1 } else { d }).collect()
}

/// K-hop truth: BFS levels truncated at `k` (deeper vertices become -1).
pub fn khop_levels<'a>(g: impl Into<GraphView<'a>>, src: u32, k: u32) -> Vec<i64> {
    bfs_levels(g, src)
        .into_iter()
        .map(|l| if l >= 0 && l <= k as i64 { l } else { -1 })
        .collect()
}

/// Check that `dist` equals Dijkstra's distances from `src`.
pub fn check_sssp<'a>(g: impl Into<GraphView<'a>>, src: u32, dist: &[i64]) -> anyhow::Result<()> {
    let g: GraphView<'a> = g.into();
    anyhow::ensure!(dist.len() == g.n(), "dist length mismatch");
    let truth = sssp_dist(g, src);
    for v in 0..g.n() {
        anyhow::ensure!(
            dist[v] == truth[v],
            "vertex {v}: distance {} but oracle says {}",
            dist[v],
            truth[v]
        );
    }
    Ok(())
}

/// Check that `levels` is the k-hop truncation of the BFS levels.
pub fn check_khop<'a>(
    g: impl Into<GraphView<'a>>,
    src: u32,
    k: u32,
    levels: &[i64],
) -> anyhow::Result<()> {
    let g: GraphView<'a> = g.into();
    anyhow::ensure!(levels.len() == g.n(), "levels length mismatch");
    let truth = khop_levels(g, src, k);
    for v in 0..g.n() {
        anyhow::ensure!(
            levels[v] == truth[v],
            "vertex {v}: k-hop level {} but oracle says {}",
            levels[v],
            truth[v]
        );
    }
    Ok(())
}

/// Plain **pull-based** power-iteration PageRank: an independent
/// implementation of the same fixpoint the push-style Pathfinder kernel
/// ([`crate::alg::pagerank`]) iterates — same damping, round cap, L1
/// stopping rule and uniform dangling-mass redistribution, but each
/// vertex *gathers* its in-neighbor contributions instead of scattering
/// pushes (in-neighbors == neighbors on an undirected graph). Returns
/// unscaled f64 ranks summing to 1.
pub fn pagerank_ranks<'a>(g: impl Into<GraphView<'a>>) -> Vec<f64> {
    use crate::alg::pagerank::{DAMPING, L1_EPS, MAX_ROUNDS};

    let g: GraphView<'a> = g.into();
    let n = g.n();
    let inv_n = 1.0 / n as f64;
    let mut scratch = NeighborScratch::default();
    let mut deg = vec![0usize; n];
    for v in 0..n as u32 {
        deg[v as usize] = g.neighbors(v, &mut scratch).len();
    }
    let mut ranks = vec![inv_n; n];
    for _ in 0..MAX_ROUNDS {
        let dangling: f64 = (0..n).filter(|&v| deg[v] == 0).map(|v| ranks[v]).sum();
        let mut next = vec![0.0f64; n];
        for v in 0..n as u32 {
            let mut acc = 0.0f64;
            for &u in g.neighbors(v, &mut scratch) {
                acc += ranks[u as usize] / deg[u as usize] as f64;
            }
            next[v as usize] = (1.0 - DAMPING) * inv_n + DAMPING * (acc + dangling * inv_n);
        }
        let residual: f64 = next.iter().zip(&ranks).map(|(a, b)| (a - b).abs()).sum();
        ranks = next;
        if residual <= L1_EPS {
            break;
        }
    }
    ranks
}

/// Check a fixed-point-scaled rank vector against [`pagerank_ranks`]:
/// per-vertex within [`crate::alg::pagerank::ORACLE_TOL`], and total mass
/// conserved to rounding.
pub fn check_pagerank<'a>(g: impl Into<GraphView<'a>>, values: &[i64]) -> anyhow::Result<()> {
    use crate::alg::pagerank::{ORACLE_TOL, RANK_SCALE};

    let g: GraphView<'a> = g.into();
    anyhow::ensure!(values.len() == g.n(), "rank vector length mismatch");
    let truth = pagerank_ranks(g);
    let tol = (ORACLE_TOL * RANK_SCALE) as i64;
    let mut sum = 0i64;
    for v in 0..g.n() {
        let want = (truth[v] * RANK_SCALE).round() as i64;
        anyhow::ensure!(
            (values[v] - want).abs() <= tol,
            "vertex {v}: scaled rank {} but oracle says {want} (tolerance {tol})",
            values[v]
        );
        sum += values[v];
    }
    let mass_tol = g.n() as i64 + tol;
    anyhow::ensure!(
        (sum - RANK_SCALE as i64).abs() <= mass_tol,
        "ranks sum to {sum}, want {} ± {mass_tol} (mass not conserved)",
        RANK_SCALE as i64
    );
    Ok(())
}

/// Brute-force triangle total: materialize the undirected edge set in a
/// hash set, then for every id-ordered edge `(u, v)` count the common
/// neighbors `w > v` — each triangle `a < b < c` is counted exactly once,
/// at edge `(a, b)` with `w = c`. Independent of the degree ordering the
/// Pathfinder kernel ([`crate::alg::tricount`]) uses.
pub fn triangle_total<'a>(g: impl Into<GraphView<'a>>) -> u64 {
    let g: GraphView<'a> = g.into();
    let n = g.n();
    let mut scratch = NeighborScratch::default();
    let mut edges: std::collections::HashSet<(u32, u32)> = std::collections::HashSet::new();
    for u in 0..n as u32 {
        for &v in g.neighbors(u, &mut scratch) {
            if u < v {
                edges.insert((u, v));
            }
        }
    }
    let mut total = 0u64;
    for &(u, v) in &edges {
        for &w in g.neighbors(u, &mut scratch) {
            if w > v && edges.contains(&(v, w)) {
                total += 1;
            }
        }
    }
    total
}

/// Check a triangle-count result (a single-element value vector) against
/// [`triangle_total`] — exact, no tolerance.
pub fn check_tricount<'a>(g: impl Into<GraphView<'a>>, values: &[i64]) -> anyhow::Result<()> {
    let g: GraphView<'a> = g.into();
    anyhow::ensure!(
        values.len() == 1,
        "triangle count is a single total, got {} values",
        values.len()
    );
    let truth = triangle_total(g) as i64;
    anyhow::ensure!(
        values[0] == truth,
        "triangle count {} but oracle says {truth}",
        values[0]
    );
    Ok(())
}

/// Check that `labels` equals the union-find component-minimum labeling.
pub fn check_cc<'a>(g: impl Into<GraphView<'a>>, labels: &[i64]) -> anyhow::Result<()> {
    let g: GraphView<'a> = g.into();
    anyhow::ensure!(labels.len() == g.n(), "labels length mismatch");
    let truth = cc_labels(g);
    for v in 0..g.n() {
        anyhow::ensure!(
            labels[v] == truth[v],
            "vertex {v}: label {} but oracle says {}",
            labels[v],
            truth[v]
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::delta::DeltaOverlay;
    use std::sync::Arc;

    fn diamond() -> Csr {
        // 0-1, 0-2, 1-3, 2-3: two equal-length paths to 3.
        build_undirected_csr(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn bfs_shortest_paths() {
        let g = diamond();
        assert_eq!(bfs_levels(&g, 0), vec![0, 1, 1, 2]);
        assert_eq!(bfs_levels(&g, 3), vec![2, 1, 1, 0]);
    }

    #[test]
    fn bfs_disconnected() {
        let g = build_undirected_csr(5, &[(0, 1), (2, 3)]);
        assert_eq!(bfs_levels(&g, 0), vec![0, 1, -1, -1, -1]);
    }

    #[test]
    fn cc_minimum_labels() {
        let g = build_undirected_csr(6, &[(1, 2), (2, 5), (3, 4)]);
        assert_eq!(cc_labels(&g), vec![0, 1, 1, 3, 3, 1]);
        assert_eq!(component_count(&cc_labels(&g)), 3);
    }

    #[test]
    fn cc_single_component() {
        let edges: Vec<(u32, u32)> = (0..63u32).map(|i| (i, i + 1)).collect();
        let g = build_undirected_csr(64, &edges);
        assert!(cc_labels(&g).iter().all(|&l| l == 0));
    }

    #[test]
    fn dijkstra_on_known_weights() {
        // Path 0-1-2: distances are cumulative edge weights.
        let g = build_undirected_csr(3, &[(0, 1), (1, 2)]);
        let w01 = crate::alg::sssp::edge_weight(0, 1) as i64;
        let w12 = crate::alg::sssp::edge_weight(1, 2) as i64;
        assert_eq!(sssp_dist(&g, 0), vec![0, w01, w01 + w12]);
    }

    #[test]
    fn dijkstra_prefers_lighter_detours() {
        // Triangle 0-1, 1-2, 0-2: d(0,2) = min(w02, w01 + w12).
        let g = build_undirected_csr(3, &[(0, 1), (1, 2), (0, 2)]);
        let w01 = crate::alg::sssp::edge_weight(0, 1) as i64;
        let w12 = crate::alg::sssp::edge_weight(1, 2) as i64;
        let w02 = crate::alg::sssp::edge_weight(0, 2) as i64;
        assert_eq!(sssp_dist(&g, 0)[2], w02.min(w01 + w12));
    }

    #[test]
    fn khop_truncation() {
        let edges: Vec<(u32, u32)> = (0..9u32).map(|i| (i, i + 1)).collect();
        let g = build_undirected_csr(10, &edges);
        let l = khop_levels(&g, 0, 2);
        assert_eq!(&l[..4], &[0, 1, 2, -1]);
        check_khop(&g, 0, 2, &l).unwrap();
        assert!(check_khop(&g, 0, 1, &l).is_err());
    }

    #[test]
    fn checkers_accept_truth_reject_lies() {
        let g = diamond();
        let levels = bfs_levels(&g, 0);
        check_bfs(&g, 0, &levels).unwrap();
        let mut bad = levels;
        bad[3] = 7;
        assert!(check_bfs(&g, 0, &bad).is_err());

        let labels = cc_labels(&g);
        check_cc(&g, &labels).unwrap();
        let mut bad = labels;
        bad[0] = 2;
        assert!(check_cc(&g, &bad).is_err());
    }

    #[test]
    fn pagerank_mass_and_symmetry() {
        // Diamond is vertex-transitive under the 1<->2 swap: equal ranks.
        let g = diamond();
        let r = pagerank_ranks(&g);
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!((r[1] - r[2]).abs() < 1e-12);
        // 0 and 3 are symmetric to each other too (both degree 2).
        assert!((r[0] - r[3]).abs() < 1e-9);
    }

    #[test]
    fn pagerank_dangling_mass_is_redistributed() {
        // One edge + three isolated vertices: mass still sums to 1, and
        // the connected pair outranks the isolated vertices.
        let g = build_undirected_csr(5, &[(0, 1)]);
        let r = pagerank_ranks(&g);
        assert!((r.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r[0] > r[2]);
        assert!((r[2] - r[4]).abs() < 1e-12);
    }

    #[test]
    fn triangle_totals_on_known_graphs() {
        assert_eq!(triangle_total(&diamond()), 0); // 4-cycle, no chord
        let tri = build_undirected_csr(3, &[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(triangle_total(&tri), 1);
        // Two triangles sharing edge 0-1.
        let bowtie = build_undirected_csr(4, &[(0, 1), (0, 2), (1, 2), (0, 3), (1, 3)]);
        assert_eq!(triangle_total(&bowtie), 2);
        check_tricount(&bowtie, &[2]).unwrap();
        assert!(check_tricount(&bowtie, &[3]).is_err());
    }

    /// Oracles evaluate the exact overlaid edge set, not the base's.
    #[test]
    fn oracles_respect_overlays() {
        let g = diamond();
        // Delete both edges into 3, insert 0-3 directly.
        let ov = [Arc::new(DeltaOverlay::from_effective(&[(0, 3)], &[(1, 3), (2, 3)]))];
        let v = crate::graph::view::GraphView::overlaid(&g, &ov);
        assert_eq!(bfs_levels(v, 0), vec![0, 1, 1, 1]);
        let base_levels = bfs_levels(&g, 0);
        assert!(check_bfs(v, 0, &base_levels).is_err(), "base result must fail on the new epoch");
        assert_eq!(cc_labels(v), vec![0, 0, 0, 0]);
    }
}
