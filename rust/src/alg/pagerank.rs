//! Push-style iterative PageRank — the canonical accumulation-heavy
//! kernel (PIUMA and FlashGraph both use it as the dense-RMW stress
//! workload), mapped onto the Pathfinder's memory-side `remote_add`.
//!
//! Every round is two synchronous phases:
//!
//! 1. **Push sweep** ([`PhaseDemand::pagerank_push_round`]) — a flat
//!    `cilk_for` over all vertices: each worker reads its own rank record,
//!    streams its edge block, and issues one MSP `remote_add` of
//!    `d·rank(u)/deg(u)` per directed edge into the query's *next-rank*
//!    array at the destination's home channel. Like the CC hook sweep the
//!    push is unconditional and dense — no frontier, no visited check (a
//!    check would be a remote read, i.e. a migration) — so per-round
//!    demand is a pure function of the graph and is computed **once** and
//!    cloned per round.
//! 2. **Residual check + commit**
//!    ([`PhaseDemand::pagerank_residual_check`]) — per-vertex commit of
//!    `next` into `rank` while accumulating node-local L1-residual
//!    partials, then a single thread migrating across all nodes to reduce
//!    the view-0 partials (the only migrations PageRank pays: frontier-less
//!    round control, exactly Fig. 2 line 2's shape).
//!
//! Rounds stop when the L1 residual drops to [`L1_EPS`] or at
//! [`MAX_ROUNDS`], whichever comes first. Dangling (isolated) vertices'
//! mass is redistributed uniformly each round, so total mass is conserved
//! and ranks always sum to 1.
//!
//! Functional results are fixed-point scaled ([`RANK_SCALE`]) into the
//! [`QueryOutput`]'s `i64` value vector; validation is tolerance-based
//! ([`ORACLE_TOL`]) against the independent pull-based oracle
//! ([`crate::alg::oracle::pagerank_ranks`]), since push- and pull-order
//! float summation differ in the last bits.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::alg::oracle;
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::demand::PhaseDemand;
use crate::sim::machine::Machine;

/// Damping factor (the canonical 0.85).
pub const DAMPING: f64 = 0.85;

/// Round cap: iteration stops here even if the residual has not crossed
/// [`L1_EPS`] (the usual case — the residual contracts by ~[`DAMPING`] per
/// round, so the cap is the effective precision knob).
pub const MAX_ROUNDS: usize = 50;

/// L1-residual convergence threshold (early exit for graphs whose mass
/// distribution is already stationary, e.g. edgeless or regular graphs).
pub const L1_EPS: f64 = 1e-8;

/// Fixed-point scale mapping ranks (which sum to 1.0) into the `i64`
/// result vector: `value = round(rank x RANK_SCALE)`.
pub const RANK_SCALE: f64 = 1e12;

/// Per-vertex absolute rank tolerance the oracle check allows — covers
/// push-vs-pull float summation order plus fixed-point rounding, both far
/// below the capped-iteration error floor this bound is calibrated to.
pub const ORACLE_TOL: f64 = 1e-6;

/// Whole-graph PageRank, as a schedulable [`Analysis`]. Parameter-free
/// like [`crate::alg::cc::Cc`], so its demand is cacheable: on the
/// static (epoch-0) graph the coordinator computes it once and serves
/// concurrent instances as channel rotations (mutation-lane epochs
/// bypass the cache and recompute).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageRank;

impl Analysis for PageRank {
    fn label(&self) -> &'static str {
        "pagerank"
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = pagerank_run_offset(g, m, stripe_offset);
        QueryOutput { label: self.label(), values: run.ranks, phases: run.phases }
    }

    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        oracle::check_pagerank(g, values)
    }

    /// Honest footprint: the machine's per-query thread-context
    /// reservation plus the query's two private f64 arrays (`rank` and
    /// `next`).
    fn ctx_mem_bytes(&self, g: GraphView<'_>, m: &Machine) -> Option<u64> {
        Some(m.cfg.ctx_bytes_per_query + 2 * 8 * g.n() as u64)
    }

    fn cacheable_demand(&self) -> Option<String> {
        Some(self.label().to_string())
    }
}

/// Result of one functional+demand PageRank execution.
#[derive(Debug, Clone)]
pub struct PageRankRun {
    /// Per-vertex rank, fixed-point scaled by [`RANK_SCALE`] (the vector
    /// sums to ~[`RANK_SCALE`]).
    pub ranks: Vec<i64>,
    /// Two demand phases (push sweep, residual check) per executed round.
    pub phases: Vec<PhaseDemand>,
    /// Rounds executed (≤ [`MAX_ROUNDS`]).
    pub rounds: usize,
    /// True iff the L1 residual crossed [`L1_EPS`] before the round cap.
    pub converged: bool,
}

/// Run PageRank at the canonical placement. Accepts a `&Csr` (the flat
/// fast path) or any epoch's [`GraphView`].
pub fn pagerank_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine) -> PageRankRun {
    pagerank_run_offset(g, m, 0)
}

/// Run PageRank with an explicit stripe offset for the query's own
/// rank/next arrays (see [`crate::alg::bfs::bfs_run_offset`]: concurrent
/// instances heat rotated channels).
pub fn pagerank_run_offset<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    stripe_offset: usize,
) -> PageRankRun {
    let g: GraphView<'a> = g.into();
    let n = g.n();
    // The dense sweep's demand is rank-independent: one shape per phase
    // kind, cloned per round (see PhaseDemand::pagerank_push_round).
    let push = PhaseDemand::pagerank_push_round(m, g, stripe_offset);
    let check = PhaseDemand::pagerank_residual_check(m, n, stripe_offset);

    let mut scratch = NeighborScratch::default();
    let mut deg = vec![0usize; n];
    for v in 0..n as u32 {
        deg[v as usize] = g.neighbors(v, &mut scratch).len();
    }
    let inv_n = 1.0 / n as f64;
    let mut ranks = vec![inv_n; n];
    let mut phases = Vec::new();
    let mut rounds = 0usize;
    let mut converged = false;

    while rounds < MAX_ROUNDS {
        rounds += 1;
        let mut next = vec![(1.0 - DAMPING) * inv_n; n];
        let mut dangling = 0.0f64;
        for u in 0..n as u32 {
            let d = deg[u as usize];
            if d == 0 {
                dangling += ranks[u as usize];
                continue;
            }
            let share = DAMPING * ranks[u as usize] / d as f64;
            for &v in g.neighbors(u, &mut scratch) {
                next[v as usize] += share;
            }
        }
        if dangling > 0.0 {
            let dshare = DAMPING * dangling * inv_n;
            for x in next.iter_mut() {
                *x += dshare;
            }
        }
        let residual: f64 = next.iter().zip(&ranks).map(|(a, b)| (a - b).abs()).sum();
        ranks = next;
        phases.push(push.clone());
        phases.push(check.clone());
        if residual <= L1_EPS {
            converged = true;
            break;
        }
    }

    let ranks = ranks.into_iter().map(|r| (r * RANK_SCALE).round() as i64).collect();
    PageRankRun { ranks, phases, rounds, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn ranks_match_oracle_on_rmat() {
        let g = rmat(10, 7);
        let run = pagerank_run(&g, &m8());
        oracle::check_pagerank(&g, &run.ranks).unwrap();
        assert_eq!(run.rounds, MAX_ROUNDS, "R-MAT needs the full round budget");
        assert!(!run.converged);
    }

    #[test]
    fn ranks_sum_to_one_and_hubs_outrank_leaves() {
        // Star: the hub holds most of the mass, leaves split the rest.
        let edges: Vec<(u32, u32)> = (1..=32u32).map(|v| (0, v)).collect();
        let g = build_undirected_csr(33, &edges);
        let run = pagerank_run(&g, &m8());
        let sum: i64 = run.ranks.iter().sum();
        assert!((sum - RANK_SCALE as i64).abs() <= 33 + (ORACLE_TOL * RANK_SCALE) as i64);
        assert!(run.ranks[0] > 10 * run.ranks[1], "hub {} leaf {}", run.ranks[0], run.ranks[1]);
        assert_eq!(run.ranks[1], run.ranks[32], "symmetric leaves tie");
        oracle::check_pagerank(&g, &run.ranks).unwrap();
    }

    #[test]
    fn edgeless_graph_converges_immediately_to_uniform() {
        let g = build_undirected_csr(8, &[]);
        let run = pagerank_run(&g, &m8());
        assert!(run.converged);
        assert_eq!(run.rounds, 1);
        assert_eq!(run.phases.len(), 2);
        // Dangling redistribution keeps the uniform distribution exact.
        for &r in &run.ranks {
            assert_eq!(r, (RANK_SCALE / 8.0).round() as i64);
        }
        oracle::check_pagerank(&g, &run.ranks).unwrap();
    }

    #[test]
    fn two_phases_per_round_and_identical_round_demand() {
        let g = rmat(9, 3);
        let m = m8();
        let run = pagerank_run(&g, &m);
        assert_eq!(run.phases.len(), 2 * run.rounds);
        // Every push phase is the same shape; ditto every check phase.
        assert_eq!(run.phases[0], run.phases[2]);
        assert_eq!(run.phases[1], run.phases[3]);
        // Push sweeps carry the MSP accumulation traffic.
        let msp: f64 = run.phases[0].msp_ops.iter().sum();
        assert_eq!(msp, g.m_directed() as f64);
        // Round control is the only migrating part.
        let migs: f64 = run.phases.iter().map(|p| p.total_migrations()).sum();
        assert_eq!(migs, (run.rounds * (m.nodes() - 1)) as f64);
    }

    #[test]
    fn offsets_do_not_change_results() {
        let g = rmat(9, 11);
        let m = m8();
        let base = pagerank_run_offset(&g, &m, 0);
        for offset in [1usize, 5] {
            let run = pagerank_run_offset(&g, &m, offset);
            assert_eq!(run.ranks, base.ranks);
            for (a, b) in run.phases.iter().zip(&base.phases) {
                assert_eq!(a.channel_ops, b.channel_ops);
            }
        }
    }

    #[test]
    fn declared_footprint_is_machine_base_plus_both_rank_arrays() {
        let g = rmat(9, 1);
        let m = m8();
        let bytes = PageRank.ctx_mem_bytes(g.view(), &m).unwrap();
        assert_eq!(bytes, m.cfg.ctx_bytes_per_query + 16 * (1 << 9));
        // A custom machine's per-query base flows through, so admission
        // never under-reserves against a non-preset config.
        let mut cfg = MachineConfig::pathfinder_8();
        cfg.ctx_bytes_per_query = 64 << 20;
        let fat = Machine::new(cfg);
        let bytes = PageRank.ctx_mem_bytes(g.view(), &fat).unwrap();
        assert_eq!(bytes, (64 << 20) + 16 * (1 << 9));
    }

    #[test]
    fn validate_rejects_mass_violations() {
        let g = rmat(9, 5);
        let run = pagerank_run(&g, &m8());
        let mut bad = run.ranks.clone();
        bad[0] += (RANK_SCALE * 0.1) as i64; // 10% of all mass appears
        assert!(oracle::check_pagerank(&g, &bad).is_err());
    }
}
