//! Degree-ordered neighbor-intersection triangle counting — the canonical
//! intersection-heavy kernel (PIUMA and FlashGraph both use it as the
//! read-skew stress workload), as a schedulable [`Analysis`].
//!
//! Every undirected edge is oriented from its `≺`-smaller endpoint to its
//! `≺`-larger one, where `u ≺ v` iff `(deg(u), u) < (deg(v), v)` — the
//! standard degree ordering that bounds every vertex's forward degree by
//! O(√m) and makes hub-hub wedges cheap. A triangle `{u, v, w}` with
//! `u ≺ v ≺ w` is counted exactly once, at its ordered edge `u → v`, as a
//! member of the sorted-merge intersection `N⁺(u) ∩ N⁺(v)`.
//!
//! The demand shape ([`PhaseDemand::tricount_intersections`]) is the
//! mirror image of everything else in this repo: traversals and PageRank
//! are *write*-shaped (unconditional remote writes / MSP RMWs, no remote
//! reads), while intersection needs the *other* endpoint's neighbor list —
//! a remote **read**, which migrates (§II–III). So triangle counting pays
//! two migrations per remote ordered edge and streams the destination's
//! edge block at its home node, with read traffic scaled by the ordered
//! wedge count and **near-zero writes**: one MSP `remote_add` per vertex
//! folding the worker's register-held partial into the query's single
//! global accumulator.
//!
//! The functional result is one value — the triangle total — validated
//! exactly (integers, no tolerance) against the brute-force hash-set
//! oracle [`crate::alg::oracle::triangle_total`].

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::alg::oracle;
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::demand::{degree_ordered, PhaseDemand};
use crate::sim::machine::Machine;

/// Whole-graph triangle counting, as a schedulable [`Analysis`].
/// Parameter-free like [`crate::alg::cc::Cc`], so its demand is
/// cacheable: on the static (epoch-0) graph the coordinator computes it
/// once and serves concurrent instances as channel rotations
/// (mutation-lane epochs bypass the cache and recompute). The demand
/// model honors the rotation-equivariance this requires — see
/// [`PhaseDemand::tricount_intersections`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TriCount;

impl Analysis for TriCount {
    fn label(&self) -> &'static str {
        "tricount"
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = tricount_run_offset(g, m, stripe_offset);
        QueryOutput {
            label: self.label(),
            values: vec![run.triangles as i64],
            phases: run.phases,
        }
    }

    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        oracle::check_tricount(g, values)
    }

    /// Honest footprint: the machine's per-query thread-context
    /// reservation plus the query's private degree-rank array (one u32
    /// per vertex, needed to evaluate the `≺` orientation while
    /// streaming).
    fn ctx_mem_bytes(&self, g: GraphView<'_>, m: &Machine) -> Option<u64> {
        Some(m.cfg.ctx_bytes_per_query + 4 * g.n() as u64)
    }

    fn cacheable_demand(&self) -> Option<String> {
        Some(self.label().to_string())
    }
}

/// Result of one functional+demand triangle-counting execution.
#[derive(Debug, Clone)]
pub struct TriCountRun {
    /// Number of distinct triangles in the graph.
    pub triangles: u64,
    /// The single intersection-sweep demand phase.
    pub phases: Vec<PhaseDemand>,
    /// Oriented (degree-ordered) edges processed — one per undirected
    /// edge; diagnostics for the read-traffic accounting.
    pub ordered_edges: usize,
}

/// Run triangle counting at the canonical placement. Accepts a `&Csr`
/// (the flat fast path) or any epoch's [`GraphView`].
pub fn tricount_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine) -> TriCountRun {
    tricount_run_offset(g, m, 0)
}

/// Run triangle counting with an explicit stripe offset for the query's
/// accumulator placement (see [`crate::alg::bfs::bfs_run_offset`]).
pub fn tricount_run_offset<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    stripe_offset: usize,
) -> TriCountRun {
    let g: GraphView<'a> = g.into();
    let n = g.n();
    let phase = PhaseDemand::tricount_intersections(m, g, stripe_offset);

    let mut scratch = NeighborScratch::default();
    let mut deg = vec![0usize; n];
    for v in 0..n as u32 {
        deg[v as usize] = g.neighbors(v, &mut scratch).len();
    }
    // Forward (degree-ordered) adjacency: each sorted neighbor list's
    // ordered suffix, still sorted by id, so intersections are merges.
    // The SAME shared order predicate the demand model walks with.
    let mut fwd: Vec<Vec<u32>> = Vec::with_capacity(n);
    for u in 0..n as u32 {
        fwd.push(
            g.neighbors(u, &mut scratch)
                .iter()
                .copied()
                .filter(|&v| degree_ordered(&deg, u, v))
                .collect(),
        );
    }

    let mut triangles = 0u64;
    let mut ordered_edges = 0usize;
    for fu in &fwd {
        for &v in fu {
            ordered_edges += 1;
            triangles += sorted_intersection_count(fu, &fwd[v as usize]);
        }
    }
    TriCountRun { triangles, phases: vec![phase], ordered_edges }
}

/// Two-pointer merge intersection size of two id-sorted lists.
fn sorted_intersection_count(a: &[u32], b: &[u32]) -> u64 {
    let (mut i, mut j, mut count) = (0usize, 0usize, 0u64);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                count += 1;
                i += 1;
                j += 1;
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn counts_match_oracle_on_rmat() {
        let g = rmat(10, 7);
        let run = tricount_run(&g, &m8());
        assert_eq!(run.triangles, oracle::triangle_total(&g));
        assert!(run.triangles > 0, "R-MAT has triangles");
        oracle::check_tricount(&g, &[run.triangles as i64]).unwrap();
    }

    #[test]
    fn closed_form_shapes() {
        let m = m8();
        // Triangle: exactly one.
        let tri = build_undirected_csr(3, &[(0, 1), (1, 2), (0, 2)]);
        assert_eq!(tricount_run(&tri, &m).triangles, 1);
        // K4: C(4,3) = 4 triangles.
        let k4 = build_undirected_csr(
            4,
            &[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)],
        );
        let run = tricount_run(&k4, &m);
        assert_eq!(run.triangles, 4);
        assert_eq!(run.ordered_edges, 6, "one oriented edge per undirected edge");
        // Path: none.
        let path = build_undirected_csr(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        assert_eq!(tricount_run(&path, &m).triangles, 0);
        // Star: none (leaves never interconnect).
        let star: Vec<(u32, u32)> = (1..=16u32).map(|v| (0, v)).collect();
        assert_eq!(tricount_run(&build_undirected_csr(17, &star), &m).triangles, 0);
    }

    #[test]
    fn single_phase_with_per_vertex_accumulator_rmws() {
        let g = rmat(9, 3);
        let m = m8();
        let run = tricount_run(&g, &m);
        assert_eq!(run.phases.len(), 1);
        let p = &run.phases[0];
        // Near-zero writes: one accumulator RMW per vertex, nothing else.
        assert_eq!(p.msp_ops.iter().sum::<f64>(), g.n() as f64);
        // Read traffic exceeds one full pass over the edge blocks (every
        // ordered edge re-streams its destination block).
        let own_pass: u64 = (0..g.n() as u32).map(|v| g.edge_block_bytes(v)).sum();
        assert!(p.stream_bytes.iter().sum::<f64>() > own_pass as f64);
        assert!(p.solo_ns(&m) > 0.0);
    }

    /// The functional walk and the demand walk must agree on the ordered
    /// edge set (they share ONE `degree_ordered` predicate): the sweep's
    /// random ops are exactly one record read per vertex + one per
    /// ordered edge + one accumulator RMW per vertex.
    #[test]
    fn demand_walk_and_kernel_agree_on_ordered_edges() {
        let g = rmat(9, 7);
        let run = tricount_run(&g, &m8());
        let p = &run.phases[0];
        assert_eq!(
            p.total_channel_ops(),
            (2 * g.n() + run.ordered_edges) as f64
        );
    }

    #[test]
    fn offsets_do_not_change_results() {
        let g = rmat(9, 11);
        let m = m8();
        let base = tricount_run_offset(&g, &m, 0);
        for offset in [1usize, 5] {
            let run = tricount_run_offset(&g, &m, offset);
            assert_eq!(run.triangles, base.triangles);
            assert_eq!(run.phases[0].channel_ops, base.phases[0].channel_ops);
        }
    }

    #[test]
    fn validate_rejects_wrong_totals_and_wrong_shapes() {
        let g = build_undirected_csr(3, &[(0, 1), (1, 2), (0, 2)]);
        oracle::check_tricount(&g, &[1]).unwrap();
        assert!(oracle::check_tricount(&g, &[2]).is_err());
        assert!(oracle::check_tricount(&g, &[]).is_err());
        assert!(oracle::check_tricount(&g, &[1, 1]).is_err());
    }
}
