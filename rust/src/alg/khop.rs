//! K-hop neighborhood / bounded reachability: the interactive "who is
//! within k hops of this vertex" query a web-facing graph service fields
//! constantly (friend-of-friend, blast-radius, recommendation seeds).
//!
//! Execution is the tuned migratory-thread BFS of [`crate::alg::bfs`]
//! truncated at depth `k`: levels 0..k-1 expand (thread launch on the
//! frontier vertex's home node, edge-block stream, unconditional remote
//! write per scanned edge), vertices discovered at level `k` are recorded
//! but not expanded. Demand phases are exactly the expanded levels', so a
//! small-k query is far cheaper than a full BFS — the short-job class in a
//! mixed workload.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::alg::oracle;
use crate::graph::view::GraphView;
use crate::sim::demand::PhaseDemand;
use crate::sim::machine::Machine;

/// K-hop neighborhood from a source vertex, as a schedulable [`Analysis`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KHop {
    /// Source vertex.
    pub src: u32,
    /// Hop bound (>= 1).
    pub k: u32,
}

impl KHop {
    /// Build a k-hop query; `k` is clamped to at least one hop.
    pub fn new(src: u32, k: u32) -> Self {
        KHop { src, k: k.max(1) }
    }
}

impl Analysis for KHop {
    fn label(&self) -> &'static str {
        "khop"
    }

    fn describe(&self) -> String {
        format!("khop(src={},k={})", self.src, self.k)
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = khop_run_offset(g, m, self.src, self.k, stripe_offset);
        QueryOutput { label: self.label(), values: run.levels, phases: run.phases }
    }

    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        oracle::check_khop(g, self.src, self.k, values)
    }

    fn source_vertex(&self) -> Option<u32> {
        Some(self.src)
    }
}

/// Result of one functional+demand k-hop execution.
#[derive(Debug, Clone)]
pub struct KhopRun {
    /// Per-vertex hop level (0..=k), -1 beyond the hop bound.
    pub levels: Vec<i64>,
    /// One demand vector per expanded level (at most k).
    pub phases: Vec<PhaseDemand>,
    /// Vertices within the k-hop neighborhood (including the source).
    pub reached: usize,
}

/// Run a k-hop traversal at the canonical placement.
pub fn khop_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine, src: u32, k: u32) -> KhopRun {
    khop_run_offset(g, m, src, k, 0)
}

/// Run a k-hop traversal with an explicit stripe offset for the query's
/// own level array (see [`crate::alg::bfs::bfs_run_offset`]). Delegates to
/// the shared depth-capped BFS core
/// ([`crate::alg::bfs::bfs_run_capped`]), so the demand model is exactly
/// the expanded BFS levels'.
pub fn khop_run_offset<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    src: u32,
    k: u32,
    stripe_offset: usize,
) -> KhopRun {
    assert!(k >= 1, "k-hop needs at least one hop");
    let run = crate::alg::bfs::bfs_run_capped(g, m, src, stripe_offset, Some(k));
    let reached = run.reached();
    KhopRun { levels: run.levels, phases: run.phases, reached }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn truncates_bfs_levels_at_k() {
        let g = rmat(10, 7);
        let m = m8();
        for k in [1u32, 2, 3] {
            let run = khop_run(&g, &m, 13, k);
            oracle::check_khop(&g, 13, k, &run.levels).unwrap();
            assert!(run.phases.len() <= k as usize);
        }
    }

    #[test]
    fn path_graph_reaches_exactly_k_plus_one() {
        let edges: Vec<(u32, u32)> = (0..19u32).map(|i| (i, i + 1)).collect();
        let g = build_undirected_csr(20, &edges);
        let run = khop_run(&g, &m8(), 0, 3);
        assert_eq!(run.reached, 4); // vertices 0..=3
        assert_eq!(run.levels[3], 3);
        assert_eq!(run.levels[4], -1);
        assert_eq!(run.phases.len(), 3);
    }

    #[test]
    fn large_k_degenerates_to_full_bfs() {
        let g = rmat(9, 5);
        let m = m8();
        let khop = khop_run(&g, &m, 1, 1000);
        let bfs = crate::alg::bfs::bfs_run(&g, &m, 1);
        assert_eq!(khop.levels, bfs.levels);
    }

    #[test]
    fn small_k_is_cheap() {
        let g = rmat(11, 9);
        let m = m8();
        let one = khop_run(&g, &m, 4, 1);
        let bfs = crate::alg::bfs::bfs_run(&g, &m, 4);
        let t_one: f64 = one.phases.iter().map(|p| p.solo_ns(&m)).sum();
        let t_bfs: f64 = bfs.phases.iter().map(|p| p.solo_ns(&m)).sum();
        assert!(t_one < t_bfs, "1-hop {t_one} vs full {t_bfs}");
    }

    #[test]
    fn constructor_clamps_k() {
        assert_eq!(KHop::new(0, 0).k, 1);
    }
}
