//! The open query API: any graph analysis the coordinator can schedule.
//!
//! The paper's experiments use two workloads (BFS, connected components),
//! but its thesis — a data center serving many concurrent, heterogeneous
//! analyses — is not two-workload-shaped. [`Analysis`] is the extension
//! point: implement it and the planner, scheduler, admission control,
//! metrics and service all pick the new workload up without modification
//! (they key on [`Analysis::label`], never on a closed type).
//!
//! An analysis has two duties:
//!
//! * **functional execution** ([`Analysis::run_offset`]) over the real
//!   graph, emitting the per-phase [`PhaseDemand`] vectors the simulator
//!   charges time for;
//! * **self-validation** ([`Analysis::validate`]) against an independent
//!   host oracle, so every scheduled result can be checked.
//!
//! Two optional hooks feed the coordinator:
//!
//! * [`Analysis::cacheable_demand`] generalizes the connected-components
//!   demand cache: a parameter-free analysis returns a cache key, and the
//!   coordinator computes its (expensive) demand once per key, serving
//!   further instances as cheap channel rotations.
//! * [`Analysis::ctx_mem_bytes`] lets an analysis declare a non-default
//!   thread-context footprint, which admission accounting sums instead of
//!   assuming the machine's per-query reservation.

use crate::graph::view::GraphView;
use crate::sim::demand::PhaseDemand;
use crate::sim::machine::Machine;

/// One schedulable graph analysis (see module docs). Object safe: the
/// coordinator holds `Arc<dyn Analysis>`.
///
/// All reads go through [`GraphView`] (DESIGN.md §Mutation): a query runs
/// against the epoch snapshot it pinned at admission — a bare CSR is just
/// the no-overlay fast path (`Csr::view()` / `(&csr).into()`), bit-identical
/// to reading the CSR directly.
pub trait Analysis: std::fmt::Debug + Send + Sync {
    /// Class label ("bfs", "cc", "sssp", "khop", ...). Everything
    /// per-class — metrics quantiles, demand-cache keys, workload specs —
    /// keys on this.
    fn label(&self) -> &'static str;

    /// Human-readable instance description, e.g. `bfs(src=42)`.
    fn describe(&self) -> String {
        self.label().to_string()
    }

    /// Execute functionally on `g` for machine `m`, producing the result
    /// values and the per-phase demand vectors. `stripe_offset` is the
    /// query's own-array placement offset (usually its index within the
    /// batch — see [`crate::alg::bfs::bfs_run_offset`]).
    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput;

    /// Check a functional result against this analysis's host oracle,
    /// evaluated on the same snapshot the result was computed from.
    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()>;

    /// Per-query thread-context memory reservation (bytes, whole machine),
    /// or `None` to use the machine's default per-query footprint.
    /// Analyses declaring "the default plus my private arrays" should add
    /// their array bytes to `m.cfg.ctx_bytes_per_query` — the hook
    /// receives the machine so custom configs (larger or smaller
    /// per-query reservations) price every analysis consistently; see
    /// docs/ANALYSES.md §Context footprint.
    fn ctx_mem_bytes(&self, g: GraphView<'_>, m: &Machine) -> Option<u64> {
        let _ = (g, m);
        None
    }

    /// If `Some(key)`, this instance's demand at stripe offset 0 is
    /// identical to every other instance returning the same key *on the
    /// same epoch* (no per-query parameter affects demand), so the
    /// coordinator may compute it once and serve further instances as
    /// channel rotations. (The implementation caches **epoch 0 only** —
    /// the static graph; mutation-lane epochs bypass the cache, see
    /// [`crate::coordinator::Coordinator::prepare_one`].)
    ///
    /// Declaring a key is a **rotation-equivariance contract**: cached
    /// instance `k` is served as `phases(g, m, 0)` with every phase
    /// [`PhaseDemand::rotate_channels`]-rotated by `k`, so a direct
    /// `phases(g, m, k)` must equal exactly that — every random op,
    /// including reads of shared graph state, must be charged in the
    /// query's stripe-rotated frame (the coordinator test
    /// `cacheable_demand_rotation_matches_direct_preparation` pins this
    /// for every shipped cacheable analysis).
    fn cacheable_demand(&self) -> Option<String> {
        None
    }

    /// Root vertex of a single-source traversal (`Some` for BFS/SSSP/
    /// k-hop), or `None` for whole-graph analyses. The fleet router
    /// ([`crate::coordinator::fleet`]) uses this to model source-rooted
    /// queries with explicit per-level cross-shard frontier exchange; a
    /// `None` analysis is scattered across shards by arc share instead.
    fn source_vertex(&self) -> Option<u32> {
        None
    }

    /// Source *set* of a rooted traversal — the batch-aware
    /// generalization of [`Analysis::source_vertex`] the fleet router
    /// keys on. A single-source analysis returns its one source; a fused
    /// batch ([`crate::alg::msbfs::BatchedAnalysis`]) returns every
    /// member's source so the router models ONE shared level-synchronous
    /// sweep over the whole set. `None` = not source-rooted.
    fn source_set(&self) -> Option<Vec<u32>> {
        self.source_vertex().map(|s| vec![s])
    }

    /// Batching compatibility key, or `None` (the default) for an
    /// analysis that must never be fused. Two queued instances whose keys
    /// are equal `Some`s — *on the same epoch* — may be coalesced by the
    /// coordinator batcher into one [`crate::alg::msbfs::BatchedAnalysis`]
    /// running a single shared edge sweep for up to
    /// [`crate::alg::msbfs::MAX_BATCH_SOURCES`] sources.
    ///
    /// Opting in is a contract (docs/ANALYSES.md §Batching): the instance
    /// must expose [`Analysis::source_vertex`], and its per-source
    /// semantics must be what the fused kernel computes (BFS levels
    /// today), which [`Analysis::validate`] pins — the fused result is
    /// checked against every member's own oracle.
    fn batch_key(&self) -> Option<String> {
        None
    }

    /// [`Analysis::run_offset`] at the canonical placement.
    fn run(&self, g: GraphView<'_>, m: &Machine) -> QueryOutput {
        self.run_offset(g, m, 0)
    }

    /// Demand phases only (skips retaining the value vector).
    fn phases(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> Vec<PhaseDemand> {
        self.run_offset(g, m, stripe_offset).phases
    }
}

/// Functional result + demand of one executed analysis.
#[derive(Debug, Clone)]
pub struct QueryOutput {
    /// Class label of the analysis that produced this output.
    pub label: &'static str,
    /// Per-vertex result values (BFS levels, CC labels, SSSP distances,
    /// k-hop levels; -1 = unreached where applicable).
    pub values: Vec<i64>,
    /// Per-phase resource demand.
    pub phases: Vec<PhaseDemand>,
}

impl QueryOutput {
    /// Total solo duration of all phases (ns) on machine `m`.
    pub fn solo_ns(&self, m: &Machine) -> f64 {
        self.phases.iter().map(|p| p.solo_ns(m)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::bfs::Bfs;
    use crate::alg::cc::Cc;
    use crate::alg::khop::KHop;
    use crate::alg::pagerank::PageRank;
    use crate::alg::sssp::Sssp;
    use crate::alg::tricount::TriCount;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;
    use std::sync::Arc;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat10() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    fn all_analyses() -> Vec<Arc<dyn Analysis>> {
        vec![
            Arc::new(Bfs { src: 3 }),
            Arc::new(Cc),
            Arc::new(Sssp { src: 3 }),
            Arc::new(KHop::new(3, 2)),
            Arc::new(PageRank),
            Arc::new(TriCount),
        ]
    }

    #[test]
    fn every_builtin_analysis_validates_through_the_trait() {
        let g = rmat10();
        let m = m8();
        for a in all_analyses() {
            let out = a.run(g.view(), &m);
            a.validate(g.view(), &out.values)
                .unwrap_or_else(|e| panic!("{}: {e}", a.describe()));
            assert_eq!(out.label, a.label());
            assert!(!out.phases.is_empty(), "{}", a.label());
            assert!(out.solo_ns(&m) > 0.0, "{}", a.label());
        }
    }

    #[test]
    fn labels_and_descriptions() {
        assert_eq!(Bfs { src: 42 }.describe(), "bfs(src=42)");
        assert_eq!(Cc.describe(), "cc");
        assert_eq!(Sssp { src: 7 }.describe(), "sssp(src=7)");
        assert_eq!(KHop::new(7, 3).describe(), "khop(src=7,k=3)");
        assert_eq!(PageRank.describe(), "pagerank");
        assert_eq!(TriCount.describe(), "tricount");
        let labels: Vec<_> = all_analyses().iter().map(|a| a.label()).collect();
        assert_eq!(labels, vec!["bfs", "cc", "sssp", "khop", "pagerank", "tricount"]);
    }

    #[test]
    fn only_parameter_free_analyses_are_demand_cacheable() {
        assert_eq!(Cc.cacheable_demand().as_deref(), Some("cc"));
        assert_eq!(PageRank.cacheable_demand().as_deref(), Some("pagerank"));
        assert_eq!(TriCount.cacheable_demand().as_deref(), Some("tricount"));
        assert!(Bfs { src: 0 }.cacheable_demand().is_none());
        assert!(Sssp { src: 0 }.cacheable_demand().is_none());
        assert!(KHop::new(0, 2).cacheable_demand().is_none());
    }

    #[test]
    fn only_rooted_traversals_expose_a_source_vertex() {
        assert_eq!(Bfs { src: 9 }.source_vertex(), Some(9));
        assert_eq!(Sssp { src: 4 }.source_vertex(), Some(4));
        assert_eq!(KHop::new(11, 2).source_vertex(), Some(11));
        assert!(Cc.source_vertex().is_none());
        assert!(PageRank.source_vertex().is_none());
        assert!(TriCount.source_vertex().is_none());
        // source_set defaults to the singleton of source_vertex.
        assert_eq!(Bfs { src: 9 }.source_set(), Some(vec![9]));
        assert!(Cc.source_set().is_none());
    }

    #[test]
    fn only_bfs_opts_into_batching() {
        assert_eq!(Bfs { src: 0 }.batch_key().as_deref(), Some("bfs"));
        assert!(Cc.batch_key().is_none());
        assert!(Sssp { src: 0 }.batch_key().is_none());
        assert!(KHop::new(0, 2).batch_key().is_none());
        assert!(PageRank.batch_key().is_none());
        assert!(TriCount.batch_key().is_none());
    }

    #[test]
    fn validate_catches_corruption() {
        let g = rmat10();
        let m = m8();
        for a in all_analyses() {
            let mut out = a.run(g.view(), &m);
            // Last element so the check also covers tricount's
            // single-value result; the magnitude is far outside every
            // oracle's tolerance (PageRank's scaled tolerance is 1e6).
            let last = out.values.len() - 1;
            out.values[last] += 999_999_999;
            assert!(a.validate(g.view(), &out.values).is_err(), "{}", a.label());
        }
    }

    /// Mutation (DESIGN.md §Mutation): every builtin analysis runs — and
    /// validates against its oracle — on an *overlaid* snapshot exactly as
    /// on a flat one, and agrees with running on the materialized CSR.
    #[test]
    fn every_builtin_analysis_validates_on_an_overlaid_view() {
        use crate::graph::store::GraphStore;
        use crate::graph::delta::EdgeUpdate;

        let g = rmat10();
        let m = m8();
        let mut store = GraphStore::new(&g);
        store.apply_batch(&[
            EdgeUpdate::insert(3, 700),
            EdgeUpdate::insert(3, 900),
            EdgeUpdate::delete(3, g.neighbors(3).first().copied().unwrap_or(0)),
        ]);
        store.apply_batch(&[EdgeUpdate::insert(700, 900)]);
        let view = store.view();
        let flat = view.to_csr();
        for a in all_analyses() {
            let out = a.run(view, &m);
            a.validate(view, &out.values)
                .unwrap_or_else(|e| panic!("{} on overlay: {e}", a.describe()));
            let flat_out = a.run(flat.view(), &m);
            assert_eq!(out.values, flat_out.values, "{}: overlay vs materialized", a.label());
            assert_eq!(
                out.phases.len(),
                flat_out.phases.len(),
                "{}: demand phase structure must match",
                a.label()
            );
        }
    }
}
