//! The tuned migratory-thread BFS (paper §III, detailed in Hein et al.
//! [10], [11]), executed functionally while emitting per-level
//! [`PhaseDemand`] vectors.
//!
//! Per level, per frontier vertex `u` (whose record and edge block live on
//! node `u mod nodes` — §IV-A):
//!
//! * the worker thread is **launched on u's home node** (the Lucata Cilk
//!   extension), which costs one migration-ish context placement plus
//!   spawn instructions;
//! * it reads u's vertex record (one fine-grained channel op) and streams
//!   u's edge block (sequential bytes on the block's channel);
//! * for **every scanned edge** it issues a **remote write** of the level /
//!   parent into `v`'s home node. Checking v's visited bit first would
//!   require a remote *read* — a migration — so the tuned implementation
//!   writes unconditionally and dedups locally when v's node builds the
//!   next frontier (this is the §III migration/write balance). Remote
//!   writes do not migrate (§II): they pay fabric bytes plus the
//!   destination channel's service.
//!
//! Per-level parallelism reported to the timing model is the level's op
//! count capped by the machine's total thread contexts: Cilk grainsize
//! splits hub vertices' edge blocks across workers, so skew does not
//! serialize a level, but a level can never use more threads than it has
//! independent memory operations.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::alg::oracle;
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::demand::{DemandBuilder, PhaseDemand};
use crate::sim::machine::Machine;

/// Breadth-first search from a source vertex, as a schedulable
/// [`Analysis`] (paper §IV: "BFS from unique sources").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bfs {
    /// Source vertex.
    pub src: u32,
}

impl Analysis for Bfs {
    fn label(&self) -> &'static str {
        "bfs"
    }

    fn describe(&self) -> String {
        format!("bfs(src={})", self.src)
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = bfs_run_offset(g, m, self.src, stripe_offset);
        QueryOutput { label: self.label(), values: run.levels, phases: run.phases }
    }

    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        oracle::check_bfs(g, self.src, values)
    }

    fn source_vertex(&self) -> Option<u32> {
        Some(self.src)
    }

    /// BFS is the batchable kind: same-epoch BFS instances fuse into one
    /// shared multi-source edge sweep ([`crate::alg::msbfs`]).
    fn batch_key(&self) -> Option<String> {
        Some(self.label().to_string())
    }
}

/// Result of one functional+demand BFS execution.
#[derive(Debug, Clone)]
pub struct BfsRun {
    /// Per-vertex BFS level, -1 if unreachable.
    pub levels: Vec<i64>,
    /// One demand vector per executed level.
    pub phases: Vec<PhaseDemand>,
    /// Frontier size per level (diagnostics / reports).
    pub frontier_sizes: Vec<usize>,
    /// Directed edges traversed per level.
    pub level_edges: Vec<usize>,
}

impl BfsRun {
    /// Number of reachable vertices (including the source).
    pub fn reached(&self) -> usize {
        self.levels.iter().filter(|&&l| l != -1).count()
    }
}

/// Run BFS from `src` on machine `m`, producing levels + per-level demand.
///
/// Equivalent to [`bfs_run_offset`] with stripe offset 0. Accepts any
/// graph read source: a `&Csr` (the flat fast path) or a [`GraphView`]
/// snapshot at an arbitrary epoch.
pub fn bfs_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine, src: u32) -> BfsRun {
    bfs_run_offset(g, m, src, 0)
}

/// Run BFS with an explicit stripe offset for the query's own arrays.
///
/// Each query allocates its own level/parent array; view-2 striping places
/// element `v` of an array with base offset `o` on channel
/// `(v/nodes + o) mod channels`. Different concurrent queries therefore
/// heat *different* channels with their hub-vertex writes — a query's own
/// load imbalance floor stays (it limits the solo time), but concurrent
/// queries spread across channels instead of all serializing on one. The
/// coordinator passes each query's index as the offset.
pub fn bfs_run_offset<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    src: u32,
    stripe_offset: usize,
) -> BfsRun {
    bfs_run_capped(g, m, src, stripe_offset, None)
}

/// The traversal core shared by full BFS (`max_depth` = None) and the
/// hop-bounded [`crate::alg::khop`] query (`Some(k)`: levels 0..k-1
/// expand, level-k vertices are discovered but not expanded). One
/// implementation so the demand model cannot diverge between the two.
pub fn bfs_run_capped<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    src: u32,
    stripe_offset: usize,
    max_depth: Option<u32>,
) -> BfsRun {
    let g: GraphView<'a> = g.into();
    let layout = m.layout;
    let nodes = m.nodes();
    let channels = m.cfg.channels_per_node;
    let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
    let cfg = &m.cfg;

    let mut levels = vec![-1i64; g.n()];
    levels[src as usize] = 0;
    let mut frontier = vec![src];
    let mut depth = 0i64;

    let mut phases = Vec::new();
    let mut frontier_sizes = Vec::new();
    let mut level_edges = Vec::new();
    let mut scratch = NeighborScratch::default();

    while !frontier.is_empty() && max_depth.is_none_or(|k| (depth as u32) < k) {
        let mut b = DemandBuilder::new(nodes, channels);
        let mut next = Vec::new();
        let mut edges_scanned = 0usize;
        let mut ops = 0.0f64;

        for &u in &frontier {
            let un = layout.node_of(u);
            // Thread launch on u's home node.
            b.migration(un, 1.0);
            b.fabric_bytes(un, 64.0); // context placement
            b.instructions(un, cfg.spawn_instr);
            // Vertex record read (local dedup of last level's writes).
            b.channel_op(un, layout.channel_of(u), 1.0);
            ops += 1.0;
            let nbrs = g.neighbors(u, &mut scratch);
            let deg = nbrs.len();
            // Edge block stream (co-located with the vertex, §IV-A).
            b.stream_bytes(un, GraphView::edge_block_bytes_for(deg) as f64);
            edges_scanned += deg;
            b.instructions(un, deg as f64 * cfg.instr_per_edge);
            for &v in nbrs {
                // Unconditional remote write of level/parent at v's home
                // (checking first would migrate; §III trades the check for
                // a write). The write lands in THIS query's own array, so
                // its channel carries the query's stripe offset.
                let vn = layout.node_of(v);
                b.channel_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                ops += 1.0;
                if vn != un {
                    b.fabric_bytes(un, 16.0);
                }
                if levels[v as usize] == -1 {
                    levels[v as usize] = depth + 1;
                    next.push(v);
                }
            }
        }

        // Grainsize-split workers: parallelism is bounded by independent
        // memory ops in the level and by the machine's context count.
        b.parallelism(ops.min(contexts_total));

        phases.push(b.finish());
        frontier_sizes.push(frontier.len());
        level_edges.push(edges_scanned);
        frontier = next;
        depth += 1;
    }

    BfsRun { levels, phases, frontier_sizes, level_edges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::oracle;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn levels_match_oracle_on_rmat() {
        let g = rmat(10, 7);
        let m = m8();
        for src in [0u32, 13, 500] {
            let run = bfs_run(&g, &m, src);
            oracle::check_bfs(&g, src, &run.levels).unwrap();
        }
    }

    #[test]
    fn one_phase_per_level() {
        let edges: Vec<(u32, u32)> = (0..9u32).map(|i| (i, i + 1)).collect();
        let g = build_undirected_csr(10, &edges);
        let run = bfs_run(&g, &m8(), 0);
        // Path of 10 vertices: levels 0..9, one expanding phase each.
        assert_eq!(run.phases.len(), 10);
        assert_eq!(run.frontier_sizes, vec![1; 10]);
        assert_eq!(run.reached(), 10);
    }

    #[test]
    fn demand_counts_match_graph_totals() {
        let g = rmat(9, 3);
        let m = m8();
        let run = bfs_run(&g, &m, g.neighbors(0).first().copied().unwrap_or(1));
        let total_ops: f64 = run.phases.iter().map(|p| p.total_channel_ops()).sum();
        let reached = run.reached() as f64;
        // One record read per frontier vertex + one unconditional write
        // per scanned edge (= every edge of every reached vertex).
        let scanned: u64 = (0..g.n() as u32)
            .filter(|&v| run.levels[v as usize] != -1)
            .map(|v| g.degree(v) as u64)
            .sum();
        assert_eq!(total_ops, reached + scanned as f64);
        // Streamed bytes = edge blocks of every reached vertex.
        let total_stream: f64 = run.phases.iter().map(|p| p.stream_bytes.iter().sum::<f64>()).sum();
        let expect: u64 = (0..g.n() as u32)
            .filter(|&v| run.levels[v as usize] != -1)
            .map(|v| g.edge_block_bytes(v))
            .sum();
        assert_eq!(total_stream, expect as f64);
    }

    #[test]
    fn migrations_one_per_reached_vertex() {
        let g = rmat(9, 11);
        let m = m8();
        let run = bfs_run(&g, &m, 1);
        let migs: f64 = run.phases.iter().map(|p| p.total_migrations()).sum();
        assert_eq!(migs, run.reached() as f64);
    }

    #[test]
    fn parallelism_tracks_level_ops() {
        // Star graph: center 0 with 64 leaves. Level 0 scans 64 edges
        // (65 ops with the record read); level 1 has 64 workers writing
        // back to the hub (128 ops).
        let edges: Vec<(u32, u32)> = (1..=64u32).map(|v| (0, v)).collect();
        let g = build_undirected_csr(65, &edges);
        let run = bfs_run(&g, &m8(), 0);
        assert_eq!(run.phases[0].parallelism, 65.0);
        assert_eq!(run.phases[1].parallelism, 128.0);
    }

    #[test]
    fn solo_time_scales_with_graph() {
        let m = m8();
        let small = rmat(9, 5);
        let big = rmat(12, 5);
        let t_small: f64 =
            bfs_run(&small, &m, 1).phases.iter().map(|p| p.solo_ns(&m)).sum();
        let t_big: f64 = bfs_run(&big, &m, 1).phases.iter().map(|p| p.solo_ns(&m)).sum();
        assert!(t_big > 2.0 * t_small, "big {t_big} small {t_small}");
    }
}
