//! Figure-2 connected components: Shiloach-Vishkin with MSP `remote_min`
//! hooks, executed functionally while emitting per-phase [`PhaseDemand`]
//! vectors.
//!
//! Each iteration is three synchronous phases, exactly the paper's loop
//! body:
//!
//! 1. **Hook sweep** — `remote_min(&C[j], C[v])` for every directed edge.
//!    The MSP at `j`'s home node performs the min inside a
//!    read-modify-write cycle: no thread migration, the issuing core keeps
//!    running (§III). Charged as MSP ops on the destination record's
//!    channel plus fabric bytes for remote endpoints.
//! 2. **Changed check + reduction** — `pC[v] != C[v]` per vertex (local
//!    reads; `pC[v] ← C[v]` is the paired local write), then the view-0
//!    `changed` flags are reduced by a single thread migrating across all
//!    nodes, casting the view-0 pointer to view-1 (a serial chain of
//!    `nodes` hops — Fig. 2 line 2).
//! 3. **Compress** — pointer-jump `C[v] ← C[C[v]]` until every label is a
//!    root. Reading `C[C[v]]` is a remote read, so it *migrates*; the
//!    migration count per vertex is its tree depth, and the phase's serial
//!    chain is the deepest tree (§III: "the number of migrations is bound
//!    by the depth of each tree").
//!
//! Functionally the hook is evaluated Jacobi-style (reads the previous
//! iteration's labels) so results are deterministic; the hardware's racy
//! in-place `remote_min` converges to the same fixpoint, possibly a sweep
//! sooner. Labels converge to each component's minimum vertex id.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::alg::oracle;
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::demand::{DemandBuilder, PhaseDemand};
use crate::sim::machine::Machine;

/// Whole-graph connected components (Figure 2), as a schedulable
/// [`Analysis`]. Parameter-free, so its demand is cacheable: the
/// coordinator computes it once and rotates channels per instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cc;

impl Analysis for Cc {
    fn label(&self) -> &'static str {
        "cc"
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = cc_run_offset(g, m, stripe_offset);
        QueryOutput { label: self.label(), values: run.labels, phases: run.phases }
    }

    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        oracle::check_cc(g, values)
    }

    fn cacheable_demand(&self) -> Option<String> {
        Some(self.label().to_string())
    }
}

/// Result of one functional+demand connected-components execution.
#[derive(Debug, Clone)]
pub struct CcRun {
    /// Final per-vertex component labels (component minimum vertex id).
    pub labels: Vec<i64>,
    /// One demand vector per synchronous phase.
    pub phases: Vec<PhaseDemand>,
    /// Number of hook/check/compress iterations executed.
    pub iterations: usize,
}

impl CcRun {
    /// Number of distinct components.
    pub fn components(&self) -> usize {
        crate::alg::oracle::component_count(&self.labels)
    }
}

/// Instructions charged per vertex in the changed-check phase (two reads,
/// compare, flag write).
const CHECK_INSTR_PER_VERTEX: f64 = 8.0;

/// Run Figure-2 connected components on machine `m` (stripe offset 0).
pub fn cc_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine) -> CcRun {
    cc_run_offset(g, m, 0)
}

/// Run connected components with an explicit stripe offset for the query's
/// own `C`/`pC` arrays (see [`crate::alg::bfs::bfs_run_offset`]: concurrent
/// queries' label traffic spreads across channels instead of stacking on
/// the canonical placement). Accepts a `&Csr` or any epoch's [`GraphView`].
pub fn cc_run_offset<'a>(g: impl Into<GraphView<'a>>, m: &Machine, stripe_offset: usize) -> CcRun {
    let g: GraphView<'a> = g.into();
    let layout = m.layout;
    let nodes = m.nodes();
    let channels = m.cfg.channels_per_node;
    let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
    let cfg = &m.cfg;
    let n = g.n();
    let mut scratch = NeighborScratch::default();

    let mut labels: Vec<i64> = (0..n as i64).collect();
    let mut phases = Vec::new();
    let mut iterations = 0usize;

    loop {
        iterations += 1;

        // --- Phase 1: hook sweep (remote_min per directed edge). ---
        let mut b = DemandBuilder::new(nodes, channels);
        let mut new_labels = labels.clone();
        let mut ops = 0.0f64;
        for u in 0..n as u32 {
            let un = layout.node_of(u);
            b.instructions(un, cfg.spawn_instr);
            b.channel_op(un, (layout.channel_of(u) + stripe_offset) % channels, 1.0); // read C[u]
            ops += 1.0;
            let nbrs = g.neighbors(u, &mut scratch);
            let deg = nbrs.len();
            b.stream_bytes(un, GraphView::edge_block_bytes_for(deg) as f64);
            b.instructions(un, deg as f64 * cfg.instr_per_edge);
            let lu = labels[u as usize];
            for &v in nbrs {
                let vn = layout.node_of(v);
                b.msp_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                ops += 1.0;
                if vn != un {
                    b.fabric_bytes(un, 16.0);
                }
                if lu < new_labels[v as usize] {
                    new_labels[v as usize] = lu;
                }
            }
        }
        // Grainsize-split edge sweeps: bounded by independent ops/contexts.
        b.parallelism(ops.min(contexts_total));
        // A flat cilk_for over all vertices, no level structure: the spawn
        // tree keeps the issue slots busy (unlike frontier-driven BFS).
        b.issue_efficiency(1.0);
        phases.push(b.finish());

        // --- Phase 2: changed check + migrating view-0 reduction. ---
        let changed = new_labels != labels;
        let mut b = DemandBuilder::new(nodes, channels);
        for v in 0..n as u32 {
            let vn = layout.node_of(v);
            // pC[v] ← C[v] (write), read back pC and C for the compare.
            b.channel_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 3.0);
            b.instructions(vn, CHECK_INSTR_PER_VERTEX);
        }
        // The reduction thread hops node to node (Fig. 2 line 2). The
        // view-0 `changed` flag is per-query private state, so its read
        // rides the stripe rotation like the C/pC arrays — CC's demand is
        // cacheable, and the cache's channel rotation must reproduce a
        // direct preparation exactly (Analysis::cacheable_demand).
        for node in 1..nodes {
            b.migration(node, 1.0);
            b.channel_op(node, stripe_offset % channels, 1.0);
            b.fabric_bytes(node - 1, 64.0);
        }
        b.serial_hops(nodes as f64 - 1.0);
        b.parallelism((n as f64).min(contexts_total));
        b.issue_efficiency(1.0); // flat per-vertex compare loop
        phases.push(b.finish());

        if !changed {
            return CcRun { labels, phases, iterations };
        }

        // --- Phase 3: compress (pointer jumping, migrations = depth). ---
        labels = new_labels;
        let mut b = DemandBuilder::new(nodes, channels);
        let mut max_depth = 0.0f64;
        let mut ops = 0.0f64;
        for v in 0..n as u32 {
            let vn = layout.node_of(v);
            b.channel_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0); // read C[v]
            ops += 1.0;
            let mut here = vn;
            let mut depth = 0.0f64;
            let mut cur = labels[v as usize] as u32;
            while labels[cur as usize] != cur as i64 {
                let tn = layout.node_of(cur);
                if tn != here {
                    b.migration(tn, 1.0);
                    b.fabric_bytes(here, 64.0);
                    here = tn;
                }
                b.channel_op(tn, (layout.channel_of(cur) + stripe_offset) % channels, 1.0); // read C[C[v]]
                ops += 1.0;
                depth += 1.0;
                cur = labels[cur as usize] as u32;
            }
            labels[v as usize] = cur as i64;
            max_depth = max_depth.max(depth);
        }
        b.serial_hops(max_depth);
        b.parallelism(ops.min(contexts_total));
        phases.push(b.finish());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::oracle;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn labels_match_oracle_on_rmat() {
        let g = rmat(10, 21);
        let run = cc_run(&g, &m8());
        oracle::check_cc(&g, &run.labels).unwrap();
        assert_eq!(run.components(), oracle::component_count(&oracle::cc_labels(&g)));
    }

    #[test]
    fn labels_match_oracle_on_forest() {
        // Disjoint paths of different lengths.
        let mut edges = Vec::new();
        let mut base = 0u32;
        for len in [1u32, 3, 7, 15] {
            for i in 0..len {
                edges.push((base + i, base + i + 1));
            }
            base += len + 1;
        }
        let g = build_undirected_csr(base as usize, &edges);
        let run = cc_run(&g, &m8());
        oracle::check_cc(&g, &run.labels).unwrap();
    }

    #[test]
    fn three_phases_per_iteration_plus_final_check() {
        let g = build_undirected_csr(4, &[(0, 1), (2, 3)]);
        let run = cc_run(&g, &m8());
        // Every iteration but the last contributes hook+check+compress;
        // the last contributes hook+check.
        assert_eq!(run.phases.len(), 3 * (run.iterations - 1) + 2);
    }

    #[test]
    fn msp_ops_equal_directed_edges_per_sweep() {
        let g = rmat(9, 2);
        let run = cc_run(&g, &m8());
        let msp: f64 = run.phases.iter().map(|p| p.msp_ops.iter().sum::<f64>()).sum();
        assert_eq!(msp, (g.m_directed() * run.iterations) as f64);
    }

    #[test]
    fn reduction_serializes_across_nodes() {
        let g = build_undirected_csr(4, &[(0, 1)]);
        let run = cc_run(&g, &m8());
        // Check phases carry the nodes-1 serial chain.
        let check = &run.phases[1];
        assert_eq!(check.serial_hops, 7.0);
        assert_eq!(check.total_migrations(), 7.0);
    }

    #[test]
    fn converges_quickly_on_rmat() {
        let g = rmat(11, 5);
        let run = cc_run(&g, &m8());
        // SV with min-hooks + full compress converges in O(log n) sweeps;
        // R-MAT's giant component typically needs only a handful.
        assert!(run.iterations <= 8, "{} iterations", run.iterations);
    }

    #[test]
    fn hook_dominates_demand() {
        // remote_min traffic (hook) should dwarf the bookkeeping phases on
        // a dense-ish graph — the §IV-C interconnect-stress story.
        let g = rmat(10, 9);
        let m = m8();
        let run = cc_run(&g, &m);
        let hook_ops: f64 = run.phases[0].total_channel_ops();
        let check_ops: f64 = run.phases[1].total_channel_ops();
        assert!(hook_ops > check_ops);
    }
}
