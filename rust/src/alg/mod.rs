//! Graph algorithms, paper §III, behind the open [`Analysis`] query API.
//!
//! Each analysis exists in two forms:
//!
//! * a **host oracle** ([`oracle`]) — the plain, obviously-correct
//!   implementation used to validate functional results;
//! * a **Pathfinder execution** ([`bfs`], [`cc`], [`sssp`], [`khop`]) —
//!   the algorithm run functionally over the real graph while emitting the
//!   per-phase [`crate::sim::PhaseDemand`] resource vectors the simulator
//!   engines charge time for. The emission follows the paper's
//!   implementation notes: the tuned BFS trades thread migrations for
//!   non-migrating remote writes (§III, [10]); connected components is
//!   Figure 2 — Shiloach-Vishkin with MSP `remote_min` hooks, a view-0
//!   `changed` flag reduced by a migrating thread, and a pointer-jumping
//!   compress; shortest paths is delta-stepping on the same `remote_min`
//!   hook; k-hop is the BFS truncated at depth k.
//!
//! The [`analysis`] module defines the [`Analysis`] trait every workload
//! implements and the coordinator schedules; [`registry`] maps class
//! labels to factories so new analyses plug in without touching the
//! serving layers (see DESIGN.md §Query-API).

pub mod analysis;
pub mod bfs;
pub mod cc;
pub mod khop;
pub mod oracle;
pub mod registry;
pub mod sssp;

pub use analysis::{Analysis, QueryOutput};
pub use bfs::{bfs_run, bfs_run_capped, bfs_run_offset, Bfs, BfsRun};
pub use cc::{cc_run, cc_run_offset, Cc, CcRun};
pub use khop::{khop_run, khop_run_offset, KHop, KhopRun};
pub use registry::{AnalysisFactory, AnalysisRegistry};
pub use sssp::{edge_weight, sssp_run, sssp_run_offset, Sssp, SsspRun};
