//! Graph algorithms, paper §III.
//!
//! Each algorithm exists in two forms:
//!
//! * a **host oracle** ([`oracle`]) — the plain, obviously-correct
//!   implementation used to validate functional results;
//! * a **Pathfinder execution** ([`bfs`], [`cc`]) — the algorithm run
//!   functionally over the real graph while emitting the per-phase
//!   [`crate::sim::PhaseDemand`] resource vectors the simulator engines
//!   charge time for. The emission follows the paper's implementation
//!   notes: the tuned BFS trades thread migrations for non-migrating
//!   remote writes (§III, [10]); connected components is Figure 2 —
//!   Shiloach-Vishkin with MSP `remote_min` hooks, a view-0 `changed`
//!   flag reduced by a migrating thread, and a pointer-jumping compress.

pub mod bfs;
pub mod cc;
pub mod oracle;
pub mod query;

pub use bfs::{bfs_run, bfs_run_offset, BfsRun};
pub use cc::{cc_run, cc_run_offset, CcRun};
pub use query::{Query, QueryOutput};
