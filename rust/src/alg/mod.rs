//! Graph algorithms, paper §III, behind the open [`Analysis`] query API.
//!
//! Each analysis exists in two forms:
//!
//! * a **host oracle** ([`oracle`]) — the plain, obviously-correct
//!   implementation used to validate functional results;
//! * a **Pathfinder execution** ([`bfs`], [`cc`], [`sssp`], [`khop`],
//!   [`pagerank`], [`tricount`]) — the algorithm run functionally over the
//!   real graph while emitting the per-phase [`crate::sim::PhaseDemand`]
//!   resource vectors the simulator engines charge time for. The emission
//!   follows the paper's implementation notes: the tuned BFS trades thread
//!   migrations for non-migrating remote writes (§III, [10]); connected
//!   components is Figure 2 — Shiloach-Vishkin with MSP `remote_min`
//!   hooks, a view-0 `changed` flag reduced by a migrating thread, and a
//!   pointer-jumping compress; shortest paths is delta-stepping on the
//!   same `remote_min` hook; k-hop is the BFS truncated at depth k;
//!   PageRank is a dense per-round `remote_add` accumulation sweep (the
//!   paper's thesis stretched to an iterative kernel — every round is a
//!   CC-hook-shaped flat sweep); triangle counting is degree-ordered
//!   neighbor intersection, the one *read*-shaped kernel (remote reads
//!   migrate, so its wedge scans pay the migrations every other kernel
//!   avoids).
//!
//! [`msbfs`] is the batched form of BFS: up to 64 same-epoch sources fused
//! into one shared edge sweep with bit-parallel u64 frontier words, the
//! kernel behind the coordinator batcher (DESIGN.md §Batching).
//!
//! The [`analysis`] module defines the [`Analysis`] trait every workload
//! implements and the coordinator schedules; [`registry`] maps class
//! labels to factories so new analyses plug in without touching the
//! serving layers (see DESIGN.md §Query-API). **Adding a seventh analysis
//! is a documented, worked-through path: see docs/ANALYSES.md**, which
//! walks the trait hooks, the demand-model derivation, the oracle and
//! property-test expectations, and the CLI/service wiring using
//! [`pagerank`] as the example.

pub mod analysis;
pub mod bfs;
pub mod cc;
pub mod khop;
pub mod msbfs;
pub mod oracle;
pub mod pagerank;
pub mod registry;
pub mod sssp;
pub mod tricount;

pub use analysis::{Analysis, QueryOutput};
pub use bfs::{bfs_run, bfs_run_capped, bfs_run_offset, Bfs, BfsRun};
pub use msbfs::{msbfs_run, msbfs_run_offset, BatchedAnalysis, MsBfsRun, MAX_BATCH_SOURCES};
pub use cc::{cc_run, cc_run_offset, Cc, CcRun};
pub use khop::{khop_run, khop_run_offset, KHop, KhopRun};
pub use pagerank::{pagerank_run, pagerank_run_offset, PageRank, PageRankRun};
pub use registry::{AnalysisFactory, AnalysisRegistry};
pub use sssp::{edge_weight, sssp_run, sssp_run_offset, Sssp, SsspRun};
pub use tricount::{tricount_run, tricount_run_offset, TriCount, TriCountRun};
