//! Multi-source BFS: up to [`MAX_BATCH_SOURCES`] breadth-first searches
//! fused into **one shared edge sweep** with bit-parallel u64 frontier /
//! visited words (the ROADMAP "Concurrent-query batching" item; the MS-BFS
//! idea of Then et al., re-priced for the Pathfinder's migratory-thread
//! cost model).
//!
//! The paper's headline workload is 100–750 *independent* concurrent BFS
//! queries over one resident graph. When many same-epoch traversals are in
//! flight, most of their per-level work is identical: launching a worker on
//! a frontier vertex's home node, reading its record, streaming its edge
//! block. The fused kernel does that once per vertex per level for the
//! whole batch — source membership rides along as one bit per source in a
//! u64 word — so k queries' worth of migrations collapses to roughly one
//! traversal's. What canNOT be shared is per-source state: each member
//! query still owns its level array, so every newly-discovered
//! `(source, vertex)` pair pays its own MSP `remote_min` relaxation into
//! that member's stripe-rotated array.
//!
//! Per level, per **union-frontier** vertex `u` (any frontier bit set):
//!
//! * one worker launch on u's home node (migration + 64 context-placement
//!   fabric bytes + spawn instructions) — charged once for the whole
//!   batch, not once per source;
//! * one channel op reading u's record + frontier word, and one edge-block
//!   stream — again once for the batch;
//! * per scanned edge `(u, v)`: one **MSP RMW** ORing u's 64-bit frontier
//!   word into v's next-frontier word at v's home (the bit-parallel
//!   analogue of the tuned BFS's unconditional remote write — checking
//!   first would migrate, so it never does), 16 fabric bytes when remote;
//! * per **newly-set bit** (source s discovers v): one MSP `remote_min`
//!   writing `levels_s[v]`, node-local at v's home (the discovery is
//!   resolved where the frontier word lives), charged in member s's
//!   stripe-rotated frame so concurrent batches heat different channels.
//!
//! [`BatchedAnalysis`] adapts a fused batch back into the open
//! [`Analysis`] API: the coordinator schedules it as ONE engine query
//! (concatenated per-source values, summed context footprint), and the
//! batching layer (`coordinator::batch`) fans per-source results and
//! latencies back out to the member requests' own records.

use crate::alg::analysis::{Analysis, QueryOutput};
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::demand::{DemandBuilder, PhaseDemand};
use crate::sim::machine::Machine;
use std::sync::Arc;

/// Widest fusable batch: one bit per source in the u64 frontier words.
pub const MAX_BATCH_SOURCES: usize = 64;

/// Result of one fused multi-source BFS execution.
#[derive(Debug, Clone)]
pub struct MsBfsRun {
    /// Per-source per-vertex BFS level, -1 if unreachable: `levels[s][v]`
    /// is bit-identical to an independent single-source BFS from
    /// `sources[s]`.
    pub levels: Vec<Vec<i64>>,
    /// One fused demand vector per executed level of the shared sweep.
    pub phases: Vec<PhaseDemand>,
    /// Union-frontier size per level (vertices with ANY bit set — the
    /// count the batch pays migrations for).
    pub frontier_sizes: Vec<usize>,
    /// Directed edges scanned per level of the shared sweep.
    pub level_edges: Vec<usize>,
}

/// [`msbfs_run_offset`] at the canonical placement.
pub fn msbfs_run<'a>(g: impl Into<GraphView<'a>>, m: &Machine, sources: &[u32]) -> MsBfsRun {
    msbfs_run_offset(g, m, sources, 0)
}

/// Run one fused multi-source BFS over `sources` (≤ 64), producing
/// per-source levels plus the fused per-level demand.
///
/// `stripe_offset` is the *batch's* own-array placement offset; member
/// `s`'s level array is additionally rotated by `s`, mirroring what the
/// members would have used had they run unfused at consecutive stripe
/// offsets.
pub fn msbfs_run_offset<'a>(
    g: impl Into<GraphView<'a>>,
    m: &Machine,
    sources: &[u32],
    stripe_offset: usize,
) -> MsBfsRun {
    assert!(
        !sources.is_empty() && sources.len() <= MAX_BATCH_SOURCES,
        "msbfs batch width must be 1..={MAX_BATCH_SOURCES}, got {}",
        sources.len()
    );
    let g: GraphView<'a> = g.into();
    let layout = m.layout;
    let nodes = m.nodes();
    let channels = m.cfg.channels_per_node;
    let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
    let cfg = &m.cfg;

    let n = g.n();
    let k = sources.len();
    let mut levels = vec![vec![-1i64; n]; k];
    let mut seen = vec![0u64; n];
    let mut frontier_mask = vec![0u64; n];
    let mut active: Vec<u32> = Vec::new();
    for (s, &src) in sources.iter().enumerate() {
        levels[s][src as usize] = 0;
        seen[src as usize] |= 1u64 << s;
        if frontier_mask[src as usize] == 0 {
            active.push(src);
        }
        frontier_mask[src as usize] |= 1u64 << s;
    }
    active.sort_unstable();

    let mut depth = 0i64;
    let mut phases = Vec::new();
    let mut frontier_sizes = Vec::new();
    let mut level_edges = Vec::new();
    let mut scratch = NeighborScratch::default();

    while !active.is_empty() {
        let mut b = DemandBuilder::new(nodes, channels);
        let mut next_mask = vec![0u64; n];
        let mut touched: Vec<u32> = Vec::new();
        let mut edges_scanned = 0usize;
        let mut ops = 0.0f64;

        for &u in &active {
            let un = layout.node_of(u);
            // ONE worker launch per union-frontier vertex — the whole
            // batch shares it (the fusion win).
            b.migration(un, 1.0);
            b.fabric_bytes(un, 64.0); // context placement
            b.instructions(un, cfg.spawn_instr);
            // Vertex record + 64-bit frontier word, read once per batch.
            b.channel_op(un, layout.channel_of(u), 1.0);
            ops += 1.0;
            let fmask = frontier_mask[u as usize];
            let nbrs = g.neighbors(u, &mut scratch);
            let deg = nbrs.len();
            b.stream_bytes(un, GraphView::edge_block_bytes_for(deg) as f64);
            edges_scanned += deg;
            b.instructions(un, deg as f64 * cfg.instr_per_edge);
            for &v in nbrs {
                // Bit-parallel analogue of the tuned BFS's unconditional
                // remote write: one MSP RMW ORs u's frontier word into
                // v's next-frontier word at v's home (checking first
                // would migrate; §III trades the check for a write). One
                // RMW carries all k sources.
                let vn = layout.node_of(v);
                b.msp_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                ops += 1.0;
                if vn != un {
                    b.fabric_bytes(un, 16.0);
                }
                let new = fmask & !seen[v as usize];
                if new != 0 {
                    if next_mask[v as usize] == 0 {
                        touched.push(v);
                    }
                    next_mask[v as usize] |= new;
                    seen[v as usize] |= new;
                    let vc = layout.channel_of(v);
                    let mut bits = new;
                    while bits != 0 {
                        let s = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        levels[s][v as usize] = depth + 1;
                        // Per-(source, vertex) relaxation: member s's own
                        // level array cannot be shared — one MSP
                        // remote_min at v's home, in s's rotated frame.
                        // Node-local: the discovery is resolved where the
                        // frontier word lives, so no fabric message.
                        b.msp_op(vn, (vc + stripe_offset + s) % channels, 1.0);
                        ops += 1.0;
                    }
                }
            }
        }

        // Grainsize-split workers, like the single-source kernel.
        b.parallelism(ops.min(contexts_total));

        phases.push(b.finish());
        frontier_sizes.push(active.len());
        level_edges.push(edges_scanned);
        touched.sort_unstable();
        active = touched;
        std::mem::swap(&mut frontier_mask, &mut next_mask);
        depth += 1;
    }

    MsBfsRun { levels, phases, frontier_sizes, level_edges }
}

/// A fused batch of compatible analyses, schedulable as ONE engine query.
///
/// This is the adapter half of the batching API redesign: the coordinator
/// batcher ([`crate::coordinator::batch`]) coalesces queued requests whose
/// [`Analysis::batch_key`] matches (same kind, same epoch) into one
/// `BatchedAnalysis`, which runs the fused multi-source kernel and carries
/// the fused demand. Per-source results fan back out through
/// [`BatchedAnalysis::member_values`]; per-source latency/SLO accounting
/// stays on the member requests' own records
/// ([`crate::coordinator::RunReport`]).
///
/// The fused execution is the level-synchronous MS-BFS kernel, so only
/// analyses whose per-source semantics are BFS levels should opt into
/// batching today (see docs/ANALYSES.md §Batching); a mismatched opt-in
/// fails loudly in [`Analysis::validate`], which checks every member
/// against its OWN oracle.
#[derive(Debug, Clone)]
pub struct BatchedAnalysis {
    members: Vec<Arc<dyn Analysis>>,
    sources: Vec<u32>,
    key: String,
}

impl BatchedAnalysis {
    /// Fuse `members` into one batch. Fails unless every member returns
    /// the same `Some` [`Analysis::batch_key`], exposes a source vertex,
    /// and the batch fits in [`MAX_BATCH_SOURCES`].
    pub fn fuse(members: Vec<Arc<dyn Analysis>>) -> anyhow::Result<Self> {
        anyhow::ensure!(!members.is_empty(), "cannot fuse an empty batch");
        anyhow::ensure!(
            members.len() <= MAX_BATCH_SOURCES,
            "batch width {} exceeds the {MAX_BATCH_SOURCES}-bit frontier word",
            members.len()
        );
        let key = members[0]
            .batch_key()
            .ok_or_else(|| anyhow::anyhow!("{} is not batchable", members[0].describe()))?;
        let mut sources = Vec::with_capacity(members.len());
        for a in &members {
            anyhow::ensure!(
                a.batch_key().as_deref() == Some(key.as_str()),
                "incompatible batch member {} (key {:?}, batch key {key:?})",
                a.describe(),
                a.batch_key()
            );
            let src = a.source_vertex().ok_or_else(|| {
                anyhow::anyhow!("batchable analysis {} exposes no source vertex", a.describe())
            })?;
            sources.push(src);
        }
        Ok(BatchedAnalysis { members, sources, key })
    }

    /// Number of fused member queries.
    pub fn width(&self) -> usize {
        self.members.len()
    }

    /// The batch's source set, in member order.
    pub fn sources(&self) -> &[u32] {
        &self.sources
    }

    /// The fused member analyses, in member order.
    pub fn members(&self) -> &[Arc<dyn Analysis>] {
        &self.members
    }

    /// The shared [`Analysis::batch_key`] of every member.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Split a fused value vector (concatenated per-source results) back
    /// into per-member slices.
    pub fn member_values<'v>(&self, values: &'v [i64]) -> anyhow::Result<Vec<&'v [i64]>> {
        let k = self.width();
        anyhow::ensure!(
            k > 0 && values.len() % k == 0,
            "fused value vector of {} does not split into {k} members",
            values.len()
        );
        Ok(values.chunks_exact(values.len() / k).collect())
    }
}

impl Analysis for BatchedAnalysis {
    fn label(&self) -> &'static str {
        "msbfs"
    }

    fn describe(&self) -> String {
        format!("msbfs(key={}, w={}, srcs={:?})", self.key, self.width(), self.sources)
    }

    fn run_offset(&self, g: GraphView<'_>, m: &Machine, stripe_offset: usize) -> QueryOutput {
        let run = msbfs_run_offset(g, m, &self.sources, stripe_offset);
        let mut values = Vec::with_capacity(self.width() * g.n());
        for lv in run.levels {
            values.extend(lv);
        }
        QueryOutput { label: self.label(), values, phases: run.phases }
    }

    /// Every member validates its own slice against its OWN oracle — the
    /// fused run must be bit-identical to each member's independent run.
    fn validate(&self, g: GraphView<'_>, values: &[i64]) -> anyhow::Result<()> {
        for (a, slice) in self.members.iter().zip(self.member_values(values)?) {
            a.validate(g, slice)
                .map_err(|e| anyhow::anyhow!("batch member {}: {e}", a.describe()))?;
        }
        Ok(())
    }

    /// Σ per-source frontiers: the batch reserves every member's context
    /// footprint — fusing shares the sweep, not the members' memory.
    fn ctx_mem_bytes(&self, g: GraphView<'_>, m: &Machine) -> Option<u64> {
        Some(
            self.members
                .iter()
                .map(|a| a.ctx_mem_bytes(g, m).unwrap_or(m.cfg.ctx_bytes_per_query))
                .sum(),
        )
    }

    /// A fused batch is never re-batched.
    fn batch_key(&self) -> Option<String> {
        None
    }

    /// Not a single-source traversal; the fleet router uses
    /// [`Analysis::source_set`] instead.
    fn source_vertex(&self) -> Option<u32> {
        None
    }

    fn source_set(&self) -> Option<Vec<u32>> {
        Some(self.sources.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::bfs::{bfs_run_offset, Bfs};
    use crate::alg::oracle;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::csr::Csr;
    use crate::graph::rmat::Rmat;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn rmat(scale: u32, seed: u64) -> Csr {
        let mut cfg = GraphConfig::with_scale(scale);
        cfg.seed = seed;
        let r = Rmat::new(cfg);
        build_undirected_csr(1 << scale, &r.edges())
    }

    #[test]
    fn fused_levels_bit_match_every_single_source_oracle() {
        let g = rmat(10, 7);
        let m = m8();
        let sources = [0u32, 13, 500, 900, 77];
        let run = msbfs_run(&g, &m, &sources);
        assert_eq!(run.levels.len(), sources.len());
        for (s, &src) in sources.iter().enumerate() {
            oracle::check_bfs(&g, src, &run.levels[s]).unwrap();
        }
    }

    #[test]
    fn duplicate_sources_share_one_frontier_bit_path() {
        let g = rmat(9, 3);
        let m = m8();
        let run = msbfs_run(&g, &m, &[5, 5]);
        assert_eq!(run.levels[0], run.levels[1]);
        oracle::check_bfs(&g, 5, &run.levels[0]).unwrap();
    }

    #[test]
    fn migrations_are_one_sweeps_worth_not_k() {
        let g = rmat(10, 11);
        let m = m8();
        let sources = [1u32, 2, 3, 4, 5, 6, 7, 8];
        let fused = msbfs_run(&g, &m, &sources);
        let fused_migs: f64 = fused.phases.iter().map(|p| p.total_migrations()).sum();
        let indiv_migs: f64 = sources
            .iter()
            .map(|&s| {
                bfs_run_offset(&g, &m, s, 0)
                    .phases
                    .iter()
                    .map(|p| p.total_migrations())
                    .sum::<f64>()
            })
            .sum();
        // Migrations = Σ per level of the UNION frontier size.
        let union: usize = fused.frontier_sizes.iter().sum();
        assert_eq!(fused_migs, union as f64);
        // On a connected-ish R-MAT the union frontiers overlap heavily:
        // fusing 8 sources must cost far less than 8 independent sweeps.
        assert!(
            fused_migs < indiv_migs / 2.0,
            "fused {fused_migs} vs independent {indiv_migs}"
        );
    }

    #[test]
    fn per_source_relaxations_are_charged_as_msp_rmws() {
        // Path 0-1-2: sources {0, 2}. Union frontiers: {0,2}, {1}, {0,2}.
        let g = build_undirected_csr(3, &[(0, 1), (1, 2)]);
        let m = m8();
        let run = msbfs_run(&g, &m, &[0, 2]);
        assert_eq!(run.frontier_sizes, vec![2, 1, 2]);
        // Edge-word RMWs = edges scanned; relaxation RMWs = newly-set
        // bits = Σ_s (reached_s - 1) = 2 + 2.
        let edges: usize = run.level_edges.iter().sum();
        let msp: f64 = run.phases.iter().map(|p| p.msp_ops.iter().sum::<f64>()).sum();
        assert_eq!(msp, edges as f64 + 4.0);
    }

    #[test]
    fn width_one_matches_single_source_levels() {
        let g = rmat(9, 5);
        let m = m8();
        let run = msbfs_run(&g, &m, &[42]);
        let single = bfs_run_offset(&g, &m, 42, 0);
        assert_eq!(run.levels[0], single.levels);
        assert_eq!(run.frontier_sizes, single.frontier_sizes);
        assert_eq!(run.level_edges, single.level_edges);
    }

    #[test]
    fn batched_analysis_runs_validates_and_fans_out() {
        let g = rmat(10, 9);
        let m = m8();
        let members: Vec<Arc<dyn Analysis>> = vec![
            Arc::new(Bfs { src: 3 }),
            Arc::new(Bfs { src: 700 }),
            Arc::new(Bfs { src: 41 }),
        ];
        let b = BatchedAnalysis::fuse(members).unwrap();
        assert_eq!(b.width(), 3);
        assert_eq!(b.sources(), &[3, 700, 41]);
        assert_eq!(b.source_set().unwrap(), vec![3, 700, 41]);
        assert!(b.source_vertex().is_none());
        assert!(b.batch_key().is_none(), "a fused batch is never re-batched");
        let out = b.run(g.view(), &m);
        assert_eq!(out.values.len(), 3 * g.n());
        b.validate(g.view(), &out.values).unwrap();
        let slices = b.member_values(&out.values).unwrap();
        oracle::check_bfs(&g, 700, slices[1]).unwrap();
        // Context footprint sums the members'.
        assert_eq!(
            b.ctx_mem_bytes(g.view(), &m),
            Some(3 * m.cfg.ctx_bytes_per_query)
        );
    }

    #[test]
    fn fusing_incompatible_or_sourceless_members_fails() {
        use crate::alg::cc::Cc;
        let no_key: Vec<Arc<dyn Analysis>> = vec![Arc::new(Cc)];
        assert!(BatchedAnalysis::fuse(no_key).is_err());
        let too_wide: Vec<Arc<dyn Analysis>> =
            (0..65).map(|s| Arc::new(Bfs { src: s }) as Arc<dyn Analysis>).collect();
        assert!(BatchedAnalysis::fuse(too_wide).is_err());
        let mixed: Vec<Arc<dyn Analysis>> = vec![Arc::new(Bfs { src: 1 }), Arc::new(Cc)];
        assert!(BatchedAnalysis::fuse(mixed).is_err());
    }

    #[test]
    fn fused_sweep_respects_overlays() {
        use crate::graph::delta::DeltaOverlay;
        let g = build_undirected_csr(4, &[(0, 1), (1, 2), (2, 3)]);
        let ov = [Arc::new(DeltaOverlay::from_effective(&[(0, 3)], &[(1, 2)]))];
        let v = GraphView::overlaid(&g, &ov);
        let m = m8();
        let run = msbfs_run(v, &m, &[0, 2]);
        for (s, &src) in [0u32, 2].iter().enumerate() {
            assert_eq!(run.levels[s], oracle::bfs_levels(v, src), "src {src}");
        }
    }
}
