//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs only at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the Layer-2 step functions (whose hot spots are the Layer-1
//! Pallas kernels) to **HLO text** under `artifacts/`, with a JSON manifest
//! describing every (kind, batch, n) variant. This module is the request-
//! path side: parse the manifest ([`artifact`]), compile each variant once
//! on the PJRT CPU client, and execute ([`client`]).
//!
//! Interchange is HLO *text*, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactEntry, ArtifactManifest};
pub use client::Engine;
