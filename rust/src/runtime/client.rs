//! The PJRT execution engine: compile HLO-text artifacts once, execute many
//! times from the rust hot path.
//!
//! The real client needs the `xla` crate (native XLA/PJRT bindings), which
//! the offline build environment does not ship. It is therefore gated
//! behind the non-default `pjrt` cargo feature; the default build compiles
//! a stub [`Engine`] with the same API whose constructor reports the
//! feature is disabled, so everything downstream (baseline engine, Table
//! III anchoring, CLI `baseline`/`validate`) compiles and degrades to the
//! modeled path. See DESIGN.md §PJRT-Gating.

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    use crate::runtime::artifact::{ArtifactEntry, ArtifactManifest};

    /// Compiled-executable cache keyed by variant name. Compilation happens
    /// on first use (lazy) or eagerly via [`Engine::compile_all`]; execution
    /// then never touches the filesystem or Python.
    pub struct Engine {
        client: xla::PjRtClient,
        manifest: ArtifactManifest,
        exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl Engine {
        /// Create a CPU PJRT engine over a loaded manifest.
        pub fn new(manifest: ArtifactManifest) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine { client, manifest, exes: Mutex::new(HashMap::new()) })
        }

        /// Convenience: load the manifest from `dir` and build the engine.
        pub fn from_dir(dir: &std::path::Path) -> Result<Self> {
            Self::new(ArtifactManifest::load(dir)?)
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Eagerly compile every variant in the manifest. Returns compile
        /// wall time per variant (name, seconds) for the §Perf report.
        pub fn compile_all(&self) -> Result<Vec<(String, f64)>> {
            let entries: Vec<ArtifactEntry> = self.manifest.entries.clone();
            let mut times = Vec::with_capacity(entries.len());
            for e in &entries {
                let t0 = std::time::Instant::now();
                self.ensure_compiled(&e.name)?;
                times.push((e.name.clone(), t0.elapsed().as_secs_f64()));
            }
            Ok(times)
        }

        /// Compile `name` if not already cached.
        fn ensure_compiled(&self, name: &str) -> Result<()> {
            {
                let exes = self.exes.lock().unwrap();
                if exes.contains_key(name) {
                    return Ok(());
                }
            }
            let entry = self
                .manifest
                .by_name(name)
                .with_context(|| format!("unknown artifact variant {name:?}"))?;
            let path = self.manifest.hlo_path(entry);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("PJRT compile of {name}"))?;
            self.exes.lock().unwrap().insert(name.to_string(), exe);
            Ok(())
        }

        /// Execute a variant with host `f32` buffers, returning the
        /// flattened output tuple as host vectors (in the manifest's
        /// `outputs` order).
        ///
        /// `inputs` are (data, dims) pairs; dims must multiply to data
        /// length.
        pub fn execute_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            self.ensure_compiled(name)?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let len: i64 = dims.iter().product::<i64>().max(1);
                    anyhow::ensure!(
                        len as usize == data.len(),
                        "input shape {dims:?} does not match data length {}",
                        data.len()
                    );
                    let lit = xla::Literal::vec1(data);
                    if dims.is_empty() {
                        // Scalar: reshape to rank-0.
                        Ok(lit.reshape(&[])?)
                    } else {
                        Ok(lit.reshape(dims)?)
                    }
                })
                .collect::<Result<_>>()?;

            let exes = self.exes.lock().unwrap();
            let exe = exes.get(name).expect("compiled above");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {name}"))?;
            let lit = result[0][0].to_literal_sync()?;
            drop(exes);

            // Lowered with return_tuple=True: always a tuple, possibly of
            // one.
            let parts = lit.to_tuple().context("decomposing output tuple")?;
            let entry = self.manifest.by_name(name).unwrap();
            anyhow::ensure!(
                parts.len() == entry.outputs.len(),
                "{name}: got {} outputs, manifest says {}",
                parts.len(),
                entry.outputs.len()
            );
            parts.into_iter().map(|p| Ok(p.to_vec::<f32>()?)).collect()
        }

        /// Number of compiled (cached) executables.
        pub fn compiled_count(&self) -> usize {
            self.exes.lock().unwrap().len()
        }
    }

    impl std::fmt::Debug for Engine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Engine")
                .field("platform", &self.platform())
                .field("variants", &self.manifest.entries.len())
                .field("compiled", &self.compiled_count())
                .finish()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use anyhow::Result;

    use crate::runtime::artifact::ArtifactManifest;

    const DISABLED: &str = "PJRT runtime disabled: this build has no `xla` bindings. \
         Add the `xla` crate to rust/Cargo.toml and build with \
         `--features pjrt` to execute AOT artifacts; the modeled \
         baseline path works without it.";

    /// API-compatible stand-in for the PJRT engine when the `pjrt` feature
    /// is off. Construction always fails with a pointer at the feature, so
    /// callers that probe for artifacts degrade exactly like a missing
    /// artifact directory.
    pub struct Engine {
        manifest: ArtifactManifest,
    }

    impl Engine {
        pub fn new(manifest: ArtifactManifest) -> Result<Self> {
            // Keep the field nominally constructible for API parity.
            let _ = &manifest;
            anyhow::bail!(DISABLED)
        }

        pub fn from_dir(dir: &std::path::Path) -> Result<Self> {
            Self::new(ArtifactManifest::load(dir)?)
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "pjrt-disabled".into()
        }

        pub fn compile_all(&self) -> Result<Vec<(String, f64)>> {
            anyhow::bail!(DISABLED)
        }

        pub fn execute_f32(
            &self,
            _name: &str,
            _inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            anyhow::bail!(DISABLED)
        }

        pub fn compiled_count(&self) -> usize {
            0
        }
    }

    impl std::fmt::Debug for Engine {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Engine")
                .field("platform", &self.platform())
                .field("variants", &self.manifest.entries.len())
                .finish()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::Engine;
#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::artifact::default_artifacts_dir;

    fn engine() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::from_dir(&dir).unwrap())
    }

    #[test]
    fn compiles_and_runs_bfs_step() {
        let Some(eng) = engine() else { return };
        let m = eng.manifest();
        let n = m.n;
        let e = m.bfs_variant_for(1).unwrap().clone();
        let b = e.batch;

        // Tiny graph embedded in the padded adjacency: 0-1, 1-2.
        let mut adj = vec![0.0f32; n * n];
        for (u, v) in [(0usize, 1usize), (1, 0), (1, 2), (2, 1)] {
            adj[u * n + v] = 1.0;
        }
        let mut frontier = vec![0.0f32; b * n];
        let mut visited = vec![0.0f32; b * n];
        let levels = vec![-1.0f32; b * n];
        frontier[0] = 1.0; // query 0 starts at vertex 0
        visited[0] = 1.0;

        let out = eng
            .execute_f32(
                &e.name,
                &[
                    (&adj, &[n as i64, n as i64]),
                    (&frontier, &[b as i64, n as i64]),
                    (&visited, &[b as i64, n as i64]),
                    (&levels, &[b as i64, n as i64]),
                    (&[1.0f32], &[]),
                ],
            )
            .unwrap();
        // Outputs: next_frontier, visited, levels, active.
        assert_eq!(out.len(), 4);
        let next = &out[0];
        assert_eq!(next[1], 1.0, "vertex 1 discovered");
        assert_eq!(next[0], 0.0, "source not rediscovered");
        assert_eq!(next[2], 0.0, "vertex 2 is two hops away");
        let active = &out[3];
        assert_eq!(active[0], 1.0, "one new vertex for query 0");
        if b > 1 {
            assert_eq!(active[1], 0.0, "idle batch lanes stay empty");
        }
        assert_eq!(eng.compiled_count(), 1);
    }

    #[test]
    fn cc_step_converges_on_tiny_graph() {
        let Some(eng) = engine() else { return };
        let m = eng.manifest();
        let n = m.n;
        let e = m.cc_variant().unwrap().clone();

        // Two components {0,1,2} and {3,4}; everything else isolated.
        let mut adj = vec![0.0f32; n * n];
        for (u, v) in [(0usize, 1usize), (1, 0), (1, 2), (2, 1), (3, 4), (4, 3)] {
            adj[u * n + v] = 1.0;
        }
        let mut labels: Vec<f32> = (0..n).map(|i| i as f32).collect();
        for _ in 0..10 {
            let out = eng
                .execute_f32(
                    &e.name,
                    &[(&adj, &[n as i64, n as i64]), (&labels, &[n as i64])],
                )
                .unwrap();
            let changed = out[1][0];
            labels = out[0].clone();
            if changed == 0.0 {
                break;
            }
        }
        assert_eq!(&labels[..5], &[0.0, 0.0, 0.0, 3.0, 3.0]);
        assert_eq!(labels[5], 5.0, "isolated vertex keeps its own label");
    }

    #[test]
    fn bad_shape_is_reported() {
        let Some(eng) = engine() else { return };
        let name = eng.manifest().bfs_variant_for(1).unwrap().name.clone();
        let err = eng.execute_f32(&name, &[(&[1.0f32], &[2, 2])]).unwrap_err();
        assert!(err.to_string().contains("does not match"));
    }

}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;
    use crate::runtime::artifact::ArtifactManifest;

    #[test]
    fn stub_construction_names_the_feature() {
        let err = Engine::new(ArtifactManifest {
            version: 1,
            n: 16,
            entries: vec![],
            dir: std::path::PathBuf::from("/nonexistent"),
        });
        let msg = err.err().expect("stub must refuse").to_string();
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
