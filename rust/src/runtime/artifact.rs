//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. One entry per AOT-lowered HLO module.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One AOT-compiled computation variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    /// Unique variant name, e.g. `bfs_step_b32_n1024`.
    pub name: String,
    /// Computation kind: `bfs_step` or `cc_step`.
    pub kind: String,
    /// Batch dimension (0 for unbatched kinds).
    pub batch: usize,
    /// Padded vertex-count dimension.
    pub n: usize,
    /// HLO text file, relative to the artifacts dir.
    pub path: String,
    /// Output tuple element names, in order.
    pub outputs: Vec<String>,
    /// SHA-256 of the HLO text (integrity check across the language gap).
    pub sha256: String,
}

impl ArtifactEntry {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(ArtifactEntry {
            name: v.str_of("name")?,
            kind: v.str_of("kind")?,
            batch: v.usize_of("batch")?,
            n: v.usize_of("n")?,
            path: v.str_of("path")?,
            outputs: v
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|x| x.as_str().map(str::to_owned))
                .collect::<Result<_>>()?,
            sha256: v.str_of("sha256")?,
        })
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactManifest {
    pub version: u64,
    /// Padded graph dimension all variants were lowered at.
    pub n: usize,
    pub entries: Vec<ArtifactEntry>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let v = Json::parse_file(&path)
            .with_context(|| format!("loading artifact manifest {path:?} — run `make artifacts`"))?;
        let m = ArtifactManifest {
            version: v.u64_of("version")?,
            n: v.usize_of("n")?,
            entries: v
                .get("entries")?
                .as_arr()?
                .iter()
                .map(ArtifactEntry::from_json)
                .collect::<Result<_>>()?,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.version == 1, "unknown manifest version {}", self.version);
        anyhow::ensure!(!self.entries.is_empty(), "empty artifact manifest");
        for e in &self.entries {
            anyhow::ensure!(e.n == self.n, "variant {} lowered at n={} != manifest n={}", e.name, e.n, self.n);
            let p = self.dir.join(&e.path);
            anyhow::ensure!(p.exists(), "artifact file missing: {p:?} — run `make artifacts`");
        }
        let mut names: Vec<&str> = self.entries.iter().map(|e| e.name.as_str()).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        anyhow::ensure!(names.len() == before, "duplicate variant names in manifest");
        Ok(())
    }

    /// Find a variant by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All variants of a kind, sorted by batch.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> =
            self.entries.iter().filter(|e| e.kind == kind).collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Available BFS batch sizes, ascending.
    pub fn bfs_batches(&self) -> Vec<usize> {
        self.by_kind("bfs_step").iter().map(|e| e.batch).collect()
    }

    /// The BFS-step variant used to serve `want` queries at once: the
    /// smallest batch ≥ `want`, or the largest available (the engine then
    /// chunks). None if no bfs_step variants exist.
    pub fn bfs_variant_for(&self, want: usize) -> Option<&ArtifactEntry> {
        let all = self.by_kind("bfs_step");
        all.iter().find(|e| e.batch >= want).copied().or_else(|| all.last().copied())
    }

    /// The CC-step variant.
    pub fn cc_variant(&self) -> Option<&ArtifactEntry> {
        self.by_kind("cc_step").into_iter().next()
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.path)
    }
}

/// Default artifacts directory: `$PATHFINDER_ARTIFACTS` or
/// `<crate root>/artifacts` (works from `cargo test` / `cargo bench`).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PATHFINDER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.version, 1);
        assert!(m.n >= 64);
        assert!(!m.bfs_batches().is_empty());
        assert!(m.cc_variant().is_some());
        // Batch selection: smallest fitting variant, fallback to largest.
        let b = m.bfs_batches();
        let first = m.bfs_variant_for(1).unwrap();
        assert_eq!(first.batch, b[0]);
        let huge = m.bfs_variant_for(100_000).unwrap();
        assert_eq!(huge.batch, *b.last().unwrap());
        // Name lookup round-trips.
        let e = m.by_name(&first.name).unwrap();
        assert_eq!(e.kind, "bfs_step");
        assert!(m.hlo_path(e).exists());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(ArtifactManifest::load(Path::new("/nonexistent")).is_err());
    }
}
