//! Workload configuration: graph shape and query mixes.

use anyhow::Result;

use crate::util::json::Json;

/// Graph500 / R-MAT generator parameters (paper §IV-A).
#[derive(Debug, Clone, PartialEq)]
pub struct GraphConfig {
    /// log2 of the vertex count. The paper uses 25; the default here is
    /// smaller so full experiment sweeps finish in CI time — the timing
    /// model is per-operation, so ratios are scale-invariant (see
    /// EXPERIMENTS.md §Scale-substitution).
    pub scale: u32,
    /// Half the average degree; the paper uses 16 (=> 32 directed).
    pub edge_factor: u32,
    /// R-MAT quadrant probabilities (Graph500 reference values).
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Generator seed; equal seeds give identical graphs.
    pub seed: u64,
}

impl Default for GraphConfig {
    fn default() -> Self {
        GraphConfig {
            scale: 16,
            edge_factor: 16,
            a: 0.57,
            b: 0.19,
            c: 0.19,
            seed: 0x4c75_6361_7461, // "Lucata"
        }
    }
}

impl GraphConfig {
    pub fn with_scale(scale: u32) -> Self {
        GraphConfig { scale, ..Default::default() }
    }

    pub fn n_vertices(&self) -> u64 {
        1u64 << self.scale
    }

    pub fn n_edges_target(&self) -> u64 {
        self.n_vertices() * self.edge_factor as u64
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.scale >= 4 && self.scale <= 32, "scale out of range");
        anyhow::ensure!(self.edge_factor >= 1, "edge_factor must be >= 1");
        let d = 1.0 - self.a - self.b - self.c;
        anyhow::ensure!(
            self.a > 0.0 && self.b >= 0.0 && self.c >= 0.0 && d >= -1e-12,
            "R-MAT probabilities must be a valid distribution (d = {d})"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scale", Json::num(self.scale as f64)),
            ("edge_factor", Json::num(self.edge_factor as f64)),
            ("a", Json::num(self.a)),
            ("b", Json::num(self.b)),
            ("c", Json::num(self.c)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let cfg = GraphConfig {
            scale: v.u64_of("scale")? as u32,
            edge_factor: v.u64_of("edge_factor")? as u32,
            a: v.f64_of("a")?,
            b: v.f64_of("b")?,
            c: v.f64_of("c")?,
            seed: v.u64_of("seed")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One query-mix point, e.g. Table II's "136 BFS + 34 CC on 8 nodes".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MixPoint {
    pub bfs: usize,
    pub cc: usize,
}

impl MixPoint {
    pub fn total(&self) -> usize {
        self.bfs + self.cc
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bfs", Json::num(self.bfs as f64)),
            ("cc", Json::num(self.cc as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(MixPoint { bfs: v.usize_of("bfs")?, cc: v.usize_of("cc")? })
    }
}

/// Workload description for an experiment run.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub graph: GraphConfig,
    /// Seed for BFS source selection (paper: "reproducibly pseudorandomly
    /// generated" unique sources).
    pub source_seed: u64,
    /// Query counts swept in the Fig. 3 / Fig. 4 experiments.
    pub query_counts: Vec<usize>,
    /// BFS/CC mixes for the Table II experiment (80/20 and 90/10 on both
    /// machine sizes, as in the paper).
    pub mixes: Vec<MixPoint>,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            graph: GraphConfig::default(),
            source_seed: 0xBF5,
            query_counts: vec![1, 8, 16, 32, 64, 128, 256, 384, 512, 640, 750],
            mixes: vec![
                MixPoint { bfs: 136, cc: 34 },
                MixPoint { bfs: 153, cc: 17 },
                MixPoint { bfs: 560, cc: 140 },
                MixPoint { bfs: 630, cc: 70 },
            ],
        }
    }
}

impl WorkloadConfig {
    pub fn validate(&self) -> Result<()> {
        self.graph.validate()?;
        anyhow::ensure!(!self.query_counts.is_empty(), "need at least one query count");
        anyhow::ensure!(
            self.query_counts.windows(2).all(|w| w[0] < w[1]),
            "query_counts must be strictly increasing"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("graph", self.graph.to_json()),
            ("source_seed", Json::num(self.source_seed as f64)),
            (
                "query_counts",
                Json::arr(self.query_counts.iter().map(|&q| Json::num(q as f64))),
            ),
            ("mixes", Json::arr(self.mixes.iter().map(|m| m.to_json()))),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let cfg = WorkloadConfig {
            graph: GraphConfig::from_json(v.get("graph")?)?,
            source_seed: v.u64_of("source_seed")?,
            query_counts: v
                .get("query_counts")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
            mixes: v
                .get("mixes")?
                .as_arr()?
                .iter()
                .map(MixPoint::from_json)
                .collect::<Result<_>>()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        WorkloadConfig::default().validate().unwrap();
    }

    #[test]
    fn rmat_probabilities_sum() {
        let g = GraphConfig::default();
        assert!((g.a + g.b + g.c - 0.95).abs() < 1e-12); // d = 0.05
        g.validate().unwrap();
    }

    #[test]
    fn paper_scale_is_expressible() {
        let g = GraphConfig { scale: 25, ..Default::default() };
        g.validate().unwrap();
        assert_eq!(g.n_vertices(), 33_554_432); // the paper's vertex count
    }

    #[test]
    fn invalid_probs_rejected() {
        let g = GraphConfig { a: 0.9, b: 0.2, ..Default::default() };
        assert!(g.validate().is_err());
    }

    #[test]
    fn monotone_counts_enforced() {
        let mut w = WorkloadConfig::default();
        w.query_counts = vec![8, 8];
        assert!(w.validate().is_err());
    }

    #[test]
    fn json_round_trip() {
        let w = WorkloadConfig::default();
        let back = WorkloadConfig::from_json(&w.to_json()).unwrap();
        assert_eq!(w, back);
    }

    #[test]
    fn paper_mixes_present() {
        // Table II's four rows must be the default mixes.
        let w = WorkloadConfig::default();
        assert!(w.mixes.contains(&MixPoint { bfs: 136, cc: 34 }));
        assert!(w.mixes.contains(&MixPoint { bfs: 630, cc: 70 }));
    }
}
