//! Pathfinder machine model configuration.
//!
//! Published parameters (paper §II and §IV): 24 cores/node at 225 MHz, 64
//! hardware thread contexts per core (1536/node), 8 NCDRAM channels/node at
//! 2 GB/s each, 8 memory-side processors (MSPs) per node, 8 nodes per
//! chassis, 64 GiB NCDRAM per node, RapidIO fabric. Two of the CRNCH
//! machine's four chassis ran with reduced memory/network speed for
//! stability (§IV-B) — modeled by `degraded_chassis` + `degrade_factor`.
//!
//! Parameters the paper does not publish (random-access service time of a
//! narrow channel, migration overhead, per-level synchronization cost) are
//! calibration knobs; their defaults are fitted so the simulator reproduces
//! the paper's single-query and saturated-concurrency rates (see
//! EXPERIMENTS.md §Calibration).

use anyhow::Result;

use crate::util::json::Json;

/// RapidIO fabric model, plus the inter-chassis *fleet interconnect* a
/// multi-machine cluster ships frontier exchanges and replication traffic
/// over (DESIGN.md §Fleet). The interconnect is a separate, slower pipe
/// from the intra-machine RapidIO links: single-machine demands never touch
/// it, so its parameters are inert outside `serve --fleet`.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricConfig {
    /// One-way latency between nodes in the same chassis (ns).
    pub intra_chassis_latency_ns: f64,
    /// One-way latency between nodes in different chassis (ns).
    pub inter_chassis_latency_ns: f64,
    /// Per-node egress/ingress bandwidth onto the fabric (bytes/s).
    pub node_link_bytes_per_s: f64,
    /// Per-node share of the inter-machine fleet interconnect (bytes/s):
    /// the capacity one node can push toward *other chassis of a fleet*
    /// (cross-shard frontier exchange, replication log shipping).
    pub interconnect_bytes_per_s: f64,
    /// One-way latency of an inter-machine interconnect message (ns);
    /// floors any phase that performs at least one cross-shard exchange.
    pub interconnect_latency_ns: f64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            intra_chassis_latency_ns: 400.0,
            inter_chassis_latency_ns: 1_100.0,
            node_link_bytes_per_s: 5.0e9,
            interconnect_bytes_per_s: 12.5e9,
            interconnect_latency_ns: 5_000.0,
        }
    }
}

impl FabricConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("intra_chassis_latency_ns", Json::num(self.intra_chassis_latency_ns)),
            ("inter_chassis_latency_ns", Json::num(self.inter_chassis_latency_ns)),
            ("node_link_bytes_per_s", Json::num(self.node_link_bytes_per_s)),
            ("interconnect_bytes_per_s", Json::num(self.interconnect_bytes_per_s)),
            ("interconnect_latency_ns", Json::num(self.interconnect_latency_ns)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let defaults = FabricConfig::default();
        Ok(FabricConfig {
            intra_chassis_latency_ns: v.f64_of("intra_chassis_latency_ns")?,
            inter_chassis_latency_ns: v.f64_of("inter_chassis_latency_ns")?,
            node_link_bytes_per_s: v.f64_of("node_link_bytes_per_s")?,
            // Fleet interconnect keys postdate saved machine configs;
            // absent keys fall back to defaults so old files keep loading.
            interconnect_bytes_per_s: v
                .f64_of("interconnect_bytes_per_s")
                .unwrap_or(defaults.interconnect_bytes_per_s),
            interconnect_latency_ns: v
                .f64_of("interconnect_latency_ns")
                .unwrap_or(defaults.interconnect_latency_ns),
        })
    }
}

/// Full machine description for the simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// Human-readable preset name (shows up in reports).
    pub name: String,
    /// Total Lucata nodes (8 per chassis).
    pub nodes: usize,
    /// Nodes per chassis (8 on the Pathfinder).
    pub nodes_per_chassis: usize,
    /// Lucata cores per node (24).
    pub cores_per_node: usize,
    /// Hardware thread contexts per core (64, round-robin issue).
    pub threads_per_core: usize,
    /// Core clock in Hz (225 MHz on the FPGA-implemented Pathfinder).
    pub clock_hz: f64,
    /// NCDRAM channels per node (8).
    pub channels_per_node: usize,
    /// Streaming bandwidth of one narrow channel (bytes/s; 2 GB/s).
    pub channel_stream_bytes_per_s: f64,
    /// Service time of one fine-grained (random 8 B) access at a channel,
    /// in ns. CALIBRATED from the paper's *concurrent*-saturation point:
    /// 128 concurrent BFS on 8 nodes take 226 s over ~268 G channel ops,
    /// i.e. ~18.5 Mops/s per channel => ~54 ns service.
    pub channel_random_op_ns: f64,
    /// Memory-side processors per node (8); MSP remote ops (remote_min,
    /// remote_add) are read-modify-write cycles at the channel.
    pub msps_per_node: usize,
    /// Channel occupancy of one MSP read-modify-write relative to a plain
    /// access: the RMW cycle holds the bank through read + ALU + write-back
    /// (§III "encapsulating the operation in a read-modify-write cycle").
    pub msp_rmw_factor: f64,
    /// Extra MSP service time per remote op beyond the channel access (ns).
    pub msp_op_extra_ns: f64,
    /// Relative weight of writes vs reads at the MSP/channel arbiter
    /// (1.0 = fair). The paper flags read/write priority balance as an open
    /// tuning question (§IV-C); exposed for the ablation bench.
    pub msp_write_priority: f64,
    /// Thread context transfer cost for one migration (ns, on top of
    /// fabric latency). Hardware-integrated transfer, so small.
    pub migration_overhead_ns: f64,
    /// Uncontended local memory access latency (ns).
    pub local_access_ns: f64,
    /// Per-level synchronization overhead of the Cilk fork-join runtime
    /// (spawn tree + barrier), ns. CALIBRATED.
    pub level_sync_ns: f64,
    /// Instructions executed per traversed edge (BFS inner loop). CALIBRATED.
    pub instr_per_edge: f64,
    /// Fraction of the machine's aggregate instruction-issue bandwidth a
    /// SINGLE query's Cilk spawn tree sustains (spawn/steal overhead, level
    /// imbalance, partially-filled context slots). This is the paper's
    /// central headroom: one BFS cannot keep the cores/channels busy, many
    /// concurrent ones can (§VI). CALIBRATED so the 8-node solo BFS /
    /// concurrent-BFS ratio lands at the paper's ~2.2x.
    pub spawn_efficiency: f64,
    /// Instructions to spawn/retire one worker thread at a frontier vertex.
    pub spawn_instr: f64,
    /// NCDRAM per node, bytes (64 GiB).
    pub mem_per_node_bytes: u64,
    /// Memory reserved for thread-context stacks per node, bytes. Running
    /// out reproduces the paper's 256-queries-on-8-nodes exhaustion.
    pub ctx_mem_per_node_bytes: u64,
    /// Stack/context footprint one in-flight query reserves per node, bytes.
    pub ctx_bytes_per_query: u64,
    /// Chassis indices running with reduced memory/network speed (§IV-B).
    pub degraded_chassis: Vec<usize>,
    /// Multiplier (< 1) on channel + fabric rates of degraded chassis.
    pub degrade_factor: f64,
    /// Fabric model.
    pub fabric: FabricConfig,
}

impl MachineConfig {
    /// Single-chassis, 8-node Pathfinder (the paper's "8 nodes" rows).
    pub fn pathfinder_8() -> Self {
        MachineConfig {
            name: "pathfinder-8".into(),
            nodes: 8,
            nodes_per_chassis: 8,
            cores_per_node: 24,
            threads_per_core: 64,
            clock_hz: 225.0e6,
            channels_per_node: 8,
            channel_stream_bytes_per_s: 2.0e9,
            channel_random_op_ns: 54.0,
            msps_per_node: 8,
            msp_rmw_factor: 2.0,
            msp_op_extra_ns: 6.0,
            msp_write_priority: 1.0,
            migration_overhead_ns: 250.0,
            local_access_ns: 90.0,
            level_sync_ns: 30_000.0,
            instr_per_edge: 36.0,
            spawn_efficiency: 0.41,
            spawn_instr: 220.0,
            mem_per_node_bytes: 64 << 30,
            ctx_mem_per_node_bytes: 510 << 20,
            // 8 nodes * 510 MiB / 16 MiB = 255 concurrent queries fit; the
            // 256th exhausts thread-context memory, matching §IV-B.
            ctx_bytes_per_query: 16 << 20,
            degraded_chassis: vec![],
            degrade_factor: 1.0,
            fabric: FabricConfig::default(),
        }
    }

    /// Full four-chassis, 32-node CRNCH Pathfinder, with the two chassis
    /// that required reduced memory/network speeds (§IV-B).
    pub fn pathfinder_32() -> Self {
        MachineConfig {
            name: "pathfinder-32".into(),
            nodes: 32,
            degraded_chassis: vec![2, 3],
            degrade_factor: 0.45,
            ..Self::pathfinder_8()
        }
    }

    /// Hypothetical fully-healthy 32-node machine (no degraded chassis);
    /// used for the what-if ablation the paper could not run.
    pub fn pathfinder_32_healthy() -> Self {
        MachineConfig {
            name: "pathfinder-32-healthy".into(),
            nodes: 32,
            degraded_chassis: vec![],
            degrade_factor: 1.0,
            ..Self::pathfinder_8()
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "pathfinder-8" => Some(Self::pathfinder_8()),
            "pathfinder-32" => Some(Self::pathfinder_32()),
            "pathfinder-32-healthy" => Some(Self::pathfinder_32_healthy()),
            _ => None,
        }
    }

    /// Chassis index of a node.
    pub fn chassis_of(&self, node: usize) -> usize {
        node / self.nodes_per_chassis
    }

    /// Rate multiplier for a node (1.0 or `degrade_factor`).
    pub fn node_derate(&self, node: usize) -> f64 {
        if self.degraded_chassis.contains(&self.chassis_of(node)) {
            self.degrade_factor
        } else {
            1.0
        }
    }

    /// Hardware thread contexts per node (cores * threads/core = 1536).
    pub fn contexts_per_node(&self) -> usize {
        self.cores_per_node * self.threads_per_core
    }

    /// Aggregate instruction issue rate of one node (instr/s).
    pub fn node_issue_rate(&self) -> f64 {
        self.cores_per_node as f64 * self.clock_hz
    }

    /// Aggregate random-op service rate of one node's channels (ops/s),
    /// before derating.
    pub fn node_channel_op_rate(&self) -> f64 {
        self.channels_per_node as f64 * 1.0e9 / self.channel_random_op_ns
    }

    /// Aggregate streaming bandwidth of one node (bytes/s), before derating.
    pub fn node_stream_rate(&self) -> f64 {
        self.channels_per_node as f64 * self.channel_stream_bytes_per_s
    }

    /// Maximum concurrently admitted queries before thread-context memory
    /// is exhausted (whole machine).
    pub fn max_concurrent_queries(&self) -> usize {
        ((self.nodes as u64 * self.ctx_mem_per_node_bytes) / self.ctx_bytes_per_query) as usize
    }

    /// One-way fabric latency between two nodes (ns), including derating of
    /// either endpoint's chassis.
    pub fn fabric_latency_ns(&self, from: usize, to: usize) -> f64 {
        let base = if self.chassis_of(from) == self.chassis_of(to) {
            self.fabric.intra_chassis_latency_ns
        } else {
            self.fabric.inter_chassis_latency_ns
        };
        let derate = self.node_derate(from).min(self.node_derate(to));
        base / derate
    }

    /// Validate invariants; call after deserializing.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.nodes > 0, "machine must have nodes");
        anyhow::ensure!(
            self.nodes % self.nodes_per_chassis == 0,
            "nodes ({}) must be a multiple of nodes_per_chassis ({})",
            self.nodes,
            self.nodes_per_chassis
        );
        anyhow::ensure!(self.channels_per_node > 0, "need memory channels");
        anyhow::ensure!(self.channel_random_op_ns > 0.0, "op service must be positive");
        anyhow::ensure!(
            self.degrade_factor > 0.0 && self.degrade_factor <= 1.0,
            "degrade_factor must be in (0, 1]"
        );
        for &c in &self.degraded_chassis {
            anyhow::ensure!(
                c < self.nodes / self.nodes_per_chassis,
                "degraded chassis {c} out of range"
            );
        }
        anyhow::ensure!(self.msp_rmw_factor >= 1.0, "RMW cannot be cheaper than an access");
        anyhow::ensure!(
            self.spawn_efficiency > 0.0 && self.spawn_efficiency <= 1.0,
            "spawn_efficiency must be in (0, 1]"
        );
        anyhow::ensure!(self.ctx_bytes_per_query > 0, "ctx footprint must be positive");
        anyhow::ensure!(
            self.fabric.interconnect_bytes_per_s > 0.0,
            "fleet interconnect bandwidth must be positive"
        );
        anyhow::ensure!(
            self.fabric.interconnect_latency_ns >= 0.0,
            "fleet interconnect latency must be non-negative"
        );
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("nodes", Json::num(self.nodes as f64)),
            ("nodes_per_chassis", Json::num(self.nodes_per_chassis as f64)),
            ("cores_per_node", Json::num(self.cores_per_node as f64)),
            ("threads_per_core", Json::num(self.threads_per_core as f64)),
            ("clock_hz", Json::num(self.clock_hz)),
            ("channels_per_node", Json::num(self.channels_per_node as f64)),
            ("channel_stream_bytes_per_s", Json::num(self.channel_stream_bytes_per_s)),
            ("channel_random_op_ns", Json::num(self.channel_random_op_ns)),
            ("msps_per_node", Json::num(self.msps_per_node as f64)),
            ("msp_rmw_factor", Json::num(self.msp_rmw_factor)),
            ("msp_op_extra_ns", Json::num(self.msp_op_extra_ns)),
            ("msp_write_priority", Json::num(self.msp_write_priority)),
            ("migration_overhead_ns", Json::num(self.migration_overhead_ns)),
            ("local_access_ns", Json::num(self.local_access_ns)),
            ("level_sync_ns", Json::num(self.level_sync_ns)),
            ("instr_per_edge", Json::num(self.instr_per_edge)),
            ("spawn_efficiency", Json::num(self.spawn_efficiency)),
            ("spawn_instr", Json::num(self.spawn_instr)),
            ("mem_per_node_bytes", Json::num(self.mem_per_node_bytes as f64)),
            ("ctx_mem_per_node_bytes", Json::num(self.ctx_mem_per_node_bytes as f64)),
            ("ctx_bytes_per_query", Json::num(self.ctx_bytes_per_query as f64)),
            (
                "degraded_chassis",
                Json::arr(self.degraded_chassis.iter().map(|&c| Json::num(c as f64))),
            ),
            ("degrade_factor", Json::num(self.degrade_factor)),
            ("fabric", self.fabric.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let cfg = MachineConfig {
            name: v.str_of("name")?,
            nodes: v.usize_of("nodes")?,
            nodes_per_chassis: v.usize_of("nodes_per_chassis")?,
            cores_per_node: v.usize_of("cores_per_node")?,
            threads_per_core: v.usize_of("threads_per_core")?,
            clock_hz: v.f64_of("clock_hz")?,
            channels_per_node: v.usize_of("channels_per_node")?,
            channel_stream_bytes_per_s: v.f64_of("channel_stream_bytes_per_s")?,
            channel_random_op_ns: v.f64_of("channel_random_op_ns")?,
            msps_per_node: v.usize_of("msps_per_node")?,
            msp_rmw_factor: v.f64_of("msp_rmw_factor")?,
            msp_op_extra_ns: v.f64_of("msp_op_extra_ns")?,
            msp_write_priority: v.f64_of("msp_write_priority")?,
            migration_overhead_ns: v.f64_of("migration_overhead_ns")?,
            local_access_ns: v.f64_of("local_access_ns")?,
            level_sync_ns: v.f64_of("level_sync_ns")?,
            instr_per_edge: v.f64_of("instr_per_edge")?,
            spawn_efficiency: v.f64_of("spawn_efficiency")?,
            spawn_instr: v.f64_of("spawn_instr")?,
            mem_per_node_bytes: v.u64_of("mem_per_node_bytes")?,
            ctx_mem_per_node_bytes: v.u64_of("ctx_mem_per_node_bytes")?,
            ctx_bytes_per_query: v.u64_of("ctx_bytes_per_query")?,
            degraded_chassis: v
                .get("degraded_chassis")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
            degrade_factor: v.f64_of("degrade_factor")?,
            fabric: FabricConfig::from_json(v.get("fabric")?)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a JSON config file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in ["pathfinder-8", "pathfinder-32", "pathfinder-32-healthy"] {
            MachineConfig::preset(name).unwrap().validate().unwrap();
        }
        assert!(MachineConfig::preset("nope").is_none());
    }

    #[test]
    fn paper_published_parameters() {
        let m = MachineConfig::pathfinder_8();
        assert_eq!(m.contexts_per_node(), 1536, "paper: 1536 contexts/node");
        assert_eq!(m.nodes_per_chassis, 8);
        assert!((m.clock_hz - 225e6).abs() < 1.0);
        assert!((m.node_stream_rate() - 16e9).abs() < 1.0, "8 x 2 GB/s");
    }

    #[test]
    fn context_exhaustion_matches_paper() {
        // "Running 256 concurrent queries on eight nodes exhausted the
        // memory used for thread contexts" — so <256 fit on 8 nodes...
        let m8 = MachineConfig::pathfinder_8();
        assert!(m8.max_concurrent_queries() >= 128);
        assert!(m8.max_concurrent_queries() < 256);
        // ... while 750 run fine on 32 nodes.
        let m32 = MachineConfig::pathfinder_32();
        assert!(m32.max_concurrent_queries() >= 750);
    }

    #[test]
    fn degraded_chassis_derate() {
        let m = MachineConfig::pathfinder_32();
        assert_eq!(m.node_derate(0), 1.0);
        assert_eq!(m.node_derate(16), m.degrade_factor); // chassis 2
        assert_eq!(m.node_derate(31), m.degrade_factor); // chassis 3
    }

    #[test]
    fn fabric_latency_intra_vs_inter() {
        let m = MachineConfig::pathfinder_32();
        assert!(m.fabric_latency_ns(0, 1) < m.fabric_latency_ns(0, 8));
        // Degraded endpoints slow the link down.
        assert!(m.fabric_latency_ns(0, 16) > m.fabric_latency_ns(0, 8));
    }

    #[test]
    fn json_round_trip() {
        let m = MachineConfig::pathfinder_32();
        let back = MachineConfig::from_json(&Json::parse(&m.to_json().render_pretty()).unwrap())
            .unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn fabric_json_tolerates_missing_interconnect_keys() {
        // Saved machine configs predate the fleet interconnect; a fabric
        // object without the new keys must load with the defaults.
        let legacy = Json::obj(vec![
            ("intra_chassis_latency_ns", Json::num(400.0)),
            ("inter_chassis_latency_ns", Json::num(1100.0)),
            ("node_link_bytes_per_s", Json::num(5.0e9)),
        ]);
        let f = FabricConfig::from_json(&legacy).unwrap();
        assert_eq!(f, FabricConfig::default());
    }

    #[test]
    fn from_json_rejects_invalid() {
        let mut m = MachineConfig::pathfinder_8();
        m.degrade_factor = 0.0;
        assert!(MachineConfig::from_json(&m.to_json()).is_err());
    }
}
