//! Declarative open-loop load scenarios (DESIGN.md §Observability,
//! docs/SCENARIOS.md).
//!
//! A [`ScenarioSpec`] describes a multi-tenant arrival pattern as plain
//! data: named streams, each with its own arrival process, analysis mix,
//! priority class, SLO and deadline. The spec is *open-loop* — arrival
//! instants are a pure function of (spec, seed) and never depend on how
//! fast the service drains them — which is what makes overload scenarios
//! meaningful: a closed-loop generator slows down exactly when the system
//! does, hiding the very congestion the scenario exists to produce.
//!
//! Four arrival processes cover the paper-motivated load shapes (their
//! closed-form expected counts are what the scenario property test pins):
//!
//! * **constant** — homogeneous Poisson at `rate_per_s`;
//!   `E[N(T)] = rate * T`.
//! * **diurnal** — inhomogeneous Poisson,
//!   `rate(t) = base * (1 + amplitude * sin(2*pi*t/period))`, sampled by
//!   Lewis–Shedler thinning;
//!   `E[N(T)] = base*T + base*amplitude*(period/2pi)*(1 - cos(2pi T/period))`.
//! * **bursty** — two-state Markov-modulated Poisson process: exponential
//!   on/off dwells (means `mean_on_s`/`mean_off_s`), rate `on_rate_per_s`
//!   while on and `off_rate_per_s` while off, initial state drawn from the
//!   stationary distribution; `E[N(T)] = T * (on_rate*mean_on +
//!   off_rate*mean_off) / (mean_on + mean_off)`.
//! * **ramp** — linear overload ramp from `start_rate_per_s` to
//!   `end_rate_per_s` over the scenario duration (also thinned);
//!   `E[N(T)] = (start + end)/2 * T`.
//!
//! The compiler that turns a spec into a merged request timeline lives in
//! [`crate::coordinator::scenario`]; this module is pure data + math so
//! specs round-trip through JSON (`ci/scenarios/*.json`) without touching
//! the graph or the registry.

use anyhow::Result;

use crate::sim::flow::Priority;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// Hard cap on arrivals one stream may generate: a mis-set rate (or a
/// forgotten `time_compressed`) cannot explode the timeline.
pub const MAX_STREAM_ARRIVALS: usize = 2_000_000;

/// One stream's arrival process (rates in queries per simulated second).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson.
    Constant { rate_per_s: f64 },
    /// Sinusoidal day/night cycle: `base * (1 + amplitude * sin(2pi t/P))`.
    Diurnal { base_rate_per_s: f64, amplitude: f64, period_s: f64 },
    /// Two-state Markov-modulated on/off bursts.
    Bursty { on_rate_per_s: f64, off_rate_per_s: f64, mean_on_s: f64, mean_off_s: f64 },
    /// Linear ramp across the scenario duration (the overload shape).
    Ramp { start_rate_per_s: f64, end_rate_per_s: f64 },
}

impl ArrivalProcess {
    pub fn validate(&self) -> Result<()> {
        let finite_nonneg = |v: f64, what: &str| {
            anyhow::ensure!(v.is_finite() && v >= 0.0, "{what} must be finite and >= 0, got {v}");
            Ok(())
        };
        match *self {
            ArrivalProcess::Constant { rate_per_s } => {
                finite_nonneg(rate_per_s, "constant rate_per_s")?;
                anyhow::ensure!(rate_per_s > 0.0, "constant stream needs a positive rate");
            }
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, period_s } => {
                finite_nonneg(base_rate_per_s, "diurnal base_rate_per_s")?;
                anyhow::ensure!(base_rate_per_s > 0.0, "diurnal stream needs a positive base");
                anyhow::ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "diurnal amplitude must be in [0, 1] (rate must stay non-negative), got \
                     {amplitude}"
                );
                anyhow::ensure!(
                    period_s.is_finite() && period_s > 0.0,
                    "diurnal period_s must be positive, got {period_s}"
                );
            }
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, mean_on_s, mean_off_s } => {
                finite_nonneg(on_rate_per_s, "bursty on_rate_per_s")?;
                finite_nonneg(off_rate_per_s, "bursty off_rate_per_s")?;
                anyhow::ensure!(
                    on_rate_per_s > 0.0 || off_rate_per_s > 0.0,
                    "bursty stream needs a positive rate in at least one state"
                );
                anyhow::ensure!(
                    mean_on_s.is_finite() && mean_on_s > 0.0,
                    "bursty mean_on_s must be positive, got {mean_on_s}"
                );
                anyhow::ensure!(
                    mean_off_s.is_finite() && mean_off_s > 0.0,
                    "bursty mean_off_s must be positive, got {mean_off_s}"
                );
            }
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => {
                finite_nonneg(start_rate_per_s, "ramp start_rate_per_s")?;
                finite_nonneg(end_rate_per_s, "ramp end_rate_per_s")?;
                anyhow::ensure!(
                    start_rate_per_s > 0.0 || end_rate_per_s > 0.0,
                    "ramp stream needs a positive rate at one end"
                );
            }
        }
        Ok(())
    }

    /// Instantaneous rate at `t_s` into a run of `duration_s` (queries/s).
    pub fn rate_at(&self, t_s: f64, duration_s: f64) -> f64 {
        match *self {
            ArrivalProcess::Constant { rate_per_s } => rate_per_s,
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, period_s } => {
                base_rate_per_s
                    * (1.0 + amplitude * (2.0 * std::f64::consts::PI * t_s / period_s).sin())
            }
            // The modulating chain is random; this is the stationary mean.
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, mean_on_s, mean_off_s } => {
                (on_rate_per_s * mean_on_s + off_rate_per_s * mean_off_s)
                    / (mean_on_s + mean_off_s)
            }
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => {
                start_rate_per_s + (end_rate_per_s - start_rate_per_s) * (t_s / duration_s)
            }
        }
    }

    /// The thinning envelope: an upper bound on the instantaneous rate
    /// over the whole run (queries/s).
    pub fn peak_rate_per_s(&self) -> f64 {
        match *self {
            ArrivalProcess::Constant { rate_per_s } => rate_per_s,
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, .. } => {
                base_rate_per_s * (1.0 + amplitude)
            }
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, .. } => {
                on_rate_per_s.max(off_rate_per_s)
            }
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => {
                start_rate_per_s.max(end_rate_per_s)
            }
        }
    }

    /// Closed-form expected arrival count over `[0, duration_s]` (module
    /// docs); the scenario property test pins sampled counts to this.
    pub fn expected_arrivals(&self, duration_s: f64) -> f64 {
        match *self {
            ArrivalProcess::Constant { rate_per_s } => rate_per_s * duration_s,
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, period_s } => {
                let w = 2.0 * std::f64::consts::PI / period_s;
                base_rate_per_s * duration_s
                    + base_rate_per_s * amplitude / w * (1.0 - (w * duration_s).cos())
            }
            ArrivalProcess::Bursty { .. } => self.rate_at(0.0, duration_s) * duration_s,
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => {
                (start_rate_per_s + end_rate_per_s) / 2.0 * duration_s
            }
        }
    }

    /// Sample one realization of the process over `[0, duration_s]`:
    /// sorted arrival instants in simulated **ns**, a pure function of the
    /// rng state (the open-loop contract). Constant uses plain inversion;
    /// diurnal/ramp use Lewis–Shedler thinning against
    /// [`ArrivalProcess::peak_rate_per_s`]; bursty walks the on/off chain
    /// explicitly (exponential dwells, Poisson arrivals within each dwell
    /// — truncation at dwell boundaries is exact by memorylessness).
    pub fn sample_arrivals_ns(&self, duration_s: f64, rng: &mut SplitMix64) -> Vec<f64> {
        let dur_ns = duration_s * 1e9;
        let mut out = Vec::new();
        match *self {
            ArrivalProcess::Constant { rate_per_s } => {
                poisson_segment(rate_per_s, 0.0, dur_ns, rng, &mut out);
            }
            ArrivalProcess::Diurnal { .. } | ArrivalProcess::Ramp { .. } => {
                let peak = self.peak_rate_per_s();
                if peak <= 0.0 {
                    return out;
                }
                let mut t = 0.0f64;
                loop {
                    let u = rng.next_f64().max(1e-12);
                    t += -u.ln() / peak * 1e9;
                    if t >= dur_ns || out.len() >= MAX_STREAM_ARRIVALS {
                        break;
                    }
                    if rng.next_f64() * peak < self.rate_at(t * 1e-9, duration_s) {
                        out.push(t);
                    }
                }
            }
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, mean_on_s, mean_off_s } => {
                let p_on = mean_on_s / (mean_on_s + mean_off_s);
                let mut on = rng.next_f64() < p_on;
                let mut seg_start = 0.0f64;
                while seg_start < dur_ns && out.len() < MAX_STREAM_ARRIVALS {
                    let u = rng.next_f64().max(1e-12);
                    let dwell_ns = -u.ln() * if on { mean_on_s } else { mean_off_s } * 1e9;
                    let seg_end = (seg_start + dwell_ns).min(dur_ns);
                    let rate = if on { on_rate_per_s } else { off_rate_per_s };
                    poisson_segment(rate, seg_start, seg_end, rng, &mut out);
                    seg_start += dwell_ns;
                    on = !on;
                }
            }
        }
        out
    }

    /// Compact human label, e.g. `ramp(10->600/s)`.
    pub fn label(&self) -> String {
        match *self {
            ArrivalProcess::Constant { rate_per_s } => format!("constant({rate_per_s}/s)"),
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, period_s } => {
                format!("diurnal({base_rate_per_s}/s +-{amplitude} over {period_s}s)")
            }
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, mean_on_s, mean_off_s } => {
                format!(
                    "bursty(on {on_rate_per_s}/s x{mean_on_s}s, off {off_rate_per_s}/s \
                     x{mean_off_s}s)"
                )
            }
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => {
                format!("ramp({start_rate_per_s}->{end_rate_per_s}/s)")
            }
        }
    }

    /// Multiply every rate by `f` (the time-compression half lives in
    /// [`ScenarioSpec::time_compressed`], which also shrinks dwell times
    /// and the diurnal period so the *shape* is preserved).
    fn rates_scaled(&self, f: f64) -> Self {
        match *self {
            ArrivalProcess::Constant { rate_per_s } => {
                ArrivalProcess::Constant { rate_per_s: rate_per_s * f }
            }
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, period_s } => {
                ArrivalProcess::Diurnal {
                    base_rate_per_s: base_rate_per_s * f,
                    amplitude,
                    period_s: period_s / f,
                }
            }
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, mean_on_s, mean_off_s } => {
                ArrivalProcess::Bursty {
                    on_rate_per_s: on_rate_per_s * f,
                    off_rate_per_s: off_rate_per_s * f,
                    mean_on_s: mean_on_s / f,
                    mean_off_s: mean_off_s / f,
                }
            }
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => ArrivalProcess::Ramp {
                start_rate_per_s: start_rate_per_s * f,
                end_rate_per_s: end_rate_per_s * f,
            },
        }
    }

    pub fn to_json(&self) -> Json {
        match *self {
            ArrivalProcess::Constant { rate_per_s } => Json::obj(vec![
                ("kind", Json::str("constant")),
                ("rate_per_s", Json::num(rate_per_s)),
            ]),
            ArrivalProcess::Diurnal { base_rate_per_s, amplitude, period_s } => Json::obj(vec![
                ("kind", Json::str("diurnal")),
                ("base_rate_per_s", Json::num(base_rate_per_s)),
                ("amplitude", Json::num(amplitude)),
                ("period_s", Json::num(period_s)),
            ]),
            ArrivalProcess::Bursty { on_rate_per_s, off_rate_per_s, mean_on_s, mean_off_s } => {
                Json::obj(vec![
                    ("kind", Json::str("bursty")),
                    ("on_rate_per_s", Json::num(on_rate_per_s)),
                    ("off_rate_per_s", Json::num(off_rate_per_s)),
                    ("mean_on_s", Json::num(mean_on_s)),
                    ("mean_off_s", Json::num(mean_off_s)),
                ])
            }
            ArrivalProcess::Ramp { start_rate_per_s, end_rate_per_s } => Json::obj(vec![
                ("kind", Json::str("ramp")),
                ("start_rate_per_s", Json::num(start_rate_per_s)),
                ("end_rate_per_s", Json::num(end_rate_per_s)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let kind = v.str_of("kind")?;
        let p = match kind.as_str() {
            "constant" => ArrivalProcess::Constant { rate_per_s: v.f64_of("rate_per_s")? },
            "diurnal" => ArrivalProcess::Diurnal {
                base_rate_per_s: v.f64_of("base_rate_per_s")?,
                amplitude: v.f64_of("amplitude")?,
                period_s: v.f64_of("period_s")?,
            },
            "bursty" => ArrivalProcess::Bursty {
                on_rate_per_s: v.f64_of("on_rate_per_s")?,
                off_rate_per_s: v.f64_of("off_rate_per_s")?,
                mean_on_s: v.f64_of("mean_on_s")?,
                mean_off_s: v.f64_of("mean_off_s")?,
            },
            "ramp" => ArrivalProcess::Ramp {
                start_rate_per_s: v.f64_of("start_rate_per_s")?,
                end_rate_per_s: v.f64_of("end_rate_per_s")?,
            },
            other => anyhow::bail!(
                "unknown arrival process kind {other:?} (want constant/diurnal/bursty/ramp)"
            ),
        };
        p.validate()?;
        Ok(p)
    }
}

/// Homogeneous Poisson arrivals at `rate_per_s` on `[from_ns, to_ns)`,
/// appended to `out` (the shared inner loop of every process).
fn poisson_segment(
    rate_per_s: f64,
    from_ns: f64,
    to_ns: f64,
    rng: &mut SplitMix64,
    out: &mut Vec<f64>,
) {
    if rate_per_s <= 0.0 {
        return;
    }
    let mut t = from_ns;
    loop {
        let u = rng.next_f64().max(1e-12);
        t += -u.ln() / rate_per_s * 1e9;
        if t >= to_ns || out.len() >= MAX_STREAM_ARRIVALS {
            break;
        }
        out.push(t);
    }
}

/// One tenant stream of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSpec {
    /// Unique stream name. The per-stream RNG seed is derived from the
    /// *name* (not the position), so reordering streams in a spec cannot
    /// change any stream's arrivals — see
    /// [`crate::coordinator::scenario::stream_seed`].
    pub name: String,
    pub process: ArrivalProcess,
    /// Weighted analysis mix (`label -> weight`), resolved against the
    /// [`crate::alg::AnalysisRegistry`] at compile time. Kept sorted by
    /// label so JSON round-trips are identity.
    pub mix: Vec<(String, f64)>,
    /// Priority class every request of this stream carries; None = each
    /// workload class's default ([`Priority::Standard`] for registry
    /// classes).
    pub priority: Option<Priority>,
    /// Per-stream p99 latency SLO (s); verdict lands in the report's
    /// scenario section.
    pub slo_p99_s: Option<f64>,
    /// Per-request deadline (s from arrival); expired queued requests are
    /// shed by admission.
    pub deadline_s: Option<f64>,
}

impl StreamSpec {
    pub fn new(name: impl Into<String>, process: ArrivalProcess, mix: Vec<(String, f64)>) -> Self {
        let mut s = StreamSpec {
            name: name.into(),
            process,
            mix,
            priority: None,
            slo_p99_s: None,
            deadline_s: None,
        };
        s.mix.sort_by(|a, b| a.0.cmp(&b.0));
        s
    }

    pub fn with_priority(mut self, p: Priority) -> Self {
        self.priority = Some(p);
        self
    }

    pub fn with_slo_p99_s(mut self, slo: f64) -> Self {
        self.slo_p99_s = Some(slo);
        self
    }

    pub fn with_deadline_s(mut self, d: f64) -> Self {
        self.deadline_s = Some(d);
        self
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "stream name must be non-empty");
        self.process.validate()?;
        anyhow::ensure!(!self.mix.is_empty(), "stream {:?} needs a non-empty mix", self.name);
        for (label, w) in &self.mix {
            anyhow::ensure!(
                w.is_finite() && *w >= 0.0,
                "stream {:?} mix weight for {label:?} must be >= 0, got {w}",
                self.name
            );
        }
        anyhow::ensure!(
            self.mix.iter().map(|(_, w)| w).sum::<f64>() > 0.0,
            "stream {:?} needs positive total mix weight",
            self.name
        );
        if let Some(s) = self.slo_p99_s {
            anyhow::ensure!(s > 0.0, "stream {:?} SLO must be positive", self.name);
        }
        if let Some(d) = self.deadline_s {
            anyhow::ensure!(d > 0.0, "stream {:?} deadline must be positive", self.name);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            ("process", self.process.to_json()),
            (
                "mix",
                Json::Obj(
                    self.mix.iter().map(|(l, w)| (l.clone(), Json::num(*w))).collect(),
                ),
            ),
        ];
        if let Some(p) = self.priority {
            fields.push(("priority", Json::str(priority_name(p))));
        }
        if let Some(s) = self.slo_p99_s {
            fields.push(("slo_p99_s", Json::num(s)));
        }
        if let Some(d) = self.deadline_s {
            fields.push(("deadline_s", Json::num(d)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let mix = match v.get("mix")? {
            Json::Obj(m) => m
                .iter()
                .map(|(l, w)| Ok((l.clone(), w.as_f64()?)))
                .collect::<Result<Vec<_>>>()?,
            other => anyhow::bail!("stream mix must be an object, got {other:?}"),
        };
        let mut s = StreamSpec::new(v.str_of("name")?, ArrivalProcess::from_json(v.get("process")?)?, mix);
        if let Some(p) = v.get_opt("priority") {
            s.priority = Some(parse_priority(p.as_str()?)?);
        }
        if let Some(x) = v.get_opt("slo_p99_s") {
            s.slo_p99_s = Some(x.as_f64()?);
        }
        if let Some(x) = v.get_opt("deadline_s") {
            s.deadline_s = Some(x.as_f64()?);
        }
        s.validate()?;
        Ok(s)
    }
}

pub fn priority_name(p: Priority) -> &'static str {
    match p {
        Priority::Interactive => "interactive",
        Priority::Standard => "standard",
        Priority::Batch => "batch",
    }
}

pub fn parse_priority(s: &str) -> Result<Priority> {
    match s {
        "interactive" => Ok(Priority::Interactive),
        "standard" => Ok(Priority::Standard),
        "batch" => Ok(Priority::Batch),
        other => anyhow::bail!(
            "unknown priority {other:?} (want interactive/standard/batch)"
        ),
    }
}

/// A whole scenario: named, bounded in time, one or more streams.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    /// Simulated length of the arrival window (s); the run itself lasts
    /// until the last admitted query drains.
    pub duration_s: f64,
    pub streams: Vec<StreamSpec>,
}

impl ScenarioSpec {
    pub fn new(name: impl Into<String>, duration_s: f64, streams: Vec<StreamSpec>) -> Self {
        ScenarioSpec { name: name.into(), duration_s, streams }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "scenario name must be non-empty");
        anyhow::ensure!(
            self.duration_s.is_finite() && self.duration_s > 0.0,
            "scenario duration must be positive, got {}",
            self.duration_s
        );
        anyhow::ensure!(!self.streams.is_empty(), "scenario needs at least one stream");
        let mut seen = std::collections::BTreeSet::new();
        for s in &self.streams {
            s.validate()?;
            anyhow::ensure!(seen.insert(s.name.as_str()), "duplicate stream name {:?}", s.name);
        }
        anyhow::ensure!(
            self.expected_arrivals() >= 1.0,
            "scenario {:?} expects fewer than one arrival over {}s",
            self.name,
            self.duration_s
        );
        Ok(())
    }

    /// Closed-form expected total arrivals across all streams.
    pub fn expected_arrivals(&self) -> f64 {
        self.streams.iter().map(|s| s.process.expected_arrivals(self.duration_s)).sum()
    }

    /// Play the same scenario `factor`x faster: every rate scales up by
    /// `factor`, the duration (and the diurnal period / bursty dwells)
    /// shrinks by it — so the expected arrival *counts* and the load
    /// *shape* relative to the timeline are invariant while the absolute
    /// demand in queries/s scales. This is how one catalog serves machines
    /// of very different capacity (the overload acceptance test compresses
    /// the ramp to a measured multiple of its machine's throughput).
    pub fn time_compressed(&self, factor: f64) -> Result<Self> {
        anyhow::ensure!(
            factor.is_finite() && factor > 0.0,
            "compression factor must be positive, got {factor}"
        );
        let mut out = self.clone();
        out.duration_s /= factor;
        for s in &mut out.streams {
            s.process = s.process.rates_scaled(factor);
        }
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("duration_s", Json::num(self.duration_s)),
            ("streams", Json::arr(self.streams.iter().map(|s| s.to_json()))),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let spec = ScenarioSpec {
            name: v.str_of("name")?,
            duration_s: v.f64_of("duration_s")?,
            streams: v
                .get("streams")?
                .as_arr()?
                .iter()
                .map(StreamSpec::from_json)
                .collect::<Result<_>>()?,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    /// Resolve a CLI argument: a catalog name first, else a JSON file path.
    pub fn load(arg: &str) -> Result<Self> {
        if let Some(spec) = Self::builtin(arg) {
            return Ok(spec);
        }
        let path = std::path::Path::new(arg);
        anyhow::ensure!(
            path.exists(),
            "{arg:?} is neither a catalog scenario ({}) nor a readable file",
            Self::catalog_names().join(", ")
        );
        Self::parse_file(path)
    }

    /// Names of the checked-in catalog (`ci/scenarios/*.json` mirrors
    /// these builtins byte-for-byte; a round-trip test pins that).
    pub fn catalog_names() -> Vec<&'static str> {
        vec!["steady", "diurnal", "burst", "overload-ramp", "multi-tenant-contention"]
    }

    /// The full catalog, in [`ScenarioSpec::catalog_names`] order.
    pub fn catalog() -> Vec<ScenarioSpec> {
        Self::catalog_names()
            .into_iter()
            .map(|n| Self::builtin(n).expect("catalog name"))
            .collect()
    }

    /// Look up a catalog scenario by name. Rates are sized for the smoke
    /// configuration CI runs (scale-11 graph on the full pathfinder-8);
    /// use [`ScenarioSpec::time_compressed`] to retarget other machines.
    pub fn builtin(name: &str) -> Option<ScenarioSpec> {
        let spec = match name {
            // Baseline: two flat tenants, one latency-sensitive.
            "steady" => ScenarioSpec::new(
                "steady",
                2.0,
                vec![
                    StreamSpec::new(
                        "frontend",
                        ArrivalProcess::Constant { rate_per_s: 150.0 },
                        vec![("khop".into(), 1.0)],
                    )
                    .with_priority(Priority::Interactive)
                    .with_slo_p99_s(0.25),
                    StreamSpec::new(
                        "analytics",
                        ArrivalProcess::Constant { rate_per_s: 50.0 },
                        vec![("bfs".into(), 0.8), ("cc".into(), 0.2)],
                    )
                    .with_priority(Priority::Batch),
                ],
            ),
            // Day/night sinusoid over a background batch trickle.
            "diurnal" => ScenarioSpec::new(
                "diurnal",
                2.0,
                vec![
                    StreamSpec::new(
                        "web",
                        ArrivalProcess::Diurnal {
                            base_rate_per_s: 200.0,
                            amplitude: 0.8,
                            period_s: 1.0,
                        },
                        vec![("bfs".into(), 0.7), ("khop".into(), 0.3)],
                    )
                    .with_slo_p99_s(0.5),
                    StreamSpec::new(
                        "nightly",
                        ArrivalProcess::Constant { rate_per_s: 25.0 },
                        vec![("cc".into(), 1.0)],
                    )
                    .with_priority(Priority::Batch),
                ],
            ),
            // Markov-modulated on/off spikes against a steady tenant.
            "burst" => ScenarioSpec::new(
                "burst",
                2.0,
                vec![
                    StreamSpec::new(
                        "spiky-tenant",
                        ArrivalProcess::Bursty {
                            on_rate_per_s: 1200.0,
                            off_rate_per_s: 50.0,
                            mean_on_s: 0.1,
                            mean_off_s: 0.3,
                        },
                        vec![("bfs".into(), 1.0)],
                    ),
                    StreamSpec::new(
                        "steady-tenant",
                        ArrivalProcess::Constant { rate_per_s: 50.0 },
                        vec![("khop".into(), 1.0)],
                    )
                    .with_priority(Priority::Interactive)
                    .with_slo_p99_s(0.25),
                ],
            ),
            // Linear overload: Batch demand ramps through capacity while a
            // flat Interactive tenant must keep its SLO — the scenario that
            // finally exercises shedding and preemption together.
            "overload-ramp" => ScenarioSpec::new(
                "overload-ramp",
                2.0,
                vec![
                    StreamSpec::new(
                        "interactive-frontend",
                        ArrivalProcess::Constant { rate_per_s: 40.0 },
                        vec![("khop".into(), 1.0)],
                    )
                    .with_priority(Priority::Interactive)
                    .with_slo_p99_s(0.25),
                    StreamSpec::new(
                        "batch-ingest-ramp",
                        ArrivalProcess::Ramp { start_rate_per_s: 10.0, end_rate_per_s: 600.0 },
                        vec![("bfs".into(), 1.0)],
                    )
                    .with_priority(Priority::Batch)
                    .with_deadline_s(0.5),
                ],
            ),
            // Three tenants with distinct shapes, classes and SLOs
            // contending for one machine.
            "multi-tenant-contention" => ScenarioSpec::new(
                "multi-tenant-contention",
                2.0,
                vec![
                    StreamSpec::new(
                        "tenant-a",
                        ArrivalProcess::Constant { rate_per_s: 120.0 },
                        vec![("khop".into(), 1.0)],
                    )
                    .with_priority(Priority::Interactive)
                    .with_slo_p99_s(0.25),
                    StreamSpec::new(
                        "tenant-b",
                        ArrivalProcess::Diurnal {
                            base_rate_per_s: 100.0,
                            amplitude: 0.6,
                            period_s: 0.5,
                        },
                        vec![("bfs".into(), 0.9), ("sssp".into(), 0.1)],
                    ),
                    StreamSpec::new(
                        "tenant-c",
                        ArrivalProcess::Bursty {
                            on_rate_per_s: 600.0,
                            off_rate_per_s: 20.0,
                            mean_on_s: 0.15,
                            mean_off_s: 0.35,
                        },
                        vec![("bfs".into(), 0.7), ("cc".into(), 0.3)],
                    )
                    .with_priority(Priority::Batch)
                    .with_deadline_s(0.75),
                ],
            ),
            _ => return None,
        };
        debug_assert!(spec.validate().is_ok(), "builtin {name} must validate");
        Some(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_validates_and_round_trips() {
        for name in ScenarioSpec::catalog_names() {
            let spec = ScenarioSpec::builtin(name).unwrap();
            spec.validate().unwrap();
            assert_eq!(spec.name, name);
            let back =
                ScenarioSpec::from_json(&Json::parse(&spec.to_json().render_pretty()).unwrap())
                    .unwrap();
            assert_eq!(spec, back, "{name} JSON round-trip");
        }
        assert!(ScenarioSpec::builtin("nope").is_none());
    }

    #[test]
    fn expected_arrivals_closed_forms() {
        let c = ArrivalProcess::Constant { rate_per_s: 100.0 };
        assert!((c.expected_arrivals(2.0) - 200.0).abs() < 1e-9);
        // A whole number of periods integrates the sinusoid away.
        let d = ArrivalProcess::Diurnal { base_rate_per_s: 100.0, amplitude: 0.5, period_s: 1.0 };
        assert!((d.expected_arrivals(2.0) - 200.0).abs() < 1e-6);
        // Half a period adds the positive lobe: base*T + base*A*P/pi.
        let half = d.expected_arrivals(0.5);
        let lobe = 100.0 * 0.5 * 1.0 / std::f64::consts::PI;
        assert!((half - (50.0 + lobe)).abs() < 1e-6, "{half}");
        let b = ArrivalProcess::Bursty {
            on_rate_per_s: 300.0,
            off_rate_per_s: 100.0,
            mean_on_s: 0.1,
            mean_off_s: 0.3,
        };
        // Stationary mean: (300*0.1 + 100*0.3)/0.4 = 150/s.
        assert!((b.expected_arrivals(2.0) - 300.0).abs() < 1e-9);
        let r = ArrivalProcess::Ramp { start_rate_per_s: 10.0, end_rate_per_s: 600.0 };
        assert!((r.expected_arrivals(2.0) - 610.0).abs() < 1e-9);
    }

    #[test]
    fn sampling_is_deterministic_and_in_range() {
        for spec in ScenarioSpec::catalog() {
            for stream in &spec.streams {
                let a = stream.process.sample_arrivals_ns(spec.duration_s, &mut SplitMix64::new(9));
                let b = stream.process.sample_arrivals_ns(spec.duration_s, &mut SplitMix64::new(9));
                assert_eq!(a.len(), b.len(), "{}/{}", spec.name, stream.name);
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "bit-identical replay");
                }
                let dur_ns = spec.duration_s * 1e9;
                assert!(a.windows(2).all(|w| w[0] <= w[1]), "sorted");
                assert!(a.iter().all(|&t| t >= 0.0 && t < dur_ns), "in window");
            }
        }
    }

    #[test]
    fn time_compression_preserves_expected_counts() {
        for spec in ScenarioSpec::catalog() {
            let fast = spec.time_compressed(8.0).unwrap();
            assert!((fast.duration_s - spec.duration_s / 8.0).abs() < 1e-12);
            assert!(
                (fast.expected_arrivals() - spec.expected_arrivals()).abs()
                    < 1e-6 * spec.expected_arrivals(),
                "{}: {} vs {}",
                spec.name,
                fast.expected_arrivals(),
                spec.expected_arrivals()
            );
        }
        assert!(ScenarioSpec::builtin("steady").unwrap().time_compressed(0.0).is_err());
    }

    #[test]
    fn validation_rejects_malformed_specs() {
        let mut spec = ScenarioSpec::builtin("steady").unwrap();
        spec.duration_s = 0.0;
        assert!(spec.validate().is_err());
        let mut spec = ScenarioSpec::builtin("steady").unwrap();
        spec.streams[1].name = spec.streams[0].name.clone();
        assert!(spec.validate().is_err(), "duplicate names");
        let mut spec = ScenarioSpec::builtin("steady").unwrap();
        spec.streams[0].mix.clear();
        assert!(spec.validate().is_err(), "empty mix");
        assert!(ArrivalProcess::Diurnal {
            base_rate_per_s: 10.0,
            amplitude: 1.5,
            period_s: 1.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Constant { rate_per_s: 0.0 }.validate().is_err());
        assert!(parse_priority("realtime").is_err());
    }
}
