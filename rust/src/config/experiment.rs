//! Top-level experiment configuration: machine + workload + output knobs.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;

use super::{MachineConfig, WorkloadConfig};

/// Everything needed to reproduce one experiment run.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Machine presets to evaluate (paper: 8-node and 32-node).
    pub machines: Vec<MachineConfig>,
    pub workload: WorkloadConfig,
    /// Where CSVs and reports land.
    pub results_dir: PathBuf,
    /// Directory holding the AOT artifacts for the baseline engine.
    pub artifacts_dir: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            machines: vec![MachineConfig::pathfinder_8(), MachineConfig::pathfinder_32()],
            workload: WorkloadConfig::default(),
            results_dir: PathBuf::from("results"),
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.machines.is_empty(), "need at least one machine");
        for m in &self.machines {
            m.validate()?;
        }
        self.workload.validate()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("machines", Json::arr(self.machines.iter().map(|m| m.to_json()))),
            ("workload", self.workload.to_json()),
            ("results_dir", Json::str(self.results_dir.display().to_string())),
            ("artifacts_dir", Json::str(self.artifacts_dir.display().to_string())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let cfg = ExperimentConfig {
            machines: v
                .get("machines")?
                .as_arr()?
                .iter()
                .map(MachineConfig::from_json)
                .collect::<Result<_>>()?,
            workload: WorkloadConfig::from_json(v.get("workload")?)?,
            results_dir: PathBuf::from(v.str_of("results_dir")?),
            artifacts_dir: PathBuf::from(v.str_of("artifacts_dir")?),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        Self::from_json(&Json::parse_file(path)?)
    }

    pub fn to_file(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    /// Fetch a machine by preset name from this experiment's set.
    pub fn machine(&self, name: &str) -> Option<&MachineConfig> {
        self.machines.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn file_round_trip() {
        let cfg = ExperimentConfig::default();
        let dir = std::env::temp_dir().join("pfq_cfg_test");
        let path = dir.join("exp.json");
        cfg.to_file(&path).unwrap();
        let back = ExperimentConfig::from_file(&path).unwrap();
        assert_eq!(cfg, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn machine_lookup() {
        let cfg = ExperimentConfig::default();
        assert!(cfg.machine("pathfinder-8").is_some());
        assert!(cfg.machine("bogus").is_none());
    }
}
