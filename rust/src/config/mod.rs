//! Configuration system: machine models, workloads, experiments.
//!
//! Everything an experiment needs is expressed as plain data (serde +
//! TOML), so runs are reproducible from a config file plus a seed. Presets
//! mirror the hardware configurations in the paper: the single-chassis
//! 8-node Pathfinder, the full 32-node CRNCH Pathfinder (with its two
//! degraded chassis, §IV-B), and the x1e.32xlarge Xeon host used for the
//! RedisGraph comparison (§IV-D).

pub mod experiment;
pub mod machine;
pub mod scenario;
pub mod workload;

pub use experiment::ExperimentConfig;
pub use machine::{FabricConfig, MachineConfig};
pub use scenario::{ArrivalProcess, ScenarioSpec, StreamSpec};
pub use workload::{GraphConfig, WorkloadConfig};
