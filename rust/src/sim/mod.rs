//! The Pathfinder simulator substrate.
//!
//! Nobody outside GT CRNCH has a Lucata Pathfinder, so the machine itself is
//! the substrate this reproduction has to build (DESIGN.md
//! §Hardware-Adaptation). The model captures the architectural mechanisms
//! the paper credits for its result (§II, §VI):
//!
//! * **many narrow memory channels** — per-node random-op service capacity
//!   is the scarce resource; a single level-synchronous query cannot keep
//!   all channels busy, concurrent queries can;
//! * **migratory threads** — remote *reads* move the thread to the data
//!   (fabric latency + context transfer), remote *writes* do not migrate;
//! * **memory-side processors** — `remote_min`/`remote_add` execute as
//!   read-modify-write cycles at the destination channel without occupying
//!   a core;
//! * **cache-less multithreaded cores** — aggregate instruction issue is
//!   `cores x clock`, round-robin, one instruction per core-cycle;
//! * **memory views** — view-0 node-local replicas (the `changed` flag of
//!   Figure 2), view-1 global addresses, view-2 striped arrays.
//!
//! Two engines share the machine description:
//!
//! * [`flow`] — a fluid/flow-level engine: algorithms run *functionally* on
//!   the real graph and emit per-phase [`demand::PhaseDemand`] resource
//!   vectors; a proportional-share allocator advances simulated time. This
//!   is what paper-scale experiments (750 concurrent queries) use.
//! * [`event`] — a discrete-event engine with explicit threads, channel
//!   FIFOs, migrations and MSP queues, used at small scale to validate the
//!   flow model's assumptions (see rust/tests/sim_tests.rs).
//!
//! [`cluster`] scales past one machine: a fleet of chassis flattened into
//! one multi-chassis [`machine::Machine`], with cross-member traffic
//! priced as the fleet-interconnect resource kind of
//! [`demand::PhaseDemand`] (DESIGN.md §Fleet).

pub mod cluster;
pub mod counters;
pub mod demand;
pub mod event;
pub mod flow;
pub mod ledger;
pub mod machine;
pub mod preempt;
pub mod trace;
pub mod views;

pub use cluster::Cluster;
pub use counters::Counters;
pub use demand::PhaseDemand;
pub use flow::{FlowSim, Priority, QueryTiming, ShareWeights, SolverMode};
pub use ledger::{ContextExhausted, ContextLedger};
pub use machine::Machine;
pub use preempt::PreemptPolicy;
pub use trace::{NullSink, TraceBuffer, TraceEvent, TraceSink};
