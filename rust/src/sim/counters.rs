//! Simulated hardware performance counters.
//!
//! The paper notes the Pathfinder "recently gained hardware performance
//! counters" and that future work will use them to explain timing variance
//! (§VI). The simulator keeps the equivalent ledger: total ops by kind,
//! per-node busy integrals, and derived utilizations — these drive both the
//! reports and the §Perf analysis.

use super::machine::Machine;

/// Accumulated activity of one simulation run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Counters {
    /// Random channel ops serviced per node.
    pub channel_ops: Vec<f64>,
    /// Streamed bytes per node.
    pub stream_bytes: Vec<f64>,
    /// Instructions issued per node.
    pub instructions: Vec<f64>,
    /// Fabric bytes per node.
    pub fabric_bytes: Vec<f64>,
    /// Thread migrations landed per node.
    pub migrations: Vec<f64>,
    /// MSP remote ops (remote_min / remote_add) per node.
    pub msp_ops: Vec<f64>,
    /// Total simulated time (ns) of the run these counters cover.
    pub elapsed_ns: f64,
}

impl Counters {
    pub fn new(nodes: usize) -> Self {
        Counters {
            channel_ops: vec![0.0; nodes],
            stream_bytes: vec![0.0; nodes],
            instructions: vec![0.0; nodes],
            fabric_bytes: vec![0.0; nodes],
            migrations: vec![0.0; nodes],
            msp_ops: vec![0.0; nodes],
            elapsed_ns: 0.0,
        }
    }

    pub fn nodes(&self) -> usize {
        self.channel_ops.len()
    }

    /// Merge another run's counters into this one.
    pub fn merge(&mut self, other: &Counters) {
        assert_eq!(self.nodes(), other.nodes());
        for n in 0..self.nodes() {
            self.channel_ops[n] += other.channel_ops[n];
            self.stream_bytes[n] += other.stream_bytes[n];
            self.instructions[n] += other.instructions[n];
            self.fabric_bytes[n] += other.fabric_bytes[n];
            self.migrations[n] += other.migrations[n];
            self.msp_ops[n] += other.msp_ops[n];
        }
        self.elapsed_ns += other.elapsed_ns;
    }

    /// Channel utilization of a node over the covered interval: fraction of
    /// the node's random-op capacity that was busy. This is the number the
    /// paper's whole thesis rides on — sequential queries leave it low,
    /// concurrent queries push it toward 1.
    pub fn channel_utilization(&self, m: &Machine, node: usize) -> f64 {
        if self.elapsed_ns <= 0.0 {
            return 0.0;
        }
        let capacity_ops = m.channel_op_rate(node) * self.elapsed_ns * 1e-9;
        (self.channel_ops[node] / capacity_ops).min(1.0)
    }

    /// Machine-wide mean channel utilization.
    pub fn mean_channel_utilization(&self, m: &Machine) -> f64 {
        let n = self.nodes();
        (0..n).map(|nd| self.channel_utilization(m, nd)).sum::<f64>() / n as f64
    }

    /// Total ops of each kind (for compact report lines).
    pub fn totals(&self) -> CounterTotals {
        CounterTotals {
            channel_ops: self.channel_ops.iter().sum(),
            stream_bytes: self.stream_bytes.iter().sum(),
            instructions: self.instructions.iter().sum(),
            fabric_bytes: self.fabric_bytes.iter().sum(),
            migrations: self.migrations.iter().sum(),
            msp_ops: self.msp_ops.iter().sum(),
        }
    }
}

/// Machine-wide totals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CounterTotals {
    pub channel_ops: f64,
    pub stream_bytes: f64,
    pub instructions: f64,
    pub fabric_bytes: f64,
    pub migrations: f64,
    pub msp_ops: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;

    #[test]
    fn merge_adds() {
        let mut a = Counters::new(2);
        a.channel_ops[0] = 5.0;
        a.elapsed_ns = 10.0;
        let mut b = Counters::new(2);
        b.channel_ops[0] = 3.0;
        b.msp_ops[1] = 7.0;
        b.elapsed_ns = 5.0;
        a.merge(&b);
        assert_eq!(a.channel_ops[0], 8.0);
        assert_eq!(a.msp_ops[1], 7.0);
        assert_eq!(a.elapsed_ns, 15.0);
    }

    #[test]
    fn utilization_bounded() {
        let m = Machine::new(MachineConfig::pathfinder_8());
        let mut c = Counters::new(8);
        c.elapsed_ns = 1e9; // 1 s
        c.channel_ops[0] = m.channel_op_rate(0) * 0.5; // half capacity
        let u = c.channel_utilization(&m, 0);
        assert!((u - 0.5).abs() < 1e-9);
        c.channel_ops[0] = m.channel_op_rate(0) * 99.0;
        assert_eq!(c.channel_utilization(&m, 0), 1.0);
    }

    #[test]
    fn totals_sum_nodes() {
        let mut c = Counters::new(3);
        c.instructions = vec![1.0, 2.0, 3.0];
        assert_eq!(c.totals().instructions, 6.0);
    }
}
