//! Resource demand vectors: the interface between the functional algorithms
//! ([`crate::alg`]) and the flow-level timing engine ([`super::flow`]).
//!
//! One [`PhaseDemand`] describes everything one synchronous phase of one
//! query (a BFS level, an SV hook sweep, a compress pass, ...) asks of the
//! machine: random channel ops, streamed bytes, instructions and fabric
//! bytes per node, plus two latency-structure numbers the fluid model needs
//! — the hottest channel's op count (load imbalance floor) and the serial
//! dependency chain (e.g. pointer-jumping depth).
//!
//! Most analyses build their demand inline with a [`DemandBuilder`] while
//! they traverse (BFS levels, SSSP buckets depend on runtime state), but
//! phases whose shape is a pure function of the graph and machine live
//! here as named constructors, so the model is written down once and the
//! cost-accounting tests pin it:
//!
//! * [`PhaseDemand::ingest_batch`] — the memory-side edge-ingest model of
//!   the mutation lane (DESIGN.md §Mutation);
//! * [`PhaseDemand::compaction_fold`] — the merge traffic of folding
//!   drained delta overlays back into a flat base CSR, submitted as
//!   Batch-class work when `serve --mutate` compacts (compaction is not
//!   free);
//! * [`PhaseDemand::pagerank_push_round`] /
//!   [`PhaseDemand::pagerank_residual_check`] — one PageRank round
//!   ([`crate::alg::pagerank`]): a dense push sweep (one MSP `remote_add`
//!   per directed edge into the query's next-rank array) plus the
//!   frontier-less round control (per-vertex commit + a migrating view-0
//!   residual reduction);
//! * [`PhaseDemand::tricount_intersections`] — the degree-ordered
//!   neighbor-intersection sweep of [`crate::alg::tricount`]: read traffic
//!   scaled by ordered wedges, near-zero writes (one MSP RMW per vertex
//!   into a global accumulator);
//! * [`PhaseDemand::uniform_channel_load`] — the synthetic closed-form
//!   shape the flow-engine fairness tests and the CI bench gate share;
//! * [`PhaseDemand::uniform_fleet_load`] — the same shape with a uniform
//!   fleet-interconnect demand on top, for the interconnect-bound
//!   closed-form fleet scenario of the CI bench gate.
//!
//! The fused multi-source BFS demand (`serve --batch`; DESIGN.md
//! §Batching) is built inline by [`crate::alg::msbfs`] like the other
//! traversals, but it is worth naming here because it deliberately bends
//! the one-query-one-array pattern above: the per-edge
//! [`DemandBuilder::msp_op`] is a single RMW ORing a *frontier word
//! shared by up to 64 sources* — one charge serves the whole batch, which
//! is exactly where the fusion win comes from — while the per-discovery
//! level write lands in the discovering *member's* own stripe-rotated
//! frame, so per-source private state still spreads across channels like
//! independent queries' arrays would.
//!
//! See docs/ANALYSES.md for how to derive a new analysis's demand model
//! from the paper's migration/MSP/fabric cost accounting.

use super::machine::Machine;
use crate::graph::delta::EdgeUpdate;
use crate::graph::view::{GraphView, NeighborScratch};

/// The degree-then-id total order that orients every undirected edge for
/// triangle counting: `a ≺ b` iff `(deg[a], a) < (deg[b], b)`. ONE copy,
/// shared by the functional kernel ([`crate::alg::tricount`]) and the
/// demand model ([`PhaseDemand::tricount_intersections`]), so the two
/// walks can never disagree about which direction an edge is oriented —
/// a divergence the functional oracle tests would not catch (the count
/// stays right under any strict total order; the charged migrations and
/// wedge re-streams would silently change).
#[inline]
pub fn degree_ordered(deg: &[usize], a: u32, b: u32) -> bool {
    (deg[a as usize], a) < (deg[b as usize], b)
}

/// Resource demand of one synchronous phase of one query.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseDemand {
    /// NCDRAM channels per node (shape of `per_channel_ops`).
    pub channels_per_node: usize,
    /// Random (8 B granularity) ops per individual channel, row-major
    /// `[node][channel]`: this is the granularity the flow engine shares
    /// capacity at — two queries hammering *different* channels of one node
    /// do not contend, two hammering the same channel serialize.
    pub per_channel_ops: Vec<f64>,
    /// Random channel ops per node (sums of `per_channel_ops` rows).
    pub channel_ops: Vec<f64>,
    /// Sequentially streamed bytes per node (edge-block scans).
    pub stream_bytes: Vec<f64>,
    /// Instructions issued per node.
    pub instructions: Vec<f64>,
    /// Bytes crossing the fabric per node (egress accounting).
    pub fabric_bytes: Vec<f64>,
    /// Bytes each node pushes over the inter-machine *fleet interconnect*
    /// (cross-shard frontier exchange, replication log shipping; DESIGN.md
    /// §Fleet). Zero for every single-machine demand, so the extra
    /// resource kind is inert outside `serve --fleet`.
    pub interconnect_bytes: Vec<f64>,
    /// Op count on the hottest single channel of each node (>= ops/chans).
    pub max_channel_ops: Vec<f64>,
    /// Thread migrations landing on each node.
    pub migrations: Vec<f64>,
    /// MSP read-modify-write ops per node (a subset of `channel_ops`,
    /// tracked separately for the §IV-C read/write-priority analysis).
    pub msp_ops: Vec<f64>,
    /// Length of the longest serial dependency chain in this phase,
    /// expressed in dependent remote hops (0 = fully parallel).
    pub serial_hops: f64,
    /// Per-phase override of the machine's `spawn_efficiency` (None =
    /// machine default). Frontier-driven phases inherit the calibrated
    /// single-query deficit; flat whole-graph sweeps (the CC hook) spawn a
    /// uniform Cilk loop that keeps the issue slots busy, so they override
    /// toward 1.0.
    pub issue_efficiency: Option<f64>,
    /// Available parallelism: number of independently runnable work items
    /// (threads) this phase can use, machine-wide.
    pub parallelism: f64,
    /// First machine node this demand's vectors describe (default 0). The
    /// per-node vectors stay *local* (length = the span's node count);
    /// machine-rate lookups and flow-resource indices add the offset. This
    /// lets a chassis-local workload on a huge flattened cluster carry
    /// span-sized vectors instead of machine-sized ones — the difference
    /// between ~kB and ~GB of demand state at 10⁵ concurrent queries.
    pub node_offset: usize,
}

impl PhaseDemand {
    pub fn zero(nodes: usize, channels_per_node: usize) -> Self {
        PhaseDemand {
            channels_per_node,
            per_channel_ops: vec![0.0; nodes * channels_per_node],
            channel_ops: vec![0.0; nodes],
            stream_bytes: vec![0.0; nodes],
            instructions: vec![0.0; nodes],
            fabric_bytes: vec![0.0; nodes],
            interconnect_bytes: vec![0.0; nodes],
            max_channel_ops: vec![0.0; nodes],
            migrations: vec![0.0; nodes],
            msp_ops: vec![0.0; nodes],
            serial_hops: 0.0,
            issue_efficiency: None,
            parallelism: 1.0,
            node_offset: 0,
        }
    }

    /// Anchor this demand's local node vectors at machine node
    /// `node_offset` (see the field doc). The vectors themselves are
    /// untouched — index `n` now describes machine node `node_offset + n`.
    pub fn with_node_offset(mut self, node_offset: usize) -> Self {
        self.node_offset = node_offset;
        self
    }

    pub fn nodes(&self) -> usize {
        self.channel_ops.len()
    }

    /// Total random channel ops across nodes.
    pub fn total_channel_ops(&self) -> f64 {
        self.channel_ops.iter().sum()
    }

    /// Total instructions across nodes.
    pub fn total_instructions(&self) -> f64 {
        self.instructions.iter().sum()
    }

    /// Total migrations across nodes.
    pub fn total_migrations(&self) -> f64 {
        self.migrations.iter().sum()
    }

    /// Total fleet-interconnect bytes across nodes.
    pub fn total_interconnect_bytes(&self) -> f64 {
        self.interconnect_bytes.iter().sum()
    }

    /// Number of shared-resource kinds the flow engine allocates per node:
    /// aggregate channel ops, the hottest single channel, streamed bytes,
    /// instruction issue, fabric link, fleet interconnect. (`solo_ns`
    /// granularity; the flow engine additionally splits channel capacity
    /// per individual channel — see [`PhaseDemand::flow_resources`].)
    pub const RESOURCE_KINDS: usize = 6;

    /// Number of capacity resources per node in the flow engine's
    /// allocation space: one per channel plus stream / instr / fabric /
    /// fleet interconnect.
    pub fn flow_kinds(&self) -> usize {
        self.channels_per_node + 4
    }

    /// Sparse utilization vector for the flow engine: for each capacity
    /// resource this phase touches, the fraction of that resource consumed
    /// when the phase runs at solo speed. Resource index space is
    /// `node * (channels_per_node + 4) + k` with `k` = channel index, then
    /// stream, instr, fabric, fleet interconnect.
    pub fn flow_resources(&self, m: &Machine, solo_ns: f64) -> Vec<(u32, f64)> {
        let mut out = Vec::new();
        if solo_ns <= 0.0 {
            return out;
        }
        let kinds = self.flow_kinds();
        let cpn = self.channels_per_node;
        for node in 0..self.nodes() {
            // Machine node this local index describes (offset demands).
            let mnode = self.node_offset + node;
            // MSP premium folded uniformly over the node's channels.
            let msp_premium = m.msp_op_ns(mnode) / m.channel_op_ns(mnode) - 1.0;
            let mix = if self.channel_ops[node] > 0.0 {
                1.0 + self.msp_ops[node] * msp_premium / self.channel_ops[node]
            } else {
                1.0
            };
            let base = mnode * kinds;
            for c in 0..cpn {
                let ops = self.per_channel_ops[node * cpn + c];
                if ops > 0.0 {
                    let drain = ops * mix * m.channel_op_ns(mnode);
                    out.push((base as u32 + c as u32, drain / solo_ns));
                }
            }
            let d = self.drain_ns(m, node);
            for (k, drain) in [d[2], d[3], d[4], d[5]].into_iter().enumerate() {
                if drain > 0.0 {
                    out.push(((base + cpn + k) as u32, drain / solo_ns));
                }
            }
        }
        out
    }

    /// Per-node drain times (ns) of this phase at *full* capacity of each
    /// shared resource: `[channel, hottest-channel, stream, instr, fabric,
    /// interconnect]`. `solo_ns` is the max of these over nodes (plus
    /// latency floors); the flow engine turns them into utilization
    /// fractions.
    pub fn drain_ns(&self, m: &Machine, node: usize) -> [f64; Self::RESOURCE_KINDS] {
        // Local index into this demand's vectors; machine lookups add the
        // span offset (0 for whole-machine demands).
        let mnode = self.node_offset + node;
        // MSP RMW ops cost more than plain accesses; fold the premium
        // into an effective op count (scaled by the write-priority knob).
        let msp_premium = m.msp_op_ns(mnode) / m.channel_op_ns(mnode) - 1.0;
        let eff_ops = self.channel_ops[node] + self.msp_ops[node] * msp_premium;
        let mix = if self.channel_ops[node] > 0.0 {
            eff_ops / self.channel_ops[node]
        } else {
            1.0
        };
        [
            eff_ops / m.channel_op_rate(mnode) * 1e9,
            // Load-imbalance floor: the hottest channel must serially
            // service its ops.
            self.max_channel_ops[node] * mix * m.channel_op_ns(mnode),
            self.stream_bytes[node] / m.stream_rate(mnode) * 1e9,
            self.instructions[node] / m.issue_rate(mnode) * 1e9,
            self.fabric_bytes[node] / m.fabric_rate(mnode) * 1e9,
            self.interconnect_bytes[node] / m.interconnect_rate(mnode) * 1e9,
        ]
    }

    /// The duration of this phase if it ran ALONE on the machine (ns):
    /// the max over per-node resource drain times, floored by the
    /// latency-structure terms. This is the fluid model's λ-cap.
    pub fn solo_ns(&self, m: &Machine) -> f64 {
        let mut t: f64 = 0.0;
        for node in 0..self.nodes() {
            for d in self.drain_ns(m, node) {
                t = t.max(d);
            }
        }
        // Single-query issue-efficiency floor: one Cilk spawn tree only
        // keeps `spawn_efficiency` of the machine's aggregate issue slots
        // doing useful work (spawn/steal overhead, level imbalance,
        // partially-filled contexts). This is the paper's headroom: the
        // floor binds a SOLO query, but it is per-query — concurrent
        // queries' threads fill the slots a single query leaves idle.
        let total_instr = self.total_instructions();
        if total_instr > 0.0 {
            let eta = self.issue_efficiency.unwrap_or(m.cfg.spawn_efficiency);
            let full_issue: f64 =
                (0..self.nodes()).map(|n| m.issue_rate(self.node_offset + n)).sum();
            t = t.max(total_instr / (eta * full_issue) * 1e9);
        }
        // Parallelism floor: with P runnable threads, each blocking on one
        // memory access at a time (cache-less cores), the phase cannot
        // finish faster than total_ops/P rounds of the mean access latency
        // (local access plus the fabric hop for the remote fraction).
        let total_ops = self.total_channel_ops();
        if total_ops > 0.0 && self.parallelism > 0.0 {
            let mean_lat = m.cfg.local_access_ns
                + self.mean_remote_fraction() * m.mean_fabric_latency_ns(self.node_offset);
            let rounds = (total_ops / self.parallelism).max(1.0);
            t = t.max(rounds * mean_lat);
        }
        // Serial chain floor (pointer jumping, reductions): each hop pays a
        // migration-ish round trip.
        let chain = self.serial_hops
            * (m.mean_fabric_latency_ns(self.node_offset) + m.cfg.migration_overhead_ns);
        t = t.max(chain);
        // Fleet-interconnect latency floor: a phase that exchanges any
        // cross-shard traffic pays at least one inter-machine round.
        // Zero-interconnect (single-machine) demands skip this entirely.
        if self.total_interconnect_bytes() > 0.0 {
            t = t.max(m.interconnect_latency_ns());
        }
        t + m.cfg.level_sync_ns
    }

    /// A synthetic latency-bound phase lasting ~`total_ns` solo while
    /// consuming `frac` of every channel of every node uniformly — the
    /// structural shape of a single Pathfinder query (latency-bound, not
    /// capacity-bound: parallelism is picked so the rounds x latency floor
    /// lands at `total_ns`). Uniformity makes saturated completion times
    /// closed-form, which the flow engine's fairness tests and the CI
    /// bench gate (`rust/benches/flow_sim.rs`, `ci/BENCH_baseline.json`)
    /// rely on; keep the shape in sync with those closed forms.
    pub fn uniform_channel_load(m: &Machine, frac: f64, total_ns: f64) -> PhaseDemand {
        Self::uniform_channel_load_span(m, frac, total_ns, 0, m.nodes())
    }

    /// [`PhaseDemand::uniform_channel_load`] restricted to the `nodes`-node
    /// span starting at machine node `node_offset`: the demand's vectors
    /// are span-sized and anchored via [`PhaseDemand::with_node_offset`].
    /// This is the workload shape of the host-cost bench axis
    /// (`host_scaling` in `ci/BENCH_baseline.json`): each chassis of a
    /// flattened cluster runs its own local queries, so 10⁵ concurrent
    /// queries decompose into ~10³ independent allocator components while
    /// each demand stays a few hundred bytes.
    pub fn uniform_channel_load_span(
        m: &Machine,
        frac: f64,
        total_ns: f64,
        node_offset: usize,
        nodes: usize,
    ) -> PhaseDemand {
        let cpn = m.cfg.channels_per_node;
        let mut p = PhaseDemand::zero(nodes, cpn).with_node_offset(node_offset);
        let mut total_ops = 0.0;
        for n in 0..nodes {
            let ops = m.channel_op_rate(node_offset + n) * frac * total_ns * 1e-9;
            p.channel_ops[n] = ops;
            p.max_channel_ops[n] = ops / cpn as f64;
            for c in 0..cpn {
                p.per_channel_ops[n * cpn + c] = ops / cpn as f64;
            }
            total_ops += ops;
        }
        p.parallelism = total_ops * m.cfg.local_access_ns / total_ns;
        p
    }

    /// [`PhaseDemand::uniform_channel_load`] plus a uniform fleet-
    /// interconnect demand: every node additionally pushes
    /// `interconnect_ns` worth of its interconnect capacity, i.e. the
    /// phase's interconnect drain time is exactly `interconnect_ns` on
    /// every node. With `interconnect_ns > frac * total_ns` the
    /// interconnect is the bottleneck, which makes saturated fleet
    /// completion times closed-form — the shape the CI bench gate's
    /// `fleet/*` scenario (`rust/benches/flow_sim.rs`,
    /// `ci/BENCH_baseline.json`) is hand-derived from.
    pub fn uniform_fleet_load(
        m: &Machine,
        frac: f64,
        total_ns: f64,
        interconnect_ns: f64,
    ) -> PhaseDemand {
        let mut p = Self::uniform_channel_load(m, frac, total_ns);
        for n in 0..m.nodes() {
            p.interconnect_bytes[n] = m.interconnect_rate(n) * interconnect_ns * 1e-9;
        }
        p
    }

    /// Rotate every node's per-channel op placement by `offset` channels —
    /// the cheap equivalent of re-running an identical query with a
    /// different own-array stripe offset (connected components is
    /// source-free, so the coordinator computes its demand once and
    /// rotates per concurrent instance).
    pub fn rotate_channels(&self, offset: usize) -> PhaseDemand {
        let cpn = self.channels_per_node;
        let mut out = self.clone();
        if cpn == 0 || offset % cpn == 0 {
            return out;
        }
        for node in 0..self.nodes() {
            for c in 0..cpn {
                out.per_channel_ops[node * cpn + (c + offset) % cpn] =
                    self.per_channel_ops[node * cpn + c];
            }
        }
        out
    }

    /// Demand of applying one batched edge-update stream — the memory-side
    /// ingest model (DESIGN.md §Mutation). Per update, per direction of
    /// the undirected edge, the applier follows the tuned-BFS write rule
    /// (§III): it issues an **unconditional remote write** of the edge
    /// record into the destination vertex's delta log (one random op at
    /// the destination channel — checking first would migrate, so it
    /// never does) plus one **MSP read-modify-write** that splices the
    /// log head (`remote_add` on the per-vertex log pointer, §II). No
    /// thread migrations at all; remote endpoints pay 16 fabric bytes per
    /// message, charged at the issuing endpoint's node like BFS's remote
    /// writes. Deletes cost the same (a tombstone is still a write). The
    /// batch is a flat loop, so it overrides issue efficiency to 1.0 like
    /// the CC hook sweep. The resulting phase runs through the same flow
    /// engine as queries — mutation traffic competes for channel
    /// bandwidth with everything else.
    ///
    /// Unlike a query's *private* arrays (which rotate by stripe offset so
    /// concurrent queries heat different channels), the delta log is
    /// **shared graph state at a fixed home**: every concurrent batch
    /// updating a hot vertex lands on the same destination channel, so
    /// skewed update streams contend exactly where the hardware would.
    pub fn ingest_batch(m: &Machine, updates: &[EdgeUpdate]) -> PhaseDemand {
        let layout = m.layout;
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let mut b = DemandBuilder::new(nodes, channels);
        let mut ops = 0.0f64;
        for upd in updates {
            for (src, dst) in [(upd.u, upd.v), (upd.v, upd.u)] {
                let sn = layout.node_of(src);
                let dn = layout.node_of(dst);
                let dc = layout.channel_of(dst);
                // Unconditional remote write of the edge record.
                b.channel_op(dn, dc, 1.0);
                // MSP RMW splicing the per-vertex log head.
                b.msp_op(dn, dc, 1.0);
                ops += 2.0;
                b.instructions(sn, m.cfg.instr_per_edge);
                if dn != sn {
                    b.fabric_bytes(sn, 2.0 * 16.0);
                }
            }
        }
        if ops > 0.0 {
            b.parallelism(ops.min(contexts_total));
            b.issue_efficiency(1.0);
        }
        b.finish()
    }

    /// Demand of one **compaction fold** — the merge traffic of folding
    /// drained delta overlays back into a flat base CSR (DESIGN.md
    /// §Mutation). The fold is a flat two-pass merge over the owned vertex
    /// range: it **streams** the old base (offsets + edge records) and
    /// **streams back** the merged base — `2 x 8 B x (base_arcs + n)`,
    /// striped evenly across nodes like the CSR itself — while each
    /// drained log entry costs **two random ops** at its vertex's home
    /// (read the log record, merge/tombstone it into the build cursor),
    /// spread evenly over channels (the drained set is scattered). Merge
    /// work is `instr_per_edge x (base_arcs + drained_arcs)` instructions.
    /// Like ingest, the fold never migrates (it is write-shaped) and its
    /// flat loop pins issue efficiency at 1.0. Submitted as Batch-class
    /// work by `serve --mutate` whenever the store compacts, so the merge
    /// bandwidth competes with queries instead of being free.
    pub fn compaction_fold(
        m: &Machine,
        n: usize,
        base_arcs: usize,
        drained_arcs: usize,
    ) -> PhaseDemand {
        const PAPER_INT_BYTES: f64 = 8.0;
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let mut b = DemandBuilder::new(nodes, channels);
        let stream_per_node = 2.0 * PAPER_INT_BYTES * (base_arcs + n) as f64 / nodes as f64;
        let log_ops_per_channel = 2.0 * drained_arcs as f64 / (nodes * channels) as f64;
        let instr_per_node =
            m.cfg.instr_per_edge * (base_arcs + drained_arcs) as f64 / nodes as f64;
        for node in 0..nodes {
            b.stream_bytes(node, stream_per_node);
            b.instructions(node, instr_per_node);
            for c in 0..channels {
                b.channel_op(node, c, log_ops_per_channel);
            }
        }
        b.parallelism((n as f64).min(contexts_total));
        b.issue_efficiency(1.0);
        b.finish()
    }

    /// Demand of one PageRank **push sweep** (see [`crate::alg::pagerank`]):
    /// a flat `cilk_for` over every vertex. Each worker reads its own rank
    /// record (one random op in the query's *private* rank array, so the
    /// stripe offset applies), streams the vertex's edge block, and issues
    /// one **MSP `remote_add`** per directed edge into the query's
    /// next-rank array at the destination's home channel (§II memory-side
    /// accumulation: a read-modify-write cycle, no thread migration —
    /// checking or fetching the old value first would migrate, so it never
    /// does). Remote endpoints pay 16 fabric bytes per message, charged at
    /// the issuing node like BFS's remote writes.
    ///
    /// Unlike a frontier-driven traversal, the sweep is **dense and
    /// unconditional**: every edge is charged every round regardless of
    /// convergence state, so per-round demand is a pure function of the
    /// graph — [`crate::alg::pagerank::pagerank_run_offset`] computes this
    /// shape once and clones it per round. Like the CC hook sweep, the
    /// flat loop keeps the issue slots busy (issue efficiency 1.0) and
    /// needs **zero migrations**.
    pub fn pagerank_push_round(
        m: &Machine,
        g: GraphView<'_>,
        stripe_offset: usize,
    ) -> PhaseDemand {
        let layout = m.layout;
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let mut b = DemandBuilder::new(nodes, channels);
        let mut scratch = NeighborScratch::default();
        let mut ops = 0.0f64;
        for u in 0..g.n() as u32 {
            let un = layout.node_of(u);
            b.instructions(un, m.cfg.spawn_instr);
            // Own rank record read (private array: stripe offset applies).
            b.channel_op(un, (layout.channel_of(u) + stripe_offset) % channels, 1.0);
            ops += 1.0;
            let nbrs = g.neighbors(u, &mut scratch);
            b.stream_bytes(un, GraphView::edge_block_bytes_for(nbrs.len()) as f64);
            b.instructions(un, nbrs.len() as f64 * m.cfg.instr_per_edge);
            for &v in nbrs {
                // remote_add into next[v] of THIS query's rank array.
                let vn = layout.node_of(v);
                b.msp_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                ops += 1.0;
                if vn != un {
                    b.fabric_bytes(un, 16.0);
                }
            }
        }
        if ops > 0.0 {
            b.parallelism(ops.min(contexts_total));
            b.issue_efficiency(1.0);
        }
        b.finish()
    }

    /// Instructions per vertex in the PageRank residual/commit phase: read
    /// `next[v]` and `rank[v]`, |diff| into the local residual partial,
    /// write `rank[v] <- next[v]`, reset `next[v]`.
    pub const PAGERANK_CHECK_INSTR_PER_VERTEX: f64 = 10.0;

    /// Demand of one PageRank **residual check + commit** — the
    /// frontier-less round control. Per vertex: three random ops in the
    /// query's private arrays (read `next[v]`, read `rank[v]`, write the
    /// commit) plus [`PhaseDemand::PAGERANK_CHECK_INSTR_PER_VERTEX`]
    /// instructions accumulating the node-local L1-residual partial. The
    /// view-0 partials are then reduced by a **single thread migrating
    /// across all nodes** (the only migrations PageRank ever pays — Fig. 2
    /// line 2's shape), a serial chain of `nodes - 1` hops that decides
    /// convergence for the next round.
    pub fn pagerank_residual_check(m: &Machine, n: usize, stripe_offset: usize) -> PhaseDemand {
        let layout = m.layout;
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let mut b = DemandBuilder::new(nodes, channels);
        for v in 0..n as u32 {
            let vn = layout.node_of(v);
            b.channel_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 3.0);
            b.instructions(vn, Self::PAGERANK_CHECK_INSTR_PER_VERTEX);
        }
        // The reduction thread hops node to node casting view-0 partials
        // (per-query private state, so it rides the query's stripe
        // rotation like every other op — the cacheable-demand contract
        // requires rotation-equivariance, see Analysis::cacheable_demand).
        for node in 1..nodes {
            b.migration(node, 1.0);
            b.channel_op(node, stripe_offset % channels, 1.0);
            b.fabric_bytes(node - 1, 64.0);
        }
        b.serial_hops(nodes as f64 - 1.0);
        b.parallelism((n as f64).min(contexts_total));
        b.issue_efficiency(1.0);
        b.finish()
    }

    /// Demand of the degree-ordered **neighbor-intersection sweep** of
    /// triangle counting (see [`crate::alg::tricount`]): one flat
    /// `cilk_for` over every vertex. For each *ordered* edge `u ≺ v`
    /// (`≺` = degree-then-id order) the worker must read `v`'s neighbor
    /// list — and a remote *read* migrates (§II–III), so unlike every
    /// write-shaped kernel in this repo the thread **pays two migrations
    /// per remote ordered edge** (to `v`'s home and back), then streams
    /// `v`'s edge block there and merge-scans it against `u`'s ordered
    /// suffix. Read traffic is therefore Σ over ordered edges of the
    /// destination block — the ordered-wedge-scaled skew the PIUMA /
    /// FlashGraph papers use this kernel to stress.
    ///
    /// Writes are near-zero: each worker keeps its triangle partial in
    /// registers and issues exactly **one MSP `remote_add` per vertex**
    /// into the query's single global accumulator (element 0 of its
    /// private result array, so the stripe offset rotates which channel
    /// the accumulator heats).
    ///
    /// Triangle counting is demand-cacheable, and the cache serves every
    /// concurrent instance as a channel *rotation* of the offset-0 demand
    /// — so this model must be rotation-equivariant (see
    /// [`crate::alg::Analysis::cacheable_demand`]): **all** random ops,
    /// including the shared vertex-record reads, are charged in the
    /// query's stripe-rotated frame. That is a deliberate concession
    /// (physically the records sit at fixed homes): per-node totals, the
    /// hottest-channel imbalance floor, migrations, streams and fabric
    /// are all rotation-invariant, so solo latency is exact — only which
    /// channel of the right node carries the reads moves, traded for
    /// computing the expensive intersection demand once instead of per
    /// instance.
    pub fn tricount_intersections(
        m: &Machine,
        g: GraphView<'_>,
        stripe_offset: usize,
    ) -> PhaseDemand {
        let layout = m.layout;
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let n = g.n();
        let mut scratch = NeighborScratch::default();
        let mut deg = vec![0usize; n];
        for v in 0..n as u32 {
            deg[v as usize] = g.neighbors(v, &mut scratch).len();
        }
        let ordered = |a: u32, b: u32| degree_ordered(&deg, a, b);
        let acc_node = layout.node_of(0);
        let acc_chan = (layout.channel_of(0) + stripe_offset) % channels;
        let mut b = DemandBuilder::new(nodes, channels);
        let mut ops = 0.0f64;
        for u in 0..n as u32 {
            let un = layout.node_of(u);
            b.instructions(un, m.cfg.spawn_instr);
            // u's vertex record (stripe-rotated frame — see above).
            b.channel_op(un, (layout.channel_of(u) + stripe_offset) % channels, 1.0);
            ops += 1.0;
            let nbrs = g.neighbors(u, &mut scratch);
            let du = nbrs.len();
            b.stream_bytes(un, GraphView::edge_block_bytes_for(du) as f64);
            // Orientation filter: one pass over u's own block.
            b.instructions(un, du as f64 * m.cfg.instr_per_edge);
            let fwd_deg = nbrs.iter().filter(|&&v| ordered(u, v)).count();
            for &v in nbrs {
                if !ordered(u, v) {
                    continue;
                }
                let vn = layout.node_of(v);
                // v's vertex record, read at v's home node.
                b.channel_op(vn, (layout.channel_of(v) + stripe_offset) % channels, 1.0);
                ops += 1.0;
                if vn != un {
                    // Remote read: migrate there, merge-scan, migrate back.
                    b.migration(vn, 1.0);
                    b.fabric_bytes(un, 64.0);
                    b.migration(un, 1.0);
                    b.fabric_bytes(vn, 64.0);
                }
                b.stream_bytes(vn, GraphView::edge_block_bytes_for(deg[v as usize]) as f64);
                // Merge scan of u's ordered suffix against v's full block.
                b.instructions(vn, (fwd_deg + deg[v as usize]) as f64 * m.cfg.instr_per_edge);
            }
            // One remote_add of the worker's partial into the global
            // accumulator.
            b.msp_op(acc_node, acc_chan, 1.0);
            ops += 1.0;
            if un != acc_node {
                b.fabric_bytes(un, 16.0);
            }
        }
        if ops > 0.0 {
            b.parallelism(ops.min(contexts_total));
            b.issue_efficiency(1.0);
        }
        b.finish()
    }

    /// Fraction of channel ops that had to cross the fabric.
    fn mean_remote_fraction(&self) -> f64 {
        let total = self.total_channel_ops();
        if total == 0.0 {
            return 0.0;
        }
        (self.fabric_bytes.iter().sum::<f64>() / 16.0 /* bytes per remote op msg */)
            .min(total)
            / total
    }
}

/// Builder that accumulates per-channel op counts and collapses them into a
/// [`PhaseDemand`] (per-node totals + hottest channel).
#[derive(Debug, Clone)]
pub struct DemandBuilder {
    nodes: usize,
    channels_per_node: usize,
    demand: PhaseDemand,
}

impl DemandBuilder {
    pub fn new(nodes: usize, channels_per_node: usize) -> Self {
        DemandBuilder {
            nodes,
            channels_per_node,
            demand: PhaseDemand::zero(nodes, channels_per_node),
        }
    }

    /// One random op at (node, channel).
    #[inline]
    pub fn channel_op(&mut self, node: usize, channel: usize, count: f64) {
        self.demand.per_channel_ops[node * self.channels_per_node + channel] += count;
        self.demand.channel_ops[node] += count;
    }

    #[inline]
    pub fn stream_bytes(&mut self, node: usize, bytes: f64) {
        self.demand.stream_bytes[node] += bytes;
    }

    #[inline]
    pub fn instructions(&mut self, node: usize, count: f64) {
        self.demand.instructions[node] += count;
    }

    #[inline]
    pub fn fabric_bytes(&mut self, node: usize, bytes: f64) {
        self.demand.fabric_bytes[node] += bytes;
    }

    /// Bytes `node` pushes over the inter-machine fleet interconnect.
    #[inline]
    pub fn interconnect_bytes(&mut self, node: usize, bytes: f64) {
        self.demand.interconnect_bytes[node] += bytes;
    }

    #[inline]
    pub fn migration(&mut self, to_node: usize, count: f64) {
        self.demand.migrations[to_node] += count;
    }

    /// One MSP remote op (remote_min/remote_add) at (node, channel):
    /// charges the channel (RMW cycle, weighted by the MSP write-priority
    /// knob at timing) and the MSP ledger.
    #[inline]
    pub fn msp_op(&mut self, node: usize, channel: usize, count: f64) {
        self.channel_op(node, channel, count);
        self.demand.msp_ops[node] += count;
    }

    pub fn serial_hops(&mut self, hops: f64) {
        self.demand.serial_hops = self.demand.serial_hops.max(hops);
    }

    /// Override the phase's issue efficiency (see
    /// [`PhaseDemand::issue_efficiency`]).
    pub fn issue_efficiency(&mut self, eta: f64) {
        assert!(eta > 0.0 && eta <= 1.0);
        self.demand.issue_efficiency = Some(eta);
    }

    pub fn parallelism(&mut self, p: f64) {
        self.demand.parallelism = p.max(1.0);
    }

    /// Collapse into the final demand vector.
    pub fn finish(mut self) -> PhaseDemand {
        for node in 0..self.nodes {
            let lo = node * self.channels_per_node;
            let hi = lo + self.channels_per_node;
            self.demand.max_channel_ops[node] = self.demand.per_channel_ops[lo..hi]
                .iter()
                .copied()
                .fold(0.0, f64::max);
        }
        self.demand
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    #[test]
    fn rotate_channels_permutes_within_node() {
        let mut b = DemandBuilder::new(2, 4);
        b.channel_op(0, 0, 5.0);
        b.channel_op(1, 3, 7.0);
        let d = b.finish();
        let r = d.rotate_channels(1);
        assert_eq!(r.per_channel_ops[1], 5.0);
        assert_eq!(r.per_channel_ops[4], 7.0); // wraps 3 -> 0
        // Node totals, instr etc. unchanged.
        assert_eq!(r.channel_ops, d.channel_ops);
        let m = m8();
        // Rotation by a full cycle is identity.
        assert_eq!(d.rotate_channels(4), d);
        let _ = m;
    }

    #[test]
    fn builder_collapses_hottest_channel() {
        let mut b = DemandBuilder::new(2, 4);
        b.channel_op(0, 1, 10.0);
        b.channel_op(0, 1, 5.0);
        b.channel_op(0, 2, 3.0);
        b.channel_op(1, 0, 7.0);
        let d = b.finish();
        assert_eq!(d.channel_ops, vec![18.0, 7.0]);
        assert_eq!(d.max_channel_ops, vec![15.0, 7.0]);
    }

    #[test]
    fn solo_ns_floor_is_level_sync() {
        let m = m8();
        let d = PhaseDemand::zero(8, 8);
        assert!((d.solo_ns(&m) - m.cfg.level_sync_ns).abs() < 1e-6);
    }

    #[test]
    fn solo_ns_scales_with_work() {
        let m = m8();
        let mut small = PhaseDemand::zero(8, 8);
        small.channel_ops[0] = 1e4;
        small.max_channel_ops[0] = 1e4 / 8.0;
        small.parallelism = 1e4;
        let mut big = small.clone();
        big.channel_ops[0] = 1e7;
        big.max_channel_ops[0] = 1e7 / 8.0;
        assert!(big.solo_ns(&m) > 10.0 * small.solo_ns(&m));
    }

    #[test]
    fn imbalance_raises_solo_time() {
        let m = m8();
        let mut balanced = PhaseDemand::zero(8, 8);
        let mut skewed = PhaseDemand::zero(8, 8);
        for n in 0..8 {
            balanced.channel_ops[n] = 1e6;
            balanced.max_channel_ops[n] = 1e6 / 8.0;
            skewed.channel_ops[n] = 1e6;
            skewed.max_channel_ops[n] = 1e6; // everything on one channel
        }
        balanced.parallelism = 1e6;
        skewed.parallelism = 1e6;
        assert!(skewed.solo_ns(&m) > 2.0 * balanced.solo_ns(&m));
    }

    #[test]
    fn low_parallelism_is_latency_bound() {
        let m = m8();
        let mut d = PhaseDemand::zero(8, 8);
        for n in 0..8 {
            d.channel_ops[n] = 1e5;
            d.max_channel_ops[n] = 1e5 / 8.0;
        }
        d.parallelism = 4.0; // four threads for 800k ops
        let slow = d.solo_ns(&m);
        d.parallelism = 1e6;
        let fast = d.solo_ns(&m);
        assert!(slow > 5.0 * fast, "slow {slow} fast {fast}");
    }

    #[test]
    fn serial_chain_floor() {
        let m = m8();
        let mut d = PhaseDemand::zero(8, 8);
        d.serial_hops = 1000.0;
        assert!(d.solo_ns(&m) > 1000.0 * m.cfg.migration_overhead_ns);
    }

    #[test]
    fn ingest_batch_charges_write_and_msp_per_half_edge_no_migrations() {
        use crate::graph::delta::EdgeUpdate;
        let m = m8();
        let updates =
            vec![EdgeUpdate::insert(0, 9), EdgeUpdate::delete(1, 2), EdgeUpdate::insert(3, 3 + 8)];
        let d = PhaseDemand::ingest_batch(&m, &updates);
        // Two half-edges per update, two channel ops each (write + MSP).
        assert_eq!(d.total_channel_ops(), updates.len() as f64 * 2.0 * 2.0);
        // Exactly half the channel ops are MSP RMWs.
        assert_eq!(d.msp_ops.iter().sum::<f64>(), updates.len() as f64 * 2.0);
        // The write rule never migrates.
        assert_eq!(d.total_migrations(), 0.0);
        // (0,9) and (1,2) cross nodes both ways on the 8-node layout;
        // (3,11) is node-local (11 mod 8 == 3): fabric only for remote.
        assert_eq!(d.fabric_bytes.iter().sum::<f64>(), 4.0 * 32.0);
        // Flat applier loop: issue efficiency pinned like the CC hook.
        assert_eq!(d.issue_efficiency, Some(1.0));
        assert!(d.solo_ns(&m) > 0.0);
    }

    #[test]
    fn ingest_targets_the_fixed_delta_log_home_channel() {
        use crate::graph::delta::EdgeUpdate;
        let m = m8();
        // Two batches hammering the same hot vertex 9 (node 1, channel 1):
        // the delta log is SHARED state at a fixed home, so both charge
        // the exact same destination channel — unlike queries' private
        // arrays, which rotate per stripe offset.
        let a = PhaseDemand::ingest_batch(&m, &[EdgeUpdate::insert(0, 9)]);
        let b = PhaseDemand::ingest_batch(&m, &[EdgeUpdate::insert(16, 9)]);
        let cpn = m.cfg.channels_per_node;
        let hot = cpn + m.layout.channel_of(9); // node 1's row
        assert_eq!(a.per_channel_ops[hot], 2.0, "write + MSP at 9's home");
        assert_eq!(b.per_channel_ops[hot], 2.0, "every batch hits the same log channel");
    }

    #[test]
    fn empty_ingest_batch_is_zero_demand() {
        let m = m8();
        let d = PhaseDemand::ingest_batch(&m, &[]);
        assert_eq!(d.total_channel_ops(), 0.0);
        assert_eq!(d.solo_ns(&m), m.cfg.level_sync_ns);
    }

    #[test]
    fn compaction_fold_streams_both_bases_and_charges_log_merge_ops() {
        let m = m8();
        let (n, base_arcs, drained) = (1024usize, 4096usize, 512usize);
        let d = PhaseDemand::compaction_fold(&m, n, base_arcs, drained);
        // Stream: read the old base + write the merged base, striped.
        assert_eq!(d.stream_bytes.iter().sum::<f64>(), 2.0 * 8.0 * (base_arcs + n) as f64);
        // Two random ops per drained log entry, spread over all channels.
        assert_eq!(d.total_channel_ops(), 2.0 * drained as f64);
        assert_eq!(d.max_channel_ops[0], 2.0 * drained as f64 / 64.0);
        // Write-shaped: no migrations, no MSP RMWs, no fabric.
        assert_eq!(d.total_migrations(), 0.0);
        assert_eq!(d.msp_ops.iter().sum::<f64>(), 0.0);
        assert_eq!(d.fabric_bytes.iter().sum::<f64>(), 0.0);
        // Merge instructions cover old arcs + drained log entries.
        assert_eq!(
            d.total_instructions(),
            m.cfg.instr_per_edge * (base_arcs + drained) as f64
        );
        // Flat fold loop: issue slots pinned busy, like ingest.
        assert_eq!(d.issue_efficiency, Some(1.0));
        assert!(d.solo_ns(&m) > 0.0);
    }

    #[test]
    fn interconnect_is_a_sixth_priced_resource() {
        let m = m8();
        let mut d = PhaseDemand::zero(8, 8);
        d.interconnect_bytes[3] = m.interconnect_rate(3) * 2e-3; // 2 ms drain
        // drain_ns exposes the interconnect as its own kind...
        assert!((d.drain_ns(&m, 3)[5] - 2e6).abs() < 1e-6);
        assert_eq!(d.drain_ns(&m, 0)[5], 0.0);
        // ...solo time is bound by it...
        assert!((d.solo_ns(&m) - (2e6 + m.cfg.level_sync_ns)).abs() < 1e-3);
        // ...and the flow engine sees it at index base + cpn + 3.
        let solo = d.solo_ns(&m);
        let res = d.flow_resources(&m, solo);
        let idx = (3 * d.flow_kinds() + 8 + 3) as u32;
        let (_, util) = res.iter().find(|(i, _)| *i == idx).expect("interconnect resource");
        assert!((util - 2e6 / solo).abs() < 1e-12);
        assert_eq!(res.len(), 1, "nothing else charged");
    }

    #[test]
    fn interconnect_latency_floors_any_cross_shard_phase() {
        let m = m8();
        let mut d = PhaseDemand::zero(8, 8);
        // A single tiny exchange: bandwidth drain is negligible, but the
        // phase still pays one inter-machine round.
        d.interconnect_bytes[0] = 16.0;
        let expect = m.interconnect_latency_ns() + m.cfg.level_sync_ns;
        assert!((d.solo_ns(&m) - expect).abs() < 1e-6);
        // Zero-interconnect phases never pay the floor.
        let z = PhaseDemand::zero(8, 8);
        assert_eq!(z.solo_ns(&m), m.cfg.level_sync_ns);
    }

    #[test]
    fn uniform_fleet_load_drains_interconnect_for_exactly_the_given_time() {
        let m = m8();
        let d = PhaseDemand::uniform_fleet_load(&m, 0.5, 1e6, 1e6);
        let base = PhaseDemand::uniform_channel_load(&m, 0.5, 1e6);
        // Channel shape identical to the plain uniform load...
        assert_eq!(d.per_channel_ops, base.per_channel_ops);
        assert_eq!(d.parallelism, base.parallelism);
        // ...plus a 1e6 ns interconnect drain on every node.
        for n in 0..8 {
            assert!((d.drain_ns(&m, n)[5] - 1e6).abs() < 1e-6);
        }
        // Solo time unchanged (the parallelism floor already sits at 1e6).
        assert!((d.solo_ns(&m) - base.solo_ns(&m)).abs() < 1e-6);
    }

    #[test]
    fn pagerank_push_round_charges_one_msp_per_directed_edge_no_migrations() {
        use crate::graph::builder::build_undirected_csr;
        let m = m8();
        let g = build_undirected_csr(16, &[(0, 1), (1, 2), (2, 9), (9, 0)]);
        let d = PhaseDemand::pagerank_push_round(&m, g.view(), 0);
        // One rank read per vertex + one remote_add per directed edge.
        assert_eq!(d.total_channel_ops(), 16.0 + g.m_directed() as f64);
        assert_eq!(d.msp_ops.iter().sum::<f64>(), g.m_directed() as f64);
        // The dense push sweep never migrates.
        assert_eq!(d.total_migrations(), 0.0);
        // Streamed bytes = every vertex's edge block, like a hook sweep.
        let expect: u64 = (0..16u32).map(|v| g.edge_block_bytes(v)).sum();
        assert_eq!(d.stream_bytes.iter().sum::<f64>(), expect as f64);
        // Flat cilk_for: issue slots pinned busy.
        assert_eq!(d.issue_efficiency, Some(1.0));
    }

    #[test]
    fn pagerank_residual_check_is_the_only_migrating_phase() {
        let m = m8();
        let d = PhaseDemand::pagerank_residual_check(&m, 64, 0);
        // The reduction thread hops across the other 7 nodes.
        assert_eq!(d.total_migrations(), 7.0);
        assert_eq!(d.serial_hops, 7.0);
        // 3 private-array ops per vertex + 7 reduction reads.
        assert_eq!(d.total_channel_ops(), 64.0 * 3.0 + 7.0);
        assert_eq!(d.msp_ops.iter().sum::<f64>(), 0.0);
    }

    #[test]
    fn pagerank_phases_rotate_with_the_stripe_offset() {
        use crate::graph::builder::build_undirected_csr;
        let m = m8();
        let g = build_undirected_csr(16, &[(0, 1), (2, 3)]);
        let base = PhaseDemand::pagerank_push_round(&m, g.view(), 0);
        let shifted = PhaseDemand::pagerank_push_round(&m, g.view(), 3);
        // Same node totals, rotated channel placement.
        assert_eq!(shifted.channel_ops, base.channel_ops);
        assert_eq!(shifted.per_channel_ops, base.rotate_channels(3).per_channel_ops);
    }

    #[test]
    fn tricount_reads_scale_with_ordered_wedges_and_writes_stay_near_zero() {
        use crate::graph::builder::build_undirected_csr;
        let m = m8();
        // Path 0-1-2-3 plus chord 0-2: degrees [2,2,3,1].
        let g = build_undirected_csr(4, &[(0, 1), (1, 2), (2, 3), (0, 2)]);
        let d = PhaseDemand::tricount_intersections(&m, g.view(), 0);
        // Degree-then-id order: 3 (deg 1) ≺ 0 ≺ 1 (deg 2) ≺ 2 (deg 3).
        // Ordered edges: 0→1, 0→2, 1→2, 3→2 — four, one per undirected edge.
        let ordered_edges = 4.0;
        // Random ops: per-vertex record + per-ordered-edge record + the
        // per-vertex accumulator remote_add.
        assert_eq!(d.total_channel_ops(), 4.0 + ordered_edges + 4.0);
        // Writes are near-zero: one MSP RMW per vertex, nothing else.
        assert_eq!(d.msp_ops.iter().sum::<f64>(), 4.0);
        // Read traffic: every block once (own scan) + the ordered-edge
        // destinations' blocks again (intersection scans).
        let block = |v: u32| g.edge_block_bytes(v) as f64;
        let expect = (0..4u32).map(block).sum::<f64>() + block(1) + block(2) + block(2) + block(2);
        assert_eq!(d.stream_bytes.iter().sum::<f64>(), expect);
        // Remote ordered edges migrate there AND back; on the 8-node
        // layout all four vertices live on distinct nodes, so every
        // ordered edge is remote.
        assert_eq!(d.total_migrations(), 2.0 * ordered_edges);
    }

    #[test]
    fn tricount_demand_is_rotation_equivariant() {
        use crate::graph::builder::build_undirected_csr;
        let m = m8();
        // Path + chord: mixed degrees, remote and wedge traffic present.
        let g = build_undirected_csr(12, &[(0, 1), (1, 2), (2, 3), (0, 2), (9, 10)]);
        let base = PhaseDemand::tricount_intersections(&m, g.view(), 0);
        // The global accumulator (element 0 of the query's private result
        // array, node 0) carries the per-vertex remote_adds.
        assert!(base.msp_ops[0] > 0.0);
        // The cacheable-demand contract: a direct preparation at offset k
        // IS the offset-0 demand rotated k channels — nothing (records,
        // accumulator, anything) may sit outside the rotated frame.
        for k in [1usize, 3, 9] {
            let direct = PhaseDemand::tricount_intersections(&m, g.view(), k);
            assert_eq!(direct, base.rotate_channels(k), "offset {k}");
        }
    }
}
