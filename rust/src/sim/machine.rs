//! Resolved machine: per-node derated capacities computed once from a
//! [`MachineConfig`], plus the data layout the graph uses.

use crate::config::machine::MachineConfig;
use crate::graph::layout::StripedLayout;

/// A machine instance the simulator engines run against.
#[derive(Debug, Clone)]
pub struct Machine {
    pub cfg: MachineConfig,
    pub layout: StripedLayout,
    /// Per-node random-op capacity (ops/s), derated.
    channel_op_rate: Vec<f64>,
    /// Per-node streaming capacity (bytes/s), derated.
    stream_rate: Vec<f64>,
    /// Per-node instruction issue capacity (instr/s).
    issue_rate: Vec<f64>,
    /// Per-node fabric link capacity (bytes/s), derated.
    fabric_rate: Vec<f64>,
    /// Per-node fleet-interconnect capacity (bytes/s), derated.
    interconnect_rate: Vec<f64>,
    /// Mean one-way fabric latency seen from each node (ns).
    mean_fabric_latency: Vec<f64>,
}

impl Machine {
    pub fn new(cfg: MachineConfig) -> Self {
        cfg.validate().expect("invalid machine config");
        let nodes = cfg.nodes;
        let layout = StripedLayout::new(nodes, cfg.channels_per_node);
        let mut channel_op_rate = Vec::with_capacity(nodes);
        let mut stream_rate = Vec::with_capacity(nodes);
        let mut issue_rate = Vec::with_capacity(nodes);
        let mut fabric_rate = Vec::with_capacity(nodes);
        let mut interconnect_rate = Vec::with_capacity(nodes);
        let mut mean_fabric_latency = Vec::with_capacity(nodes);
        // Mean one-way fabric latency, closed form. Latency between two
        // nodes depends only on their chassis (same/different base, min of
        // the two chassis derates — see `MachineConfig::fabric_latency_ns`),
        // so the mean over all other nodes is a per-chassis quantity:
        // summing per chassis (O(chassis²) total) instead of per node pair
        // (O(nodes²)) is what makes thousand-chassis cluster machines —
        // the host-cost bench's 100k-query fleet — cheap to construct.
        let n_chassis = nodes / cfg.nodes_per_chassis;
        let npc = cfg.nodes_per_chassis;
        let chassis_derate: Vec<f64> =
            (0..n_chassis).map(|c| cfg.node_derate(c * npc)).collect();
        let chassis_mean_lat: Vec<f64> = (0..n_chassis)
            .map(|mc| {
                if nodes == 1 {
                    return 0.0;
                }
                let dm = chassis_derate[mc];
                let mut sum = (npc - 1) as f64 * (cfg.fabric.intra_chassis_latency_ns / dm);
                for (c, &dc) in chassis_derate.iter().enumerate() {
                    if c != mc {
                        sum += npc as f64
                            * (cfg.fabric.inter_chassis_latency_ns / dm.min(dc));
                    }
                }
                sum / (nodes - 1) as f64
            })
            .collect();
        for node in 0..nodes {
            let derate = cfg.node_derate(node);
            channel_op_rate.push(cfg.node_channel_op_rate() * derate);
            stream_rate.push(cfg.node_stream_rate() * derate);
            // Cores are not derated (the §IV-B issues were RAM + network).
            issue_rate.push(cfg.node_issue_rate());
            fabric_rate.push(cfg.fabric.node_link_bytes_per_s * derate);
            interconnect_rate.push(cfg.fabric.interconnect_bytes_per_s * derate);
            mean_fabric_latency.push(chassis_mean_lat[cfg.chassis_of(node)]);
        }
        Machine {
            cfg,
            layout,
            channel_op_rate,
            stream_rate,
            issue_rate,
            fabric_rate,
            interconnect_rate,
            mean_fabric_latency,
        }
    }

    pub fn nodes(&self) -> usize {
        self.cfg.nodes
    }

    /// Derated random-op capacity of one node (ops/s).
    pub fn channel_op_rate(&self, node: usize) -> f64 {
        self.channel_op_rate[node]
    }

    /// Derated service time of one random op at one channel of `node` (ns).
    pub fn channel_op_ns(&self, node: usize) -> f64 {
        self.cfg.channel_random_op_ns / self.cfg.node_derate(node)
    }

    pub fn stream_rate(&self, node: usize) -> f64 {
        self.stream_rate[node]
    }

    pub fn issue_rate(&self, node: usize) -> f64 {
        self.issue_rate[node]
    }

    pub fn fabric_rate(&self, node: usize) -> f64 {
        self.fabric_rate[node]
    }

    /// Derated fleet-interconnect capacity of one node (bytes/s): the
    /// node's share of the inter-machine pipe a cluster ships cross-shard
    /// frontier exchanges and replication traffic over. Single-machine
    /// demands never charge it.
    pub fn interconnect_rate(&self, node: usize) -> f64 {
        self.interconnect_rate[node]
    }

    /// One-way fleet-interconnect message latency (ns).
    pub fn interconnect_latency_ns(&self) -> f64 {
        self.cfg.fabric.interconnect_latency_ns
    }

    /// Mean one-way fabric latency from `node` to a uniformly random other
    /// node (ns). Used for the latency floor of scattered remote traffic.
    pub fn mean_fabric_latency_ns(&self, node: usize) -> f64 {
        self.mean_fabric_latency[node]
    }

    /// Full cost of one thread migration landing on `to` (ns): fabric
    /// latency plus the hardware context transfer.
    pub fn migration_ns(&self, from: usize, to: usize) -> f64 {
        self.cfg.fabric_latency_ns(from, to) + self.cfg.migration_overhead_ns
    }

    /// Instruction rate available to a single thread when `active` threads
    /// share a node (round-robin issue, one instruction per core per cycle).
    pub fn per_thread_issue_rate(&self, node: usize, active: usize) -> f64 {
        let cores = self.cfg.cores_per_node as f64;
        if active == 0 {
            return self.cfg.clock_hz;
        }
        let threads_per_core = (active as f64 / cores).max(1.0);
        (self.issue_rate[node] / cores / threads_per_core).min(self.cfg.clock_hz)
    }

    /// Service time of an MSP remote op at `node` (ns): a read-modify-write
    /// channel cycle (holding the bank `msp_rmw_factor` times as long as a
    /// plain access) plus MSP overhead, weighted by the write-priority knob.
    pub fn msp_op_ns(&self, node: usize) -> f64 {
        (self.channel_op_ns(node) * self.cfg.msp_rmw_factor + self.cfg.msp_op_extra_ns)
            / self.cfg.msp_write_priority
    }

    /// Total machine-wide random-op capacity (ops/s).
    pub fn total_channel_op_rate(&self) -> f64 {
        self.channel_op_rate.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_machine_uniform() {
        let m = Machine::new(MachineConfig::pathfinder_8());
        assert_eq!(m.nodes(), 8);
        for n in 0..8 {
            assert_eq!(m.channel_op_rate(n), m.channel_op_rate(0));
        }
        // 8 channels / 54ns => ~148 Mops/s/node.
        let expect = 8.0 * 1e9 / 54.0;
        assert!((m.channel_op_rate(0) - expect).abs() < 1.0);
    }

    #[test]
    fn degraded_nodes_slower() {
        let m = Machine::new(MachineConfig::pathfinder_32());
        assert!(m.channel_op_rate(16) < m.channel_op_rate(0));
        assert!(m.channel_op_ns(16) > m.channel_op_ns(0));
        assert!(m.fabric_rate(31) < m.fabric_rate(0));
        // Issue rate is NOT derated.
        assert_eq!(m.issue_rate(16), m.issue_rate(0));
    }

    #[test]
    fn per_thread_issue_round_robin() {
        let m = Machine::new(MachineConfig::pathfinder_8());
        // One thread alone on a node runs at the core clock.
        assert_eq!(m.per_thread_issue_rate(0, 1), 225e6);
        // At full occupancy (1536 threads, 24 cores) each thread gets
        // clock / 64.
        let r = m.per_thread_issue_rate(0, 1536);
        assert!((r - 225e6 / 64.0).abs() < 1.0);
    }

    #[test]
    fn fabric_latency_mean_reflects_chassis() {
        let m8 = Machine::new(MachineConfig::pathfinder_8());
        let m32 = Machine::new(MachineConfig::pathfinder_32());
        // 32-node machine reaches across chassis, so mean latency is higher.
        assert!(m32.mean_fabric_latency_ns(0) > m8.mean_fabric_latency_ns(0));
    }

    #[test]
    fn msp_priority_knob() {
        let mut cfg = MachineConfig::pathfinder_8();
        let base = Machine::new(cfg.clone()).msp_op_ns(0);
        cfg.msp_write_priority = 2.0;
        assert!(Machine::new(cfg).msp_op_ns(0) < base);
    }
}
