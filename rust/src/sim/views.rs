//! Memory-view address arithmetic (paper §II).
//!
//! "Hardware supports multiple *views* of memory via fields in the
//! addresses beyond the 48 bits used for global physical addresses."
//!
//! * view 0 — node-local replica: the same address names a different
//!   physical location on every node ("constants" like the vertex count,
//!   and the per-node `changed` flag in Figure 2);
//! * view 1 — plain global physical address;
//! * view 2 — 64-bit elements striped round-robin across nodes
//!   ("for an address p on node n, p+8 is on node n+1").
//!
//! The connected-components algorithm uses exactly the trick the paper
//! describes: keep `changed` in view-0, then *cast the pointer back to a
//! view-1 global address* to read each node's copy while migrating across
//! the machine.

/// Address view selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum View {
    /// Node-local replicated storage.
    Local0,
    /// Global physical address.
    Global1,
    /// Striped 64-bit elements.
    Striped2,
}

const ADDR_BITS: u32 = 48;
const ADDR_MASK: u64 = (1 << ADDR_BITS) - 1;

/// A Lucata-style address: view bits above the 48-bit physical offset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Addr(pub u64);

impl Addr {
    pub fn new(view: View, offset: u64) -> Addr {
        assert!(offset <= ADDR_MASK, "offset exceeds 48 bits");
        let v = match view {
            View::Local0 => 0u64,
            View::Global1 => 1,
            View::Striped2 => 2,
        };
        Addr((v << ADDR_BITS) | offset)
    }

    pub fn view(self) -> View {
        match self.0 >> ADDR_BITS {
            0 => View::Local0,
            1 => View::Global1,
            2 => View::Striped2,
            v => panic!("unknown view {v}"),
        }
    }

    pub fn offset(self) -> u64 {
        self.0 & ADDR_MASK
    }

    /// Home node of this address on a `nodes`-node machine.
    ///
    /// * view 0: every node (returns None — it names the local copy);
    /// * view 1: high bits of the physical address select the node
    ///   (contiguous per-node ranges);
    /// * view 2: element index modulo nodes (8-byte stripe).
    pub fn node(self, nodes: usize, mem_per_node: u64) -> Option<usize> {
        match self.view() {
            View::Local0 => None,
            View::Global1 => Some(((self.offset() / mem_per_node) as usize).min(nodes - 1)),
            View::Striped2 => Some(((self.offset() / 8) % nodes as u64) as usize),
        }
    }

    /// Convert a view-0 local address to the view-1 global address of the
    /// replica on `node` — the Figure-2 reduction trick.
    pub fn local_to_global(self, node: usize, mem_per_node: u64) -> Addr {
        assert_eq!(self.view(), View::Local0);
        Addr::new(View::Global1, node as u64 * mem_per_node + self.offset())
    }

    /// Element index of a view-2 striped address.
    pub fn striped_index(self) -> u64 {
        assert_eq!(self.view(), View::Striped2);
        self.offset() / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MEM: u64 = 64 << 30;

    #[test]
    fn view_round_trip() {
        for view in [View::Local0, View::Global1, View::Striped2] {
            let a = Addr::new(view, 0x1234);
            assert_eq!(a.view(), view);
            assert_eq!(a.offset(), 0x1234);
        }
    }

    #[test]
    fn striped_adjacent_elements_hop_nodes() {
        // "For an address p on node n, p+8 is on node n+1."
        let nodes = 8;
        let p = Addr::new(View::Striped2, 0x100 * 8);
        let p8 = Addr::new(View::Striped2, 0x101 * 8);
        let n0 = p.node(nodes, MEM).unwrap();
        let n1 = p8.node(nodes, MEM).unwrap();
        assert_eq!((n0 + 1) % nodes, n1);
    }

    #[test]
    fn global_addresses_are_contiguous_per_node() {
        let a = Addr::new(View::Global1, 0);
        let b = Addr::new(View::Global1, MEM - 8);
        let c = Addr::new(View::Global1, MEM);
        assert_eq!(a.node(8, MEM), Some(0));
        assert_eq!(b.node(8, MEM), Some(0));
        assert_eq!(c.node(8, MEM), Some(1));
    }

    #[test]
    fn local_view_has_no_single_home() {
        assert_eq!(Addr::new(View::Local0, 64).node(8, MEM), None);
    }

    #[test]
    fn figure2_reduction_cast() {
        // The changed-flag reduction: local address cast to each node's
        // global replica address.
        let changed = Addr::new(View::Local0, 0x40);
        for node in 0..8 {
            let g = changed.local_to_global(node, MEM);
            assert_eq!(g.view(), View::Global1);
            assert_eq!(g.node(8, MEM), Some(node));
            assert_eq!(g.offset() % MEM, 0x40);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds 48 bits")]
    fn offset_overflow_rejected() {
        Addr::new(View::Global1, 1 << 48);
    }
}
