//! Discrete-event validation engine.
//!
//! Where [`super::flow`] treats a phase as a fluid demand vector, this
//! engine walks the actual graph with *explicit* threads, hardware thread
//! context slots, per-channel FIFO service, MSP queues and migrations. It is
//! far too slow for the paper-scale runs (750 concurrent queries) but at
//! small scale it validates the assumptions the fluid model is built on —
//! see `rust/tests/sim_tests.rs` for the cross-checks.
//!
//! Modeling choices (all deliberate simplifications, documented here so the
//! validation tests know what they are comparing):
//!
//! * **Channels are FIFO servers**: threads emit timestamped requests
//!   during the sweep; at the end of each synchronous phase every
//!   channel's queue is served in arrival order
//!   (`completion = max(arrival, clock) + service`). Two-pass scheduling
//!   keeps request *order* time-accurate regardless of the vertex
//!   iteration order. A thread's own timeline uses the uncontended service
//!   time of its reads (contended completions only push the phase end) —
//!   that is the approximation the flow cross-checks bound.
//! * **Thread contexts are slots**: each node owns `cores x 64` context
//!   slots kept in a min-heap of free times; a spawned thread takes the
//!   earliest-free slot. Running out of slots delays work, which is exactly
//!   the single-query parallelism ceiling the paper exploits.
//! * **Remote writes don't migrate** (§II): they pay fabric latency and the
//!   destination channel's service, the issuing thread fires and forgets,
//!   but the *level* does not end until all its writes land.
//! * **MSP remote ops** (`remote_min`) are read-modify-write cycles at the
//!   destination record's channel plus the MSP premium.
//! * **Migrations** (the CC compress phase, the view-0 `changed`
//!   reduction) pay fabric latency + context transfer and continue on the
//!   destination node.

use super::counters::Counters;
use super::machine::Machine;
use crate::graph::csr::Csr;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Cilk grainsize: a vertex's edge block is scanned in chunks of this many
/// edges, each by its own worker thread — hubs do not serialize a level
/// (matching the splittable-loop assumption of the flow model).
const GRAIN: usize = 64;

/// Wrapper giving f64 a total order for the slot heaps (times are never
/// NaN here).
#[derive(PartialEq, PartialOrd)]
struct Time(f64);
impl Eq for Time {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap()
    }
}

/// Functional result + timing of one event-simulated query.
#[derive(Debug, Clone)]
pub struct EventOutcome {
    /// BFS levels (-1 = unreached) or CC labels, depending on the query.
    pub values: Vec<i64>,
    /// End-to-end simulated time (ns).
    pub elapsed_ns: f64,
    /// Hardware counters accumulated by the run.
    pub counters: Counters,
    /// Synchronous phases executed (BFS levels / CC iterations x 3).
    pub phases: usize,
}

/// Per-node context-slot pool.
struct SlotPool {
    heaps: Vec<BinaryHeap<Reverse<Time>>>,
    slots_per_node: usize,
}

impl SlotPool {
    fn new(nodes: usize, slots_per_node: usize) -> Self {
        SlotPool {
            heaps: (0..nodes).map(|_| BinaryHeap::new()).collect(),
            slots_per_node,
        }
    }

    /// Earliest time a thread can start on `node` at or after `t`.
    fn acquire(&mut self, node: usize, t: f64) -> f64 {
        let h = &mut self.heaps[node];
        if h.len() < self.slots_per_node {
            return t;
        }
        let Reverse(Time(free)) = h.pop().expect("non-empty");
        free.max(t)
    }

    fn release(&mut self, node: usize, until: f64) {
        self.heaps[node].push(Reverse(Time(until)));
    }

    fn reset(&mut self) {
        for h in &mut self.heaps {
            h.clear();
        }
    }
}

/// One timestamped channel request emitted during a sweep.
#[derive(Debug, Clone, Copy)]
struct Request {
    flat_channel: u32,
    arrival: f64,
    service_ns: f64,
}

/// The discrete-event engine. One instance simulates one query at a time
/// (the flow engine owns concurrency; this engine's job is validating
/// single-query timing structure).
pub struct EventSim {
    m: Machine,
    /// Busy-until clock per flat channel (persists across phases).
    chan_free: Vec<f64>,
    slots: SlotPool,
    /// Requests accumulated during the current phase sweep.
    pending: Vec<Request>,
}

impl EventSim {
    pub fn new(m: Machine) -> Self {
        let chans = m.layout.total_channels();
        let nodes = m.nodes();
        let slots = m.cfg.contexts_per_node();
        EventSim {
            m,
            chan_free: vec![0.0; chans],
            slots: SlotPool::new(nodes, slots),
            pending: Vec::new(),
        }
    }

    pub fn machine(&self) -> &Machine {
        &self.m
    }

    fn reset(&mut self) {
        self.chan_free.iter_mut().for_each(|t| *t = 0.0);
        self.slots.reset();
        self.pending.clear();
    }

    /// Queue one fine-grained access; the thread's own timeline advances by
    /// the uncontended service time.
    fn channel_request(&mut self, node: usize, chan: usize, arrival: f64) -> f64 {
        let fc = self.m.layout.flat_channel(node, chan) as u32;
        let service_ns = self.m.channel_op_ns(node);
        self.pending.push(Request { flat_channel: fc, arrival, service_ns });
        arrival + service_ns
    }

    /// Queue one MSP read-modify-write (remote_min / remote_add).
    fn msp_request(&mut self, node: usize, chan: usize, arrival: f64) -> f64 {
        let fc = self.m.layout.flat_channel(node, chan) as u32;
        let service_ns = self.m.msp_op_ns(node);
        self.pending.push(Request { flat_channel: fc, arrival, service_ns });
        arrival + service_ns
    }

    /// Queue a streamed chunk of an edge block.
    fn stream_request(&mut self, node: usize, chan: usize, arrival: f64, bytes: f64) -> f64 {
        let fc = self.m.layout.flat_channel(node, chan) as u32;
        let per_chan_rate = self.m.stream_rate(node) / self.m.cfg.channels_per_node as f64;
        let service_ns = bytes / per_chan_rate * 1e9;
        self.pending.push(Request { flat_channel: fc, arrival, service_ns });
        arrival + service_ns
    }

    /// Serve every pending request FIFO-per-channel in arrival order and
    /// return the latest completion (>= `floor`). Advances the persistent
    /// channel clocks.
    fn drain_requests(&mut self, floor: f64) -> f64 {
        let mut reqs = std::mem::take(&mut self.pending);
        reqs.sort_by(|a, b| {
            a.flat_channel
                .cmp(&b.flat_channel)
                .then(a.arrival.partial_cmp(&b.arrival).unwrap())
        });
        let mut end = floor;
        for r in &reqs {
            let fc = r.flat_channel as usize;
            let done = self.chan_free[fc].max(r.arrival) + r.service_ns;
            self.chan_free[fc] = done;
            end = end.max(done);
        }
        end
    }

    /// Event-simulated level-synchronous BFS from `src` (paper §III: the
    /// tuned implementation migrates for clustered reads but uses remote
    /// *writes* for frontier insertion, which do not migrate).
    pub fn bfs(&mut self, g: &Csr, src: u32) -> EventOutcome {
        self.reset();
        let nodes = self.m.nodes();
        let mut counters = Counters::new(nodes);
        let layout = self.m.layout;
        let mut levels = vec![-1i64; g.n()];
        levels[src as usize] = 0;
        let mut frontier = vec![src];
        let mut t = 0.0f64;
        let mut depth = 0i64;
        let mut phases = 0usize;

        while !frontier.is_empty() {
            phases += 1;
            let t0 = t;
            let mut level_end = t0;
            let mut next = Vec::new();
            // Worker threads per node this level (for issue-slot sharing).
            let per_node_threads =
                (frontier.len().div_ceil(nodes)).max(1).min(self.m.cfg.contexts_per_node());
            for &u in &frontier {
                let un = layout.node_of(u);
                // Read the vertex record once (local dedup of last level's
                // writes) on the first worker.
                let start = self.slots.acquire(un, t0);
                let head = self.channel_request(un, layout.channel_of(u), start);
                counters.channel_ops[un] += 1.0;
                self.slots.release(un, head);
                // Grainsize-split edge scan: each chunk is its own worker
                // thread with its own context slot.
                for chunk in g.neighbors(u).chunks(GRAIN) {
                    let start = self.slots.acquire(un, head.max(t0));
                    counters.instructions[un] += self.m.cfg.spawn_instr;
                    // Stream this chunk of the edge block.
                    let bytes = (chunk.len() as u64 * Csr::PAPER_INT_BYTES) as f64;
                    let mut tt =
                        self.stream_request(un, layout.edge_block_channel(u), start, bytes);
                    counters.stream_bytes[un] += bytes;
                    let work = chunk.len() as f64 * self.m.cfg.instr_per_edge;
                    counters.instructions[un] += work;
                    tt += work / self.m.per_thread_issue_rate(un, per_node_threads) * 1e9;
                    for &v in chunk {
                        if levels[v as usize] != -1 {
                            continue;
                        }
                        let vn = layout.node_of(v);
                        let arrival = if vn == un {
                            tt
                        } else {
                            counters.fabric_bytes[un] += 16.0;
                            tt + self.m.cfg.fabric_latency_ns(un, vn)
                        };
                        self.channel_request(vn, layout.channel_of(v), arrival);
                        counters.channel_ops[vn] += 1.0;
                        levels[v as usize] = depth + 1;
                        next.push(v);
                    }
                    level_end = level_end.max(tt);
                    self.slots.release(un, tt);
                }
            }
            level_end = self.drain_requests(level_end);
            t = level_end + self.m.cfg.level_sync_ns;
            depth += 1;
            frontier = next;
        }
        counters.elapsed_ns = t;
        EventOutcome { values: levels, elapsed_ns: t, counters, phases }
    }

    /// Event-simulated Figure-2 connected components: hook sweeps through
    /// MSP `remote_min`, a migrating view-0 `changed` reduction, and a
    /// pointer-jumping compress whose migrations are bounded by tree depth.
    ///
    /// Functionally this runs Jacobi-style (hooks read the previous
    /// iteration's labels) so the result is deterministic; the hardware's
    /// racy in-place `remote_min` converges to the same labels, possibly in
    /// fewer sweeps.
    pub fn cc(&mut self, g: &Csr) -> EventOutcome {
        self.reset();
        let nodes = self.m.nodes();
        let mut counters = Counters::new(nodes);
        let layout = self.m.layout;
        let n = g.n();
        let mut labels: Vec<i64> = (0..n as i64).collect();
        let mut t = 0.0f64;
        let mut phases = 0usize;

        loop {
            // --- Hook sweep: remote_min(&C[j], C[v]) over every edge. ---
            phases += 1;
            let t0 = t;
            let mut phase_end = t0;
            let mut new_labels = labels.clone();
            let per_node_threads =
                (n.div_ceil(nodes)).max(1).min(self.m.cfg.contexts_per_node());
            for u in 0..n as u32 {
                let un = layout.node_of(u);
                let start = self.slots.acquire(un, t0);
                let head = self.channel_request(un, layout.channel_of(u), start);
                counters.channel_ops[un] += 1.0;
                self.slots.release(un, head);
                let lu = labels[u as usize];
                for chunk in g.neighbors(u).chunks(GRAIN) {
                    let start = self.slots.acquire(un, head.max(t0));
                    counters.instructions[un] += self.m.cfg.spawn_instr;
                    let bytes = (chunk.len() as u64 * Csr::PAPER_INT_BYTES) as f64;
                    let mut tt =
                        self.stream_request(un, layout.edge_block_channel(u), start, bytes);
                    counters.stream_bytes[un] += bytes;
                    let work = chunk.len() as f64 * self.m.cfg.instr_per_edge;
                    counters.instructions[un] += work;
                    tt += work / self.m.per_thread_issue_rate(un, per_node_threads) * 1e9;
                    for &v in chunk {
                        let vn = layout.node_of(v);
                        let arrival = if vn == un {
                            tt
                        } else {
                            counters.fabric_bytes[un] += 16.0;
                            tt + self.m.cfg.fabric_latency_ns(un, vn)
                        };
                        self.msp_request(vn, layout.channel_of(v), arrival);
                        counters.channel_ops[vn] += 1.0;
                        counters.msp_ops[vn] += 1.0;
                        if lu < new_labels[v as usize] {
                            new_labels[v as usize] = lu;
                        }
                    }
                    phase_end = phase_end.max(tt);
                    self.slots.release(un, tt);
                }
            }
            phase_end = self.drain_requests(phase_end);
            t = phase_end + self.m.cfg.level_sync_ns;

            // --- Changed check + view-0 reduction (Fig. 2 line 2). ---
            phases += 1;
            let changed = new_labels != labels;
            // Each vertex reads pC and C: two local channel ops.
            let t0 = t;
            let mut phase_end = t0;
            for u in 0..n as u32 {
                let un = layout.node_of(u);
                let after_read = self.channel_request(un, layout.channel_of(u), t0);
                self.channel_request(un, layout.channel_of(u), after_read);
                counters.channel_ops[un] += 2.0;
            }
            phase_end = self.drain_requests(phase_end);
            // The reduction migrates a single thread across all nodes,
            // casting the view-0 pointer to view-1 (serial chain).
            let mut red = phase_end;
            for node in 1..nodes {
                red += self.m.migration_ns(node - 1, node);
                counters.migrations[node] += 1.0;
                counters.channel_ops[node] += 1.0;
            }
            t = red + self.m.cfg.level_sync_ns;

            if !changed {
                counters.elapsed_ns = t;
                return EventOutcome { values: labels, elapsed_ns: t, counters, phases };
            }

            // --- Compress: pointer-jump until C[v] == C[C[v]]. ---
            phases += 1;
            labels = new_labels;
            let t0 = t;
            let mut phase_end = t0;
            for v in 0..n as u32 {
                let vn = layout.node_of(v);
                let start = self.slots.acquire(vn, t0);
                let mut tt = self.channel_request(vn, layout.channel_of(v), start);
                counters.channel_ops[vn] += 1.0;
                let mut here = vn;
                // Each jump reads C[C[v]]: a migration to the label's home
                // node (remote read), then a channel access there.
                let mut cur = labels[v as usize] as u32;
                while labels[cur as usize] != cur as i64 {
                    let target = labels[cur as usize] as u32;
                    let tn = layout.node_of(cur);
                    if tn != here {
                        tt += self.m.migration_ns(here, tn);
                        counters.migrations[tn] += 1.0;
                        counters.fabric_bytes[here] += 64.0; // context transfer
                        here = tn;
                    }
                    tt = self.channel_request(tn, layout.channel_of(cur), tt);
                    counters.channel_ops[tn] += 1.0;
                    cur = target;
                }
                labels[v as usize] = cur as i64;
                phase_end = phase_end.max(tt);
                self.slots.release(vn, tt);
            }
            phase_end = self.drain_requests(phase_end);
            t = phase_end + self.m.cfg.level_sync_ns;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::graph::builder::build_undirected_csr;

    fn machine() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    fn path(n: usize) -> Csr {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        build_undirected_csr(n, &edges)
    }

    #[test]
    fn bfs_levels_correct_on_path() {
        let g = path(16);
        let mut sim = EventSim::new(machine());
        let out = sim.bfs(&g, 0);
        for v in 0..16 {
            assert_eq!(out.values[v], v as i64);
        }
        assert_eq!(out.phases, 16); // 15 expanding levels + final empty check
    }

    #[test]
    fn bfs_unreachable_is_minus_one() {
        // Two components: 0-1, 2-3.
        let g = build_undirected_csr(4, &[(0, 1), (2, 3)]);
        let mut sim = EventSim::new(machine());
        let out = sim.bfs(&g, 0);
        assert_eq!(out.values, vec![0, 1, -1, -1]);
    }

    #[test]
    fn bfs_deeper_graph_takes_longer() {
        let mut sim = EventSim::new(machine());
        let t_short = sim.bfs(&path(4), 0).elapsed_ns;
        let t_long = sim.bfs(&path(64), 0).elapsed_ns;
        assert!(t_long > 4.0 * t_short);
    }

    #[test]
    fn cc_labels_are_component_minima() {
        // Components {0,1,2}, {3,4}, {5}.
        let g = build_undirected_csr(6, &[(0, 1), (1, 2), (3, 4)]);
        let mut sim = EventSim::new(machine());
        let out = sim.cc(&g);
        assert_eq!(out.values, vec![0, 0, 0, 3, 3, 5]);
    }

    #[test]
    fn cc_counts_msp_ops_per_edge_per_sweep() {
        let g = path(8);
        let mut sim = EventSim::new(machine());
        let out = sim.cc(&g);
        let msp: f64 = out.counters.msp_ops.iter().sum();
        // Each hook sweep fires one remote_min per directed edge.
        let m = g.m_directed() as f64;
        assert!(msp >= m, "at least one sweep");
        assert_eq!(msp % m, 0.0, "whole sweeps");
    }

    #[test]
    fn cc_reduction_migrates_across_nodes() {
        let g = path(8);
        let mut sim = EventSim::new(machine());
        let out = sim.cc(&g);
        // The view-0 changed reduction walks nodes 1..8 every iteration.
        let mig: f64 = out.counters.migrations.iter().sum();
        assert!(mig >= 7.0);
    }

    #[test]
    fn elapsed_matches_counters_ledger() {
        let g = path(32);
        let mut sim = EventSim::new(machine());
        let out = sim.bfs(&g, 0);
        assert_eq!(out.counters.elapsed_ns, out.elapsed_ns);
        assert!(out.elapsed_ns > 0.0);
    }
}
