//! Flow-level (fluid) concurrency engine.
//!
//! This is the engine the paper-scale experiments run on: hundreds of
//! concurrent queries, each a sequence of [`PhaseDemand`] phases produced by
//! the functional algorithms in [`crate::alg`]. The model:
//!
//! * Running **alone**, a phase takes [`PhaseDemand::solo_ns`] — its
//!   latency/parallelism/synchronization structure caps how fast it can go
//!   even on an idle machine. A single level-synchronous BFS cannot saturate
//!   the Pathfinder's many narrow channels; that headroom is the paper's
//!   whole thesis.
//! * Running **concurrently**, each active phase progresses at a rate
//!   `s ∈ (0, 1]` relative to its solo speed. A phase running at its solo
//!   speed consumes a *fraction* `u_j = drain_ns(j) / solo_ns` of each
//!   shared resource `j` (a node's channel capacity, its hottest channel,
//!   stream bandwidth, instruction issue, fabric link). Rates are chosen by
//!   progressive-filling **max-min fairness**: grow every query's rate
//!   together until a resource saturates, freeze the queries using it, and
//!   continue with the rest — the fluid analogue of hardware round-robin
//!   thread scheduling with FIFO memory channels. With non-flat
//!   [`ShareWeights`] the filling is *weighted*: each query grows at its
//!   priority class's multiple of the fill level, so Interactive work
//!   holds a larger share of every saturated resource (DESIGN.md
//!   §Scheduling).
//! * Under [`Admission::preempt`], running Batch work can be **parked at a
//!   phase boundary** (context bytes released, completed phases kept) when
//!   a blocked Interactive waiter needs its reservation, and resumed when
//!   the pressure clears — see [`crate::sim::preempt`].
//! * Time advances event-to-event (phase completions and query arrivals);
//!   rates are recomputed whenever the active set changes.
//!
//! Sequential execution (`run_sequential`) is exact under this model — a
//! lone query always gets rate 1.0 — so it is computed directly from solo
//! times rather than through the event loop.

use super::counters::Counters;
use super::demand::PhaseDemand;
use super::ledger::ContextLedger;
use super::machine::Machine;
use super::preempt::{Parker, PreemptPolicy};

/// Scheduling priority class of a query.
///
/// The derived ordering is the admission ordering: a *smaller* variant is
/// served first (`Interactive < Standard < Batch`), FIFO within a class.
/// Defined here because the engine's wait queue orders by it; the
/// coordinator re-exports it as `coordinator::request::Priority`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Latency-sensitive, user-facing.
    Interactive,
    /// The default class.
    #[default]
    Standard,
    /// Throughput-oriented background work; first to be shed under
    /// overload.
    Batch,
}

impl Priority {
    /// All classes, best-served first.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Batch];
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Priority::Interactive => write!(f, "interactive"),
            Priority::Standard => write!(f, "standard"),
            Priority::Batch => write!(f, "batch"),
        }
    }
}

/// Per-priority-class fair-share weights for the progress loop.
///
/// Under plain max-min every running query's rate grows uniformly until a
/// resource saturates; with weights, a query of class `p` grows at
/// `weights.of(p)` times the uniform fill level (still capped at solo
/// speed), so an Interactive query receives proportionally more of every
/// saturated resource than a Batch query sharing it. Flat weights (the
/// default) reproduce plain max-min exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShareWeights {
    pub interactive: f64,
    pub standard: f64,
    pub batch: f64,
}

impl Default for ShareWeights {
    fn default() -> Self {
        ShareWeights::flat()
    }
}

impl ShareWeights {
    /// Equal shares: plain max-min fairness (the pre-weighting behavior).
    pub fn flat() -> Self {
        ShareWeights { interactive: 1.0, standard: 1.0, batch: 1.0 }
    }

    /// The 4:2:1 preset: Interactive gets four times a Batch query's share
    /// of every saturated resource, Standard twice.
    pub fn priority_weighted() -> Self {
        ShareWeights { interactive: 4.0, standard: 2.0, batch: 1.0 }
    }

    /// The weight of one priority class.
    pub fn of(&self, p: Priority) -> f64 {
        match p {
            Priority::Interactive => self.interactive,
            Priority::Standard => self.standard,
            Priority::Batch => self.batch,
        }
    }

    /// All classes weighted equally (any scale): rates degenerate to plain
    /// max-min.
    pub fn is_flat(&self) -> bool {
        self.interactive == self.standard && self.standard == self.batch
    }

    /// Parse `class=weight,...` (e.g. `interactive=4,standard=2,batch=1`);
    /// omitted classes keep weight 1.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut w = ShareWeights::flat();
        for (class, weight) in crate::util::cli::parse_kv_f64_list(spec, "share weights")? {
            match class {
                "interactive" => w.interactive = weight,
                "standard" => w.standard = weight,
                "batch" => w.batch = weight,
                other => anyhow::bail!(
                    "unknown priority class {other:?} (want interactive/standard/batch)"
                ),
            }
        }
        w.validate()?;
        Ok(w)
    }

    /// Weights must be finite and strictly positive (a zero weight would
    /// starve a running query forever).
    pub fn validate(&self) -> anyhow::Result<()> {
        for p in Priority::ALL {
            let w = self.of(p);
            anyhow::ensure!(
                w.is_finite() && w > 0.0,
                "share weight for {p} must be finite and positive, got {w}"
            );
        }
        Ok(())
    }

    /// Compact `i:s:b` label for reports (e.g. `4:2:1`).
    pub fn label(&self) -> String {
        format!("{}:{}:{}", self.interactive, self.standard, self.batch)
    }
}

/// One query submitted to the flow engine: an ordered list of phases plus
/// an arrival time and the admission metadata the engine schedules by.
#[derive(Debug, Clone)]
pub struct QuerySpec {
    /// Caller-chosen identifier (reported back in [`QueryTiming`]).
    pub id: usize,
    /// Short label for reports ("bfs", "cc", ...).
    pub label: &'static str,
    /// Synchronous phases, executed in order.
    pub phases: Vec<PhaseDemand>,
    /// Simulated arrival time (ns).
    pub arrival_ns: f64,
    /// Priority class: orders the wait queue and picks shedding victims.
    pub priority: Priority,
    /// Optional end-to-end latency budget (ns from arrival). A queued
    /// query whose deadline expires before it starts is shed rather than
    /// run uselessly.
    pub deadline_ns: Option<f64>,
    /// Thread-context bytes reserved while this query is in flight
    /// (0 = free). The coordinator fills in each analysis's declared
    /// footprint; byte-aware admission sums these against
    /// [`Admission::ctx_capacity_bytes`].
    pub ctx_bytes: u64,
}

impl QuerySpec {
    /// A spec with default admission metadata ([`Priority::Standard`], no
    /// deadline, zero context footprint).
    pub fn new(
        id: usize,
        label: &'static str,
        phases: Vec<PhaseDemand>,
        arrival_ns: f64,
    ) -> Self {
        QuerySpec {
            id,
            label,
            phases,
            arrival_ns,
            priority: Priority::default(),
            deadline_ns: None,
            ctx_bytes: 0,
        }
    }

    /// Set the priority class.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set a latency deadline (ns from arrival).
    pub fn with_deadline_ns(mut self, deadline_ns: f64) -> Self {
        self.deadline_ns = Some(deadline_ns);
        self
    }

    /// Set the thread-context reservation (bytes).
    pub fn with_ctx_bytes(mut self, ctx_bytes: u64) -> Self {
        self.ctx_bytes = ctx_bytes;
        self
    }

    /// Duration of this query if it ran alone on `m` (ns).
    pub fn solo_ns(&self, m: &Machine) -> f64 {
        self.phases.iter().map(|p| p.solo_ns(m)).sum()
    }
}

/// Per-query outcome of a flow-engine run.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryTiming {
    pub id: usize,
    pub label: &'static str,
    /// When the query arrived (ns).
    pub arrival_ns: f64,
    /// When its first phase started progressing (ns). **NaN = the query
    /// never started**: it was rejected at arrival or shed while waiting.
    /// A queued query's start is later than its arrival; the gap is its
    /// admission wait.
    pub start_ns: f64,
    /// When its last phase completed (ns). NaN if the query never ran.
    pub finish_ns: f64,
    /// Phase count of the submitted spec. Recorded uniformly for every
    /// outcome — a rejected or shed query reports the work it *would*
    /// have run, not 0.
    pub phases: usize,
    /// Priority class the spec declared.
    pub priority: Priority,
    /// Class the query was *admitted as*: the declared class, or
    /// `Interactive` when anti-starvation aging promoted it out of the
    /// wait queue. Recording both sides keeps per-class wait statistics
    /// honest — a promoted Batch query's long wait belongs to Batch, but
    /// reports can now also see that it competed as Interactive.
    pub admitted_as: Priority,
}

impl QueryTiming {
    /// End-to-end latency of the query (ns); NaN if it never ran.
    pub fn latency_ns(&self) -> f64 {
        self.finish_ns - self.arrival_ns
    }

    /// Whether the query ran to completion.
    pub fn completed(&self) -> bool {
        self.finish_ns.is_finite()
    }
}

/// What to do with an arriving query when the admission limits (in-flight
/// count or context bytes) are reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnFull {
    /// Reject the query outright (it appears in `FlowReport::rejected`).
    /// This is what the §IV-B "256 concurrent queries exhausted the memory
    /// used for thread contexts" failure becomes under admission control.
    Reject,
    /// Hold the query in the priority-ordered wait queue and start it when
    /// capacity frees. Queued queries whose deadline expires before they
    /// start are shed (`FlowReport::shed`).
    Queue,
    /// Queue, but bound the standing wait queue at `max_waiting`: overflow
    /// sheds the newest entry of the lowest-priority class (Batch work is
    /// dropped first; an Interactive query is shed only when nothing of a
    /// lower class is left to drop).
    Shed {
        /// Largest standing wait-queue length before shedding kicks in.
        max_waiting: usize,
    },
}

/// Admission policy applied inside the engine's event loop.
///
/// The wait queue is priority-ordered (`Interactive < Standard < Batch`,
/// FIFO within a class) with an aging rule: a query that has waited at
/// least [`Admission::age_promote_ns`] competes as `Interactive`
/// regardless of its class, so Batch work is never starved forever —
/// its wait before reaching the front of the queue is bounded by
/// `age_promote_ns` plus the backlog that aged before it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Admission {
    /// Maximum queries simultaneously in flight (None = unlimited).
    pub max_in_flight: Option<usize>,
    /// Thread-context byte budget across all in-flight queries (None =
    /// unlimited). Each query holds [`QuerySpec::ctx_bytes`] while in
    /// flight; a query whose own footprint exceeds the whole budget is
    /// rejected at arrival (it could never run).
    pub ctx_capacity_bytes: Option<u64>,
    /// Behavior when an arrival cannot start immediately.
    pub on_full: OnFull,
    /// Anti-starvation bound (ns): a query waiting at least this long is
    /// ordered as `Interactive`. `f64::INFINITY` disables aging (strict
    /// priority).
    pub age_promote_ns: f64,
    /// Fair-share weights the progress loop divides bandwidth by (flat =
    /// plain max-min; see [`ShareWeights`]).
    pub weights: ShareWeights,
    /// Checkpoint preemption of running low-priority work under
    /// Interactive pressure (None = disabled; see
    /// [`crate::sim::preempt`]). Only meaningful with a queueing
    /// [`OnFull`] policy — under `Reject` nothing ever waits.
    pub preempt: Option<PreemptPolicy>,
}

impl Admission {
    /// Default anti-starvation bound: 100 ms of simulated wait promotes a
    /// query to the front class.
    pub const DEFAULT_AGE_PROMOTE_NS: f64 = 100e6;

    /// No admission control at all.
    pub fn unlimited() -> Self {
        Admission {
            max_in_flight: None,
            ctx_capacity_bytes: None,
            on_full: OnFull::Reject,
            age_promote_ns: f64::INFINITY,
            weights: ShareWeights::flat(),
            preempt: None,
        }
    }

    /// Count-capped admission (no byte budget), default aging.
    pub fn capped(max_in_flight: usize, on_full: OnFull) -> Self {
        Admission {
            max_in_flight: Some(max_in_flight),
            ctx_capacity_bytes: None,
            on_full,
            age_promote_ns: Admission::DEFAULT_AGE_PROMOTE_NS,
            weights: ShareWeights::flat(),
            preempt: None,
        }
    }

    /// Byte-budgeted admission (no count cap), default aging.
    pub fn byte_budget(ctx_capacity_bytes: u64, on_full: OnFull) -> Self {
        Admission {
            max_in_flight: None,
            ctx_capacity_bytes: Some(ctx_capacity_bytes),
            on_full,
            age_promote_ns: Admission::DEFAULT_AGE_PROMOTE_NS,
            weights: ShareWeights::flat(),
            preempt: None,
        }
    }

    /// Override the anti-starvation bound.
    pub fn with_age_promote_ns(mut self, age_promote_ns: f64) -> Self {
        self.age_promote_ns = age_promote_ns;
        self
    }

    /// Set priority-scaled fair-share weights for the progress loop.
    pub fn with_weights(mut self, weights: ShareWeights) -> Self {
        self.weights = weights;
        self
    }

    /// Enable checkpoint preemption.
    pub fn with_preempt(mut self, preempt: PreemptPolicy) -> Self {
        self.preempt = Some(preempt);
        self
    }
}

/// Result of one flow-engine run.
#[derive(Debug, Clone)]
pub struct FlowReport {
    /// Per-query timings, in input order.
    pub timings: Vec<QueryTiming>,
    /// Time the last query finished (ns).
    pub makespan_ns: f64,
    /// Accumulated hardware counters over the run.
    pub counters: Counters,
    /// Largest number of queries simultaneously in flight.
    pub peak_concurrency: usize,
    /// Ids of queries rejected at arrival (admission full under
    /// [`OnFull::Reject`], or a footprint larger than the whole byte
    /// budget). Empty without admission control.
    pub rejected: Vec<usize>,
    /// Ids of queries shed from the wait queue after being admitted to it:
    /// deadline expired while waiting, or dropped by [`OnFull::Shed`]
    /// overflow. Empty without admission control.
    pub shed: Vec<usize>,
    /// High-water mark of reserved thread-context bytes over the run
    /// (from the [`ContextLedger`] the engine admits against).
    pub peak_ctx_bytes: u64,
    /// Ids of queries that were checkpoint-parked at least once. The run
    /// always drains the parked set before finishing, so every id here
    /// also completed (its latency includes the parked time).
    pub preempted: Vec<usize>,
    /// Total park events over the run (one query can park repeatedly, up
    /// to [`crate::sim::preempt::PreemptPolicy::max_parks_per_query`]).
    pub parks: usize,
    /// Total resume events over the run.
    pub resumes: usize,
    /// The fair-share weights the run used (flat = plain max-min).
    pub weights: ShareWeights,
}

impl FlowReport {
    /// Mean completed-query latency (s). Rejected/shed queries carry NaN
    /// timings and are excluded (they have no latency, and one NaN would
    /// otherwise poison the mean).
    pub fn mean_latency_s(&self) -> f64 {
        let (sum, n) = self
            .timings
            .iter()
            .filter(|t| t.completed())
            .fold((0.0, 0usize), |(s, n), t| (s + t.latency_ns(), n + 1));
        if n == 0 {
            return 0.0;
        }
        sum / n as f64 * 1e-9
    }

    /// Makespan in seconds.
    pub fn makespan_s(&self) -> f64 {
        self.makespan_ns * 1e-9
    }

    /// Completed-query latencies in seconds (input order); rejected and
    /// shed queries are filtered out.
    pub fn latencies_s(&self) -> Vec<f64> {
        self.timings
            .iter()
            .filter(|t| t.completed())
            .map(|t| t.latency_ns() * 1e-9)
            .collect()
    }

    /// Completed-query latencies (s) of one declared priority class — the
    /// realized per-class service the weighted progress loop divides.
    pub fn class_latencies_s(&self, priority: Priority) -> Vec<f64> {
        self.timings
            .iter()
            .filter(|t| t.completed() && t.priority == priority)
            .map(|t| t.latency_ns() * 1e-9)
            .collect()
    }

    /// Mean completed-query latency (s) of one declared priority class;
    /// 0.0 if the class completed nothing.
    pub fn class_mean_latency_s(&self, priority: Priority) -> f64 {
        let xs = self.class_latencies_s(priority);
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    /// Completed latencies (s) of one spec label — e.g. the `"mutate"`
    /// ingest lane sharing the engine with queries (DESIGN.md §Mutation).
    pub fn label_latencies_s(&self, label: &str) -> Vec<f64> {
        self.timings
            .iter()
            .filter(|t| t.completed() && t.label == label)
            .map(|t| t.latency_ns() * 1e-9)
            .collect()
    }

    /// Mean completed latency (s) of one spec label; 0.0 if none
    /// completed.
    pub fn label_mean_latency_s(&self, label: &str) -> f64 {
        let xs = self.label_latencies_s(label);
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// One in-flight phase inside the allocator.
struct ActivePhase {
    /// Index into the run's query vector.
    qi: usize,
    /// Index of the current phase.
    phase_idx: usize,
    /// Solo duration of the current phase (ns).
    solo_ns: f64,
    /// Remaining fraction of the current phase in [0, 1].
    remaining: f64,
    /// Sparse utilization vector: (resource index, fraction of capacity
    /// consumed at rate 1.0).
    util: Vec<(u32, f64)>,
    /// Allocated rate from the last allocation pass.
    rate: f64,
    /// Fair-share weight of the owning query's priority class: this phase
    /// grows at `weight x` the uniform fill level during allocation, and
    /// contributes `weight x util` to the aggregate demand vector.
    weight: f64,
}

/// The flow-level simulator.
#[derive(Debug, Clone)]
pub struct FlowSim {
    m: Machine,
}

/// Resources below this utilization are treated as unused by a phase; keeps
/// the sparse vectors short and the waterfill numerically stable.
const UTIL_EPS: f64 = 1e-9;

impl FlowSim {
    pub fn new(m: Machine) -> Self {
        FlowSim { m }
    }

    pub fn machine(&self) -> &Machine {
        &self.m
    }

    /// Run all queries concurrently (respecting arrival times), without
    /// admission control.
    pub fn run(&self, queries: &[QuerySpec]) -> FlowReport {
        self.run_admitted(queries, Admission::unlimited())
    }

    /// Run with an admission policy: arrivals beyond `max_in_flight`
    /// concurrent queries or the context byte budget are queued, shed or
    /// rejected per `on_full`. The wait queue is priority-ordered with
    /// aging (see [`Admission`]); the head of the queue blocks lower
    /// classes even when they would fit — strict ordering, so a fat
    /// high-priority query is never starved by a stream of thin ones.
    ///
    /// Running queries share saturated resources by *weighted* max-min
    /// ([`Admission::weights`]; flat weights = plain max-min), and with
    /// [`Admission::preempt`] set, running Batch-class work is parked at
    /// phase boundaries (context bytes released, completed phases kept)
    /// when a blocked Interactive waiter needs its reservation, then
    /// resumed once the pressure clears.
    pub fn run_admitted(&self, queries: &[QuerySpec], adm: Admission) -> FlowReport {
        adm.weights.validate().expect("invalid fair-share weights");
        let weights = adm.weights;
        let mut parker: Option<Parker> = adm.preempt.map(|p| Parker::new(p, queries.len()));
        let nodes = self.m.nodes();
        let n_res = nodes * (self.m.cfg.channels_per_node + 4);
        let mut counters = Counters::new(nodes);

        // Arrival ordering (stable by input order for equal times).
        let mut order: Vec<usize> = (0..queries.len()).collect();
        order.sort_by(|&a, &b| {
            queries[a]
                .arrival_ns
                .partial_cmp(&queries[b].arrival_ns)
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut next_arrival = 0usize;

        let mut timings: Vec<Option<QueryTiming>> = vec![None; queries.len()];
        let mut active: Vec<ActivePhase> = Vec::new();
        // Allocator scratch, reused across every event (the rate solve is
        // the engine's hot path at paper-scale concurrency — §Perf).
        let mut demand_scratch = vec![0.0f64; n_res];
        let mut residual_scratch = vec![0.0f64; n_res];
        // Aggregate demand maintained incrementally as phases enter/leave,
        // so the solve never rebuilds it from scratch (§Perf).
        let mut total_demand = vec![0.0f64; n_res];
        // Wait queue in enqueue (= arrival) order; selection scans for the
        // best effective class, so FIFO-within-class falls out of position.
        let mut waiting: Vec<usize> = Vec::new();
        let mut rejected: Vec<usize> = Vec::new();
        let mut shed: Vec<usize> = Vec::new();
        let mut in_flight = 0usize;
        // The byte ledger this run admits against: every started query
        // reserves its ctx_bytes until completion.
        let mut ledger = match adm.ctx_capacity_bytes {
            Some(cap_bytes) => ContextLedger::with_capacity_bytes(cap_bytes, 1),
            None => ContextLedger::unlimited(),
        };
        let cap = adm.max_in_flight.unwrap_or(usize::MAX);
        let mut t = 0.0f64;
        let mut peak = 0usize;
        let mut rates_dirty = true;

        // Effective admission class of a waiter at time `now`: aging
        // promotes long waiters to the front class.
        let effective_class = |qi: usize, now: f64| -> Priority {
            let q = &queries[qi];
            if now - q.arrival_ns >= adm.age_promote_ns {
                Priority::Interactive
            } else {
                q.priority
            }
        };

        // Start query qi at time t (caller checked `in_flight < cap` and
        // `ledger.would_fit`); `admitted_as` is the class it won its slot
        // under (declared, or Interactive when aging promoted it).
        macro_rules! start_query {
            ($qi:expr, $admitted_as:expr) => {{
                let qi = $qi;
                let q = &queries[qi];
                in_flight += 1;
                ledger.admit(qi, q.ctx_bytes).expect("caller checked would_fit");
                timings[qi] = Some(QueryTiming {
                    id: q.id,
                    label: q.label,
                    arrival_ns: q.arrival_ns,
                    start_ns: t,
                    finish_ns: f64::NAN,
                    phases: q.phases.len(),
                    priority: q.priority,
                    admitted_as: $admitted_as,
                });
                let w = weights.of(q.priority);
                if let Some(ap) = self.enter_phase(qi, 0, q, w, &mut counters) {
                    for &(j, u) in &ap.util {
                        total_demand[j as usize] += w * u;
                    }
                    active.push(ap);
                } else {
                    // Query with no phases (or all-empty phases): finishes
                    // instantly.
                    timings[qi].as_mut().unwrap().finish_ns = t;
                    in_flight -= 1;
                    ledger.release(qi);
                }
                rates_dirty = true;
            }};
        }

        // Record a query that will never run (NaN start/finish; the spec's
        // phase count is reported as-declared).
        macro_rules! drop_query {
            ($qi:expr, $sink:ident) => {{
                let qi = $qi;
                let q = &queries[qi];
                timings[qi] = Some(QueryTiming {
                    id: q.id,
                    label: q.label,
                    arrival_ns: q.arrival_ns,
                    start_ns: f64::NAN,
                    finish_ns: f64::NAN,
                    phases: q.phases.len(),
                    priority: q.priority,
                    admitted_as: q.priority,
                });
                $sink.push(q.id);
            }};
        }

        loop {
            // Take every arrival due by `t`. Under a queueing policy the
            // arrival always goes through the wait queue so that the
            // priority order — not submission order — decides who starts
            // when several arrivals land on the same event.
            while next_arrival < order.len() && queries[order[next_arrival]].arrival_ns <= t {
                let qi = order[next_arrival];
                next_arrival += 1;
                let q = &queries[qi];
                if ledger.check_admissible(q.ctx_bytes).is_err() {
                    // Larger than the whole budget: could never run. The
                    // coordinator pre-checks and raises a typed
                    // ContextExhausted; at the engine level it degrades to
                    // a recorded rejection instead of an eternal wait.
                    drop_query!(qi, rejected);
                    continue;
                }
                match adm.on_full {
                    OnFull::Reject => {
                        if in_flight < cap && ledger.would_fit(q.ctx_bytes) {
                            start_query!(qi, q.priority);
                        } else {
                            drop_query!(qi, rejected);
                        }
                    }
                    OnFull::Queue | OnFull::Shed { .. } => waiting.push(qi),
                }
            }

            // Shed queued queries whose deadline already expired: running
            // them is wasted work.
            let mut wi = 0;
            while wi < waiting.len() {
                let q = &queries[waiting[wi]];
                if q.deadline_ns.is_some_and(|d| q.arrival_ns + d <= t) {
                    let qi = waiting.remove(wi);
                    drop_query!(qi, shed);
                } else {
                    wi += 1;
                }
            }

            // Drain the wait queue in priority order: best effective class
            // first (aging promotes long waiters to the front class), FIFO
            // within a class. Strict head-of-queue blocking: if the best
            // waiter does not fit, nothing behind it starts.
            loop {
                let best = waiting
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &qi)| effective_class(qi, t))
                    .map(|(i, _)| i);
                match best {
                    Some(i)
                        if in_flight < cap
                            && ledger.would_fit(queries[waiting[i]].ctx_bytes) =>
                    {
                        let qi = waiting.remove(i);
                        start_query!(qi, effective_class(qi, t));
                    }
                    _ => break,
                }
            }

            // Checkpoint preemption (see [`crate::sim::preempt`]): under
            // Interactive pressure, mark running victim-class queries to
            // park at their next phase boundary; with the pressure gone,
            // resume parked work FIFO. Marks are recomputed from scratch
            // at every event, so stale pressure never leaves a mark.
            if let Some(pk) = parker.as_mut() {
                pk.unmark_all();
                // The best blocked waiter (the drain above started every
                // waiter that fits, in priority order, until one did not).
                let blocked = waiting
                    .iter()
                    .map(|&qi| (effective_class(qi, t), qi))
                    .min_by_key(|&(c, _)| c);
                match blocked {
                    // The trigger keys on the *declared* class: an
                    // aging-promoted Batch waiter competes as Interactive
                    // for queue order, but parking running Batch work to
                    // admit other Batch work would be pure churn.
                    Some((Priority::Interactive, head_qi))
                        if queries[head_qi].priority == Priority::Interactive =>
                    {
                        // Park the victims that reach a checkpoint soonest,
                        // just enough of them to cover the head waiter's
                        // reservation (bytes and, under a count cap, one
                        // slot). If the preemptible set cannot cover it at
                        // all, park nothing — churn would not help.
                        let head = &queries[head_qi];
                        let free = ledger.capacity_bytes().saturating_sub(ledger.in_use_bytes());
                        let needed_bytes = head.ctx_bytes.saturating_sub(free);
                        let needed_slots = usize::from(in_flight >= cap);
                        let mut cands: Vec<(f64, usize, u64)> = active
                            .iter()
                            .filter(|ap| pk.can_mark(ap.qi, queries[ap.qi].priority))
                            .map(|ap| {
                                let boundary_ns = ap.remaining * ap.solo_ns / ap.rate;
                                (boundary_ns, ap.qi, queries[ap.qi].ctx_bytes)
                            })
                            .collect();
                        let coverable = cands.iter().map(|c| c.2).sum::<u64>() >= needed_bytes
                            && cands.len() >= needed_slots;
                        if coverable && (needed_bytes > 0 || needed_slots > 0) {
                            cands.sort_by(|a, b| {
                                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                            });
                            let (mut freed_bytes, mut freed_slots) = (0u64, 0usize);
                            for (_, qi, bytes) in cands {
                                if freed_bytes >= needed_bytes && freed_slots >= needed_slots {
                                    break;
                                }
                                pk.mark(qi);
                                freed_bytes = freed_bytes.saturating_add(bytes);
                                freed_slots += 1;
                            }
                        }
                    }
                    _ => {
                        // Resume parked work FIFO while it fits, never
                        // bypassing a blocked waiter of a better class
                        // (a blocked equal-class waiter defers to parked
                        // work, which already holds partial progress).
                        let blocked_class = blocked.map(|(c, _)| c);
                        while let Some((qi, next_phase)) = pk.peek_parked() {
                            let q = &queries[qi];
                            if blocked_class.is_some_and(|c| c < q.priority)
                                || in_flight >= cap
                                || !ledger.would_fit(q.ctx_bytes)
                            {
                                break;
                            }
                            pk.resume_front();
                            in_flight += 1;
                            ledger.admit(qi, q.ctx_bytes).expect("checked would_fit");
                            let w = weights.of(q.priority);
                            match self.enter_phase(qi, next_phase, q, w, &mut counters) {
                                Some(ap) => {
                                    for &(j, u) in &ap.util {
                                        total_demand[j as usize] += w * u;
                                    }
                                    active.push(ap);
                                }
                                None => {
                                    // Only zero-solo phases remained past
                                    // the checkpoint: the query is done.
                                    timings[qi].as_mut().unwrap().finish_ns = t;
                                    in_flight -= 1;
                                    ledger.release(qi);
                                }
                            }
                            rates_dirty = true;
                        }
                    }
                }
            }

            // Overflow shedding: bound the standing queue, dropping the
            // newest entry of the lowest class first (Batch before
            // Standard before Interactive — base class, not the aged one:
            // a promoted Batch waiter is still the first shedding victim).
            if let OnFull::Shed { max_waiting } = adm.on_full {
                while waiting.len() > max_waiting {
                    // max_by_key returns the *last* maximal element: the
                    // newest entry of the worst class.
                    let victim = waiting
                        .iter()
                        .enumerate()
                        .max_by_key(|&(_, &qi)| queries[qi].priority)
                        .map(|(i, _)| i)
                        .expect("non-empty: len > max_waiting");
                    let qi = waiting.remove(victim);
                    drop_query!(qi, shed);
                }
            }
            peak = peak.max(active.len());

            if active.is_empty() {
                match order.get(next_arrival) {
                    Some(&qi) => {
                        // Idle gap until the next arrival.
                        t = queries[qi].arrival_ns;
                        continue;
                    }
                    None => break,
                }
            }

            if rates_dirty {
                demand_scratch.copy_from_slice(&total_demand);
                max_min_rates(&mut active, &mut demand_scratch, &mut residual_scratch);
                rates_dirty = false;
            }

            // Earliest phase completion under current rates.
            let mut t_done = f64::INFINITY;
            for ap in &active {
                let dt = ap.remaining * ap.solo_ns / ap.rate;
                t_done = t_done.min(t + dt);
            }
            // Next arrival, if sooner.
            let t_arrive = order
                .get(next_arrival)
                .map(|&qi| queries[qi].arrival_ns)
                .unwrap_or(f64::INFINITY);
            let t_next = t_done.min(t_arrive).max(t);
            let dt = t_next - t;

            // Progress everything to t_next.
            for ap in &mut active {
                ap.remaining -= dt * ap.rate / ap.solo_ns;
            }
            t = t_next;

            // Retire completed phases; advance or finish their queries.
            // The epsilon is RELATIVE to the clock: at large t, a phase
            // whose residual time is below f64 resolution of t can never
            // advance the clock (t + dt == t) and must be retired now or
            // the loop spins forever.
            let eps_ns = 1e-9f64.max(t * 1e-12);
            let mut i = 0;
            while i < active.len() {
                if active[i].remaining * active[i].solo_ns / active[i].rate <= eps_ns {
                    let ap = active.swap_remove(i);
                    for &(j, u) in &ap.util {
                        total_demand[j as usize] -= ap.weight * u;
                    }
                    let q = &queries[ap.qi];
                    let next_phase = ap.phase_idx + 1;
                    let draining = parker.as_ref().is_some_and(|p| p.is_draining(ap.qi));
                    if draining
                        && next_phase < q.phases.len()
                        && q.phases[next_phase..].iter().any(|p| p.solo_ns(&self.m) > 0.0)
                    {
                        // Checkpoint: keep the completed phase prefix,
                        // release the context reservation, park until the
                        // Interactive pressure clears. A query with only
                        // zero-solo phases left finishes instead — parking
                        // it would just delay its recorded completion.
                        parker.as_mut().unwrap().park(ap.qi, next_phase);
                        in_flight -= 1;
                        ledger.release(ap.qi);
                    } else {
                        match self.enter_phase(ap.qi, next_phase, q, ap.weight, &mut counters) {
                            Some(next) => {
                                for &(j, u) in &next.util {
                                    total_demand[j as usize] += ap.weight * u;
                                }
                                active.push(next);
                            }
                            None => {
                                timings[ap.qi].as_mut().unwrap().finish_ns = t;
                                in_flight -= 1;
                                ledger.release(ap.qi);
                                if let Some(p) = parker.as_mut() {
                                    p.finish(ap.qi);
                                }
                            }
                        }
                    }
                    rates_dirty = true;
                } else {
                    i += 1;
                }
            }
            if t_arrive <= t_done {
                rates_dirty = true;
            }
        }

        counters.elapsed_ns = t;
        let (preempted, parks, resumes) = match &parker {
            Some(p) => {
                debug_assert_eq!(p.parked_len(), 0, "run finished with queries still parked");
                let ids = (0..queries.len())
                    .filter(|&qi| p.was_parked(qi))
                    .map(|qi| queries[qi].id)
                    .collect();
                (ids, p.parks(), p.resumes())
            }
            None => (Vec::new(), 0, 0),
        };
        FlowReport {
            timings: timings.into_iter().map(|x| x.expect("query never admitted")).collect(),
            makespan_ns: t,
            counters,
            peak_concurrency: peak,
            rejected,
            shed,
            peak_ctx_bytes: ledger.peak_bytes(),
            preempted,
            parks,
            resumes,
            weights,
        }
    }

    /// Run the same queries strictly one after the other (the paper's
    /// "sequential" arm). Exact under the fluid model: a lone query always
    /// runs at rate 1.0, so this is a direct sum of solo times.
    pub fn run_sequential(&self, queries: &[QuerySpec]) -> FlowReport {
        let nodes = self.m.nodes();
        let mut counters = Counters::new(nodes);
        let mut t = 0.0f64;
        let mut timings = Vec::with_capacity(queries.len());
        for q in queries {
            t = t.max(q.arrival_ns);
            let start = t;
            for p in &q.phases {
                charge_counters(&mut counters, p);
                t += p.solo_ns(&self.m);
            }
            timings.push(QueryTiming {
                id: q.id,
                label: q.label,
                arrival_ns: q.arrival_ns,
                start_ns: start,
                finish_ns: t,
                phases: q.phases.len(),
                priority: q.priority,
                admitted_as: q.priority,
            });
        }
        counters.elapsed_ns = t;
        FlowReport {
            timings,
            makespan_ns: t,
            counters,
            peak_concurrency: usize::from(!queries.is_empty()),
            rejected: Vec::new(),
            shed: Vec::new(),
            // One query at a time: the peak reservation is the fattest
            // single query.
            peak_ctx_bytes: queries.iter().map(|q| q.ctx_bytes).max().unwrap_or(0),
            preempted: Vec::new(),
            parks: 0,
            resumes: 0,
            weights: ShareWeights::flat(),
        }
    }

    /// Build the allocator state for phase `phase_idx` of query `qi`,
    /// charging its demand to the counters. Skips zero-solo phases.
    /// Returns None when the query has no further phases. `weight` is the
    /// query's fair-share weight (1.0 under flat weights).
    fn enter_phase(
        &self,
        qi: usize,
        mut phase_idx: usize,
        q: &QuerySpec,
        weight: f64,
        counters: &mut Counters,
    ) -> Option<ActivePhase> {
        while phase_idx < q.phases.len() {
            let p = &q.phases[phase_idx];
            charge_counters(counters, p);
            let solo = p.solo_ns(&self.m);
            if solo > 0.0 {
                let mut util = p.flow_resources(&self.m, solo);
                util.retain(|&(_, u)| u > UTIL_EPS);
                return Some(ActivePhase {
                    qi,
                    phase_idx,
                    solo_ns: solo,
                    remaining: 1.0,
                    util,
                    rate: 1.0,
                    weight,
                });
            }
            phase_idx += 1;
        }
        None
    }
}

fn charge_counters(c: &mut Counters, p: &PhaseDemand) {
    for n in 0..p.nodes() {
        c.channel_ops[n] += p.channel_ops[n];
        c.stream_bytes[n] += p.stream_bytes[n];
        c.instructions[n] += p.instructions[n];
        c.fabric_bytes[n] += p.fabric_bytes[n];
        c.migrations[n] += p.migrations[n];
        c.msp_ops[n] += p.msp_ops[n];
    }
}

/// Progressive-filling *weighted* max-min fair rate allocation.
///
/// Every unfrozen phase's rate grows at `weight x` a uniform fill level
/// until some resource would exceed capacity (1.0 of each node-resource);
/// the phases using that bottleneck are frozen at `weight x level` and
/// filling continues. Rates are capped at 1.0 — a phase can never beat its
/// solo time — and a phase that reaches that cap before any resource
/// saturates is frozen at full rate first (its consumption is then its
/// plain utilization, below the linear-growth estimate, so the remaining
/// saturation levels only move up). With flat weights (all 1.0) every step
/// reduces to the unweighted allocator: the cap pass fires exactly when
/// `level >= 1.0`, freezing everyone at once.
///
/// §Perf: `demand` arrives pre-aggregated as *weighted* utilization (the
/// run loop maintains `Σ weight x util` incrementally as phases enter and
/// leave) and is *decremented* as phases freeze, so each phase's util
/// vector is scanned at most once per solve; the scratch buffers are
/// caller-owned so the solve allocates only the small `frozen` bitmap.
fn max_min_rates(active: &mut [ActivePhase], demand: &mut [f64], residual: &mut [f64]) {
    if active.is_empty() {
        return;
    }
    let n_res = demand.len();
    residual.iter_mut().for_each(|r| *r = 1.0);
    let mut frozen = vec![false; active.len()];
    let mut unfrozen = active.len();

    while unfrozen > 0 {
        // Uniform fill level at which the first resource saturates (each
        // unfrozen phase consuming weight x level x util).
        let mut level = f64::INFINITY;
        let mut bottleneck = usize::MAX;
        for j in 0..n_res {
            if demand[j] > UTIL_EPS {
                let l = residual[j].max(0.0) / demand[j];
                if l < level {
                    level = l;
                    bottleneck = j;
                }
            }
        }
        if bottleneck == usize::MAX {
            // Nothing binds below the solo-speed cap: everyone left runs
            // at full rate.
            for (i, ap) in active.iter_mut().enumerate() {
                if !frozen[i] {
                    ap.rate = 1.0;
                }
            }
            return;
        }
        // Phases whose weighted growth hits the solo cap at or before the
        // saturation level run at full rate; retire them and re-solve —
        // they consume util (not weight x level x util), so the remaining
        // levels are monotonically non-decreasing.
        let mut capped_any = false;
        for (i, ap) in active.iter_mut().enumerate() {
            if frozen[i] || ap.weight * level < 1.0 {
                continue;
            }
            ap.rate = 1.0;
            frozen[i] = true;
            unfrozen -= 1;
            capped_any = true;
            for &(j, u) in &ap.util {
                residual[j as usize] -= u;
                demand[j as usize] -= ap.weight * u;
            }
        }
        if capped_any {
            continue;
        }
        // Freeze every unfrozen phase that touches the bottleneck at its
        // weighted share; retire its demand and charge its consumption.
        let mut froze_any = false;
        for (i, ap) in active.iter_mut().enumerate() {
            if frozen[i] {
                continue;
            }
            if ap.util.iter().any(|&(j, _)| j as usize == bottleneck) {
                ap.rate = (ap.weight * level).min(1.0).max(1e-9);
                frozen[i] = true;
                unfrozen -= 1;
                froze_any = true;
                for &(j, u) in &ap.util {
                    residual[j as usize] -= ap.rate * u;
                    demand[j as usize] -= ap.weight * u;
                }
            }
        }
        debug_assert!(froze_any, "bottleneck had no users");
        if !froze_any {
            // Defensive: avoid an infinite loop on numerical corner cases.
            for (i, ap) in active.iter_mut().enumerate() {
                if !frozen[i] {
                    ap.rate = (ap.weight * level).min(1.0).max(1e-9);
                }
            }
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    /// A latency-bound phase lasting ~`total_ns` solo while consuming only
    /// `frac` of every node's channel capacity — the structural shape of a
    /// single Pathfinder query (the paper's concurrency headroom). Shared
    /// with the bench gate via [`PhaseDemand::uniform_channel_load`].
    fn uniform_phase(m: &Machine, frac: f64, total_ns: f64) -> PhaseDemand {
        PhaseDemand::uniform_channel_load(m, frac, total_ns)
    }

    fn query(m: &Machine, id: usize, frac: f64, total_ns: f64) -> QuerySpec {
        QuerySpec::new(id, "test", vec![uniform_phase(m, frac, total_ns)], 0.0)
    }

    #[test]
    fn single_query_runs_at_solo_speed() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let q = query(&m, 0, 0.4, 1e6);
        let solo = q.solo_ns(&m);
        // The helper really is latency-bound: solo ~= total_ns + sync.
        assert!((solo - (1e6 + m.cfg.level_sync_ns)).abs() < 2.0);
        let rep = sim.run(std::slice::from_ref(&q));
        assert!((rep.makespan_ns - solo).abs() / solo < 1e-9);
        assert_eq!(rep.peak_concurrency, 1);
    }

    #[test]
    fn sequential_is_sum_of_solos() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.3, 1e6)).collect();
        let solo: f64 = qs.iter().map(|q| q.solo_ns(&m)).sum();
        let rep = sim.run_sequential(&qs);
        assert!((rep.makespan_ns - solo).abs() / solo < 1e-9);
    }

    #[test]
    fn low_utilization_queries_overlap_fully() {
        // Two queries each using 30% of the channels: both should run at
        // solo speed concurrently (makespan == one solo time) because
        // their aggregate demand stays under every capacity.
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..2).map(|i| query(&m, i, 0.3, 1e6)).collect();
        let solo = qs[0].solo_ns(&m);
        let rep = sim.run(&qs);
        assert!((rep.makespan_ns - solo).abs() / solo < 1e-6, "{}", rep.makespan_ns);
    }

    #[test]
    fn saturation_shares_fairly() {
        // Four queries each utilizing ~50% of the channels solo: the
        // channels saturate, so the makespan is total channel work over
        // machine capacity (= 4 x 0.5 x total_ns of drain).
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.5, 1e6)).collect();
        let rep = sim.run(&qs);
        let expect = 4.0 * 0.5 * 1e6;
        assert!(
            (rep.makespan_ns - expect).abs() / expect < 0.05,
            "makespan {} expect {}",
            rep.makespan_ns,
            expect
        );
        // And it beats running them back to back.
        let seq = sim.run_sequential(&qs).makespan_ns;
        assert!(rep.makespan_ns < 0.55 * seq);
    }

    #[test]
    fn concurrent_never_slower_than_sequential() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        for frac in [0.1, 0.5, 0.9] {
            let qs: Vec<_> = (0..8).map(|i| query(&m, i, frac, 1e6)).collect();
            let conc = sim.run(&qs).makespan_ns;
            let seq = sim.run_sequential(&qs).makespan_ns;
            assert!(conc <= seq * (1.0 + 1e-9), "frac {frac}: conc {conc} seq {seq}");
        }
    }

    #[test]
    fn concurrent_not_faster_than_capacity_bound() {
        // Makespan can never beat total-channel-work / machine-capacity.
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..16).map(|i| query(&m, i, 0.7, 1e6)).collect();
        let rep = sim.run(&qs);
        let total_ops: f64 = qs
            .iter()
            .flat_map(|q| &q.phases)
            .map(|p| p.total_channel_ops())
            .sum();
        let bound = total_ops / m.total_channel_op_rate() * 1e9;
        assert!(rep.makespan_ns >= bound * (1.0 - 1e-9));
    }

    #[test]
    fn arrivals_respected() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut q0 = query(&m, 0, 0.2, 1e6);
        let mut q1 = query(&m, 1, 0.2, 1e6);
        q0.arrival_ns = 0.0;
        q1.arrival_ns = 5e8; // arrives long after q0 finished
        let solo = q0.solo_ns(&m);
        let rep = sim.run(&[q0, q1]);
        assert!((rep.timings[0].finish_ns - solo).abs() / solo < 1e-9);
        assert!((rep.timings[1].start_ns - 5e8).abs() < 1.0);
        assert!((rep.makespan_ns - (5e8 + solo)).abs() / solo < 1e-6);
        assert_eq!(rep.peak_concurrency, 1);
    }

    #[test]
    fn counters_accumulate_all_phases() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.4, 1e6)).collect();
        let rep = sim.run(&qs);
        let expect: f64 = qs
            .iter()
            .flat_map(|q| &q.phases)
            .map(|p| p.total_channel_ops())
            .sum();
        assert!((rep.counters.totals().channel_ops - expect).abs() < 1e-6);
    }

    #[test]
    fn empty_query_finishes_at_arrival() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let q = QuerySpec::new(7, "nop", vec![], 3.0);
        let rep = sim.run(&[q]);
        assert_eq!(rep.timings[0].finish_ns, 3.0);
        assert_eq!(rep.timings[0].latency_ns(), 0.0);
    }

    #[test]
    fn admission_reject_over_cap() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.1, 1e6)).collect();
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Reject));
        assert_eq!(rep.rejected, vec![2, 3]);
        assert!(rep.shed.is_empty());
        assert!(rep.timings[2].finish_ns.is_nan());
        assert!(rep.timings[0].finish_ns.is_finite());
        assert!(rep.peak_concurrency <= 2);
    }

    #[test]
    fn admission_queue_serializes_excess() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.1, 1e6)).collect();
        let solo = qs[0].solo_ns(&m);
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Queue));
        assert!(rep.rejected.is_empty());
        // Two waves of two fully-overlapping queries.
        assert!((rep.makespan_ns - 2.0 * solo).abs() / solo < 1e-6);
        assert_eq!(rep.peak_concurrency, 2);
        // Queued queries' latency includes the wait.
        assert!(rep.timings[3].latency_ns() > rep.timings[0].latency_ns() * 1.5);
    }

    #[test]
    fn admission_cap_one_equals_sequential() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.5, 1e6)).collect();
        let capped = sim.run_admitted(&qs, Admission::capped(1, OnFull::Queue)).makespan_ns;
        let seq = sim.run_sequential(&qs).makespan_ns;
        assert!((capped - seq).abs() / seq < 1e-9);
    }

    /// Regression (NaN-stats bugfix): rejected queries carry NaN timings;
    /// the report's mean and latency list must filter them, not return
    /// NaN.
    #[test]
    fn rejected_timings_do_not_poison_latency_stats() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.1, 1e6)).collect();
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Reject));
        assert_eq!(rep.rejected.len(), 2);
        assert!(rep.mean_latency_s().is_finite());
        assert!(rep.mean_latency_s() > 0.0);
        let lats = rep.latencies_s();
        assert_eq!(lats.len(), 2, "only completed queries have latencies");
        assert!(lats.iter().all(|l| l.is_finite()));
    }

    /// Regression: a rejected query reports the phase count it *would*
    /// have run (uniform with queued-then-run queries), not 0.
    #[test]
    fn rejected_timings_carry_spec_phase_count() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.1, 1e6)).collect();
        qs[2].phases = vec![uniform_phase(&m, 0.1, 1e6), uniform_phase(&m, 0.1, 1e6)];
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Reject));
        assert_eq!(rep.rejected, vec![2]);
        assert_eq!(rep.timings[2].phases, 2);
        assert!(rep.timings[2].start_ns.is_nan(), "never started");
        assert!(!rep.timings[2].completed());
    }

    /// The wait queue is priority-ordered: with one slot busy, a later-
    /// arriving Interactive query starts before an earlier-queued Batch
    /// one, and Standard before Batch.
    #[test]
    fn wait_queue_orders_by_priority_class() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let running = query(&m, 0, 0.5, 1e6);
        let batch = query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch);
        let mut standard = query(&m, 2, 0.5, 1e5);
        standard.arrival_ns = 1e3;
        let mut interactive = query(&m, 3, 0.5, 1e5).with_priority(Priority::Interactive);
        interactive.arrival_ns = 2e3;
        let qs = vec![running, batch, standard, interactive];
        let adm = Admission::capped(1, OnFull::Queue).with_age_promote_ns(f64::INFINITY);
        let rep = sim.run_admitted(&qs, adm);
        // All queued behind query 0; start order: interactive, standard,
        // batch — the reverse of arrival order.
        assert!(rep.timings[3].start_ns < rep.timings[2].start_ns);
        assert!(rep.timings[2].start_ns < rep.timings[1].start_ns);
        assert!(rep.rejected.is_empty() && rep.shed.is_empty());
    }

    /// Aging promotes a long-waiting Batch query: with a small
    /// `age_promote_ns`, Batch work overtakes Interactive arrivals that
    /// have not yet aged.
    #[test]
    fn aging_prevents_batch_starvation() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs = vec![
            query(&m, 0, 0.5, 1e6),
            query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch),
        ];
        // A stream of Interactive arrivals that would starve Batch under
        // strict priority.
        for i in 0..6 {
            let mut q = query(&m, 2 + i, 0.5, 1e5).with_priority(Priority::Interactive);
            q.arrival_ns = 1e3 * (i as f64 + 1.0);
            qs.push(q);
        }
        let strict = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(f64::INFINITY),
        );
        // Strict: batch goes last.
        assert!(qs[2..]
            .iter()
            .all(|q| strict.timings[q.id].start_ns < strict.timings[1].start_ns));
        // Aged: after waiting 2e5 ns the batch query competes as
        // Interactive with the earliest enqueue order, so it beats the
        // still-waiting interactive stream.
        let aged = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(2e5),
        );
        let later_interactive_starts =
            qs[2..].iter().filter(|q| aged.timings[q.id].start_ns > aged.timings[1].start_ns);
        assert!(
            later_interactive_starts.count() > 0,
            "aged batch must overtake part of the interactive stream"
        );
        // And the wait of the batch query is bounded near the promotion
        // age plus one in-flight query.
        let batch_wait = aged.timings[1].start_ns - qs[1].arrival_ns;
        assert!(batch_wait < 2e5 + 2.0 * 1e6, "batch waited {batch_wait} ns");
    }

    /// Byte-aware admission: in-flight context bytes never exceed the
    /// budget even when the query-count cap would allow more.
    #[test]
    fn byte_budget_bounds_in_flight_reservations() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..6)
            .map(|i| query(&m, i, 0.1, 1e6).with_ctx_bytes(40))
            .collect();
        let rep = sim.run_admitted(&qs, Admission::byte_budget(100, OnFull::Queue));
        // 100 / 40 = at most 2 concurrently.
        assert_eq!(rep.peak_concurrency, 2);
        assert_eq!(rep.peak_ctx_bytes, 80, "ledger high-water mark surfaced");
        assert_eq!(rep.timings.iter().filter(|t| t.completed()).count(), 6);
    }

    /// A query whose own footprint exceeds the whole byte budget is
    /// rejected at arrival — even under Queue, where waiting would be
    /// eternal.
    #[test]
    fn oversized_query_rejected_not_queued_forever() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs = vec![
            query(&m, 0, 0.1, 1e6).with_ctx_bytes(50),
            query(&m, 1, 0.1, 1e6).with_ctx_bytes(1000),
        ];
        let rep = sim.run_admitted(&qs, Admission::byte_budget(100, OnFull::Queue));
        assert_eq!(rep.rejected, vec![1]);
        assert!(rep.timings[0].completed());
    }

    /// A queued query whose deadline expires while waiting is shed, not
    /// run after the fact.
    #[test]
    fn expired_deadline_sheds_waiting_query() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let long = query(&m, 0, 0.5, 1e6);
        // Would have to wait ~1e6 ns; its deadline is far shorter.
        let doomed = query(&m, 1, 0.5, 1e5).with_deadline_ns(1e4);
        let patient = query(&m, 2, 0.5, 1e5).with_deadline_ns(1e9);
        let qs = vec![long, doomed, patient];
        let rep = sim.run_admitted(&qs, Admission::capped(1, OnFull::Queue));
        assert_eq!(rep.shed, vec![1]);
        assert!(rep.rejected.is_empty());
        assert!(rep.timings[1].start_ns.is_nan());
        assert!(rep.timings[0].completed() && rep.timings[2].completed());
    }

    /// Shed-on-overflow drops Batch work first: with a bounded wait
    /// queue, every shed victim is Batch while Interactive work survives.
    #[test]
    fn shed_policy_drops_batch_before_interactive() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs = vec![query(&m, 0, 0.5, 1e6)];
        for i in 0..4 {
            let mut q = query(&m, 1 + i, 0.5, 1e5).with_priority(Priority::Batch);
            q.arrival_ns = 1e3 * (i as f64 + 1.0);
            qs.push(q);
        }
        for i in 0..3 {
            let mut q = query(&m, 5 + i, 0.5, 1e5).with_priority(Priority::Interactive);
            q.arrival_ns = 1e4 + 1e3 * (i as f64 + 1.0);
            qs.push(q);
        }
        let rep = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Shed { max_waiting: 3 }),
        );
        assert!(!rep.shed.is_empty(), "overflow must shed");
        assert!(
            rep.shed.iter().all(|&id| qs[id].priority == Priority::Batch),
            "only batch work may be shed while batch remains: {:?}",
            rep.shed
        );
        // Interactive queries all completed.
        assert!(qs[5..].iter().all(|q| rep.timings[q.id].completed()));
    }

    /// Weighted fair share, closed form: 4 Interactive (weight 4) + 4
    /// Batch (weight 1) identical queries, channels saturated. Per-channel
    /// utilization is `u = drain/solo` with `drain = frac x total_ns`, so
    /// the fill level is `solo/(20 drain)`, the Interactive rate is
    /// `4 x level`, and Interactive finishes at exactly `20 drain / 4 =
    /// 2.5e6 ns` — the solo time cancels. Batch then holds 75% of its work
    /// and drains the now-private channels at `solo/(4 drain)`, finishing
    /// at `4.0e6 ns`. The makespan equals the flat-weights makespan: the
    /// allocator redistributes bandwidth, it does not create or destroy
    /// work.
    #[test]
    fn weighted_shares_follow_class_weights() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs: Vec<QuerySpec> = Vec::new();
        for i in 0..4 {
            qs.push(query(&m, i, 0.5, 1e6).with_priority(Priority::Interactive));
        }
        for i in 4..8 {
            qs.push(query(&m, i, 0.5, 1e6).with_priority(Priority::Batch));
        }
        let flat = sim.run_admitted(&qs, Admission::unlimited());
        let weighted = sim.run_admitted(
            &qs,
            Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
        );
        // Flat: all eight share equally and finish together at 8 x drain.
        assert!((flat.makespan_ns - 4e6).abs() / 4e6 < 0.01, "{}", flat.makespan_ns);
        let t_int = weighted.timings[0].latency_ns();
        let t_batch = weighted.timings[7].latency_ns();
        assert!((t_int - 2.5e6).abs() / 2.5e6 < 0.01, "interactive at {t_int}");
        assert!((t_batch - 4.0e6).abs() / 4.0e6 < 0.01, "batch at {t_batch}");
        // Work conservation: the weighted makespan matches the flat one.
        assert!((weighted.makespan_ns - flat.makespan_ns).abs() / flat.makespan_ns < 0.01);
        // Surfaced through the report: per-class latencies and the weights.
        assert!(weighted.class_mean_latency_s(Priority::Interactive)
            < weighted.class_mean_latency_s(Priority::Batch));
        assert_eq!(weighted.weights, ShareWeights::priority_weighted());
        assert!(weighted.preempted.is_empty() && weighted.parks == 0);
    }

    /// The solo-speed cap still binds under weights: a heavily-weighted
    /// query whose `weight x level` exceeds 1 runs at solo speed, no
    /// faster, and the leftover bandwidth goes to the rest.
    #[test]
    fn weighted_rate_caps_at_solo_speed() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs = vec![query(&m, 0, 0.25, 1e6).with_priority(Priority::Interactive)];
        for i in 1..9 {
            qs.push(query(&m, i, 0.25, 1e6).with_priority(Priority::Batch));
        }
        let w = ShareWeights { interactive: 8.0, standard: 1.0, batch: 1.0 };
        let rep = sim.run_admitted(&qs, Admission::unlimited().with_weights(w));
        let solo = qs[0].solo_ns(&m);
        let t_int = rep.timings[0].latency_ns();
        // weight x level = 8 x 0.25 = 2 >= 1: capped at solo speed.
        assert!((t_int - solo).abs() / solo < 0.01, "{t_int} vs solo {solo}");
        // Channels stay saturated throughout: makespan = total work over
        // capacity = 9 x 0.25e6 ns.
        assert!((rep.makespan_ns - 2.25e6).abs() / 2.25e6 < 0.01, "{}", rep.makespan_ns);
    }

    /// Weights are scale-free: any flat vector reproduces plain max-min.
    #[test]
    fn flat_weights_at_any_scale_match_default() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs: Vec<QuerySpec> = (0..6).map(|i| query(&m, i, 0.5, 1e6)).collect();
        for (i, q) in qs.iter_mut().enumerate() {
            q.priority = Priority::ALL[i % 3];
        }
        let base = sim.run_admitted(&qs, Admission::unlimited());
        let scaled = sim.run_admitted(
            &qs,
            Admission::unlimited()
                .with_weights(ShareWeights { interactive: 3.0, standard: 3.0, batch: 3.0 }),
        );
        assert!((base.makespan_ns - scaled.makespan_ns).abs() / base.makespan_ns < 1e-9);
        for (a, b) in base.timings.iter().zip(&scaled.timings) {
            assert!((a.finish_ns - b.finish_ns).abs() / a.finish_ns < 1e-9);
        }
    }

    /// Checkpoint preemption round trip: a running Batch query parks at
    /// its next phase boundary when a blocked Interactive arrival needs
    /// its context bytes (60 + 60 > 100: the interactive query can only
    /// start because the ledger reservation was released), then resumes
    /// and completes once the pressure clears.
    #[test]
    fn preemption_parks_batch_at_checkpoint_for_interactive() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let batch = QuerySpec::new(
            0,
            "batch",
            (0..4).map(|_| uniform_phase(&m, 0.5, 1e6)).collect(),
            0.0,
        )
        .with_priority(Priority::Batch)
        .with_ctx_bytes(60);
        let mut interactive = query(&m, 1, 0.5, 1e5)
            .with_priority(Priority::Interactive)
            .with_ctx_bytes(60);
        interactive.arrival_ns = 1.2e6; // mid-phase-2 of the batch query
        let qs = vec![batch, interactive];
        let adm = Admission::byte_budget(100, OnFull::Queue);

        // PR 2 behavior: the interactive query waits out the whole batch.
        let plain = sim.run_admitted(&qs, adm);
        assert!(plain.preempted.is_empty() && plain.parks == 0);
        assert!(plain.timings[1].start_ns > 3.9e6, "{}", plain.timings[1].start_ns);

        let rep = sim.run_admitted(&qs, adm.with_preempt(PreemptPolicy::default()));
        assert_eq!(rep.preempted, vec![0]);
        assert_eq!((rep.parks, rep.resumes), (1, 1));
        // Parked at the ~2e6 phase boundary, not mid-phase.
        let istart = rep.timings[1].start_ns;
        assert!((1.9e6..2.5e6).contains(&istart), "interactive started at {istart}");
        assert!(rep.peak_ctx_bytes <= 100);
        // Both complete; the parked time lands in the batch latency.
        assert!(rep.timings[0].completed() && rep.timings[1].completed());
        assert!(rep.timings[0].finish_ns > rep.timings[1].finish_ns);
        assert!(
            rep.timings[1].latency_ns() < 0.5 * plain.timings[1].latency_ns(),
            "preemption must shorten the interactive latency: {} vs {}",
            rep.timings[1].latency_ns(),
            plain.timings[1].latency_ns()
        );
        // Work is conserved: the batch query still runs all four phases.
        assert_eq!(rep.timings[0].phases, 4);
        assert!(
            (rep.counters.totals().channel_ops - plain.counters.totals().channel_ops).abs()
                < 1e-6
        );
    }

    /// An Interactive or Standard query is never a preemption victim under
    /// the default (Batch-only) policy.
    #[test]
    fn preemption_spares_non_victim_classes() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let standard = QuerySpec::new(
            0,
            "std",
            (0..4).map(|_| uniform_phase(&m, 0.5, 1e6)).collect(),
            0.0,
        )
        .with_ctx_bytes(60);
        let mut interactive = query(&m, 1, 0.5, 1e5)
            .with_priority(Priority::Interactive)
            .with_ctx_bytes(60);
        interactive.arrival_ns = 1.2e6;
        let qs = vec![standard, interactive];
        let rep = sim.run_admitted(
            &qs,
            Admission::byte_budget(100, OnFull::Queue).with_preempt(PreemptPolicy::default()),
        );
        // No victim: the interactive query waits like under PR 2.
        assert!(rep.preempted.is_empty() && rep.parks == 0);
        assert!(rep.timings[1].start_ns > 3.9e6);
        assert!(rep.timings.iter().all(|t| t.completed()));
    }

    /// An aging-promoted Batch waiter orders the queue like Interactive
    /// but must not trigger parking of running Batch work — swapping
    /// running Batch for waiting Batch is pure churn.
    #[test]
    fn aged_batch_waiter_does_not_preempt_running_batch() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let running = QuerySpec::new(
            0,
            "b0",
            (0..4).map(|_| uniform_phase(&m, 0.5, 1e6)).collect(),
            0.0,
        )
        .with_priority(Priority::Batch)
        .with_ctx_bytes(60);
        let waiter = query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch).with_ctx_bytes(60);
        let adm = Admission::byte_budget(100, OnFull::Queue)
            .with_age_promote_ns(1e5) // promotes long before the batch finishes
            .with_preempt(PreemptPolicy::default());
        let rep = sim.run_admitted(&[running, waiter], adm);
        assert_eq!(rep.parks, 0, "aged Batch pressure must not park running Batch");
        // The waiter starts only when the running query completes — but it
        // is still recorded as aged into the Interactive class.
        assert!(rep.timings[1].start_ns > 3.9e6, "{}", rep.timings[1].start_ns);
        assert_eq!(rep.timings[1].admitted_as, Priority::Interactive);
        assert!(rep.timings.iter().all(|t| t.completed()));
    }

    /// Bugfix (aging accounting): a promoted waiter records both sides —
    /// the declared class it belongs to and the class it was admitted as.
    #[test]
    fn aging_promotion_recorded_as_admitted_class() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let long = query(&m, 0, 0.5, 1e6);
        let batch = query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch);
        let rep = sim.run_admitted(
            &[long, batch],
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(2e5),
        );
        // The batch query waited ~1e6 ns >> 2e5: promoted on admission.
        assert_eq!(rep.timings[1].priority, Priority::Batch);
        assert_eq!(rep.timings[1].admitted_as, Priority::Interactive);
        // The first query started without waiting: no promotion.
        assert_eq!(rep.timings[0].admitted_as, rep.timings[0].priority);
    }

    #[test]
    fn share_weights_parse_and_validate() {
        let w = ShareWeights::parse("interactive=4, standard=2, batch=1").unwrap();
        assert_eq!(w, ShareWeights::priority_weighted());
        assert!(!w.is_flat());
        assert_eq!(w.label(), "4:2:1");
        // Omitted classes default to 1.
        let w = ShareWeights::parse("interactive=6").unwrap();
        assert_eq!(w.standard, 1.0);
        assert_eq!(w.batch, 1.0);
        assert!(ShareWeights::flat().is_flat());
        assert!(ShareWeights::parse("realtime=2").is_err());
        assert!(ShareWeights::parse("batch=0").is_err(), "zero weight starves");
        assert!(ShareWeights::parse("batch=-1").is_err());
        assert!(ShareWeights::parse("batch=inf").is_err());
    }

    #[test]
    fn heterogeneous_rates_water_fill() {
        // One channel-hungry query + one instruction-only query: the
        // instruction query should be unaffected by channel saturation.
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let hungry: Vec<_> = (0..4).map(|i| query(&m, i, 0.5, 1e6)).collect();
        let mut instr_only = PhaseDemand::zero(8, 8);
        for n in 0..8 {
            instr_only.instructions[n] = m.issue_rate(n) * 0.1 * 1e-3; // 0.1 util for 1e6 ns
        }
        instr_only.parallelism = 1e12;
        let iq = QuerySpec::new(99, "instr", vec![instr_only], 0.0);
        let solo_iq = iq.solo_ns(&m);
        let mut all = hungry;
        all.push(iq);
        let rep = sim.run(&all);
        let iq_t = rep.timings[4].latency_ns();
        assert!((iq_t - solo_iq).abs() / solo_iq < 1e-6, "{iq_t} vs {solo_iq}");
    }
}
