//! Checkpoint preemption: park running low-priority queries at phase
//! boundaries so a blocked Interactive query can start.
//!
//! PR 2's admission orders the *wait queue* by priority, but once a query
//! is running it holds its thread-context reservation until completion: a
//! fat Batch query in flight can keep an Interactive arrival queued for
//! its whole remaining runtime. Checkpoint preemption closes that gap.
//! The engine ([`crate::sim::flow::FlowSim::run_admitted`]) drives the
//! [`Parker`] state machine; each in-flight query is in one of three
//! states:
//!
//! ```text
//!             mark()                park(next_phase)
//!  Running ──────────▶ Draining ──────────────────────▶ Parked
//!     ▲                   │                                │
//!     │   unmark_all()    │                resume_front()  │
//!     └───────────────────┴────────────────────────────────┘
//! ```
//!
//! * **Running → Draining**: when a *declared*-Interactive waiter is
//!   blocked, the engine marks enough preemptible (by default Batch-class)
//!   running queries to cover the waiter's context bytes. An
//!   aging-promoted Batch waiter orders the wait queue like Interactive
//!   but never triggers parking — swapping running Batch work for waiting
//!   Batch work would be pure churn. Marks are recomputed at every event,
//!   so a mark evaporates (`unmark_all`) if the pressure clears before
//!   the victim reaches a checkpoint.
//! * **Draining → Parked**: a phase boundary is the checkpoint — the
//!   completed prefix of phases is retained (nothing is re-executed), the
//!   query's [`crate::sim::flow::QuerySpec::ctx_bytes`] reservation is
//!   released back to the [`crate::sim::ledger::ContextLedger`], and the
//!   index of the next phase to run is recorded here.
//! * **Parked → Running**: when no better-class waiter is blocked and the
//!   reservation fits again, the engine re-admits the query and resumes it
//!   from the checkpointed phase. Parked queries resume FIFO.
//!
//! [`PreemptPolicy::max_parks_per_query`] bounds how often one query can
//! cycle through this loop, so adversarial arrival patterns cannot thrash
//! a Batch query forever.

use super::flow::Priority;
use std::collections::VecDeque;

/// Knobs for checkpoint preemption (carried by
/// [`crate::sim::flow::Admission::preempt`]; `None` disables it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptPolicy {
    /// Best (smallest) priority class that may be parked: classes at or
    /// *below* this one are preemptible. The default is
    /// [`Priority::Batch`] — only throughput-oriented background work is
    /// ever parked; `Standard` would make both Standard and Batch fair
    /// game.
    pub victim_class: Priority,
    /// Maximum times one query may be parked over a run (thrash bound).
    pub max_parks_per_query: usize,
}

impl Default for PreemptPolicy {
    fn default() -> Self {
        PreemptPolicy { victim_class: Priority::Batch, max_parks_per_query: 16 }
    }
}

impl PreemptPolicy {
    /// The default policy: only Batch work is preemptible.
    pub fn batch_only() -> Self {
        Self::default()
    }

    /// Widen (or narrow) the preemptible classes.
    pub fn with_victim_class(mut self, victim_class: Priority) -> Self {
        self.victim_class = victim_class;
        self
    }

    /// Override the per-query park bound.
    pub fn with_max_parks(mut self, max_parks_per_query: usize) -> Self {
        self.max_parks_per_query = max_parks_per_query;
        self
    }

    /// Whether a query of declared class `p` may be parked at all.
    pub fn can_preempt(&self, p: Priority) -> bool {
        p >= self.victim_class
    }
}

/// Preemption state of one in-flight query (see the module diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParkState {
    /// Not involved in preemption (running normally, waiting, or done).
    #[default]
    Running,
    /// Marked: will park at its next phase boundary.
    Draining,
    /// Parked: context bytes released, waiting to resume.
    Parked,
}

/// The per-run preemption state machine the flow engine drives.
#[derive(Debug, Clone)]
pub struct Parker {
    policy: PreemptPolicy,
    state: Vec<ParkState>,
    parks_per_query: Vec<usize>,
    /// Parked queries in park order: (query index, next phase to run).
    parked: VecDeque<(usize, usize)>,
    parks: usize,
    resumes: usize,
}

impl Parker {
    pub fn new(policy: PreemptPolicy, n_queries: usize) -> Self {
        Parker {
            policy,
            state: vec![ParkState::Running; n_queries],
            parks_per_query: vec![0; n_queries],
            parked: VecDeque::new(),
            parks: 0,
            resumes: 0,
        }
    }

    pub fn policy(&self) -> &PreemptPolicy {
        &self.policy
    }

    pub fn state(&self, qi: usize) -> ParkState {
        self.state[qi]
    }

    /// Whether query `qi` (declared class `p`) is eligible to be marked:
    /// running, in a preemptible class, and under its park budget.
    pub fn can_mark(&self, qi: usize, p: Priority) -> bool {
        self.state[qi] == ParkState::Running
            && self.policy.can_preempt(p)
            && self.parks_per_query[qi] < self.policy.max_parks_per_query
    }

    /// Running → Draining: park at the next phase boundary.
    pub fn mark(&mut self, qi: usize) {
        debug_assert_eq!(self.state[qi], ParkState::Running, "mark of non-running query {qi}");
        self.state[qi] = ParkState::Draining;
    }

    /// Drop every pending mark (pressure cleared before the checkpoint).
    pub fn unmark_all(&mut self) {
        for s in &mut self.state {
            if *s == ParkState::Draining {
                *s = ParkState::Running;
            }
        }
    }

    pub fn is_draining(&self, qi: usize) -> bool {
        self.state[qi] == ParkState::Draining
    }

    /// Draining → Parked at a phase boundary; `next_phase` is the
    /// checkpoint to resume from.
    pub fn park(&mut self, qi: usize, next_phase: usize) {
        debug_assert_eq!(self.state[qi], ParkState::Draining, "park of unmarked query {qi}");
        self.state[qi] = ParkState::Parked;
        self.parks_per_query[qi] += 1;
        self.parks += 1;
        self.parked.push_back((qi, next_phase));
    }

    /// The longest-parked query, if any: (query index, next phase).
    pub fn peek_parked(&self) -> Option<(usize, usize)> {
        self.parked.front().copied()
    }

    /// Parked → Running for the front of the parked queue.
    pub fn resume_front(&mut self) -> (usize, usize) {
        let (qi, next_phase) = self.parked.pop_front().expect("resume with nothing parked");
        debug_assert_eq!(self.state[qi], ParkState::Parked);
        self.state[qi] = ParkState::Running;
        self.resumes += 1;
        (qi, next_phase)
    }

    /// Clear any leftover mark when a query completes (a Draining query
    /// whose final phase finished never parks).
    pub fn finish(&mut self, qi: usize) {
        if self.state[qi] == ParkState::Draining {
            self.state[qi] = ParkState::Running;
        }
    }

    /// How many queries are currently parked.
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// Total park events over the run.
    pub fn parks(&self) -> usize {
        self.parks
    }

    /// Total resume events over the run.
    pub fn resumes(&self) -> usize {
        self.resumes
    }

    /// Whether query `qi` was parked at least once.
    pub fn was_parked(&self, qi: usize) -> bool {
        self.parks_per_query[qi] > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_defaults_park_batch_only() {
        let p = PreemptPolicy::default();
        assert!(p.can_preempt(Priority::Batch));
        assert!(!p.can_preempt(Priority::Standard));
        assert!(!p.can_preempt(Priority::Interactive));
        let wide = p.with_victim_class(Priority::Standard);
        assert!(wide.can_preempt(Priority::Standard) && wide.can_preempt(Priority::Batch));
        assert!(!wide.can_preempt(Priority::Interactive));
    }

    #[test]
    fn mark_park_resume_round_trip() {
        let mut pk = Parker::new(PreemptPolicy::default(), 3);
        assert!(pk.can_mark(1, Priority::Batch));
        assert!(!pk.can_mark(1, Priority::Interactive), "victim class gates marking");
        pk.mark(1);
        assert!(pk.is_draining(1));
        assert!(!pk.can_mark(1, Priority::Batch), "already draining");
        pk.park(1, 2);
        assert_eq!(pk.state(1), ParkState::Parked);
        assert_eq!(pk.parked_len(), 1);
        assert_eq!(pk.peek_parked(), Some((1, 2)));
        assert_eq!(pk.resume_front(), (1, 2));
        assert_eq!(pk.state(1), ParkState::Running);
        assert_eq!(pk.parked_len(), 0);
        assert_eq!((pk.parks(), pk.resumes()), (1, 1));
        assert!(pk.was_parked(1) && !pk.was_parked(0));
    }

    #[test]
    fn unmark_reverts_draining_without_counting_a_park() {
        let mut pk = Parker::new(PreemptPolicy::default(), 2);
        pk.mark(0);
        pk.unmark_all();
        assert_eq!(pk.state(0), ParkState::Running);
        assert_eq!(pk.parks(), 0);
        assert!(!pk.was_parked(0));
        // A completed query with a leftover mark is cleared the same way.
        pk.mark(1);
        pk.finish(1);
        assert_eq!(pk.state(1), ParkState::Running);
    }

    #[test]
    fn park_budget_bounds_thrash() {
        let mut pk = Parker::new(PreemptPolicy::default().with_max_parks(2), 1);
        for round in 0..2 {
            assert!(pk.can_mark(0, Priority::Batch), "round {round}");
            pk.mark(0);
            pk.park(0, round + 1);
            pk.resume_front();
        }
        assert!(!pk.can_mark(0, Priority::Batch), "park budget exhausted");
        assert_eq!(pk.parks(), 2);
    }

    #[test]
    fn parked_queue_is_fifo() {
        let mut pk = Parker::new(PreemptPolicy::default(), 4);
        for qi in [2, 0, 3] {
            pk.mark(qi);
            pk.park(qi, 1);
        }
        assert_eq!(pk.resume_front().0, 2);
        assert_eq!(pk.resume_front().0, 0);
        assert_eq!(pk.resume_front().0, 3);
    }
}
