//! A fleet of Pathfinder chassis flattened into one simulatable
//! [`Machine`] (DESIGN.md §Fleet).
//!
//! A [`Cluster`] is `shards x replicas` copies of a base machine: every
//! copy ("fleet member") holds one shard replica of the partitioned graph
//! (see [`crate::graph::partition`]). Rather than running one flow engine
//! per member, the cluster *flattens* into a single [`Machine`] whose
//! chassis ARE the members: `nodes = shards x replicas x base.nodes` with
//! `nodes_per_chassis = base.nodes`. That reuses the whole simulator —
//! admission, the weighted allocator, counters, preemption — unchanged,
//! while keeping per-member capacity exact (every per-node rate is a
//! per-node rate regardless of grouping). What the flattening does *not*
//! capture — that crossing members is slower than crossing nodes — is
//! exactly what the fleet demand models price explicitly: cross-shard
//! bytes are charged to [`PhaseDemand::interconnect_bytes`] (its own
//! capacity kind + latency floor) rather than to the intra-machine fabric.
//!
//! Fleet members are assumed healthy: the base config's degraded-chassis
//! list describes the one physical CRNCH machine and its indices would
//! silently re-target fleet members after flattening, so it is cleared.
//!
//! [`PhaseDemand::interconnect_bytes`]: crate::sim::demand::PhaseDemand

use std::ops::Range;

use super::machine::Machine;
use crate::config::machine::MachineConfig;
use crate::graph::layout::StripedLayout;

/// A shards x replicas fleet flattened into one multi-chassis machine.
#[derive(Debug, Clone)]
pub struct Cluster {
    machine: Machine,
    shards: usize,
    replicas: usize,
    nodes_per_chassis: usize,
}

impl Cluster {
    /// Build a fleet of `shards x replicas` copies of `base`.
    pub fn new(base: &MachineConfig, shards: usize, replicas: usize) -> Self {
        assert!(shards > 0 && replicas > 0, "need at least one shard and one replica");
        let mut cfg = base.clone();
        cfg.name = format!("fleet-{}x{}-{}", shards, replicas, base.name);
        cfg.nodes = shards * replicas * base.nodes;
        cfg.nodes_per_chassis = base.nodes;
        cfg.degraded_chassis = Vec::new();
        cfg.degrade_factor = 1.0;
        Cluster {
            machine: Machine::new(cfg),
            shards,
            replicas,
            nodes_per_chassis: base.nodes,
        }
    }

    /// The flattened machine the flow engine runs against.
    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Nodes inside one fleet member (= the base machine's node count).
    pub fn nodes_per_chassis(&self) -> usize {
        self.nodes_per_chassis
    }

    /// Total fleet members.
    pub fn chassis(&self) -> usize {
        self.shards * self.replicas
    }

    /// Fleet member holding replica `replica` of shard `shard`.
    /// Replica-major: replica set r is the contiguous chassis block
    /// `[r*shards, (r+1)*shards)`, so "the fleet's primary copy" is the
    /// first block and each added replica appends a full copy.
    #[inline]
    pub fn chassis_of(&self, shard: usize, replica: usize) -> usize {
        debug_assert!(shard < self.shards && replica < self.replicas);
        replica * self.shards + shard
    }

    /// Global node range of one fleet member.
    #[inline]
    pub fn node_range(&self, chassis: usize) -> Range<usize> {
        let base = chassis * self.nodes_per_chassis;
        base..base + self.nodes_per_chassis
    }

    /// The striped placement *within* one member: vertex v of a shard
    /// lives on local node `v mod nodes_per_chassis` at the usual view-2
    /// channel — the same rule a single machine uses, composed with the
    /// member's node offset by [`Cluster::vertex_node`].
    pub fn chassis_layout(&self) -> StripedLayout {
        StripedLayout::new(self.nodes_per_chassis, self.machine.cfg.channels_per_node)
    }

    /// Global node of vertex `v`'s record on fleet member `chassis`.
    #[inline]
    pub fn vertex_node(&self, chassis: usize, v: u32) -> usize {
        chassis * self.nodes_per_chassis + (v as usize % self.nodes_per_chassis)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flattened_machine_validates_and_sizes() {
        let c = Cluster::new(&MachineConfig::pathfinder_8(), 4, 2);
        assert_eq!(c.machine().nodes(), 64);
        assert_eq!(c.chassis(), 8);
        assert_eq!(c.nodes_per_chassis(), 8);
        assert!(c.machine().cfg.name.starts_with("fleet-4x2-"));
        // Per-node capacity identical to the base machine's.
        let base = Machine::new(MachineConfig::pathfinder_8());
        assert_eq!(c.machine().channel_op_rate(63), base.channel_op_rate(0));
    }

    #[test]
    fn chassis_addressing_is_replica_major() {
        let c = Cluster::new(&MachineConfig::pathfinder_8(), 4, 2);
        assert_eq!(c.chassis_of(0, 0), 0);
        assert_eq!(c.chassis_of(3, 0), 3);
        assert_eq!(c.chassis_of(0, 1), 4);
        assert_eq!(c.chassis_of(3, 1), 7);
        assert_eq!(c.node_range(1), 8..16);
        // Vertex placement composes member offset with the striped rule.
        assert_eq!(c.vertex_node(1, 0), 8);
        assert_eq!(c.vertex_node(1, 11), 11);
    }

    #[test]
    fn degraded_base_chassis_do_not_leak_into_the_fleet() {
        let c = Cluster::new(&MachineConfig::pathfinder_32(), 2, 1);
        assert_eq!(c.machine().nodes(), 64);
        assert_eq!(c.nodes_per_chassis(), 32);
        // pathfinder-32's degraded chassis [2,3] would have re-targeted
        // fleet members 2..4 after flattening; they are cleared instead.
        for n in 0..64 {
            assert_eq!(c.machine().cfg.node_derate(n), 1.0);
        }
    }

    #[test]
    fn fabric_crossing_members_is_inter_chassis() {
        let c = Cluster::new(&MachineConfig::pathfinder_8(), 2, 1);
        let cfg = &c.machine().cfg;
        assert!(cfg.fabric_latency_ns(0, 8) > cfg.fabric_latency_ns(0, 1));
    }
}
