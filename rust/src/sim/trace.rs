//! Query-lifecycle tracing: a typed event stream out of the flow engine.
//!
//! The engine's end-of-run aggregates ([`crate::sim::flow::FlowReport`])
//! answer *what* happened; this module answers *when and why*. Every
//! scheduling decision the runtime makes — arrival, admission, queueing,
//! shedding, parking, phase boundaries, solver re-anchoring — is emitted
//! as a [`TraceEvent`] carrying its simulated timestamp, and the
//! coordinator layers above add their own events (batch fusion, epoch
//! apply/compaction, fleet shard routing). The stream is event-sourced:
//! [`crate::coordinator::telemetry`] replays it to derive time-series
//! (utilization per chassis, queue depth per class, context bytes in
//! flight) and to export Chrome trace-event JSON for Perfetto.
//!
//! # The observation-only invariant
//!
//! Tracing must never branch the simulation. Sinks receive copies of
//! state the engine already computed; they cannot mutate it, and every
//! emission site is wrapped in `if S::ENABLED { ... }` so the
//! [`NullSink`] path (the default for `run`/`run_admitted`) compiles to
//! the untraced event loop unchanged — event construction included.
//! `prop_tests.rs` pins both halves: a traced run's `FlowReport` is
//! bit-identical to the untraced run, and per-type event counts
//! reconcile exactly with the report's counters.

use crate::sim::flow::Priority;

/// One scheduling event, stamped with simulated time in nanoseconds.
///
/// Engine events (emitted by `sim/flow/runtime.rs` / `solver.rs`) carry
/// the query's stable request id (`QuerySpec::id`), not its slot index,
/// so they join against [`crate::sim::flow::QueryTiming`] records.
/// Coordinator events (batch fusion, epochs, routing) are emitted by
/// `coordinator/service.rs` around the engine run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// A query arrived at the admission boundary.
    Arrival { t_ns: f64, id: usize, label: &'static str, class: Priority },
    /// The query could not start and joined the wait queue.
    QueueEnter { t_ns: f64, id: usize, class: Priority, waiting: usize },
    /// The query was admitted and started its first phase.
    /// `admitted_as != class` records an anti-starvation aging
    /// promotion (an aged front competing as Interactive).
    Admit {
        t_ns: f64,
        id: usize,
        class: Priority,
        admitted_as: Priority,
        wait_ns: f64,
        ctx_bytes: u64,
    },
    /// The query was rejected outright (`oversized` = its context can
    /// never fit; otherwise the `OnFull::Reject` policy fired).
    Reject { t_ns: f64, id: usize, class: Priority, oversized: bool },
    /// The query was shed from the wait queue (`expired` = its deadline
    /// passed while queued; otherwise it was the overflow victim).
    Shed { t_ns: f64, id: usize, class: Priority, expired: bool },
    /// A phase was scheduled onto the machine. `node_offset`/`node_len`
    /// locate its demand span (chassis attribution); `util_sum` is the
    /// phase's total fractional resource demand at rate 1.0.
    PhaseStart {
        t_ns: f64,
        id: usize,
        phase: usize,
        solo_ns: f64,
        node_offset: usize,
        node_len: usize,
        util_sum: f64,
    },
    /// A phase ran to completion.
    PhaseEnd { t_ns: f64, id: usize, phase: usize },
    /// The query finished its last phase and released its context.
    Finish { t_ns: f64, id: usize, ctx_bytes: u64 },
    /// Checkpoint preemption: the query was parked at a phase boundary
    /// (its context spilled; `next_phase` resumes later).
    Park { t_ns: f64, id: usize, next_phase: usize, ctx_bytes: u64 },
    /// A parked query was resumed (context re-admitted).
    Resume { t_ns: f64, id: usize, phase: usize, ctx_bytes: u64 },
    /// The incremental solver re-solved one connected component:
    /// `members` active phases over `resources` touched machine
    /// resources. Host-cost attribution for the event-scoped engine.
    Solve { t_ns: f64, members: usize, resources: usize },
    /// A query's fair-share rate changed; its progress closed form was
    /// re-anchored at `t_ns` with the new `rate`.
    ReAnchor { t_ns: f64, id: usize, rate: f64 },
    /// Coordinator: compatible queued requests fused into one
    /// multi-source engine query (`id` = the fused spec's id).
    BatchFuse { t_ns: f64, id: usize, width: usize, label: &'static str },
    /// Coordinator: an update batch advanced the graph store to `epoch`.
    EpochApply { t_ns: f64, epoch: u64, updates: usize },
    /// Coordinator: compaction folded `drained` overlays at `epoch`.
    Compaction { t_ns: f64, epoch: u64, drained: usize },
    /// Coordinator: a fleet request was routed to shard `shard`
    /// (replica index `replica`).
    ShardRoute { t_ns: f64, id: usize, shard: usize, replica: usize },
}

impl TraceEvent {
    /// Simulated timestamp (ns) of the event.
    pub fn t_ns(&self) -> f64 {
        match *self {
            TraceEvent::Arrival { t_ns, .. }
            | TraceEvent::QueueEnter { t_ns, .. }
            | TraceEvent::Admit { t_ns, .. }
            | TraceEvent::Reject { t_ns, .. }
            | TraceEvent::Shed { t_ns, .. }
            | TraceEvent::PhaseStart { t_ns, .. }
            | TraceEvent::PhaseEnd { t_ns, .. }
            | TraceEvent::Finish { t_ns, .. }
            | TraceEvent::Park { t_ns, .. }
            | TraceEvent::Resume { t_ns, .. }
            | TraceEvent::Solve { t_ns, .. }
            | TraceEvent::ReAnchor { t_ns, .. }
            | TraceEvent::BatchFuse { t_ns, .. }
            | TraceEvent::EpochApply { t_ns, .. }
            | TraceEvent::Compaction { t_ns, .. }
            | TraceEvent::ShardRoute { t_ns, .. } => t_ns,
        }
    }

    /// Stable kind label, used for event-count telemetry and the CI
    /// job-summary table.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Arrival { .. } => "arrival",
            TraceEvent::QueueEnter { .. } => "queue_enter",
            TraceEvent::Admit { .. } => "admit",
            TraceEvent::Reject { .. } => "reject",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::PhaseStart { .. } => "phase_start",
            TraceEvent::PhaseEnd { .. } => "phase_end",
            TraceEvent::Finish { .. } => "finish",
            TraceEvent::Park { .. } => "park",
            TraceEvent::Resume { .. } => "resume",
            TraceEvent::Solve { .. } => "solve",
            TraceEvent::ReAnchor { .. } => "re_anchor",
            TraceEvent::BatchFuse { .. } => "batch_fuse",
            TraceEvent::EpochApply { .. } => "epoch_apply",
            TraceEvent::Compaction { .. } => "compaction",
            TraceEvent::ShardRoute { .. } => "shard_route",
        }
    }

    /// The query id the event is about, when it is about one.
    pub fn query_id(&self) -> Option<usize> {
        match *self {
            TraceEvent::Arrival { id, .. }
            | TraceEvent::QueueEnter { id, .. }
            | TraceEvent::Admit { id, .. }
            | TraceEvent::Reject { id, .. }
            | TraceEvent::Shed { id, .. }
            | TraceEvent::PhaseStart { id, .. }
            | TraceEvent::PhaseEnd { id, .. }
            | TraceEvent::Finish { id, .. }
            | TraceEvent::Park { id, .. }
            | TraceEvent::Resume { id, .. }
            | TraceEvent::BatchFuse { id, .. }
            | TraceEvent::ShardRoute { id, .. } => Some(id),
            TraceEvent::Solve { .. }
            | TraceEvent::ReAnchor { .. }
            | TraceEvent::EpochApply { .. }
            | TraceEvent::Compaction { .. } => None,
        }
    }
}

/// Receiver for the engine's event stream.
///
/// `ENABLED` is an associated const so the runtime can wrap every
/// emission in `if S::ENABLED { ... }`: for [`NullSink`] the branch —
/// and the `TraceEvent` construction inside it — is dead code after
/// monomorphization, keeping the untraced hot path at PR 8 cost (the
/// `host_scaling` bench gate runs on this path).
pub trait TraceSink {
    /// Whether emission sites should construct and deliver events.
    const ENABLED: bool = true;
    fn emit(&mut self, ev: TraceEvent);
}

/// The zero-cost default: discards everything at compile time.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    const ENABLED: bool = false;
    #[inline(always)]
    fn emit(&mut self, _ev: TraceEvent) {}
}

/// Records every event in arrival order (the engine emits in
/// nondecreasing simulated time, so the buffer is time-sorted except
/// for coordinator events appended around the run).
#[derive(Debug, Clone, Default)]
pub struct TraceBuffer {
    pub events: Vec<TraceEvent>,
}

impl TraceBuffer {
    pub fn new() -> Self {
        TraceBuffer::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Event counts by [`TraceEvent::kind`], sorted by kind label.
    pub fn counts_by_kind(&self) -> Vec<(&'static str, usize)> {
        let mut counts: std::collections::BTreeMap<&'static str, usize> =
            std::collections::BTreeMap::new();
        for ev in &self.events {
            *counts.entry(ev.kind()).or_insert(0) += 1;
        }
        counts.into_iter().collect()
    }
}

impl TraceSink for TraceBuffer {
    fn emit(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }
}

/// Forwarding impl so callers can hand a `&mut TraceBuffer` into the
/// generic engine entry points without giving up the buffer.
impl<S: TraceSink> TraceSink for &mut S {
    const ENABLED: bool = S::ENABLED;
    #[inline(always)]
    fn emit(&mut self, ev: TraceEvent) {
        (**self).emit(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_is_disabled_buffer_is_enabled() {
        assert!(!NullSink::ENABLED);
        assert!(TraceBuffer::ENABLED);
        assert!(<&mut TraceBuffer as TraceSink>::ENABLED);
        assert!(!<&mut NullSink as TraceSink>::ENABLED);
    }

    #[test]
    fn buffer_records_in_order_and_counts_by_kind() {
        let mut buf = TraceBuffer::new();
        buf.emit(TraceEvent::Arrival { t_ns: 0.0, id: 7, label: "bfs", class: Priority::Standard });
        buf.emit(TraceEvent::Admit {
            t_ns: 0.0,
            id: 7,
            class: Priority::Standard,
            admitted_as: Priority::Standard,
            wait_ns: 0.0,
            ctx_bytes: 64,
        });
        buf.emit(TraceEvent::Finish { t_ns: 5.0, id: 7, ctx_bytes: 64 });
        buf.emit(TraceEvent::Solve { t_ns: 5.0, members: 1, resources: 2 });
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.events[0].kind(), "arrival");
        assert_eq!(buf.events[0].query_id(), Some(7));
        assert_eq!(buf.events[3].query_id(), None);
        assert_eq!(
            buf.counts_by_kind(),
            vec![("admit", 1), ("arrival", 1), ("finish", 1), ("solve", 1)]
        );
    }

    #[test]
    fn t_ns_covers_every_variant() {
        let evs = [
            TraceEvent::QueueEnter { t_ns: 1.0, id: 0, class: Priority::Batch, waiting: 3 },
            TraceEvent::Reject { t_ns: 2.0, id: 0, class: Priority::Batch, oversized: true },
            TraceEvent::Shed { t_ns: 3.0, id: 0, class: Priority::Batch, expired: false },
            TraceEvent::PhaseStart {
                t_ns: 4.0,
                id: 0,
                phase: 0,
                solo_ns: 1.0,
                node_offset: 0,
                node_len: 8,
                util_sum: 0.5,
            },
            TraceEvent::PhaseEnd { t_ns: 5.0, id: 0, phase: 0 },
            TraceEvent::Park { t_ns: 6.0, id: 0, next_phase: 1, ctx_bytes: 1 },
            TraceEvent::Resume { t_ns: 7.0, id: 0, phase: 1, ctx_bytes: 1 },
            TraceEvent::ReAnchor { t_ns: 8.0, id: 0, rate: 0.5 },
            TraceEvent::BatchFuse { t_ns: 9.0, id: 0, width: 4, label: "bfs" },
            TraceEvent::EpochApply { t_ns: 10.0, epoch: 1, updates: 32 },
            TraceEvent::Compaction { t_ns: 11.0, epoch: 1, drained: 2 },
            TraceEvent::ShardRoute { t_ns: 12.0, id: 0, shard: 1, replica: 0 },
        ];
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.t_ns(), (i + 1) as f64);
            assert!(!ev.kind().is_empty());
        }
    }
}
