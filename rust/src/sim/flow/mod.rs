//! Flow-level (fluid) concurrency engine.
//!
//! This is the engine the paper-scale experiments run on: up to hundreds
//! of thousands of concurrent queries, each a sequence of
//! [`crate::sim::demand::PhaseDemand`] phases produced by the functional
//! algorithms in [`crate::alg`]. The model:
//!
//! * Running **alone**, a phase takes
//!   [`crate::sim::demand::PhaseDemand::solo_ns`] — its
//!   latency/parallelism/synchronization structure caps how fast it can go
//!   even on an idle machine. A single level-synchronous BFS cannot
//!   saturate the Pathfinder's many narrow channels; that headroom is the
//!   paper's whole thesis.
//! * Running **concurrently**, each active phase progresses at a rate
//!   `s ∈ (0, 1]` relative to its solo speed. A phase running at its solo
//!   speed consumes a *fraction* `u_j = drain_ns(j) / solo_ns` of each
//!   shared resource `j` (a node's channel capacity, its hottest channel,
//!   stream bandwidth, instruction issue, fabric link — plus the cluster
//!   interconnect as a sixth resource). Rates are chosen by
//!   progressive-filling **max-min fairness**: grow every query's rate
//!   together until a resource saturates, freeze the queries using it, and
//!   continue with the rest — the fluid analogue of hardware round-robin
//!   thread scheduling with FIFO memory channels. With non-flat
//!   [`ShareWeights`] the filling is *weighted*: each query grows at its
//!   priority class's multiple of the fill level, so Interactive work
//!   holds a larger share of every saturated resource (DESIGN.md
//!   §Scheduling).
//! * Under [`Admission::preempt`], running Batch work can be **parked at a
//!   phase boundary** (context bytes released, completed phases kept) when
//!   a blocked Interactive waiter needs its reservation, and resumed when
//!   the pressure clears — see [`crate::sim::preempt`].
//! * Time advances event-to-event (phase completions and query arrivals).
//!   Rates are recomputed **event-scoped**: the [`solver`] re-solves only
//!   the connected component(s) of queries/resources an event structurally
//!   touched, and the [`runtime`] tracks completions in a lazy-deletion
//!   heap, so host cost per simulated event stays near-constant as
//!   concurrency grows (DESIGN.md §Engine).
//!
//! Sequential execution ([`FlowSim::run_sequential`]) is exact under this
//! model — a lone query always gets rate 1.0 — so it is computed directly
//! from solo times rather than through the event loop.
//!
//! The module is split by concern — [`spec`] (what callers submit),
//! [`report`] (what runs return), [`solver`] (the incremental rate
//! allocator), [`runtime`] (the event loop) — with everything re-exported
//! here, so `sim::flow::FlowSim` and friends keep working unchanged.

pub mod report;
pub mod runtime;
pub mod solver;
pub mod spec;

pub use report::{FlowReport, QueryTiming};
pub use runtime::{FlowSim, SolverMode};
pub use spec::{Admission, OnFull, Priority, QuerySpec, ShareWeights};
