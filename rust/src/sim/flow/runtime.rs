//! The flow-engine *runtime*: the event loop that drives admission,
//! preemption and phase scheduling over the incremental solver.
//!
//! [`FlowSim::run_admitted`] is the same simulation the old monolithic
//! `sim/flow.rs` ran — arrivals, a priority-ordered wait queue with aging,
//! byte-ledger admission, checkpoint preemption, overflow shedding —
//! rebuilt on two structures that keep host cost per event flat as
//! concurrency grows (DESIGN.md §Engine):
//!
//! * the event-scoped [`IncrementalSolver`]: a structural event re-solves
//!   only the connected component(s) of queries/resources whose rates can
//!   change, and re-anchors a query's progress only on a *bitwise* rate
//!   change;
//! * a lazy-deletion completion-time min-heap: each active phase has
//!   exactly one *fresh* entry `(completion_ns, qi, stamp)`; a rate change
//!   bumps the query's stamp and pushes a replacement, and stale entries
//!   are discarded on pop (with periodic compaction), so finding the next
//!   completion is O(log n) instead of a scan over every running query;
//! * the arrival-ordered wait cursor ([`WaitQueue`], private): per-class
//!   FIFO deques plus a lazy-deletion expiry heap replace the old linear
//!   scans over the waiting set (deadline expiry, best-class selection,
//!   overflow shedding), closing the first §Engine follow-up hot spot —
//!   an admission event no longer pays for the queue's length.
//!
//! Progress is anchored (see [`super::solver`]): nothing is decremented at
//! events, so a query whose component an event does not touch costs the
//! event *nothing* — its heap entry and rate stay exactly as scheduled.
//!
//! [`SolverMode::Dense`] re-solves every component at every event through
//! the same component solver; because commits are bitwise-gated, a Dense
//! run is bit-identical to an Incremental one (pinned by the equivalence
//! property test) while costing what the old engine cost — it exists as
//! the in-tree reference and the bench contrast arm.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::sim::counters::Counters;
use crate::sim::demand::PhaseDemand;
use crate::sim::ledger::ContextLedger;
use crate::sim::machine::Machine;
use crate::sim::preempt::Parker;
use crate::sim::trace::{NullSink, TraceEvent, TraceSink};

use super::report::{FlowReport, QueryTiming};
use super::solver::{ActivePhase, IncrementalSolver, UTIL_EPS};
use super::spec::{Admission, OnFull, Priority, QuerySpec, ShareWeights};

/// Which rate solver the engine runs (see the module doc).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverMode {
    /// Event-scoped re-solving (the default): only components whose user
    /// set changed are re-solved at each event.
    #[default]
    Incremental,
    /// Re-solve every component at every event through the same component
    /// solver. Bit-identical results to `Incremental` at the old engine's
    /// cost; kept as the equivalence reference and bench contrast arm.
    Dense,
}

/// The flow-level simulator.
#[derive(Debug, Clone)]
pub struct FlowSim {
    m: Machine,
    mode: SolverMode,
}

/// Completion-time key with a total order (`f64::total_cmp`), so heap
/// entries need no `partial_cmp().unwrap()` at every comparison.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Tc(f64);

impl Eq for Tc {}

impl PartialOrd for Tc {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Tc {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Arrival-ordered wait cursor (DESIGN.md §Engine). The old engine kept
/// one `Vec` of waiters and ran three linear scans over it at every
/// event (deadline expiry, best-effective-class selection, overflow
/// shedding) — the first hot spot the ROADMAP flags at high concurrency
/// under admission control. This replaces the scans with cursors:
///
/// * one FIFO deque per declared class, in enqueue (= arrival) order.
///   Arrival times are non-decreasing along enqueue order, so the
///   aging-promoted waiters of a class always form a *prefix* of its
///   deque — every selection the scans made is available at a deque end:
///   the best effective-Interactive waiter is the earliest-enqueued of
///   the qualifying fronts, the overflow victim is the back of the
///   worst declared-class deque;
/// * a lazy-deletion min-heap of `(expiry_ns, seq, qi)` for deadline
///   expiry: entries for waiters that already started (or were shed)
///   are skipped on pop, exactly like the completion heap's stamps.
///
/// Every mutation is O(log n) or amortized O(1), and an event that
/// touches no waiter no longer pays for the queue's length.
struct WaitQueue {
    /// `[Interactive, Standard, Batch]` FIFO lanes of `(seq, qi)`.
    classes: [VecDeque<(u64, usize)>; 3],
    /// Deadline expiry instants, lazily deleted against `is_waiting`.
    expiry: BinaryHeap<Reverse<(Tc, u64, usize)>>,
    /// Still queued? Cleared on start/shed; dead entries are pruned from
    /// the deque ends and skipped on expiry pops.
    is_waiting: Vec<bool>,
    seq: u64,
    live: usize,
}

fn class_idx(p: Priority) -> usize {
    match p {
        Priority::Interactive => 0,
        Priority::Standard => 1,
        Priority::Batch => 2,
    }
}

impl WaitQueue {
    fn new(n_queries: usize) -> Self {
        WaitQueue {
            classes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            expiry: BinaryHeap::new(),
            is_waiting: vec![false; n_queries],
            seq: 0,
            live: 0,
        }
    }

    /// Live waiter count (dead deque entries excluded).
    fn len(&self) -> usize {
        self.live
    }

    fn push(&mut self, qi: usize, declared: Priority, expiry_ns: Option<f64>) {
        self.seq += 1;
        self.classes[class_idx(declared)].push_back((self.seq, qi));
        if let Some(e) = expiry_ns {
            self.expiry.push(Reverse((Tc(e), self.seq, qi)));
        }
        self.is_waiting[qi] = true;
        self.live += 1;
    }

    /// Pop every waiter whose deadline expired by `t`, in enqueue order
    /// (the order the old linear scan shed them in). Entries for waiters
    /// that already left the queue are discarded on the way.
    fn take_expired(&mut self, t: f64) -> Vec<usize> {
        let mut due: Vec<(u64, usize)> = Vec::new();
        while let Some(&Reverse((Tc(e), seq, qi))) = self.expiry.peek() {
            if e > t {
                break;
            }
            self.expiry.pop();
            if self.is_waiting[qi] {
                self.is_waiting[qi] = false;
                self.live -= 1;
                due.push((seq, qi));
            }
        }
        due.sort_unstable();
        due.into_iter().map(|(_, qi)| qi).collect()
    }

    /// The waiter the admission drain would start next: the earliest-
    /// enqueued of the best effective class (aged Standard/Batch fronts
    /// compete as Interactive). Returns `(effective class, lane, qi)`.
    fn peek_best(
        &mut self,
        t: f64,
        age_promote_ns: f64,
        queries: &[QuerySpec],
    ) -> Option<(Priority, usize, usize)> {
        for c in 0..3 {
            while let Some(&(_, qi)) = self.classes[c].front() {
                if self.is_waiting[qi] {
                    break;
                }
                self.classes[c].pop_front();
            }
        }
        // Effective-Interactive candidates: the Interactive front plus
        // any aged Standard/Batch front (the aged waiters of a lane are
        // a prefix, so a lane's earliest aged waiter IS its front).
        let mut best: Option<(u64, usize, usize)> = None;
        for (c, d) in self.classes.iter().enumerate() {
            if let Some(&(seq, qi)) = d.front() {
                if c == 0 || t - queries[qi].arrival_ns >= age_promote_ns {
                    if best.is_none_or(|(bs, _, _)| seq < bs) {
                        best = Some((seq, c, qi));
                    }
                }
            }
        }
        if let Some((_, c, qi)) = best {
            return Some((Priority::Interactive, c, qi));
        }
        // No effective-Interactive waiter: the fronts are unaged, so
        // declared order decides.
        for (c, prio) in [(1, Priority::Standard), (2, Priority::Batch)] {
            if let Some(&(_, qi)) = self.classes[c].front() {
                return Some((prio, c, qi));
            }
        }
        None
    }

    /// Dequeue the front of lane `c` (the waiter `peek_best` returned).
    fn start_front(&mut self, c: usize) -> usize {
        let (_, qi) = self.classes[c].pop_front().expect("peek_best saw a live front");
        self.is_waiting[qi] = false;
        self.live -= 1;
        qi
    }

    /// Overflow victim: the newest entry of the worst declared class
    /// (Batch back, then Standard, then Interactive) — what the old
    /// `max_by_key` scan's last-maximal pick selected.
    fn shed_victim(&mut self) -> Option<usize> {
        for c in [2, 1, 0] {
            while let Some(&(_, qi)) = self.classes[c].back() {
                self.classes[c].pop_back();
                if self.is_waiting[qi] {
                    self.is_waiting[qi] = false;
                    self.live -= 1;
                    return Some(qi);
                }
            }
        }
        None
    }
}

impl FlowSim {
    /// An engine over machine `m` with the default (incremental) solver.
    pub fn new(m: Machine) -> Self {
        FlowSim { m, mode: SolverMode::default() }
    }

    /// The machine this engine simulates.
    pub fn machine(&self) -> &Machine {
        &self.m
    }

    /// Select the rate-solver mode (testing/benchmarking knob; results are
    /// bit-identical between modes).
    pub fn with_solver_mode(mut self, mode: SolverMode) -> Self {
        self.mode = mode;
        self
    }

    /// Run all queries concurrently (respecting arrival times), without
    /// admission control.
    pub fn run(&self, queries: &[QuerySpec]) -> FlowReport {
        self.run_admitted(queries, Admission::unlimited())
    }

    /// [`Self::run`] with a [`TraceSink`] (see
    /// [`Self::run_admitted_traced`]).
    pub fn run_traced<S: TraceSink>(&self, queries: &[QuerySpec], sink: &mut S) -> FlowReport {
        self.run_admitted_traced(queries, Admission::unlimited(), sink)
    }

    /// Run with an admission policy: arrivals beyond `max_in_flight`
    /// concurrent queries or the context byte budget are queued, shed or
    /// rejected per `on_full`. The wait queue is priority-ordered with
    /// aging (see [`Admission`]); the head of the queue blocks lower
    /// classes even when they would fit — strict ordering, so a fat
    /// high-priority query is never starved by a stream of thin ones.
    ///
    /// Running queries share saturated resources by *weighted* max-min
    /// ([`Admission::weights`]; flat weights = plain max-min), and with
    /// [`Admission::preempt`] set, running Batch-class work is parked at
    /// phase boundaries (context bytes released, completed phases kept)
    /// when a blocked Interactive waiter needs its reservation, then
    /// resumed once the pressure clears.
    pub fn run_admitted(&self, queries: &[QuerySpec], adm: Admission) -> FlowReport {
        self.run_admitted_traced(queries, adm, &mut NullSink)
    }

    /// [`Self::run_admitted`] with a [`TraceSink`] receiving every
    /// scheduling event (arrival, admit, queue-enter, shed, reject,
    /// park/resume, phase start/end, solver re-anchor and flood extent)
    /// stamped with its simulated time.
    ///
    /// Tracing is **observation only**: sinks receive copies of state the
    /// loop already computed and every emission is gated on
    /// `S::ENABLED`, so the [`NullSink`] instantiation (what
    /// [`Self::run_admitted`] delegates to) compiles to the untraced
    /// loop and a traced run's [`FlowReport`] is bit-identical to the
    /// untraced one (pinned in `tests/prop_tests.rs`).
    pub fn run_admitted_traced<S: TraceSink>(
        &self,
        queries: &[QuerySpec],
        adm: Admission,
        sink: &mut S,
    ) -> FlowReport {
        adm.weights.validate().expect("invalid fair-share weights");
        let weights = adm.weights;
        let dense = self.mode == SolverMode::Dense;
        let mut parker: Option<Parker> = adm.preempt.map(|p| Parker::new(p, queries.len()));
        let nodes = self.m.nodes();
        let n_res = nodes * (self.m.cfg.channels_per_node + 4);
        let mut counters = Counters::new(nodes);

        // Arrival ordering (stable by input order for equal times).
        let mut order: Vec<usize> = (0..queries.len()).collect();
        order.sort_by(|&a, &b| {
            queries[a]
                .arrival_ns
                .partial_cmp(&queries[b].arrival_ns)
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut next_arrival = 0usize;

        let mut timings: Vec<Option<QueryTiming>> = vec![None; queries.len()];
        let mut solver = IncrementalSolver::new(n_res, queries.len());
        // Lazy-deletion completion heap: exactly one *fresh* entry per
        // active phase — the one whose stamp matches `stamps[qi]`. A rate
        // change bumps the stamp and pushes a replacement; stale entries
        // are dropped on pop and bulk-pruned by the compaction below.
        let mut heap: BinaryHeap<Reverse<(Tc, usize, u64)>> = BinaryHeap::new();
        let mut stamps: Vec<u64> = vec![0; queries.len()];
        // Query indices whose rate the last solve changed (solver-owned
        // scratch would borrow-lock the solver; the runtime owns it).
        let mut changed: Vec<usize> = Vec::new();
        // Wait queue as per-class arrival-ordered cursors (see
        // [`WaitQueue`]): FIFO within a class, best effective class at
        // the qualifying fronts, no linear scans.
        let mut waiting = WaitQueue::new(queries.len());
        let mut rejected: Vec<usize> = Vec::new();
        let mut shed: Vec<usize> = Vec::new();
        let mut in_flight = 0usize;
        // The byte ledger this run admits against: every started query
        // reserves its ctx_bytes until completion.
        let mut ledger = match adm.ctx_capacity_bytes {
            Some(cap_bytes) => ContextLedger::with_capacity_bytes(cap_bytes, 1),
            None => ContextLedger::unlimited(),
        };
        let cap = adm.max_in_flight.unwrap_or(usize::MAX);
        let mut t = 0.0f64;
        let mut peak = 0usize;
        let mut rates_dirty = true;
        // Scheduling events processed (query starts, phase completions,
        // parks, resumes) — the denominator of the host_ns_per_event
        // bench axis.
        let mut events = 0usize;

        // Register a freshly-entered phase with the solver and schedule
        // its completion (at rate 1.0 until the next solve says
        // otherwise).
        macro_rules! schedule_phase {
            ($ap:expr) => {{
                let ap = $ap;
                let qi = ap.qi;
                let tc = Tc(ap.completion_ns());
                if S::ENABLED {
                    let p = &queries[qi].phases[ap.phase_idx];
                    sink.emit(TraceEvent::PhaseStart {
                        t_ns: t,
                        id: queries[qi].id,
                        phase: ap.phase_idx,
                        solo_ns: ap.solo_ns,
                        node_offset: p.node_offset,
                        node_len: p.nodes(),
                        util_sum: ap.util.iter().map(|&(_, u)| u).sum(),
                    });
                }
                solver.insert(ap);
                stamps[qi] += 1;
                heap.push(Reverse((tc, qi, stamps[qi])));
            }};
        }

        // Start query qi at time t (caller checked `in_flight < cap` and
        // `ledger.would_fit`); `admitted_as` is the class it won its slot
        // under (declared, or Interactive when aging promoted it).
        macro_rules! start_query {
            ($qi:expr, $admitted_as:expr) => {{
                let qi = $qi;
                let q = &queries[qi];
                in_flight += 1;
                events += 1;
                ledger.admit(qi, q.ctx_bytes).expect("caller checked would_fit");
                if S::ENABLED {
                    sink.emit(TraceEvent::Admit {
                        t_ns: t,
                        id: q.id,
                        class: q.priority,
                        admitted_as: $admitted_as,
                        wait_ns: t - q.arrival_ns,
                        ctx_bytes: q.ctx_bytes,
                    });
                }
                timings[qi] = Some(QueryTiming {
                    id: q.id,
                    label: q.label,
                    arrival_ns: q.arrival_ns,
                    start_ns: t,
                    finish_ns: f64::NAN,
                    phases: q.phases.len(),
                    priority: q.priority,
                    admitted_as: $admitted_as,
                });
                let w = weights.of(q.priority);
                if let Some(ap) = self.enter_phase(qi, 0, q, w, t, &mut counters) {
                    schedule_phase!(ap);
                } else {
                    // Query with no phases (or all-empty phases): finishes
                    // instantly.
                    timings[qi].as_mut().unwrap().finish_ns = t;
                    in_flight -= 1;
                    ledger.release(qi);
                    if S::ENABLED {
                        sink.emit(TraceEvent::Finish { t_ns: t, id: q.id, ctx_bytes: q.ctx_bytes });
                    }
                }
                rates_dirty = true;
            }};
        }

        // Record a query that will never run (NaN start/finish; the spec's
        // phase count is reported as-declared).
        macro_rules! drop_query {
            ($qi:expr, $sink:ident) => {{
                let qi = $qi;
                let q = &queries[qi];
                timings[qi] = Some(QueryTiming {
                    id: q.id,
                    label: q.label,
                    arrival_ns: q.arrival_ns,
                    start_ns: f64::NAN,
                    finish_ns: f64::NAN,
                    phases: q.phases.len(),
                    priority: q.priority,
                    admitted_as: q.priority,
                });
                $sink.push(q.id);
            }};
        }

        loop {
            // Take every arrival due by `t`. Under a queueing policy the
            // arrival always goes through the wait queue so that the
            // priority order — not submission order — decides who starts
            // when several arrivals land on the same event.
            while next_arrival < order.len() && queries[order[next_arrival]].arrival_ns <= t {
                let qi = order[next_arrival];
                next_arrival += 1;
                let q = &queries[qi];
                if S::ENABLED {
                    sink.emit(TraceEvent::Arrival {
                        t_ns: q.arrival_ns,
                        id: q.id,
                        label: q.label,
                        class: q.priority,
                    });
                }
                if ledger.check_admissible(q.ctx_bytes).is_err() {
                    // Larger than the whole budget: could never run. The
                    // coordinator pre-checks and raises a typed
                    // ContextExhausted; at the engine level it degrades to
                    // a recorded rejection instead of an eternal wait.
                    if S::ENABLED {
                        sink.emit(TraceEvent::Reject {
                            t_ns: q.arrival_ns,
                            id: q.id,
                            class: q.priority,
                            oversized: true,
                        });
                    }
                    drop_query!(qi, rejected);
                    continue;
                }
                match adm.on_full {
                    OnFull::Reject => {
                        if in_flight < cap && ledger.would_fit(q.ctx_bytes) {
                            start_query!(qi, q.priority);
                        } else {
                            if S::ENABLED {
                                sink.emit(TraceEvent::Reject {
                                    t_ns: q.arrival_ns,
                                    id: q.id,
                                    class: q.priority,
                                    oversized: false,
                                });
                            }
                            drop_query!(qi, rejected);
                        }
                    }
                    OnFull::Queue | OnFull::Shed { .. } => {
                        waiting.push(qi, q.priority, q.deadline_ns.map(|d| q.arrival_ns + d));
                        if S::ENABLED {
                            sink.emit(TraceEvent::QueueEnter {
                                t_ns: q.arrival_ns,
                                id: q.id,
                                class: q.priority,
                                waiting: waiting.len(),
                            });
                        }
                    }
                }
            }

            // Shed queued queries whose deadline already expired: running
            // them is wasted work.
            for qi in waiting.take_expired(t) {
                if S::ENABLED {
                    let q = &queries[qi];
                    sink.emit(TraceEvent::Shed {
                        t_ns: t,
                        id: q.id,
                        class: q.priority,
                        expired: true,
                    });
                }
                drop_query!(qi, shed);
            }

            // Drain the wait queue in priority order: best effective class
            // first (aging promotes long waiters to the front class), FIFO
            // within a class. Strict head-of-queue blocking: if the best
            // waiter does not fit, nothing behind it starts.
            while let Some((eff, lane, qi)) = waiting.peek_best(t, adm.age_promote_ns, queries) {
                if in_flight < cap && ledger.would_fit(queries[qi].ctx_bytes) {
                    waiting.start_front(lane);
                    start_query!(qi, eff);
                } else {
                    break;
                }
            }

            // Checkpoint preemption (see [`crate::sim::preempt`]): under
            // Interactive pressure, mark running victim-class queries to
            // park at their next phase boundary; with the pressure gone,
            // resume parked work FIFO. Marks are recomputed from scratch
            // at every event, so stale pressure never leaves a mark.
            if let Some(pk) = parker.as_mut() {
                pk.unmark_all();
                // The best blocked waiter (the drain above started every
                // waiter that fits, in priority order, until one did not).
                let blocked = waiting
                    .peek_best(t, adm.age_promote_ns, queries)
                    .map(|(eff, _, qi)| (eff, qi));
                match blocked {
                    // The trigger keys on the *declared* class: an
                    // aging-promoted Batch waiter competes as Interactive
                    // for queue order, but parking running Batch work to
                    // admit other Batch work would be pure churn.
                    Some((Priority::Interactive, head_qi))
                        if queries[head_qi].priority == Priority::Interactive =>
                    {
                        // Park the victims that reach a checkpoint soonest,
                        // just enough of them to cover the head waiter's
                        // reservation (bytes and, under a count cap, one
                        // slot). If the preemptible set cannot cover it at
                        // all, park nothing — churn would not help.
                        let head = &queries[head_qi];
                        let free = ledger.capacity_bytes().saturating_sub(ledger.in_use_bytes());
                        let needed_bytes = head.ctx_bytes.saturating_sub(free);
                        let needed_slots = usize::from(in_flight >= cap);
                        let mut cands: Vec<(f64, usize, u64)> = solver
                            .iter_active()
                            .filter(|ap| pk.can_mark(ap.qi, queries[ap.qi].priority))
                            .map(|ap| {
                                let boundary_ns = ap.remaining_at(t) * ap.solo_ns / ap.rate;
                                (boundary_ns, ap.qi, queries[ap.qi].ctx_bytes)
                            })
                            .collect();
                        let coverable = cands.iter().map(|c| c.2).sum::<u64>() >= needed_bytes
                            && cands.len() >= needed_slots;
                        if coverable && (needed_bytes > 0 || needed_slots > 0) {
                            cands.sort_by(|a, b| {
                                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
                            });
                            let (mut freed_bytes, mut freed_slots) = (0u64, 0usize);
                            for (_, qi, bytes) in cands {
                                if freed_bytes >= needed_bytes && freed_slots >= needed_slots {
                                    break;
                                }
                                pk.mark(qi);
                                freed_bytes = freed_bytes.saturating_add(bytes);
                                freed_slots += 1;
                            }
                        }
                    }
                    _ => {
                        // Resume parked work FIFO while it fits, never
                        // bypassing a blocked waiter of a better class
                        // (a blocked equal-class waiter defers to parked
                        // work, which already holds partial progress).
                        let blocked_class = blocked.map(|(c, _)| c);
                        while let Some((qi, next_phase)) = pk.peek_parked() {
                            let q = &queries[qi];
                            if blocked_class.is_some_and(|c| c < q.priority)
                                || in_flight >= cap
                                || !ledger.would_fit(q.ctx_bytes)
                            {
                                break;
                            }
                            pk.resume_front();
                            in_flight += 1;
                            events += 1;
                            ledger.admit(qi, q.ctx_bytes).expect("checked would_fit");
                            if S::ENABLED {
                                sink.emit(TraceEvent::Resume {
                                    t_ns: t,
                                    id: q.id,
                                    phase: next_phase,
                                    ctx_bytes: q.ctx_bytes,
                                });
                            }
                            let w = weights.of(q.priority);
                            match self.enter_phase(qi, next_phase, q, w, t, &mut counters) {
                                Some(ap) => schedule_phase!(ap),
                                None => {
                                    // Only zero-solo phases remained past
                                    // the checkpoint: the query is done.
                                    timings[qi].as_mut().unwrap().finish_ns = t;
                                    in_flight -= 1;
                                    ledger.release(qi);
                                    if S::ENABLED {
                                        sink.emit(TraceEvent::Finish {
                                            t_ns: t,
                                            id: q.id,
                                            ctx_bytes: q.ctx_bytes,
                                        });
                                    }
                                }
                            }
                            rates_dirty = true;
                        }
                    }
                }
            }

            // Overflow shedding: bound the standing queue, dropping the
            // newest entry of the lowest class first (Batch before
            // Standard before Interactive — base class, not the aged one:
            // a promoted Batch waiter is still the first shedding victim).
            if let OnFull::Shed { max_waiting } = adm.on_full {
                while waiting.len() > max_waiting {
                    let qi = waiting.shed_victim().expect("non-empty: len > max_waiting");
                    if S::ENABLED {
                        let q = &queries[qi];
                        sink.emit(TraceEvent::Shed {
                            t_ns: t,
                            id: q.id,
                            class: q.priority,
                            expired: false,
                        });
                    }
                    drop_query!(qi, shed);
                }
            }
            peak = peak.max(solver.active_count());

            if solver.active_count() == 0 {
                match order.get(next_arrival) {
                    Some(&qi) => {
                        // Idle gap until the next arrival.
                        t = queries[qi].arrival_ns;
                        continue;
                    }
                    None => break,
                }
            }

            if rates_dirty {
                solver.solve_event_traced(t, dense, &mut changed, sink);
                // Re-schedule the completions the solve moved: bump the
                // stamp (staling the old heap entry) and push the new one.
                for &qi in &changed {
                    stamps[qi] += 1;
                    heap.push(Reverse((Tc(solver.slot(qi).completion_ns()), qi, stamps[qi])));
                    if S::ENABLED {
                        sink.emit(TraceEvent::ReAnchor {
                            t_ns: t,
                            id: queries[qi].id,
                            rate: solver.slot(qi).rate,
                        });
                    }
                }
                rates_dirty = false;
            }

            // Earliest phase completion under current rates: the heap's
            // first fresh entry (stale entries are popped on the way).
            let t_done = loop {
                match heap.peek() {
                    Some(&Reverse((Tc(tc), qi, stamp))) => {
                        if stamp == stamps[qi] {
                            break tc;
                        }
                        heap.pop();
                    }
                    None => break f64::INFINITY,
                }
            };
            // Next arrival, if sooner.
            let t_arrive = order
                .get(next_arrival)
                .map(|&qi| queries[qi].arrival_ns)
                .unwrap_or(f64::INFINITY);
            t = t_done.min(t_arrive).max(t);

            // Retire completed phases; advance or finish their queries.
            // Progress is anchored, so nothing needs stepping — a phase is
            // due exactly when its scheduled completion is reached. The
            // epsilon is RELATIVE to the clock: at large t, a phase whose
            // residual time is below f64 resolution of t can never advance
            // the clock (t + dt == t) and must be retired now or the loop
            // would spin forever. A phase entered *during* this loop with a
            // near-zero solo time lands back on the heap top and retires in
            // the same pass (the old engine's same-event cascade).
            let eps_ns = 1e-9f64.max(t * 1e-12);
            loop {
                let Some(&Reverse((Tc(tc), qi, stamp))) = heap.peek() else { break };
                if stamp != stamps[qi] {
                    heap.pop();
                    continue;
                }
                if tc > t + eps_ns {
                    break;
                }
                heap.pop();
                events += 1;
                let ap = solver.remove(qi);
                let q = &queries[qi];
                if S::ENABLED {
                    sink.emit(TraceEvent::PhaseEnd { t_ns: t, id: q.id, phase: ap.phase_idx });
                }
                let next_phase = ap.phase_idx + 1;
                let draining = parker.as_ref().is_some_and(|p| p.is_draining(qi));
                if draining
                    && next_phase < q.phases.len()
                    && q.phases[next_phase..].iter().any(|p| p.solo_ns(&self.m) > 0.0)
                {
                    // Checkpoint: keep the completed phase prefix,
                    // release the context reservation, park until the
                    // Interactive pressure clears. A query with only
                    // zero-solo phases left finishes instead — parking
                    // it would just delay its recorded completion.
                    parker.as_mut().unwrap().park(qi, next_phase);
                    in_flight -= 1;
                    events += 1;
                    ledger.release(qi);
                    if S::ENABLED {
                        sink.emit(TraceEvent::Park {
                            t_ns: t,
                            id: q.id,
                            next_phase,
                            ctx_bytes: q.ctx_bytes,
                        });
                    }
                } else {
                    match self.enter_phase(qi, next_phase, q, ap.weight, t, &mut counters) {
                        Some(next) => schedule_phase!(next),
                        None => {
                            timings[qi].as_mut().unwrap().finish_ns = t;
                            in_flight -= 1;
                            ledger.release(qi);
                            if let Some(p) = parker.as_mut() {
                                p.finish(qi);
                            }
                            if S::ENABLED {
                                sink.emit(TraceEvent::Finish {
                                    t_ns: t,
                                    id: q.id,
                                    ctx_bytes: q.ctx_bytes,
                                });
                            }
                        }
                    }
                }
                rates_dirty = true;
            }
            // Bulk-prune stale heap entries once they dominate: keeps the
            // heap O(active) without paying a scan at every event.
            if heap.len() > 64 + 4 * solver.active_count() {
                heap.retain(|&Reverse((_, qi, stamp))| stamp == stamps[qi]);
            }
        }

        counters.elapsed_ns = t;
        let (preempted, parks, resumes) = match &parker {
            Some(p) => {
                debug_assert_eq!(p.parked_len(), 0, "run finished with queries still parked");
                let ids = (0..queries.len())
                    .filter(|&qi| p.was_parked(qi))
                    .map(|qi| queries[qi].id)
                    .collect();
                (ids, p.parks(), p.resumes())
            }
            None => (Vec::new(), 0, 0),
        };
        FlowReport {
            timings: timings.into_iter().map(|x| x.expect("query never admitted")).collect(),
            makespan_ns: t,
            counters,
            peak_concurrency: peak,
            rejected,
            shed,
            peak_ctx_bytes: ledger.peak_bytes(),
            preempted,
            parks,
            resumes,
            weights,
            events,
        }
    }

    /// Run the same queries strictly one after the other (the paper's
    /// "sequential" arm). Exact under the fluid model: a lone query always
    /// runs at rate 1.0, so this is a direct sum of solo times.
    pub fn run_sequential(&self, queries: &[QuerySpec]) -> FlowReport {
        self.run_sequential_traced(queries, &mut NullSink)
    }

    /// [`Self::run_sequential`] with a [`TraceSink`]: one
    /// arrival/admit/finish triple per query plus a phase start/end pair
    /// per declared phase, same observation-only contract as
    /// [`Self::run_admitted_traced`].
    pub fn run_sequential_traced<S: TraceSink>(
        &self,
        queries: &[QuerySpec],
        sink: &mut S,
    ) -> FlowReport {
        let nodes = self.m.nodes();
        let mut counters = Counters::new(nodes);
        let mut t = 0.0f64;
        let mut timings = Vec::with_capacity(queries.len());
        let mut events = 0usize;
        for q in queries {
            t = t.max(q.arrival_ns);
            let start = t;
            events += 1 + q.phases.len();
            if S::ENABLED {
                sink.emit(TraceEvent::Arrival {
                    t_ns: q.arrival_ns,
                    id: q.id,
                    label: q.label,
                    class: q.priority,
                });
                sink.emit(TraceEvent::Admit {
                    t_ns: start,
                    id: q.id,
                    class: q.priority,
                    admitted_as: q.priority,
                    wait_ns: start - q.arrival_ns,
                    ctx_bytes: q.ctx_bytes,
                });
            }
            for (pi, p) in q.phases.iter().enumerate() {
                charge_counters(&mut counters, p);
                let solo = p.solo_ns(&self.m);
                if S::ENABLED {
                    sink.emit(TraceEvent::PhaseStart {
                        t_ns: t,
                        id: q.id,
                        phase: pi,
                        solo_ns: solo,
                        node_offset: p.node_offset,
                        node_len: p.nodes(),
                        // Zero-solo phases never enter the allocator, so
                        // their fractional demand is reported as zero.
                        util_sum: if solo > 0.0 {
                            p.flow_resources(&self.m, solo).iter().map(|&(_, u)| u).sum()
                        } else {
                            0.0
                        },
                    });
                }
                t += solo;
                if S::ENABLED {
                    sink.emit(TraceEvent::PhaseEnd { t_ns: t, id: q.id, phase: pi });
                }
            }
            if S::ENABLED {
                sink.emit(TraceEvent::Finish { t_ns: t, id: q.id, ctx_bytes: q.ctx_bytes });
            }
            timings.push(QueryTiming {
                id: q.id,
                label: q.label,
                arrival_ns: q.arrival_ns,
                start_ns: start,
                finish_ns: t,
                phases: q.phases.len(),
                priority: q.priority,
                admitted_as: q.priority,
            });
        }
        counters.elapsed_ns = t;
        FlowReport {
            timings,
            makespan_ns: t,
            counters,
            peak_concurrency: usize::from(!queries.is_empty()),
            rejected: Vec::new(),
            shed: Vec::new(),
            // One query at a time: the peak reservation is the fattest
            // single query.
            peak_ctx_bytes: queries.iter().map(|q| q.ctx_bytes).max().unwrap_or(0),
            preempted: Vec::new(),
            parks: 0,
            resumes: 0,
            weights: ShareWeights::flat(),
            events,
        }
    }

    /// Build the allocator state for phase `phase_idx` of query `qi` at
    /// time `t`, charging its demand to the counters. Skips zero-solo
    /// phases. Returns None when the query has no further phases. `weight`
    /// is the query's fair-share weight (1.0 under flat weights).
    fn enter_phase(
        &self,
        qi: usize,
        mut phase_idx: usize,
        q: &QuerySpec,
        weight: f64,
        t: f64,
        counters: &mut Counters,
    ) -> Option<ActivePhase> {
        while phase_idx < q.phases.len() {
            let p = &q.phases[phase_idx];
            charge_counters(counters, p);
            let solo = p.solo_ns(&self.m);
            if solo > 0.0 {
                let mut util = p.flow_resources(&self.m, solo);
                util.retain(|&(_, u)| u > UTIL_EPS);
                return Some(ActivePhase {
                    qi,
                    phase_idx,
                    solo_ns: solo,
                    util,
                    weight,
                    rate: 1.0,
                    anchor_ns: t,
                    remaining_at_anchor: 1.0,
                });
            }
            phase_idx += 1;
        }
        None
    }
}

fn charge_counters(c: &mut Counters, p: &PhaseDemand) {
    let off = p.node_offset;
    for n in 0..p.nodes() {
        c.channel_ops[off + n] += p.channel_ops[n];
        c.stream_bytes[off + n] += p.stream_bytes[n];
        c.instructions[off + n] += p.instructions[n];
        c.fabric_bytes[off + n] += p.fabric_bytes[n];
        c.migrations[off + n] += p.migrations[n];
        c.msp_ops[off + n] += p.msp_ops[n];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::sim::preempt::PreemptPolicy;

    fn m8() -> Machine {
        Machine::new(MachineConfig::pathfinder_8())
    }

    /// A latency-bound phase lasting ~`total_ns` solo while consuming only
    /// `frac` of every node's channel capacity — the structural shape of a
    /// single Pathfinder query (the paper's concurrency headroom). Shared
    /// with the bench gate via [`PhaseDemand::uniform_channel_load`].
    fn uniform_phase(m: &Machine, frac: f64, total_ns: f64) -> PhaseDemand {
        PhaseDemand::uniform_channel_load(m, frac, total_ns)
    }

    fn query(m: &Machine, id: usize, frac: f64, total_ns: f64) -> QuerySpec {
        QuerySpec::new(id, "test", vec![uniform_phase(m, frac, total_ns)], 0.0)
    }

    #[test]
    fn single_query_runs_at_solo_speed() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let q = query(&m, 0, 0.4, 1e6);
        let solo = q.solo_ns(&m);
        // The helper really is latency-bound: solo ~= total_ns + sync.
        assert!((solo - (1e6 + m.cfg.level_sync_ns)).abs() < 2.0);
        let rep = sim.run(std::slice::from_ref(&q));
        assert!((rep.makespan_ns - solo).abs() / solo < 1e-9);
        assert_eq!(rep.peak_concurrency, 1);
    }

    #[test]
    fn sequential_is_sum_of_solos() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.3, 1e6)).collect();
        let solo: f64 = qs.iter().map(|q| q.solo_ns(&m)).sum();
        let rep = sim.run_sequential(&qs);
        assert!((rep.makespan_ns - solo).abs() / solo < 1e-9);
    }

    #[test]
    fn low_utilization_queries_overlap_fully() {
        // Two queries each using 30% of the channels: both should run at
        // solo speed concurrently (makespan == one solo time) because
        // their aggregate demand stays under every capacity.
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..2).map(|i| query(&m, i, 0.3, 1e6)).collect();
        let solo = qs[0].solo_ns(&m);
        let rep = sim.run(&qs);
        assert!((rep.makespan_ns - solo).abs() / solo < 1e-6, "{}", rep.makespan_ns);
    }

    #[test]
    fn saturation_shares_fairly() {
        // Four queries each utilizing ~50% of the channels solo: the
        // channels saturate, so the makespan is total channel work over
        // machine capacity (= 4 x 0.5 x total_ns of drain).
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.5, 1e6)).collect();
        let rep = sim.run(&qs);
        let expect = 4.0 * 0.5 * 1e6;
        assert!(
            (rep.makespan_ns - expect).abs() / expect < 0.05,
            "makespan {} expect {}",
            rep.makespan_ns,
            expect
        );
        // And it beats running them back to back.
        let seq = sim.run_sequential(&qs).makespan_ns;
        assert!(rep.makespan_ns < 0.55 * seq);
    }

    #[test]
    fn concurrent_never_slower_than_sequential() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        for frac in [0.1, 0.5, 0.9] {
            let qs: Vec<_> = (0..8).map(|i| query(&m, i, frac, 1e6)).collect();
            let conc = sim.run(&qs).makespan_ns;
            let seq = sim.run_sequential(&qs).makespan_ns;
            assert!(conc <= seq * (1.0 + 1e-9), "frac {frac}: conc {conc} seq {seq}");
        }
    }

    #[test]
    fn concurrent_not_faster_than_capacity_bound() {
        // Makespan can never beat total-channel-work / machine-capacity.
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..16).map(|i| query(&m, i, 0.7, 1e6)).collect();
        let rep = sim.run(&qs);
        let total_ops: f64 = qs
            .iter()
            .flat_map(|q| &q.phases)
            .map(|p| p.total_channel_ops())
            .sum();
        let bound = total_ops / m.total_channel_op_rate() * 1e9;
        assert!(rep.makespan_ns >= bound * (1.0 - 1e-9));
    }

    #[test]
    fn arrivals_respected() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut q0 = query(&m, 0, 0.2, 1e6);
        let mut q1 = query(&m, 1, 0.2, 1e6);
        q0.arrival_ns = 0.0;
        q1.arrival_ns = 5e8; // arrives long after q0 finished
        let solo = q0.solo_ns(&m);
        let rep = sim.run(&[q0, q1]);
        assert!((rep.timings[0].finish_ns - solo).abs() / solo < 1e-9);
        assert!((rep.timings[1].start_ns - 5e8).abs() < 1.0);
        assert!((rep.makespan_ns - (5e8 + solo)).abs() / solo < 1e-6);
        assert_eq!(rep.peak_concurrency, 1);
    }

    #[test]
    fn counters_accumulate_all_phases() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.4, 1e6)).collect();
        let rep = sim.run(&qs);
        let expect: f64 = qs
            .iter()
            .flat_map(|q| &q.phases)
            .map(|p| p.total_channel_ops())
            .sum();
        assert!((rep.counters.totals().channel_ops - expect).abs() < 1e-6);
    }

    #[test]
    fn empty_query_finishes_at_arrival() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let q = QuerySpec::new(7, "nop", vec![], 3.0);
        let rep = sim.run(&[q]);
        assert_eq!(rep.timings[0].finish_ns, 3.0);
        assert_eq!(rep.timings[0].latency_ns(), 0.0);
    }

    /// The events counter is the host-cost denominator: one event per
    /// query start plus one per phase completion (plus parks/resumes),
    /// and `run_sequential` reports the same accounting.
    #[test]
    fn events_counter_tracks_starts_and_phase_completions() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.3, 1e6)).collect();
        assert_eq!(sim.run(&qs).events, 6, "3 starts + 3 phase completions");
        assert_eq!(sim.run_sequential(&qs).events, 6);
    }

    #[test]
    fn admission_reject_over_cap() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.1, 1e6)).collect();
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Reject));
        assert_eq!(rep.rejected, vec![2, 3]);
        assert!(rep.shed.is_empty());
        assert!(rep.timings[2].finish_ns.is_nan());
        assert!(rep.timings[0].finish_ns.is_finite());
        assert!(rep.peak_concurrency <= 2);
    }

    #[test]
    fn admission_queue_serializes_excess() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.1, 1e6)).collect();
        let solo = qs[0].solo_ns(&m);
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Queue));
        assert!(rep.rejected.is_empty());
        // Two waves of two fully-overlapping queries.
        assert!((rep.makespan_ns - 2.0 * solo).abs() / solo < 1e-6);
        assert_eq!(rep.peak_concurrency, 2);
        // Queued queries' latency includes the wait.
        assert!(rep.timings[3].latency_ns() > rep.timings[0].latency_ns() * 1.5);
    }

    #[test]
    fn admission_cap_one_equals_sequential() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.5, 1e6)).collect();
        let capped = sim.run_admitted(&qs, Admission::capped(1, OnFull::Queue)).makespan_ns;
        let seq = sim.run_sequential(&qs).makespan_ns;
        assert!((capped - seq).abs() / seq < 1e-9);
    }

    /// Regression (NaN-stats bugfix): rejected queries carry NaN timings;
    /// the report's mean and latency list must filter them, not return
    /// NaN.
    #[test]
    fn rejected_timings_do_not_poison_latency_stats() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..4).map(|i| query(&m, i, 0.1, 1e6)).collect();
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Reject));
        assert_eq!(rep.rejected.len(), 2);
        let mean = rep.mean_latency_s().expect("two queries completed");
        assert!(mean.is_finite());
        assert!(mean > 0.0);
        let lats = rep.latencies_s();
        assert_eq!(lats.len(), 2, "only completed queries have latencies");
        assert!(lats.iter().all(|l| l.is_finite()));
    }

    /// Regression: a rejected query reports the phase count it *would*
    /// have run (uniform with queued-then-run queries), not 0.
    #[test]
    fn rejected_timings_carry_spec_phase_count() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs: Vec<_> = (0..3).map(|i| query(&m, i, 0.1, 1e6)).collect();
        qs[2].phases = vec![uniform_phase(&m, 0.1, 1e6), uniform_phase(&m, 0.1, 1e6)];
        let rep = sim.run_admitted(&qs, Admission::capped(2, OnFull::Reject));
        assert_eq!(rep.rejected, vec![2]);
        assert_eq!(rep.timings[2].phases, 2);
        assert!(rep.timings[2].start_ns.is_nan(), "never started");
        assert!(!rep.timings[2].completed());
    }

    /// The wait queue is priority-ordered: with one slot busy, a later-
    /// arriving Interactive query starts before an earlier-queued Batch
    /// one, and Standard before Batch.
    #[test]
    fn wait_queue_orders_by_priority_class() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let running = query(&m, 0, 0.5, 1e6);
        let batch = query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch);
        let mut standard = query(&m, 2, 0.5, 1e5);
        standard.arrival_ns = 1e3;
        let mut interactive = query(&m, 3, 0.5, 1e5).with_priority(Priority::Interactive);
        interactive.arrival_ns = 2e3;
        let qs = vec![running, batch, standard, interactive];
        let adm = Admission::capped(1, OnFull::Queue).with_age_promote_ns(f64::INFINITY);
        let rep = sim.run_admitted(&qs, adm);
        // All queued behind query 0; start order: interactive, standard,
        // batch — the reverse of arrival order.
        assert!(rep.timings[3].start_ns < rep.timings[2].start_ns);
        assert!(rep.timings[2].start_ns < rep.timings[1].start_ns);
        assert!(rep.rejected.is_empty() && rep.shed.is_empty());
    }

    /// Aging promotes a long-waiting Batch query: with a small
    /// `age_promote_ns`, Batch work overtakes Interactive arrivals that
    /// have not yet aged.
    #[test]
    fn aging_prevents_batch_starvation() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs = vec![
            query(&m, 0, 0.5, 1e6),
            query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch),
        ];
        // A stream of Interactive arrivals that would starve Batch under
        // strict priority.
        for i in 0..6 {
            let mut q = query(&m, 2 + i, 0.5, 1e5).with_priority(Priority::Interactive);
            q.arrival_ns = 1e3 * (i as f64 + 1.0);
            qs.push(q);
        }
        let strict = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(f64::INFINITY),
        );
        // Strict: batch goes last.
        assert!(qs[2..]
            .iter()
            .all(|q| strict.timings[q.id].start_ns < strict.timings[1].start_ns));
        // Aged: after waiting 2e5 ns the batch query competes as
        // Interactive with the earliest enqueue order, so it beats the
        // still-waiting interactive stream.
        let aged = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(2e5),
        );
        let later_interactive_starts =
            qs[2..].iter().filter(|q| aged.timings[q.id].start_ns > aged.timings[1].start_ns);
        assert!(
            later_interactive_starts.count() > 0,
            "aged batch must overtake part of the interactive stream"
        );
        // And the wait of the batch query is bounded near the promotion
        // age plus one in-flight query.
        let batch_wait = aged.timings[1].start_ns - qs[1].arrival_ns;
        assert!(batch_wait < 2e5 + 2.0 * 1e6, "batch waited {batch_wait} ns");
    }

    /// The `age_promote_ns` threshold is INCLUSIVE: a waiter admitted at
    /// exactly its promotion age is promoted; one admitted any earlier is
    /// not. Pinned by replaying the same scenario with the threshold set
    /// to the observed wait (bit-identical across runs — the determinism
    /// guarantee is what makes this test well-posed).
    #[test]
    fn age_promote_boundary_exactly_at_threshold() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs = vec![
            query(&m, 0, 0.5, 1e6),
            query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch),
        ];
        // Observe the waiter's admission time with aging disabled.
        let base = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(f64::INFINITY),
        );
        let wait_ns = base.timings[1].start_ns; // arrival 0 → wait == start
        assert!(wait_ns > 0.0);
        assert_eq!(base.timings[1].admitted_as, Priority::Batch);
        // Threshold exactly at the observed wait: promoted (>= compare).
        let at = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(wait_ns),
        );
        assert_eq!(at.timings[1].admitted_as, Priority::Interactive);
        assert_eq!(at.timings[1].priority, Priority::Batch);
        // Threshold just above the observed wait: not promoted.
        let above = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(wait_ns * (1.0 + 1e-9)),
        );
        assert_eq!(above.timings[1].admitted_as, Priority::Batch);
        // The boundary does not move the schedule, only the accounting.
        assert_eq!(at.timings[1].start_ns.to_bits(), above.timings[1].start_ns.to_bits());
    }

    /// Byte-aware admission: in-flight context bytes never exceed the
    /// budget even when the query-count cap would allow more.
    #[test]
    fn byte_budget_bounds_in_flight_reservations() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs: Vec<_> = (0..6)
            .map(|i| query(&m, i, 0.1, 1e6).with_ctx_bytes(40))
            .collect();
        let rep = sim.run_admitted(&qs, Admission::byte_budget(100, OnFull::Queue));
        // 100 / 40 = at most 2 concurrently.
        assert_eq!(rep.peak_concurrency, 2);
        assert_eq!(rep.peak_ctx_bytes, 80, "ledger high-water mark surfaced");
        assert_eq!(rep.timings.iter().filter(|t| t.completed()).count(), 6);
    }

    /// A query whose own footprint exceeds the whole byte budget is
    /// rejected at arrival — even under Queue, where waiting would be
    /// eternal.
    #[test]
    fn oversized_query_rejected_not_queued_forever() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let qs = vec![
            query(&m, 0, 0.1, 1e6).with_ctx_bytes(50),
            query(&m, 1, 0.1, 1e6).with_ctx_bytes(1000),
        ];
        let rep = sim.run_admitted(&qs, Admission::byte_budget(100, OnFull::Queue));
        assert_eq!(rep.rejected, vec![1]);
        assert!(rep.timings[0].completed());
    }

    /// Lazy deletion in the wait cursor's expiry heap: a deadline that
    /// fires after its query already *started* must not shed it — only
    /// still-queued work expires.
    #[test]
    fn stale_expiry_entries_do_not_shed_started_queries() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let long = query(&m, 0, 0.5, 1e6);
        // Starts at ~1e6 ns (when query 0 finishes), deadline 1.05e6 ns:
        // the expiry instant passes while the query is RUNNING, and the
        // next event (its own completion) pops the stale entry.
        let started = query(&m, 1, 0.5, 1e5).with_deadline_ns(1.05e6);
        let rep = sim.run_admitted(&[long, started], Admission::capped(1, OnFull::Queue));
        assert!(rep.shed.is_empty(), "started work never expires: {:?}", rep.shed);
        assert!(rep.timings[1].completed());
        assert!(rep.timings[1].start_ns < 1.05e6);
    }

    /// A queued query whose deadline expires while waiting is shed, not
    /// run after the fact.
    #[test]
    fn expired_deadline_sheds_waiting_query() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let long = query(&m, 0, 0.5, 1e6);
        // Would have to wait ~1e6 ns; its deadline is far shorter.
        let doomed = query(&m, 1, 0.5, 1e5).with_deadline_ns(1e4);
        let patient = query(&m, 2, 0.5, 1e5).with_deadline_ns(1e9);
        let qs = vec![long, doomed, patient];
        let rep = sim.run_admitted(&qs, Admission::capped(1, OnFull::Queue));
        assert_eq!(rep.shed, vec![1]);
        assert!(rep.rejected.is_empty());
        assert!(rep.timings[1].start_ns.is_nan());
        assert!(rep.timings[0].completed() && rep.timings[2].completed());
    }

    /// Shed-on-overflow drops Batch work first: with a bounded wait
    /// queue, every shed victim is Batch while Interactive work survives.
    #[test]
    fn shed_policy_drops_batch_before_interactive() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs = vec![query(&m, 0, 0.5, 1e6)];
        for i in 0..4 {
            let mut q = query(&m, 1 + i, 0.5, 1e5).with_priority(Priority::Batch);
            q.arrival_ns = 1e3 * (i as f64 + 1.0);
            qs.push(q);
        }
        for i in 0..3 {
            let mut q = query(&m, 5 + i, 0.5, 1e5).with_priority(Priority::Interactive);
            q.arrival_ns = 1e4 + 1e3 * (i as f64 + 1.0);
            qs.push(q);
        }
        let rep = sim.run_admitted(
            &qs,
            Admission::capped(1, OnFull::Shed { max_waiting: 3 }),
        );
        assert!(!rep.shed.is_empty(), "overflow must shed");
        assert!(
            rep.shed.iter().all(|&id| qs[id].priority == Priority::Batch),
            "only batch work may be shed while batch remains: {:?}",
            rep.shed
        );
        // Interactive queries all completed.
        assert!(qs[5..].iter().all(|q| rep.timings[q.id].completed()));
    }

    /// Weighted fair share, closed form: 4 Interactive (weight 4) + 4
    /// Batch (weight 1) identical queries, channels saturated. Per-channel
    /// utilization is `u = drain/solo` with `drain = frac x total_ns`, so
    /// the fill level is `solo/(20 drain)`, the Interactive rate is
    /// `4 x level`, and Interactive finishes at exactly `20 drain / 4 =
    /// 2.5e6 ns` — the solo time cancels. Batch then holds 75% of its work
    /// and drains the now-private channels at `solo/(4 drain)`, finishing
    /// at `4.0e6 ns`. The makespan equals the flat-weights makespan: the
    /// allocator redistributes bandwidth, it does not create or destroy
    /// work.
    #[test]
    fn weighted_shares_follow_class_weights() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs: Vec<QuerySpec> = Vec::new();
        for i in 0..4 {
            qs.push(query(&m, i, 0.5, 1e6).with_priority(Priority::Interactive));
        }
        for i in 4..8 {
            qs.push(query(&m, i, 0.5, 1e6).with_priority(Priority::Batch));
        }
        let flat = sim.run_admitted(&qs, Admission::unlimited());
        let weighted = sim.run_admitted(
            &qs,
            Admission::unlimited().with_weights(ShareWeights::priority_weighted()),
        );
        // Flat: all eight share equally and finish together at 8 x drain.
        assert!((flat.makespan_ns - 4e6).abs() / 4e6 < 0.01, "{}", flat.makespan_ns);
        let t_int = weighted.timings[0].latency_ns();
        let t_batch = weighted.timings[7].latency_ns();
        assert!((t_int - 2.5e6).abs() / 2.5e6 < 0.01, "interactive at {t_int}");
        assert!((t_batch - 4.0e6).abs() / 4.0e6 < 0.01, "batch at {t_batch}");
        // Work conservation: the weighted makespan matches the flat one.
        assert!((weighted.makespan_ns - flat.makespan_ns).abs() / flat.makespan_ns < 0.01);
        // Surfaced through the report: per-class latencies and the weights.
        assert!(
            weighted.class_mean_latency_s(Priority::Interactive).unwrap()
                < weighted.class_mean_latency_s(Priority::Batch).unwrap()
        );
        assert_eq!(weighted.weights, ShareWeights::priority_weighted());
        assert!(weighted.preempted.is_empty() && weighted.parks == 0);
    }

    /// The solo-speed cap still binds under weights: a heavily-weighted
    /// query whose `weight x level` exceeds 1 runs at solo speed, no
    /// faster, and the leftover bandwidth goes to the rest.
    #[test]
    fn weighted_rate_caps_at_solo_speed() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs = vec![query(&m, 0, 0.25, 1e6).with_priority(Priority::Interactive)];
        for i in 1..9 {
            qs.push(query(&m, i, 0.25, 1e6).with_priority(Priority::Batch));
        }
        let w = ShareWeights { interactive: 8.0, standard: 1.0, batch: 1.0 };
        let rep = sim.run_admitted(&qs, Admission::unlimited().with_weights(w));
        let solo = qs[0].solo_ns(&m);
        let t_int = rep.timings[0].latency_ns();
        // weight x level = 8 x 0.25 = 2 >= 1: capped at solo speed.
        assert!((t_int - solo).abs() / solo < 0.01, "{t_int} vs solo {solo}");
        // Channels stay saturated throughout: makespan = total work over
        // capacity = 9 x 0.25e6 ns.
        assert!((rep.makespan_ns - 2.25e6).abs() / 2.25e6 < 0.01, "{}", rep.makespan_ns);
    }

    /// Weights are scale-free: any flat vector reproduces plain max-min.
    #[test]
    fn flat_weights_at_any_scale_match_default() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let mut qs: Vec<QuerySpec> = (0..6).map(|i| query(&m, i, 0.5, 1e6)).collect();
        for (i, q) in qs.iter_mut().enumerate() {
            q.priority = Priority::ALL[i % 3];
        }
        let base = sim.run_admitted(&qs, Admission::unlimited());
        let scaled = sim.run_admitted(
            &qs,
            Admission::unlimited()
                .with_weights(ShareWeights { interactive: 3.0, standard: 3.0, batch: 3.0 }),
        );
        assert!((base.makespan_ns - scaled.makespan_ns).abs() / base.makespan_ns < 1e-9);
        for (a, b) in base.timings.iter().zip(&scaled.timings) {
            assert!((a.finish_ns - b.finish_ns).abs() / a.finish_ns < 1e-9);
        }
    }

    /// Checkpoint preemption round trip: a running Batch query parks at
    /// its next phase boundary when a blocked Interactive arrival needs
    /// its context bytes (60 + 60 > 100: the interactive query can only
    /// start because the ledger reservation was released), then resumes
    /// and completes once the pressure clears.
    #[test]
    fn preemption_parks_batch_at_checkpoint_for_interactive() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let batch = QuerySpec::new(
            0,
            "batch",
            (0..4).map(|_| uniform_phase(&m, 0.5, 1e6)).collect(),
            0.0,
        )
        .with_priority(Priority::Batch)
        .with_ctx_bytes(60);
        let mut interactive = query(&m, 1, 0.5, 1e5)
            .with_priority(Priority::Interactive)
            .with_ctx_bytes(60);
        interactive.arrival_ns = 1.2e6; // mid-phase-2 of the batch query
        let qs = vec![batch, interactive];
        let adm = Admission::byte_budget(100, OnFull::Queue);

        // PR 2 behavior: the interactive query waits out the whole batch.
        let plain = sim.run_admitted(&qs, adm);
        assert!(plain.preempted.is_empty() && plain.parks == 0);
        assert!(plain.timings[1].start_ns > 3.9e6, "{}", plain.timings[1].start_ns);

        let rep = sim.run_admitted(&qs, adm.with_preempt(PreemptPolicy::default()));
        assert_eq!(rep.preempted, vec![0]);
        assert_eq!((rep.parks, rep.resumes), (1, 1));
        // Parked at the ~2e6 phase boundary, not mid-phase.
        let istart = rep.timings[1].start_ns;
        assert!((1.9e6..2.5e6).contains(&istart), "interactive started at {istart}");
        assert!(rep.peak_ctx_bytes <= 100);
        // Both complete; the parked time lands in the batch latency.
        assert!(rep.timings[0].completed() && rep.timings[1].completed());
        assert!(rep.timings[0].finish_ns > rep.timings[1].finish_ns);
        assert!(
            rep.timings[1].latency_ns() < 0.5 * plain.timings[1].latency_ns(),
            "preemption must shorten the interactive latency: {} vs {}",
            rep.timings[1].latency_ns(),
            plain.timings[1].latency_ns()
        );
        // Work is conserved: the batch query still runs all four phases.
        assert_eq!(rep.timings[0].phases, 4);
        assert!(
            (rep.counters.totals().channel_ops - plain.counters.totals().channel_ops).abs()
                < 1e-6
        );
    }

    /// An Interactive or Standard query is never a preemption victim under
    /// the default (Batch-only) policy.
    #[test]
    fn preemption_spares_non_victim_classes() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let standard = QuerySpec::new(
            0,
            "std",
            (0..4).map(|_| uniform_phase(&m, 0.5, 1e6)).collect(),
            0.0,
        )
        .with_ctx_bytes(60);
        let mut interactive = query(&m, 1, 0.5, 1e5)
            .with_priority(Priority::Interactive)
            .with_ctx_bytes(60);
        interactive.arrival_ns = 1.2e6;
        let qs = vec![standard, interactive];
        let rep = sim.run_admitted(
            &qs,
            Admission::byte_budget(100, OnFull::Queue).with_preempt(PreemptPolicy::default()),
        );
        // No victim: the interactive query waits like under PR 2.
        assert!(rep.preempted.is_empty() && rep.parks == 0);
        assert!(rep.timings[1].start_ns > 3.9e6);
        assert!(rep.timings.iter().all(|t| t.completed()));
    }

    /// An aging-promoted Batch waiter orders the queue like Interactive
    /// but must not trigger parking of running Batch work — swapping
    /// running Batch for waiting Batch is pure churn.
    #[test]
    fn aged_batch_waiter_does_not_preempt_running_batch() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let running = QuerySpec::new(
            0,
            "b0",
            (0..4).map(|_| uniform_phase(&m, 0.5, 1e6)).collect(),
            0.0,
        )
        .with_priority(Priority::Batch)
        .with_ctx_bytes(60);
        let waiter = query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch).with_ctx_bytes(60);
        let adm = Admission::byte_budget(100, OnFull::Queue)
            .with_age_promote_ns(1e5) // promotes long before the batch finishes
            .with_preempt(PreemptPolicy::default());
        let rep = sim.run_admitted(&[running, waiter], adm);
        assert_eq!(rep.parks, 0, "aged Batch pressure must not park running Batch");
        // The waiter starts only when the running query completes — but it
        // is still recorded as aged into the Interactive class.
        assert!(rep.timings[1].start_ns > 3.9e6, "{}", rep.timings[1].start_ns);
        assert_eq!(rep.timings[1].admitted_as, Priority::Interactive);
        assert!(rep.timings.iter().all(|t| t.completed()));
    }

    /// Bugfix (aging accounting): a promoted waiter records both sides —
    /// the declared class it belongs to and the class it was admitted as.
    #[test]
    fn aging_promotion_recorded_as_admitted_class() {
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let long = query(&m, 0, 0.5, 1e6);
        let batch = query(&m, 1, 0.5, 1e5).with_priority(Priority::Batch);
        let rep = sim.run_admitted(
            &[long, batch],
            Admission::capped(1, OnFull::Queue).with_age_promote_ns(2e5),
        );
        // The batch query waited ~1e6 ns >> 2e5: promoted on admission.
        assert_eq!(rep.timings[1].priority, Priority::Batch);
        assert_eq!(rep.timings[1].admitted_as, Priority::Interactive);
        // The first query started without waiting: no promotion.
        assert_eq!(rep.timings[0].admitted_as, rep.timings[0].priority);
    }

    #[test]
    fn heterogeneous_rates_water_fill() {
        // One channel-hungry query + one instruction-only query: the
        // instruction query should be unaffected by channel saturation.
        let m = m8();
        let sim = FlowSim::new(m.clone());
        let hungry: Vec<_> = (0..4).map(|i| query(&m, i, 0.5, 1e6)).collect();
        let mut instr_only = PhaseDemand::zero(8, 8);
        for n in 0..8 {
            instr_only.instructions[n] = m.issue_rate(n) * 0.1 * 1e-3; // 0.1 util for 1e6 ns
        }
        instr_only.parallelism = 1e12;
        let iq = QuerySpec::new(99, "instr", vec![instr_only], 0.0);
        let solo_iq = iq.solo_ns(&m);
        let mut all = hungry;
        all.push(iq);
        let rep = sim.run(&all);
        let iq_t = rep.timings[4].latency_ns();
        assert!((iq_t - solo_iq).abs() / solo_iq < 1e-6, "{iq_t} vs {solo_iq}");
    }

    /// Dense mode drives every event through full re-solves yet must be
    /// bit-identical to the incremental engine — the in-tree equivalence
    /// contract (the randomized version lives in tests/prop_tests.rs).
    #[test]
    fn dense_mode_reproduces_incremental_bitwise() {
        let m = m8();
        let inc = FlowSim::new(m.clone());
        let dense = FlowSim::new(m.clone()).with_solver_mode(SolverMode::Dense);
        let mut qs: Vec<QuerySpec> = Vec::new();
        for i in 0..6 {
            let mut q = QuerySpec::new(
                i,
                "mix",
                (0..2).map(|k| uniform_phase(&m, 0.3 + 0.1 * (k as f64), 5e5)).collect(),
                2e4 * i as f64,
            )
            .with_priority(Priority::ALL[i % 3])
            .with_ctx_bytes(40);
            if i == 5 {
                q = q.with_deadline_ns(1e4); // shed while waiting
            }
            qs.push(q);
        }
        let adm = Admission::byte_budget(120, OnFull::Queue)
            .with_weights(ShareWeights::priority_weighted())
            .with_preempt(PreemptPolicy::default());
        let a = inc.run_admitted(&qs, adm);
        let b = dense.run_admitted(&qs, adm);
        assert_eq!(a.makespan_ns.to_bits(), b.makespan_ns.to_bits());
        assert_eq!(a.events, b.events);
        assert_eq!((a.parks, a.resumes), (b.parks, b.resumes));
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.shed, b.shed);
        for (x, y) in a.timings.iter().zip(&b.timings) {
            assert_eq!(x.start_ns.to_bits(), y.start_ns.to_bits(), "query {}", x.id);
            assert_eq!(x.finish_ns.to_bits(), y.finish_ns.to_bits(), "query {}", x.id);
            assert_eq!(x.admitted_as, y.admitted_as);
        }
    }
}
