//! Query and admission *specifications*: what callers hand the flow
//! engine before a run starts.
//!
//! This module carries the passive data types — [`Priority`],
//! [`ShareWeights`], [`QuerySpec`], [`OnFull`], [`Admission`] — split out
//! of the old monolithic `sim/flow.rs` so the incremental solver
//! ([`super::solver`]) and the event loop ([`super::runtime`]) stay
//! focused. Everything here is re-exported at `sim::flow::*`, so callers
//! are unaffected by the split.

use crate::sim::demand::PhaseDemand;
use crate::sim::machine::Machine;
use crate::sim::preempt::PreemptPolicy;

/// Scheduling priority class of a query.
///
/// The derived ordering is the admission ordering: a *smaller* variant is
/// served first (`Interactive < Standard < Batch`), FIFO within a class.
/// Defined here because the engine's wait queue orders by it; the
/// coordinator re-exports it as `coordinator::request::Priority`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Latency-sensitive, user-facing.
    Interactive,
    /// The default class.
    #[default]
    Standard,
    /// Throughput-oriented background work; first to be shed under
    /// overload.
    Batch,
}

impl Priority {
    /// All classes, best-served first.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Batch];
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Priority::Interactive => write!(f, "interactive"),
            Priority::Standard => write!(f, "standard"),
            Priority::Batch => write!(f, "batch"),
        }
    }
}

/// Per-priority-class fair-share weights for the progress loop.
///
/// Under plain max-min every running query's rate grows uniformly until a
/// resource saturates; with weights, a query of class `p` grows at
/// `weights.of(p)` times the uniform fill level (still capped at solo
/// speed), so an Interactive query receives proportionally more of every
/// saturated resource than a Batch query sharing it. Flat weights (the
/// default) reproduce plain max-min exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShareWeights {
    pub interactive: f64,
    pub standard: f64,
    pub batch: f64,
}

impl Default for ShareWeights {
    fn default() -> Self {
        ShareWeights::flat()
    }
}

impl ShareWeights {
    /// Equal shares: plain max-min fairness (the pre-weighting behavior).
    pub fn flat() -> Self {
        ShareWeights { interactive: 1.0, standard: 1.0, batch: 1.0 }
    }

    /// The 4:2:1 preset: Interactive gets four times a Batch query's share
    /// of every saturated resource, Standard twice.
    pub fn priority_weighted() -> Self {
        ShareWeights { interactive: 4.0, standard: 2.0, batch: 1.0 }
    }

    /// The weight of one priority class.
    pub fn of(&self, p: Priority) -> f64 {
        match p {
            Priority::Interactive => self.interactive,
            Priority::Standard => self.standard,
            Priority::Batch => self.batch,
        }
    }

    /// All classes weighted equally (any scale): rates degenerate to plain
    /// max-min.
    pub fn is_flat(&self) -> bool {
        self.interactive == self.standard && self.standard == self.batch
    }

    /// Parse `class=weight,...` (e.g. `interactive=4,standard=2,batch=1`);
    /// omitted classes keep weight 1.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut w = ShareWeights::flat();
        for (class, weight) in crate::util::cli::parse_kv_f64_list(spec, "share weights")? {
            match class {
                "interactive" => w.interactive = weight,
                "standard" => w.standard = weight,
                "batch" => w.batch = weight,
                other => anyhow::bail!(
                    "unknown priority class {other:?} (want interactive/standard/batch)"
                ),
            }
        }
        w.validate()?;
        Ok(w)
    }

    /// Weights must be finite and strictly positive (a zero weight would
    /// starve a running query forever).
    pub fn validate(&self) -> anyhow::Result<()> {
        for p in Priority::ALL {
            let w = self.of(p);
            anyhow::ensure!(
                w.is_finite() && w > 0.0,
                "share weight for {p} must be finite and positive, got {w}"
            );
        }
        Ok(())
    }

    /// Compact `i:s:b` label for reports (e.g. `4:2:1`).
    pub fn label(&self) -> String {
        format!("{}:{}:{}", self.interactive, self.standard, self.batch)
    }
}

/// One query submitted to the flow engine: an ordered list of phases plus
/// an arrival time and the admission metadata the engine schedules by.
#[derive(Debug, Clone)]
pub struct QuerySpec {
    /// Caller-chosen identifier (reported back in
    /// [`super::report::QueryTiming`]).
    pub id: usize,
    /// Short label for reports ("bfs", "cc", ...).
    pub label: &'static str,
    /// Synchronous phases, executed in order.
    pub phases: Vec<PhaseDemand>,
    /// Simulated arrival time (ns).
    pub arrival_ns: f64,
    /// Priority class: orders the wait queue and picks shedding victims.
    pub priority: Priority,
    /// Optional end-to-end latency budget (ns from arrival). A queued
    /// query whose deadline expires before it starts is shed rather than
    /// run uselessly.
    pub deadline_ns: Option<f64>,
    /// Thread-context bytes reserved while this query is in flight
    /// (0 = free). The coordinator fills in each analysis's declared
    /// footprint; byte-aware admission sums these against
    /// [`Admission::ctx_capacity_bytes`].
    pub ctx_bytes: u64,
}

impl QuerySpec {
    /// A spec with default admission metadata ([`Priority::Standard`], no
    /// deadline, zero context footprint).
    pub fn new(
        id: usize,
        label: &'static str,
        phases: Vec<PhaseDemand>,
        arrival_ns: f64,
    ) -> Self {
        QuerySpec {
            id,
            label,
            phases,
            arrival_ns,
            priority: Priority::default(),
            deadline_ns: None,
            ctx_bytes: 0,
        }
    }

    /// Set the priority class.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set a latency deadline (ns from arrival).
    pub fn with_deadline_ns(mut self, deadline_ns: f64) -> Self {
        self.deadline_ns = Some(deadline_ns);
        self
    }

    /// Set the thread-context reservation (bytes).
    pub fn with_ctx_bytes(mut self, ctx_bytes: u64) -> Self {
        self.ctx_bytes = ctx_bytes;
        self
    }

    /// Duration of this query if it ran alone on `m` (ns).
    pub fn solo_ns(&self, m: &Machine) -> f64 {
        self.phases.iter().map(|p| p.solo_ns(m)).sum()
    }
}

/// What to do with an arriving query when the admission limits (in-flight
/// count or context bytes) are reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OnFull {
    /// Reject the query outright (it appears in
    /// [`super::report::FlowReport::rejected`]). This is what the §IV-B
    /// "256 concurrent queries exhausted the memory used for thread
    /// contexts" failure becomes under admission control.
    Reject,
    /// Hold the query in the priority-ordered wait queue and start it when
    /// capacity frees. Queued queries whose deadline expires before they
    /// start are shed ([`super::report::FlowReport::shed`]).
    Queue,
    /// Queue, but bound the standing wait queue at `max_waiting`: overflow
    /// sheds the newest entry of the lowest-priority class (Batch work is
    /// dropped first; an Interactive query is shed only when nothing of a
    /// lower class is left to drop).
    Shed {
        /// Largest standing wait-queue length before shedding kicks in.
        max_waiting: usize,
    },
}

/// Admission policy applied inside the engine's event loop.
///
/// The wait queue is priority-ordered (`Interactive < Standard < Batch`,
/// FIFO within a class) with an aging rule: a query that has waited at
/// least [`Admission::age_promote_ns`] competes as `Interactive`
/// regardless of its class, so Batch work is never starved forever —
/// its wait before reaching the front of the queue is bounded by
/// `age_promote_ns` plus the backlog that aged before it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Admission {
    /// Maximum queries simultaneously in flight (None = unlimited).
    pub max_in_flight: Option<usize>,
    /// Thread-context byte budget across all in-flight queries (None =
    /// unlimited). Each query holds [`QuerySpec::ctx_bytes`] while in
    /// flight; a query whose own footprint exceeds the whole budget is
    /// rejected at arrival (it could never run).
    pub ctx_capacity_bytes: Option<u64>,
    /// Behavior when an arrival cannot start immediately.
    pub on_full: OnFull,
    /// Anti-starvation bound (ns): a query waiting at least this long is
    /// ordered as `Interactive`. `f64::INFINITY` disables aging (strict
    /// priority).
    pub age_promote_ns: f64,
    /// Fair-share weights the progress loop divides bandwidth by (flat =
    /// plain max-min; see [`ShareWeights`]).
    pub weights: ShareWeights,
    /// Checkpoint preemption of running low-priority work under
    /// Interactive pressure (None = disabled; see
    /// [`crate::sim::preempt`]). Only meaningful with a queueing
    /// [`OnFull`] policy — under `Reject` nothing ever waits.
    pub preempt: Option<PreemptPolicy>,
}

impl Admission {
    /// Default anti-starvation bound: 100 ms of simulated wait promotes a
    /// query to the front class.
    pub const DEFAULT_AGE_PROMOTE_NS: f64 = 100e6;

    /// No admission control at all.
    pub fn unlimited() -> Self {
        Admission {
            max_in_flight: None,
            ctx_capacity_bytes: None,
            on_full: OnFull::Reject,
            age_promote_ns: f64::INFINITY,
            weights: ShareWeights::flat(),
            preempt: None,
        }
    }

    /// Count-capped admission (no byte budget), default aging.
    pub fn capped(max_in_flight: usize, on_full: OnFull) -> Self {
        Admission {
            max_in_flight: Some(max_in_flight),
            ctx_capacity_bytes: None,
            on_full,
            age_promote_ns: Admission::DEFAULT_AGE_PROMOTE_NS,
            weights: ShareWeights::flat(),
            preempt: None,
        }
    }

    /// Byte-budgeted admission (no count cap), default aging.
    pub fn byte_budget(ctx_capacity_bytes: u64, on_full: OnFull) -> Self {
        Admission {
            max_in_flight: None,
            ctx_capacity_bytes: Some(ctx_capacity_bytes),
            on_full,
            age_promote_ns: Admission::DEFAULT_AGE_PROMOTE_NS,
            weights: ShareWeights::flat(),
            preempt: None,
        }
    }

    /// Override the anti-starvation bound.
    pub fn with_age_promote_ns(mut self, age_promote_ns: f64) -> Self {
        self.age_promote_ns = age_promote_ns;
        self
    }

    /// Set priority-scaled fair-share weights for the progress loop.
    pub fn with_weights(mut self, weights: ShareWeights) -> Self {
        self.weights = weights;
        self
    }

    /// Enable checkpoint preemption.
    pub fn with_preempt(mut self, preempt: PreemptPolicy) -> Self {
        self.preempt = Some(preempt);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn share_weights_parse_and_validate() {
        let w = ShareWeights::parse("interactive=4, standard=2, batch=1").unwrap();
        assert_eq!(w, ShareWeights::priority_weighted());
        assert!(!w.is_flat());
        assert_eq!(w.label(), "4:2:1");
        // Omitted classes default to 1.
        let w = ShareWeights::parse("interactive=6").unwrap();
        assert_eq!(w.standard, 1.0);
        assert_eq!(w.batch, 1.0);
        assert!(ShareWeights::flat().is_flat());
        assert!(ShareWeights::parse("realtime=2").is_err());
        assert!(ShareWeights::parse("batch=0").is_err(), "zero weight starves");
        assert!(ShareWeights::parse("batch=-1").is_err());
        assert!(ShareWeights::parse("batch=inf").is_err());
    }

    /// Every malformed spec is a typed error, not a panic or a silent
    /// default — the `serve --weights` surface forwards these verbatim.
    #[test]
    fn share_weights_parse_error_paths() {
        // Missing '=' separator / missing value / missing key.
        assert!(ShareWeights::parse("interactive").is_err());
        assert!(ShareWeights::parse("interactive=").is_err());
        assert!(ShareWeights::parse("=4").is_err());
        // Non-numeric weight.
        assert!(ShareWeights::parse("interactive=fast").is_err());
        // NaN is not finite.
        assert!(ShareWeights::parse("standard=nan").is_err());
        // One bad entry poisons the whole spec even when others are fine.
        assert!(ShareWeights::parse("interactive=4,standard=oops").is_err());
        // Error messages name the offending class for unknown keys.
        let err = ShareWeights::parse("realtime=2").unwrap_err().to_string();
        assert!(err.contains("realtime"), "unhelpful error: {err}");
    }

    /// `validate` rejects each class independently and names it; the
    /// builders cannot produce these, but deserialized configs can.
    #[test]
    fn share_weights_validate_error_paths() {
        for (w, class) in [
            (ShareWeights { interactive: 0.0, standard: 1.0, batch: 1.0 }, "interactive"),
            (ShareWeights { interactive: 1.0, standard: -2.0, batch: 1.0 }, "standard"),
            (ShareWeights { interactive: 1.0, standard: 1.0, batch: f64::NAN }, "batch"),
            (
                ShareWeights { interactive: f64::INFINITY, standard: 1.0, batch: 1.0 },
                "interactive",
            ),
        ] {
            let err = w.validate().unwrap_err().to_string();
            assert!(err.contains(class), "error must name {class}: {err}");
        }
        assert!(ShareWeights::flat().validate().is_ok());
        assert!(ShareWeights::priority_weighted().validate().is_ok());
    }
}
