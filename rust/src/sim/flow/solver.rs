//! Incremental weighted max-min rate solver with event-scoped
//! recomputation.
//!
//! The old engine re-ran dense progressive filling over *every* running
//! query at *every* admit/finish/park/resume event, so host wall-clock per
//! event grew linearly with concurrency (and the whole run superlinearly).
//! This module replaces that with an event-scoped solve built on one
//! observation: **weighted max-min decomposes over connected components**
//! of the bipartite phase↔resource graph. A structural event (a phase
//! entering or leaving) can only change rates inside the component(s)
//! connected to the resources that phase touches; every other component's
//! allocation — and its already-scheduled completion events — is provably
//! unchanged.
//!
//! [`IncrementalSolver`] therefore maintains, per resource, the set of
//! active phases using it (`res_users`) plus the set of *seed* resources
//! whose user set changed since the last solve. [`IncrementalSolver::
//! solve_event`] floods out from the seeds (generation-stamped BFS, no
//! clearing between events), collects each affected component, and runs
//! the same cap-first weighted progressive filling the dense solver used —
//! restricted to that component's members and resources, in a canonical
//! (ascending-index) order so the result is a pure function of the
//! component's membership. Rates are committed with **re-anchoring only on
//! a bitwise change**, so a query whose rate merely re-derives to the same
//! value keeps its exact scheduled completion time.
//!
//! Progress is *anchored*, not stepped: an [`ActivePhase`] stores the
//! remaining fraction at its last rate change plus the anchor time, so the
//! runtime never touches untouched phases to advance them — remaining work
//! and completion time are closed forms of the anchor
//! ([`ActivePhase::remaining_at`], [`ActivePhase::completion_ns`]).
//!
//! Two safety nets cover the refactor:
//! * [`SolverMode::Dense`] (on [`super::runtime::FlowSim`]) re-solves
//!   *every* component at every event through this same component solver.
//!   Because re-anchoring happens only on bitwise rate change, Dense and
//!   Incremental runs are **bit-identical** — the equivalence property
//!   test in `tests/prop_tests.rs` pins this at tolerance 0.
//! * In debug builds, every solve is checked against the retained PR 3
//!   dense reference oracle ([`max_min_rates`]) — the old global
//!   progressive-filling pass over the full resource vector — at 1e-9
//!   relative (the global pass interleaves cap-freezes across components,
//!   which reorders floating-point decrements at the ulp level).
//!
//! [`SolverMode`]: super::runtime::SolverMode

/// Resources below this utilization are treated as unused by a phase; keeps
/// the sparse vectors short and the waterfill numerically stable.
pub const UTIL_EPS: f64 = 1e-9;

/// One in-flight phase inside the allocator, with anchored progress.
///
/// Instead of decrementing a `remaining` fraction at every event, the
/// phase records the remaining fraction at the instant its rate last
/// changed (`remaining_at_anchor` at `anchor_ns`). While the rate holds,
/// progress is linear, so remaining work and completion time are closed
/// forms — the solver re-anchors only when it commits a bitwise rate
/// change.
#[derive(Debug, Clone)]
pub struct ActivePhase {
    /// Index into the run's query vector.
    pub qi: usize,
    /// Index of the current phase.
    pub phase_idx: usize,
    /// Solo duration of the current phase (ns).
    pub solo_ns: f64,
    /// Sparse utilization vector: (resource index, fraction of capacity
    /// consumed at rate 1.0).
    pub util: Vec<(u32, f64)>,
    /// Fair-share weight of the owning query's priority class: this phase
    /// grows at `weight x` the uniform fill level during allocation, and
    /// contributes `weight x util` to the aggregate demand vector.
    pub weight: f64,
    /// Allocated rate from the last allocation pass.
    pub rate: f64,
    /// Simulated time of the last rate change (ns).
    pub anchor_ns: f64,
    /// Remaining fraction of the phase in [0, 1] at `anchor_ns`.
    pub remaining_at_anchor: f64,
}

impl ActivePhase {
    /// Remaining fraction of the phase at time `t >= anchor_ns` under the
    /// current rate.
    pub fn remaining_at(&self, t: f64) -> f64 {
        self.remaining_at_anchor - (t - self.anchor_ns) * self.rate / self.solo_ns
    }

    /// Absolute completion time (ns) under the current rate.
    pub fn completion_ns(&self) -> f64 {
        self.anchor_ns + self.remaining_at_anchor * self.solo_ns / self.rate
    }
}

/// Event-scoped weighted max-min solver (see the module doc).
///
/// Owns the active-phase table (`slots`, indexed by query index so every
/// walk is in deterministic ascending order — never map iteration), the
/// per-resource user lists, and the seed set of resources whose user set
/// changed since the last [`IncrementalSolver::solve_event`]. All scratch
/// (aggregate demand, residual capacity, generation stamps, component
/// member/resource lists) is generation-stamped and reused, so a solve
/// allocates nothing and initializes only what it floods.
#[derive(Debug, Clone)]
pub struct IncrementalSolver {
    /// Size of the machine's flow-resource index space.
    n_res: usize,
    /// Active phase of each query (None = not running), indexed by qi.
    slots: Vec<Option<ActivePhase>>,
    /// Number of Some entries in `slots`.
    active_count: usize,
    /// Per resource: query indices of the active phases using it.
    res_users: Vec<Vec<u32>>,
    /// Resources whose user set changed since the last solve — the flood
    /// origins of the next event-scoped recomputation.
    seeds: Vec<u32>,
    /// Scratch: aggregate weighted demand per resource (valid for the
    /// current generation's touched resources only).
    demand: Vec<f64>,
    /// Scratch: residual capacity per resource (same validity).
    residual: Vec<f64>,
    /// Generation stamp per resource: equal to `gen` = flooded this event.
    res_gen: Vec<u64>,
    /// Generation stamp per query: equal to `gen` = flooded this event.
    query_gen: Vec<u64>,
    /// Current flood generation (one per solve_event call).
    gen: u64,
    /// Scratch: current component's members (query indices).
    members: Vec<usize>,
    /// Scratch: current component's touched resources.
    touched: Vec<u32>,
    /// Scratch: per-member frozen flags for the progressive filling.
    frozen: Vec<bool>,
    /// Scratch: per-member solved rates before commit.
    rates: Vec<f64>,
}

impl IncrementalSolver {
    /// A solver for `n_queries` potential queries over a machine with
    /// `n_res` flow resources.
    pub fn new(n_res: usize, n_queries: usize) -> Self {
        IncrementalSolver {
            n_res,
            slots: vec![None; n_queries],
            active_count: 0,
            res_users: vec![Vec::new(); n_res],
            seeds: Vec::new(),
            demand: vec![0.0; n_res],
            residual: vec![0.0; n_res],
            res_gen: vec![0; n_res],
            query_gen: vec![0; n_queries],
            gen: 0,
            members: Vec::new(),
            touched: Vec::new(),
            frozen: Vec::new(),
            rates: Vec::new(),
        }
    }

    /// Number of active phases.
    pub fn active_count(&self) -> usize {
        self.active_count
    }

    /// The active phase of query `qi` (panics if inactive).
    pub fn slot(&self, qi: usize) -> &ActivePhase {
        self.slots[qi].as_ref().expect("query has no active phase")
    }

    /// All active phases in ascending query-index order.
    pub fn iter_active(&self) -> impl Iterator<Item = &ActivePhase> + '_ {
        self.slots.iter().flatten()
    }

    /// Register a newly-entered phase. Its resources become seeds: the
    /// next [`IncrementalSolver::solve_event`] re-solves the component the
    /// phase joins (possibly merging previously separate components).
    pub fn insert(&mut self, ap: ActivePhase) {
        let qi = ap.qi;
        debug_assert!(self.slots[qi].is_none(), "query already has an active phase");
        for &(j, _) in &ap.util {
            self.res_users[j as usize].push(qi as u32);
            self.seeds.push(j);
        }
        self.slots[qi] = Some(ap);
        self.active_count += 1;
    }

    /// Detach the active phase of `qi` (completion, park). Its resources
    /// become seeds: the departing demand can speed up everything that was
    /// transitively sharing them — and any component the departure splits
    /// off still contains a user of one of these resources, so flooding
    /// the seeds provably reaches every query whose rate can change.
    pub fn remove(&mut self, qi: usize) -> ActivePhase {
        let ap = self.slots[qi].take().expect("query has no active phase to remove");
        for &(j, _) in &ap.util {
            let users = &mut self.res_users[j as usize];
            let pos = users
                .iter()
                .position(|&u| u as usize == qi)
                .expect("resource user list out of sync");
            users.swap_remove(pos);
            self.seeds.push(j);
        }
        self.active_count -= 1;
        ap
    }

    /// Re-solve rates at time `t` after structural changes, appending the
    /// query indices whose rate changed (bitwise) to `changed`.
    ///
    /// With `dense` false (the default mode), only the components
    /// reachable from the seed resources are re-solved; with `dense` true
    /// every component is re-solved through the same component solver —
    /// bit-identical by construction, kept as the equivalence reference.
    pub fn solve_event(&mut self, t: f64, dense: bool, changed: &mut Vec<usize>) {
        self.solve_event_traced(t, dense, changed, &mut crate::sim::trace::NullSink);
    }

    /// [`Self::solve_event`] with a [`crate::sim::trace::TraceSink`]:
    /// emits one `Solve` event per re-solved component (member/resource
    /// counts — the flood extent that attributes host cost per event).
    /// Emission is observation-only and gated on `S::ENABLED`, so the
    /// [`crate::sim::trace::NullSink`] instantiation is the untraced
    /// solve, unchanged.
    pub fn solve_event_traced<S: crate::sim::trace::TraceSink>(
        &mut self,
        t: f64,
        dense: bool,
        changed: &mut Vec<usize>,
        sink: &mut S,
    ) {
        changed.clear();
        if self.active_count == 0 {
            self.seeds.clear();
            return;
        }
        self.gen += 1;
        let gen = self.gen;
        let mut members = std::mem::take(&mut self.members);
        let mut touched = std::mem::take(&mut self.touched);
        if dense {
            for qi in 0..self.slots.len() {
                if self.slots[qi].is_none() || self.query_gen[qi] == gen {
                    continue;
                }
                members.clear();
                touched.clear();
                self.query_gen[qi] = gen;
                members.push(qi);
                self.flood(&mut members, &mut touched, gen);
                self.solve_component(&mut members, &mut touched, t, changed);
                if S::ENABLED {
                    sink.emit(crate::sim::trace::TraceEvent::Solve {
                        t_ns: t,
                        members: members.len(),
                        resources: touched.len(),
                    });
                }
            }
            self.seeds.clear();
        } else {
            let seeds = std::mem::take(&mut self.seeds);
            for &j in &seeds {
                let ji = j as usize;
                if self.res_gen[ji] == gen {
                    continue;
                }
                self.res_gen[ji] = gen;
                self.demand[ji] = 0.0;
                self.residual[ji] = 1.0;
                members.clear();
                touched.clear();
                touched.push(j);
                for k in 0..self.res_users[ji].len() {
                    let uq = self.res_users[ji][k] as usize;
                    if self.query_gen[uq] != gen {
                        self.query_gen[uq] = gen;
                        members.push(uq);
                    }
                }
                self.flood(&mut members, &mut touched, gen);
                if !members.is_empty() {
                    self.solve_component(&mut members, &mut touched, t, changed);
                    if S::ENABLED {
                        sink.emit(crate::sim::trace::TraceEvent::Solve {
                            t_ns: t,
                            members: members.len(),
                            resources: touched.len(),
                        });
                    }
                }
            }
            self.seeds = seeds;
            self.seeds.clear();
        }
        self.members = members;
        self.touched = touched;
        #[cfg(debug_assertions)]
        self.check_against_dense_oracle();
    }

    /// Generation-stamped BFS over the phase↔resource bipartite graph:
    /// expand `members` (used as the BFS queue) and `touched` to the full
    /// connected component. Newly-touched resources get their scratch
    /// demand/residual initialized on first visit, so nothing is ever
    /// cleared between events.
    fn flood(&mut self, members: &mut Vec<usize>, touched: &mut Vec<u32>, gen: u64) {
        let IncrementalSolver { slots, res_users, res_gen, query_gen, demand, residual, .. } =
            self;
        let mut head = 0;
        while head < members.len() {
            let qi = members[head];
            head += 1;
            let ap = slots[qi].as_ref().expect("flood reached an inactive query");
            for &(j, _) in &ap.util {
                let ji = j as usize;
                if res_gen[ji] == gen {
                    continue;
                }
                res_gen[ji] = gen;
                demand[ji] = 0.0;
                residual[ji] = 1.0;
                touched.push(j);
                for &uq in &res_users[ji] {
                    let uq = uq as usize;
                    if query_gen[uq] != gen {
                        query_gen[uq] = gen;
                        members.push(uq);
                    }
                }
            }
        }
    }

    /// Cap-first weighted progressive filling over one component, in
    /// canonical order: members ascending by query index, resources
    /// scanned ascending with a strict `<` bottleneck tie-break. The
    /// result is therefore a pure function of the component's membership —
    /// independent of which seed discovered it — which is what makes
    /// Dense-mode re-solves of untouched components bitwise no-ops.
    ///
    /// Semantics are exactly the PR 3 dense pass: every unfrozen phase
    /// grows at `weight x` a uniform fill level; phases whose weighted
    /// growth reaches the solo cap (`weight x level >= 1`) freeze at rate
    /// 1.0 first (their consumption is plain utilization, so remaining
    /// levels only move up); then the bottleneck's users freeze at
    /// `(weight x level).min(1.0).max(1e-9)`.
    fn solve_component(
        &mut self,
        members: &mut Vec<usize>,
        touched: &mut Vec<u32>,
        t: f64,
        changed: &mut Vec<usize>,
    ) {
        members.sort_unstable();
        touched.sort_unstable();
        let IncrementalSolver { slots, demand, residual, frozen, rates, .. } = self;
        frozen.clear();
        frozen.resize(members.len(), false);
        rates.clear();
        rates.resize(members.len(), 1.0);
        // Aggregate weighted demand, in ascending member order.
        for &qi in members.iter() {
            let ap = slots[qi].as_ref().expect("component member is inactive");
            for &(j, u) in &ap.util {
                demand[j as usize] += ap.weight * u;
            }
        }
        let mut unfrozen = members.len();
        while unfrozen > 0 {
            // Uniform fill level at which the first resource saturates
            // (each unfrozen phase consuming weight x level x util).
            let mut level = f64::INFINITY;
            let mut bottleneck = u32::MAX;
            for &j in touched.iter() {
                let d = demand[j as usize];
                if d > UTIL_EPS {
                    let l = residual[j as usize].max(0.0) / d;
                    if l < level {
                        level = l;
                        bottleneck = j;
                    }
                }
            }
            if bottleneck == u32::MAX {
                // Nothing binds below the solo-speed cap: everyone left
                // runs at full rate.
                for (i, r) in rates.iter_mut().enumerate() {
                    if !frozen[i] {
                        *r = 1.0;
                    }
                }
                break;
            }
            // Phases whose weighted growth hits the solo cap at or before
            // the saturation level run at full rate; retire them and
            // re-solve — they consume util (not weight x level x util), so
            // the remaining levels are monotonically non-decreasing.
            let mut capped_any = false;
            for (i, &qi) in members.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                let ap = slots[qi].as_ref().expect("component member is inactive");
                if ap.weight * level < 1.0 {
                    continue;
                }
                rates[i] = 1.0;
                frozen[i] = true;
                unfrozen -= 1;
                capped_any = true;
                for &(j, u) in &ap.util {
                    residual[j as usize] -= u;
                    demand[j as usize] -= ap.weight * u;
                }
            }
            if capped_any {
                continue;
            }
            // Freeze every unfrozen phase that touches the bottleneck at
            // its weighted share; retire its demand and charge its
            // consumption.
            let mut froze_any = false;
            for (i, &qi) in members.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                let ap = slots[qi].as_ref().expect("component member is inactive");
                if ap.util.iter().any(|&(j, _)| j == bottleneck) {
                    let r = (ap.weight * level).min(1.0).max(1e-9);
                    rates[i] = r;
                    frozen[i] = true;
                    unfrozen -= 1;
                    froze_any = true;
                    for &(j, u) in &ap.util {
                        residual[j as usize] -= r * u;
                        demand[j as usize] -= ap.weight * u;
                    }
                }
            }
            debug_assert!(froze_any, "bottleneck had no users");
            if !froze_any {
                // Defensive: avoid an infinite loop on numerical corner
                // cases.
                for (i, r) in rates.iter_mut().enumerate() {
                    if !frozen[i] {
                        let w = slots[members[i]].as_ref().unwrap().weight;
                        *r = (w * level).min(1.0).max(1e-9);
                    }
                }
                break;
            }
        }
        // Commit, re-anchoring ONLY on a bitwise rate change: a query
        // whose rate re-derives to the same value keeps its exact
        // scheduled completion event, and Dense-mode full re-solves stay
        // bit-identical to incremental ones.
        for (i, &qi) in members.iter().enumerate() {
            let ap = slots[qi].as_mut().expect("component member is inactive");
            let r = rates[i];
            if r.to_bits() != ap.rate.to_bits() {
                ap.remaining_at_anchor = ap.remaining_at(t);
                ap.anchor_ns = t;
                ap.rate = r;
                changed.push(qi);
            }
        }
    }

    /// Debug-build safety net: after every solve, replay the retained
    /// PR 3 dense reference solver ([`max_min_rates`]) over the full
    /// active set and compare every committed rate at 1e-9 relative.
    /// Skipped above 256 active phases (the oracle is the quadratic pass
    /// this module exists to retire).
    #[cfg(debug_assertions)]
    fn check_against_dense_oracle(&self) {
        if self.active_count == 0 || self.active_count > 256 {
            return;
        }
        let order: Vec<usize> =
            (0..self.slots.len()).filter(|&qi| self.slots[qi].is_some()).collect();
        let phases: Vec<(f64, &[(u32, f64)])> = order
            .iter()
            .map(|&qi| {
                let ap = self.slots[qi].as_ref().unwrap();
                (ap.weight, ap.util.as_slice())
            })
            .collect();
        let mut rates = vec![1.0f64; order.len()];
        let mut demand = vec![0.0f64; self.n_res];
        let mut residual = vec![0.0f64; self.n_res];
        for &(w, util) in &phases {
            for &(j, u) in util {
                demand[j as usize] += w * u;
            }
        }
        max_min_rates(&phases, &mut rates, &mut demand, &mut residual);
        for (i, &qi) in order.iter().enumerate() {
            let got = self.slots[qi].as_ref().unwrap().rate;
            let want = rates[i];
            let tol = 1e-9 * got.abs().max(want.abs());
            debug_assert!(
                (got - want).abs() <= tol,
                "incremental rate diverged from dense oracle: qi {qi} got {got} want {want}"
            );
        }
    }
}

/// The retained dense reference solver: the old global progressive-filling
/// *weighted* max-min pass over the full resource vector, kept verbatim as
/// the debug-assert oracle for the incremental solver (see the module
/// doc). `phases` is `(weight, util)` per active phase; `demand` arrives
/// pre-aggregated as `Σ weight x util`; `rates` receives the allocation.
///
/// Unlike the component solver, this pass picks its bottleneck *globally*,
/// interleaving cap-freezes across unrelated components — semantically
/// identical, bitwise different at the ulp level, which is why the oracle
/// comparison uses a 1e-9 relative tolerance rather than 0.
#[cfg(debug_assertions)]
fn max_min_rates(
    phases: &[(f64, &[(u32, f64)])],
    rates: &mut [f64],
    demand: &mut [f64],
    residual: &mut [f64],
) {
    if phases.is_empty() {
        return;
    }
    let n_res = demand.len();
    residual.iter_mut().for_each(|r| *r = 1.0);
    let mut frozen = vec![false; phases.len()];
    let mut unfrozen = phases.len();

    while unfrozen > 0 {
        let mut level = f64::INFINITY;
        let mut bottleneck = usize::MAX;
        for j in 0..n_res {
            if demand[j] > UTIL_EPS {
                let l = residual[j].max(0.0) / demand[j];
                if l < level {
                    level = l;
                    bottleneck = j;
                }
            }
        }
        if bottleneck == usize::MAX {
            for (i, r) in rates.iter_mut().enumerate() {
                if !frozen[i] {
                    *r = 1.0;
                }
            }
            return;
        }
        let mut capped_any = false;
        for (i, &(w, util)) in phases.iter().enumerate() {
            if frozen[i] || w * level < 1.0 {
                continue;
            }
            rates[i] = 1.0;
            frozen[i] = true;
            unfrozen -= 1;
            capped_any = true;
            for &(j, u) in util {
                residual[j as usize] -= u;
                demand[j as usize] -= w * u;
            }
        }
        if capped_any {
            continue;
        }
        let mut froze_any = false;
        for (i, &(w, util)) in phases.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            if util.iter().any(|&(j, _)| j as usize == bottleneck) {
                let r = (w * level).min(1.0).max(1e-9);
                rates[i] = r;
                frozen[i] = true;
                unfrozen -= 1;
                froze_any = true;
                for &(j, u) in util {
                    residual[j as usize] -= r * u;
                    demand[j as usize] -= w * u;
                }
            }
        }
        debug_assert!(froze_any, "bottleneck had no users");
        if !froze_any {
            for (i, r) in rates.iter_mut().enumerate() {
                if !frozen[i] {
                    *r = (phases[i].0 * level).min(1.0).max(1e-9);
                }
            }
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phase(qi: usize, util: Vec<(u32, f64)>, weight: f64, t: f64, solo: f64) -> ActivePhase {
        ActivePhase {
            qi,
            phase_idx: 0,
            solo_ns: solo,
            util,
            weight,
            rate: 1.0,
            anchor_ns: t,
            remaining_at_anchor: 1.0,
        }
    }

    /// Two disjoint components: an event in one must not re-anchor (or
    /// even report as changed) anything in the other.
    #[test]
    fn event_scoped_solve_leaves_disjoint_components_untouched() {
        let mut s = IncrementalSolver::new(8, 8);
        let mut changed = Vec::new();
        // Component A: queries 0,1 share resource 0 at 0.7 each.
        s.insert(phase(0, vec![(0, 0.7)], 1.0, 0.0, 1e6));
        s.insert(phase(1, vec![(0, 0.7)], 1.0, 0.0, 1e6));
        // Component B: query 2 alone on resource 5.
        s.insert(phase(2, vec![(5, 0.4)], 1.0, 0.0, 1e6));
        s.solve_event(0.0, false, &mut changed);
        // A saturates resource 0 (1.4 demand): both throttle to 1/1.4.
        assert_eq!(changed, vec![0, 1], "B's solo query stays at rate 1.0");
        let r = s.slot(0).rate;
        assert!((r - 1.0 / 1.4).abs() < 1e-12, "rate {r}");
        assert_eq!(s.slot(2).rate, 1.0);
        // Now query 3 joins B. Re-solving must not touch A at all.
        s.insert(phase(3, vec![(5, 0.8)], 1.0, 100.0, 1e6));
        s.solve_event(100.0, false, &mut changed);
        assert_eq!(changed, vec![2, 3], "A is a different component");
        assert_eq!(s.slot(0).anchor_ns, 0.0, "A was never re-anchored");
        assert_eq!(s.slot(0).rate, s.slot(1).rate);
        // B now saturates: rates are 1/1.2 each.
        assert!((s.slot(2).rate - 1.0 / 1.2).abs() < 1e-12);
    }

    /// A departure seeds the resources it used, and the freed capacity
    /// re-rates the survivors — including a component that SPLITS in two.
    #[test]
    fn removal_reaches_split_components() {
        let mut s = IncrementalSolver::new(8, 8);
        let mut changed = Vec::new();
        // Chain: q0 -(r0)- q1 -(r1)- q2. One component through q1.
        s.insert(phase(0, vec![(0, 0.8)], 1.0, 0.0, 1e6));
        s.insert(phase(1, vec![(0, 0.8), (1, 0.8)], 1.0, 0.0, 1e6));
        s.insert(phase(2, vec![(1, 0.8)], 1.0, 0.0, 1e6));
        s.solve_event(0.0, false, &mut changed);
        assert_eq!(changed, vec![0, 1, 2]);
        // q1 leaves: the component splits into {q0} and {q2}, neither of
        // which contains the other's resource — but both r0 and r1 are
        // seeds, so both parts re-solve to full rate.
        s.remove(1);
        s.solve_event(50.0, false, &mut changed);
        assert_eq!(changed, vec![0, 2]);
        assert_eq!(s.slot(0).rate, 1.0);
        assert_eq!(s.slot(2).rate, 1.0);
        assert_eq!(s.slot(0).anchor_ns, 50.0, "rate change re-anchors");
    }

    /// Dense mode re-solves everything but commits nothing new: rates are
    /// a pure function of component membership, so a full re-solve of an
    /// unchanged system is a bitwise no-op.
    #[test]
    fn dense_resolve_of_unchanged_system_is_a_noop() {
        let mut s = IncrementalSolver::new(8, 8);
        let mut changed = Vec::new();
        s.insert(phase(0, vec![(0, 0.7), (2, 0.3)], 2.0, 0.0, 1e6));
        s.insert(phase(1, vec![(0, 0.7)], 1.0, 0.0, 1e6));
        s.insert(phase(2, vec![(5, 0.4)], 1.0, 0.0, 1e6));
        s.solve_event(0.0, false, &mut changed);
        let before: Vec<u64> = s.iter_active().map(|ap| ap.rate.to_bits()).collect();
        s.solve_event(123.0, true, &mut changed);
        assert!(changed.is_empty(), "unchanged system must not re-anchor");
        let after: Vec<u64> = s.iter_active().map(|ap| ap.rate.to_bits()).collect();
        assert_eq!(before, after);
        assert_eq!(s.slot(0).anchor_ns, 0.0);
    }

    /// Anchored progress closed forms.
    #[test]
    fn anchored_progress_closed_forms() {
        let ap = phase(0, vec![(0, 0.5)], 1.0, 100.0, 1e6);
        assert_eq!(ap.remaining_at(100.0), 1.0);
        assert!((ap.completion_ns() - (100.0 + 1e6)).abs() < 1e-9);
        let mut half = ap.clone();
        half.rate = 0.5;
        assert!((half.completion_ns() - (100.0 + 2e6)).abs() < 1e-9);
        assert!((half.remaining_at(100.0 + 1e6) - 0.5).abs() < 1e-12);
    }
}
