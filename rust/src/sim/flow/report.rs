//! Per-run *results*: what the flow engine hands back after a run.
//!
//! [`QueryTiming`] is one query's outcome (start/finish, declared vs
//! admitted class); [`FlowReport`] aggregates a whole run — timings,
//! counters, admission outcomes, preemption totals, and the new
//! [`FlowReport::events`] count that the host-cost bench axis divides
//! wall-clock by. Split out of the old monolithic `sim/flow.rs`;
//! everything here is re-exported at `sim::flow::*`.

use crate::sim::counters::Counters;

use super::spec::{Priority, ShareWeights};

/// Per-query outcome of a flow-engine run.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryTiming {
    pub id: usize,
    pub label: &'static str,
    /// When the query arrived (ns).
    pub arrival_ns: f64,
    /// When its first phase started progressing (ns). **NaN = the query
    /// never started**: it was rejected at arrival or shed while waiting.
    /// A queued query's start is later than its arrival; the gap is its
    /// admission wait.
    pub start_ns: f64,
    /// When its last phase completed (ns). NaN if the query never ran.
    pub finish_ns: f64,
    /// Phase count of the submitted spec. Recorded uniformly for every
    /// outcome — a rejected or shed query reports the work it *would*
    /// have run, not 0.
    pub phases: usize,
    /// Priority class the spec declared.
    pub priority: Priority,
    /// Class the query was *admitted as*: the declared class, or
    /// `Interactive` when anti-starvation aging promoted it out of the
    /// wait queue. Recording both sides keeps per-class wait statistics
    /// honest — a promoted Batch query's long wait belongs to Batch, but
    /// reports can now also see that it competed as Interactive.
    pub admitted_as: Priority,
}

impl QueryTiming {
    /// End-to-end latency of the query (ns); NaN if it never ran.
    pub fn latency_ns(&self) -> f64 {
        self.finish_ns - self.arrival_ns
    }

    /// Whether the query ran to completion.
    pub fn completed(&self) -> bool {
        self.finish_ns.is_finite()
    }
}

/// Result of one flow-engine run.
#[derive(Debug, Clone)]
pub struct FlowReport {
    /// Per-query timings, in input order.
    pub timings: Vec<QueryTiming>,
    /// Time the last query finished (ns).
    pub makespan_ns: f64,
    /// Accumulated hardware counters over the run.
    pub counters: Counters,
    /// Largest number of queries simultaneously in flight.
    pub peak_concurrency: usize,
    /// Ids of queries rejected at arrival (admission full under
    /// [`super::spec::OnFull::Reject`], or a footprint larger than the
    /// whole byte budget). Empty without admission control.
    pub rejected: Vec<usize>,
    /// Ids of queries shed from the wait queue after being admitted to it:
    /// deadline expired while waiting, or dropped by
    /// [`super::spec::OnFull::Shed`] overflow. Empty without admission
    /// control.
    pub shed: Vec<usize>,
    /// High-water mark of reserved thread-context bytes over the run
    /// (from the [`crate::sim::ledger::ContextLedger`] the engine admits
    /// against).
    pub peak_ctx_bytes: u64,
    /// Ids of queries that were checkpoint-parked at least once. The run
    /// always drains the parked set before finishing, so every id here
    /// also completed (its latency includes the parked time).
    pub preempted: Vec<usize>,
    /// Total park events over the run (one query can park repeatedly, up
    /// to [`crate::sim::preempt::PreemptPolicy::max_parks_per_query`]).
    pub parks: usize,
    /// Total resume events over the run.
    pub resumes: usize,
    /// The fair-share weights the run used (flat = plain max-min).
    pub weights: ShareWeights,
    /// Scheduling events processed: query starts, phase completions, parks
    /// and resumes. This is the denominator of the `host_ns_per_event`
    /// bench axis (host wall-clock per simulated event) — the quantity the
    /// incremental solver keeps near-constant as concurrency grows
    /// (DESIGN.md §Engine).
    pub events: usize,
}

impl FlowReport {
    /// Mean completed-query latency (s), or `None` if *nothing*
    /// completed — a fully-shed run has no latency, and the old `0.0`
    /// return was indistinguishable from a true zero-latency run.
    /// Rejected/shed queries carry NaN timings and are excluded (they
    /// have no latency, and one NaN would otherwise poison the mean).
    pub fn mean_latency_s(&self) -> Option<f64> {
        let (sum, n) = self
            .timings
            .iter()
            .filter(|t| t.completed())
            .fold((0.0, 0usize), |(s, n), t| (s + t.latency_ns(), n + 1));
        if n == 0 {
            return None;
        }
        Some(sum / n as f64 * 1e-9)
    }

    /// Makespan in seconds.
    pub fn makespan_s(&self) -> f64 {
        self.makespan_ns * 1e-9
    }

    /// Completed-query latencies in seconds (input order); rejected and
    /// shed queries are filtered out.
    pub fn latencies_s(&self) -> Vec<f64> {
        self.timings
            .iter()
            .filter(|t| t.completed())
            .map(|t| t.latency_ns() * 1e-9)
            .collect()
    }

    /// Completed-query latencies (s) of one declared priority class — the
    /// realized per-class service the weighted progress loop divides.
    pub fn class_latencies_s(&self, priority: Priority) -> Vec<f64> {
        self.timings
            .iter()
            .filter(|t| t.completed() && t.priority == priority)
            .map(|t| t.latency_ns() * 1e-9)
            .collect()
    }

    /// Mean completed-query latency (s) of one declared priority class,
    /// or `None` if the class completed nothing (e.g. fully shed).
    pub fn class_mean_latency_s(&self, priority: Priority) -> Option<f64> {
        let xs = self.class_latencies_s(priority);
        if xs.is_empty() {
            return None;
        }
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }

    /// Completed latencies (s) of one spec label — e.g. the `"mutate"`
    /// ingest lane sharing the engine with queries (DESIGN.md §Mutation).
    pub fn label_latencies_s(&self, label: &str) -> Vec<f64> {
        self.timings
            .iter()
            .filter(|t| t.completed() && t.label == label)
            .map(|t| t.latency_ns() * 1e-9)
            .collect()
    }

    /// Mean completed latency (s) of one spec label, or `None` if none
    /// completed.
    pub fn label_mean_latency_s(&self, label: &str) -> Option<f64> {
        let xs = self.label_latencies_s(label);
        if xs.is_empty() {
            return None;
        }
        Some(xs.iter().sum::<f64>() / xs.len() as f64)
    }
}
