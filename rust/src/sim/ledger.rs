//! Thread-context memory byte ledger — the admission accounting the flow
//! engine runs against (paper §IV-B).
//!
//! Each in-flight query reserves [`crate::sim::flow::QuerySpec::ctx_bytes`]
//! of the machine's thread-context memory; [`crate::sim::flow::FlowSim::run_admitted`]
//! admits against a [`ContextLedger`] and releases on completion, so the
//! ledger's `in_use`/`peak`/`refusals` diagnostics reflect the actual run.
//! The coordinator builds the ledger from the machine config and re-exports
//! these types as `coordinator::admission::{ContextLedger, ContextExhausted}`.

use crate::config::machine::MachineConfig;
use crate::sim::flow::{Admission, OnFull};
use std::collections::HashMap;

/// Why an admission was refused: the query's reservation does not fit in
/// the machine's thread-context memory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContextExhausted {
    /// Bytes the refused query asked to reserve.
    pub requested_bytes: u64,
    /// Bytes already reserved by in-flight queries at refusal time.
    pub in_use_bytes: u64,
    /// Total thread-context memory of the machine (bytes).
    pub capacity_bytes: u64,
}

impl ContextExhausted {
    /// True when the query could never run on this machine, even alone.
    pub fn oversized(&self) -> bool {
        self.requested_bytes > self.capacity_bytes
    }
}

impl std::fmt::Display for ContextExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "thread-context memory exhausted: query reserves {} MiB, \
             {} MiB of {} MiB already in use",
            self.requested_bytes >> 20,
            self.in_use_bytes >> 20,
            self.capacity_bytes >> 20,
        )
    }
}

impl std::error::Error for ContextExhausted {}

/// Per-machine context-memory byte ledger: tracks each in-flight query's
/// reserved bytes against the machine's total thread-context memory.
#[derive(Debug, Clone)]
pub struct ContextLedger {
    capacity_bytes: u64,
    /// Machine default reservation for analyses with no declared footprint.
    default_bytes_per_query: u64,
    /// Reserved bytes per in-flight query id.
    reserved: HashMap<usize, u64>,
    in_use_bytes: u64,
    /// High-water mark (diagnostics).
    peak_bytes: u64,
    /// Total refused admissions.
    refusals: usize,
}

impl ContextLedger {
    /// Build from a machine config: capacity is the whole machine's
    /// thread-context memory.
    pub fn new(cfg: &MachineConfig) -> Self {
        ContextLedger::with_capacity_bytes(
            cfg.nodes as u64 * cfg.ctx_mem_per_node_bytes,
            cfg.ctx_bytes_per_query,
        )
    }

    /// Build with explicit byte capacity and default per-query footprint
    /// (tests, what-if runs).
    pub fn with_capacity_bytes(capacity_bytes: u64, default_bytes_per_query: u64) -> Self {
        ContextLedger {
            capacity_bytes,
            default_bytes_per_query: default_bytes_per_query.max(1),
            reserved: HashMap::new(),
            in_use_bytes: 0,
            peak_bytes: 0,
            refusals: 0,
        }
    }

    /// A ledger with no byte limit (the engine's no-admission-control arm).
    pub fn unlimited() -> Self {
        ContextLedger::with_capacity_bytes(u64::MAX, 1)
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// How many default-footprint queries fit (the paper's query-count
    /// capacity).
    pub fn capacity_queries(&self) -> usize {
        (self.capacity_bytes / self.default_bytes_per_query) as usize
    }

    pub fn in_use_bytes(&self) -> u64 {
        self.in_use_bytes
    }

    pub fn in_flight(&self) -> usize {
        self.reserved.len()
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    pub fn refusals(&self) -> usize {
        self.refusals
    }

    /// Whether a reservation of `bytes` would fit right now (no side
    /// effects — the engine's wait-queue drain peeks with this).
    pub fn would_fit(&self, bytes: u64) -> bool {
        self.in_use_bytes.saturating_add(bytes) <= self.capacity_bytes
    }

    /// Reserve `bytes` of context memory for query `id`.
    pub fn admit(&mut self, id: usize, bytes: u64) -> Result<(), ContextExhausted> {
        assert!(!self.reserved.contains_key(&id), "double admit of query {id}");
        if !self.would_fit(bytes) {
            self.refusals += 1;
            return Err(ContextExhausted {
                requested_bytes: bytes,
                in_use_bytes: self.in_use_bytes,
                capacity_bytes: self.capacity_bytes,
            });
        }
        self.reserved.insert(id, bytes);
        // Saturating: an unlimited ledger admits arbitrarily declared
        // footprints without risking overflow panics.
        self.in_use_bytes = self.in_use_bytes.saturating_add(bytes);
        self.peak_bytes = self.peak_bytes.max(self.in_use_bytes);
        Ok(())
    }

    /// Reserve the machine-default footprint for query `id`.
    pub fn admit_default(&mut self, id: usize) -> Result<(), ContextExhausted> {
        self.admit(id, self.default_bytes_per_query)
    }

    /// Release query `id`'s reservation.
    pub fn release(&mut self, id: usize) {
        let bytes = self.reserved.remove(&id).expect("release without admit");
        self.in_use_bytes = self.in_use_bytes.saturating_sub(bytes);
    }

    /// Whether `k` default-footprint queries can run fully concurrently.
    pub fn fits(&self, k: usize) -> bool {
        k as u64 * self.default_bytes_per_query <= self.capacity_bytes
    }

    /// Check a single declared footprint against total capacity: a query
    /// larger than the whole machine could never run, even alone.
    pub fn check_admissible(&self, bytes: u64) -> Result<(), ContextExhausted> {
        if bytes > self.capacity_bytes {
            return Err(ContextExhausted {
                requested_bytes: bytes,
                in_use_bytes: 0,
                capacity_bytes: self.capacity_bytes,
            });
        }
        Ok(())
    }

    /// The flow-engine admission policy this ledger implies: a byte budget
    /// (exact, per-query reserved bytes summed by the engine) with the
    /// default anti-starvation aging.
    pub fn policy(&self, on_full: OnFull) -> Admission {
        Admission::byte_budget(self.capacity_bytes, on_full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_release_cycle_is_byte_exact() {
        let mut l = ContextLedger::with_capacity_bytes(100, 40);
        l.admit(0, 40).unwrap();
        l.admit(1, 40).unwrap();
        let err = l.admit(2, 40).unwrap_err();
        assert_eq!(err.in_use_bytes, 80);
        assert_eq!(err.requested_bytes, 40);
        assert_eq!(err.capacity_bytes, 100);
        assert!(!err.oversized());
        assert_eq!(l.refusals(), 1);
        // A thinner query still fits exactly.
        assert!(l.would_fit(20));
        l.admit(3, 20).unwrap();
        assert!(!l.would_fit(1));
        assert_eq!(l.in_use_bytes(), 100);
        assert_eq!(l.in_flight(), 3);
        l.release(1);
        assert_eq!(l.in_use_bytes(), 60);
        l.admit_default(4).unwrap();
        assert_eq!(l.peak_bytes(), 100);
    }

    #[test]
    fn oversized_query_is_inadmissible_even_when_idle() {
        let l = ContextLedger::with_capacity_bytes(100, 10);
        let err = l.check_admissible(101).unwrap_err();
        assert!(err.oversized());
        assert_eq!(err.in_use_bytes, 0);
        assert!(l.check_admissible(100).is_ok());
        assert!(err.to_string().contains("thread-context memory"));
    }

    #[test]
    #[should_panic(expected = "release without admit")]
    fn release_underflow_panics() {
        ContextLedger::with_capacity_bytes(10, 1).release(0);
    }

    #[test]
    fn unlimited_always_fits() {
        let mut l = ContextLedger::unlimited();
        assert!(l.would_fit(u64::MAX));
        l.admit(0, 1 << 40).unwrap();
        l.release(0);
    }

    #[test]
    fn policy_carries_byte_budget() {
        let l = ContextLedger::with_capacity_bytes(7 << 20, 1 << 20);
        let p = l.policy(OnFull::Queue);
        assert_eq!(p.ctx_capacity_bytes, Some(7 << 20));
        assert_eq!(p.max_in_flight, None);
        assert_eq!(p.on_full, OnFull::Queue);
        assert!(p.age_promote_ns.is_finite(), "aging enabled by default");
    }
}
