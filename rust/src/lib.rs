//! # pathfinder-queries
//!
//! Reproduction of **"Concurrent Graph Queries on the Lucata Pathfinder"**
//! (Smith, Kuntz, Riedy, Deneroff — CS.DC 2022).
//!
//! The paper shows that the Lucata Pathfinder — a cache-less,
//! migratory-thread architecture with narrow-channel memory and memory-side
//! processors (MSPs) — runs hundreds of *concurrent* graph queries with
//! 81–97 % end-to-end improvement over sequential execution, and outperforms
//! RedisGraph-on-Xeon by up to 19× at 128 concurrent BFS.
//!
//! Nobody outside GT CRNCH has a Pathfinder, so this repo builds the machine
//! as a calibrated simulator (see DESIGN.md §Hardware-Adaptation) and keeps
//! everything else real:
//!
//! * [`graph`] — Graph500/R-MAT generation, the paper's loose-sparse-row
//!   striped storage (§IV-A), and the live-mutation substrate: an
//!   epoch-based snapshot store ([`graph::store::GraphStore`]) with
//!   per-epoch delta overlays behind the [`graph::view::GraphView`] read
//!   abstraction (DESIGN.md §Mutation).
//! * [`sim`] — the Pathfinder model: nodes, multi-threaded cache-less cores,
//!   NCDRAM channels, MSPs with `remote_min`, migration engine, RapidIO
//!   fabric, memory views; both a flow-level and a discrete-event engine.
//! * [`alg`] — the open query API (the [`alg::Analysis`] trait +
//!   [`alg::AnalysisRegistry`], DESIGN.md §Query-API) and the six analyses
//!   behind it: the migratory-thread BFS, the Figure-2 Shiloach-Vishkin
//!   connected components (MSP `remote_min` hooks), delta-stepping SSSP on
//!   the same hook, hop-bounded k-hop neighborhoods, push-style PageRank
//!   on MSP `remote_add`, and degree-ordered triangle counting
//!   (docs/ANALYSES.md is the guide for adding a seventh).
//! * [`coordinator`] — the serving layer: [`coordinator::QueryRequest`]
//!   scheduling metadata, admission control by thread-context memory,
//!   sequential/concurrent policies, per-class metrics, declarative
//!   [`coordinator::WorkloadSpec`] service mixes.
//! * [`runtime`] — PJRT (via the `xla` crate) loader/executor for the AOT
//!   HLO artifacts compiled from JAX+Pallas (`python/compile`).
//! * [`baseline`] — the RedisGraph/GraphBLAS comparison platform: BFS/CC as
//!   masked linear algebra on PJRT plus the calibrated Xeon timing model.
//! * [`bench_harness`] — regenerates every figure and table in the paper's
//!   evaluation (Fig. 3, Fig. 4, Tables I–III, the §IV-B scaling study).
//!
//! Python (JAX + Pallas) exists only on the compile path; the request path
//! is pure rust + PJRT.

pub mod alg;
pub mod baseline;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod runtime;
pub mod sim;
pub mod util;

pub use config::machine::MachineConfig;
pub use graph::csr::Csr;
