//! Graph validation and inspection: structural invariants plus the degree
//! and connectivity statistics the evaluation cares about (the Graph500
//! R-MAT's wide level-size variation is what stresses the machine, §VI).

use super::csr::Csr;

/// Structural + statistical report for a graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphReport {
    pub n: usize,
    pub m_directed: usize,
    pub m_undirected: usize,
    pub max_degree: usize,
    pub mean_degree: f64,
    pub isolated_vertices: usize,
    pub components: usize,
    pub largest_component: usize,
}

/// Check structural invariants required by the algorithms:
/// symmetry (undirected closure), no self loops, sorted+deduped blocks.
pub fn check_invariants(g: &Csr) -> anyhow::Result<()> {
    for u in 0..g.n() as u32 {
        let nbrs = g.neighbors(u);
        anyhow::ensure!(
            nbrs.windows(2).all(|w| w[0] < w[1]),
            "edge block of {u} not sorted/deduped"
        );
        anyhow::ensure!(!nbrs.contains(&u), "self loop at {u}");
        for &v in nbrs {
            anyhow::ensure!(
                g.neighbors(v).binary_search(&u).is_ok(),
                "asymmetric edge ({u},{v})"
            );
        }
    }
    Ok(())
}

/// Compute the full report (host-side union-find for components).
pub fn report(g: &Csr) -> GraphReport {
    let n = g.n();
    let mut parent: Vec<u32> = (0..n as u32).collect();

    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }

    for (u, v) in g.edges() {
        let (ru, rv) = (find(&mut parent, u), find(&mut parent, v));
        if ru != rv {
            let (lo, hi) = (ru.min(rv), ru.max(rv));
            parent[hi as usize] = lo;
        }
    }
    let mut comp_size = std::collections::HashMap::new();
    for v in 0..n as u32 {
        *comp_size.entry(find(&mut parent, v)).or_insert(0usize) += 1;
    }

    let isolated = (0..n as u32).filter(|&v| g.degree(v) == 0).count();
    GraphReport {
        n,
        m_directed: g.m_directed(),
        m_undirected: g.m_directed() / 2,
        max_degree: g.max_degree(),
        mean_degree: g.m_directed() as f64 / n as f64,
        isolated_vertices: isolated,
        components: comp_size.len(),
        largest_component: comp_size.values().copied().max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;

    #[test]
    fn invariants_pass_for_built_graph() {
        let g = build_undirected_csr(5, &[(0, 1), (1, 2), (2, 0), (3, 4)]);
        check_invariants(&g).unwrap();
    }

    #[test]
    fn invariants_catch_asymmetry() {
        let g = Csr::from_parts(vec![0, 1, 1], vec![1]); // 0->1 only
        assert!(check_invariants(&g).is_err());
    }

    #[test]
    fn report_counts_components() {
        let g = build_undirected_csr(6, &[(0, 1), (1, 2), (3, 4)]);
        let r = report(&g);
        assert_eq!(r.components, 3); // {0,1,2}, {3,4}, {5}
        assert_eq!(r.largest_component, 3);
        assert_eq!(r.isolated_vertices, 1);
        assert_eq!(r.m_undirected, 3);
    }
}
