//! `GraphStore` — epoch-based snapshot store for a live, mutating graph
//! (DESIGN.md §Mutation).
//!
//! The store owns an immutable base [`Csr`] plus a stack of per-epoch
//! [`DeltaOverlay`]s. Applying an update batch creates a new epoch;
//! nothing is ever modified in place, so a query that **pins the epoch
//! current at its admission** reads a frozen snapshot for its whole run —
//! a half-applied batch is unrepresentable. Pins are refcounted;
//! compaction merges the *drained* overlay prefix (epochs at or below the
//! oldest pin) into a new base through the same sorted-merge routine the
//! CSR builder uses, so the flat-CSR read fast path is restored as soon as
//! readers move on. FlashGraph-style overlay/compaction: updates never
//! stall reads, reads never block ingest.

use crate::graph::csr::Csr;
use crate::graph::delta::{DeltaOverlay, EdgeUpdate, UpdateOp};
use crate::graph::view::{GraphView, NeighborScratch};
use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Outcome of applying one update batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchStats {
    /// Epoch the batch created (the store's new current epoch).
    pub epoch: u64,
    /// Undirected edges actually inserted (absent before the batch).
    pub inserted: usize,
    /// Undirected edges actually deleted (present before the batch).
    pub deleted: usize,
    /// Updates that were no-ops: inserting a present edge, deleting an
    /// absent one, or cancelled within the batch (last op wins).
    pub redundant: usize,
    /// Updates dropped as invalid (self loop or endpoint out of range).
    pub invalid: usize,
}

/// Outcome of one compaction pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactStats {
    /// Overlays merged into the new base (0 = nothing was drainable).
    pub drained: usize,
    /// Epoch of the new base after the pass.
    pub base_epoch: u64,
}

/// The epoch-based snapshot store (see module docs).
#[derive(Debug)]
pub struct GraphStore<'g> {
    /// The flat base. Starts borrowed from the caller; the first
    /// compaction replaces it with an owned merged CSR.
    base: Cow<'g, Csr>,
    /// Epoch id of the base. `overlays[i]` is epoch `base_epoch + i + 1`.
    base_epoch: u64,
    overlays: Vec<Arc<DeltaOverlay>>,
    /// Refcount per pinned epoch.
    pins: BTreeMap<u64, usize>,
    compactions: usize,
    overlays_compacted: usize,
}

impl<'g> GraphStore<'g> {
    /// A store whose epoch 0 is `base`.
    pub fn new(base: &'g Csr) -> Self {
        GraphStore {
            base: Cow::Borrowed(base),
            base_epoch: 0,
            overlays: Vec::new(),
            pins: BTreeMap::new(),
            compactions: 0,
            overlays_compacted: 0,
        }
    }

    /// The newest epoch (what an arriving query pins).
    pub fn current_epoch(&self) -> u64 {
        self.base_epoch + self.overlays.len() as u64
    }

    /// Epoch of the compacted base; epochs below it are retired.
    pub fn base_epoch(&self) -> u64 {
        self.base_epoch
    }

    /// Overlays currently stacked (epochs newer than the base).
    pub fn live_overlays(&self) -> usize {
        self.overlays.len()
    }

    /// Compaction passes run so far.
    pub fn compactions(&self) -> usize {
        self.compactions
    }

    /// Overlays merged away over the store's lifetime.
    pub fn overlays_compacted(&self) -> usize {
        self.overlays_compacted
    }

    /// View of the newest epoch.
    pub fn view(&self) -> GraphView<'_> {
        GraphView::overlaid(&self.base, &self.overlays)
    }

    /// View of a specific epoch. Errors if the epoch was retired by
    /// compaction (pin it to prevent that) or never existed.
    pub fn view_at(&self, epoch: u64) -> anyhow::Result<GraphView<'_>> {
        anyhow::ensure!(
            epoch >= self.base_epoch,
            "epoch {epoch} was retired by compaction (base epoch {})",
            self.base_epoch
        );
        anyhow::ensure!(
            epoch <= self.current_epoch(),
            "epoch {epoch} not yet created (current {})",
            self.current_epoch()
        );
        let k = (epoch - self.base_epoch) as usize;
        Ok(GraphView::overlaid(&self.base, &self.overlays[..k]))
    }

    /// Pin the current epoch for a starting query: compaction will not
    /// retire it (or anything it stacks on) until every pin is released.
    /// Returns the pinned epoch.
    pub fn pin(&mut self) -> u64 {
        let e = self.current_epoch();
        *self.pins.entry(e).or_insert(0) += 1;
        e
    }

    /// Release one pin on `epoch`. Panics on unbalanced unpins — a
    /// refcount underflow is a scheduler bug, not load.
    pub fn unpin(&mut self, epoch: u64) {
        let count = self.pins.get_mut(&epoch).expect("unpin of never-pinned epoch");
        *count -= 1;
        if *count == 0 {
            self.pins.remove(&epoch);
        }
    }

    /// Whether `epoch` currently has pins.
    pub fn pinned(&self, epoch: u64) -> bool {
        self.pins.contains_key(&epoch)
    }

    /// The oldest pinned epoch, if any query is in flight.
    pub fn min_pinned(&self) -> Option<u64> {
        self.pins.keys().next().copied()
    }

    /// Overlays a compaction pass could merge right now: those at or below
    /// the oldest pin (a pinned epoch's view needs the base to stop
    /// *before* any newer overlay, so only the prefix up to the oldest pin
    /// is drainable).
    pub fn drainable_overlays(&self) -> usize {
        let horizon = self.min_pinned().unwrap_or(self.current_epoch());
        (horizon.min(self.current_epoch()) - self.base_epoch) as usize
    }

    /// Apply one update batch as a new epoch. The overlay records the
    /// batch's *net effect*: within the batch the last op on an edge wins,
    /// and updates that do not change the current view (inserting a
    /// present edge, deleting an absent one) are counted as redundant
    /// rather than recorded — so overlay arc counts are exact.
    pub fn apply_batch(&mut self, updates: &[EdgeUpdate]) -> BatchStats {
        let n = self.n() as u32;
        let mut invalid = 0usize;
        // Last-op-wins per normalized edge, in deterministic first-seen
        // order so overlay construction is reproducible.
        let mut order: Vec<(u32, u32)> = Vec::new();
        let mut net: std::collections::HashMap<(u32, u32), UpdateOp> =
            std::collections::HashMap::new();
        for upd in updates {
            if upd.u == upd.v || upd.u >= n || upd.v >= n {
                invalid += 1;
                continue;
            }
            let key = upd.normalized();
            if net.insert(key, upd.op).is_none() {
                order.push(key);
            }
        }
        let redundant_in_batch = updates.len() - invalid - order.len();

        let mut inserts: Vec<(u32, u32)> = Vec::new();
        let mut deletes: Vec<(u32, u32)> = Vec::new();
        let mut redundant = redundant_in_batch;
        {
            let view = self.view();
            let mut scratch = NeighborScratch::default();
            for key in order {
                let present = view.neighbors(key.0, &mut scratch).binary_search(&key.1).is_ok();
                match (net[&key], present) {
                    (UpdateOp::Insert, false) => inserts.push(key),
                    (UpdateOp::Delete, true) => deletes.push(key),
                    _ => redundant += 1,
                }
            }
        }
        let inserted = inserts.len();
        let deleted = deletes.len();
        self.overlays.push(Arc::new(DeltaOverlay::from_effective(&inserts, &deletes)));
        BatchStats { epoch: self.current_epoch(), inserted, deleted, redundant, invalid }
    }

    /// Merge every drainable overlay into a new flat base (the shared
    /// sorted-merge routine does each row — see
    /// [`crate::graph::delta::merge_neighbors`]). A no-op returning
    /// `drained: 0` when pins block everything; never retires a pinned
    /// epoch.
    pub fn compact(&mut self) -> CompactStats {
        let k = self.drainable_overlays();
        if k == 0 {
            return CompactStats { drained: 0, base_epoch: self.base_epoch };
        }
        let target = self.base_epoch + k as u64;
        let merged = self
            .view_at(target)
            .expect("drainable epoch is always viewable")
            .to_csr();
        self.base = Cow::Owned(merged);
        self.overlays.drain(..k);
        self.base_epoch = target;
        self.compactions += 1;
        self.overlays_compacted += k;
        CompactStats { drained: k, base_epoch: self.base_epoch }
    }

    /// Number of vertices (constant across epochs).
    pub fn n(&self) -> usize {
        self.base.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::validate;

    fn base() -> Csr {
        build_undirected_csr(6, &[(0, 1), (1, 2), (2, 3), (4, 5)])
    }

    #[test]
    fn epochs_advance_and_views_freeze() {
        let g = base();
        let mut store = GraphStore::new(&g);
        assert_eq!(store.current_epoch(), 0);
        let s = store.apply_batch(&[EdgeUpdate::insert(0, 3), EdgeUpdate::delete(4, 5)]);
        assert_eq!(s.epoch, 1);
        assert_eq!((s.inserted, s.deleted, s.redundant, s.invalid), (1, 1, 0, 0));
        // Epoch 0 still reads the original graph; epoch 1 the mutated one.
        assert_eq!(store.view_at(0).unwrap().to_csr(), g);
        let v1 = store.view_at(1).unwrap();
        assert_eq!(v1.degree(0), 2);
        assert_eq!(v1.degree(4), 0);
        validate::check_invariants(&v1.to_csr()).unwrap();
    }

    #[test]
    fn redundant_and_invalid_updates_are_counted_not_recorded() {
        let g = base();
        let mut store = GraphStore::new(&g);
        let s = store.apply_batch(&[
            EdgeUpdate::insert(0, 1),  // already present
            EdgeUpdate::delete(0, 3),  // absent
            EdgeUpdate::insert(2, 2),  // self loop
            EdgeUpdate::insert(0, 99), // out of range
            EdgeUpdate::insert(3, 5),  // effective
            EdgeUpdate::delete(3, 5),  // cancels within the batch
        ]);
        assert_eq!((s.inserted, s.deleted), (0, 0));
        assert_eq!(s.redundant, 3); // present-insert, absent-delete, cancelled pair
        assert_eq!(s.invalid, 2);
        assert_eq!(store.view().to_csr(), g, "net no-op batch");
    }

    #[test]
    fn last_op_wins_within_a_batch() {
        let g = base();
        let mut store = GraphStore::new(&g);
        // Delete then re-insert an existing edge: net effect depends on
        // the LAST op — the edge stays (insert of a present edge after an
        // in-batch delete nets out to "still present").
        let s = store.apply_batch(&[EdgeUpdate::delete(0, 1), EdgeUpdate::insert(1, 0)]);
        assert_eq!((s.inserted, s.deleted), (0, 0));
        assert!(store.view().degree(0) == 1);
    }

    #[test]
    fn compaction_respects_pins_and_refcounts() {
        let g = base();
        let mut store = GraphStore::new(&g);
        let e0 = store.pin();
        let e0_again = store.pin();
        assert_eq!(e0, 0);
        assert_eq!(e0_again, 0);
        store.apply_batch(&[EdgeUpdate::insert(0, 3)]);
        store.apply_batch(&[EdgeUpdate::insert(0, 4)]);
        // Pins at 0 block everything.
        assert_eq!(store.drainable_overlays(), 0);
        assert_eq!(store.compact().drained, 0);
        // One unpin is not enough (refcount 2).
        store.unpin(e0);
        assert_eq!(store.compact().drained, 0);
        assert_eq!(store.view_at(0).unwrap().to_csr(), g, "pinned epoch intact");
        // Final unpin releases both overlays.
        store.unpin(e0_again);
        let c = store.compact();
        assert_eq!(c.drained, 2);
        assert_eq!(store.base_epoch(), 2);
        assert_eq!(store.live_overlays(), 0);
        assert!(store.view().is_flat(), "compaction restores the flat fast path");
        // The retired epoch is gone; the surviving one reads correctly.
        assert!(store.view_at(0).is_err());
        assert!(store.view_at(1).is_err());
        assert_eq!(store.view_at(2).unwrap().degree(0), 3);
        assert_eq!(store.compactions(), 1);
        assert_eq!(store.overlays_compacted(), 2);
    }

    #[test]
    fn mid_stack_pin_allows_prefix_compaction() {
        let g = base();
        let mut store = GraphStore::new(&g);
        store.apply_batch(&[EdgeUpdate::insert(0, 3)]);
        let e1 = store.pin();
        assert_eq!(e1, 1);
        store.apply_batch(&[EdgeUpdate::insert(0, 4)]);
        store.apply_batch(&[EdgeUpdate::insert(0, 5)]);
        // Overlay 1 is at the pin; only it is drainable.
        assert_eq!(store.drainable_overlays(), 1);
        let before = store.view_at(e1).unwrap().to_csr();
        let c = store.compact();
        assert_eq!(c.drained, 1);
        assert_eq!(store.base_epoch(), 1);
        // The pinned epoch's snapshot is unchanged by compaction.
        assert_eq!(store.view_at(e1).unwrap().to_csr(), before);
        // Newer epochs still resolve.
        assert_eq!(store.view_at(3).unwrap().degree(0), 4);
        store.unpin(e1);
        assert_eq!(store.compact().drained, 2);
    }

    #[test]
    #[should_panic(expected = "unpin of never-pinned epoch")]
    fn unbalanced_unpin_panics() {
        let g = base();
        let mut store = GraphStore::new(&g);
        store.unpin(0);
    }

    #[test]
    fn compacted_store_equals_replayed_updates() {
        let g = build_undirected_csr(32, &(0..31u32).map(|i| (i, i + 1)).collect::<Vec<_>>());
        let mut store = GraphStore::new(&g);
        let mut rng = crate::util::rng::SplitMix64::new(77);
        let mut reference: std::collections::BTreeSet<(u32, u32)> =
            (0..31u32).map(|i| (i, i + 1)).collect();
        for _ in 0..6 {
            let batch = crate::graph::delta::random_batch(store.view(), 24, 0.3, &mut rng);
            for upd in &batch {
                let key = upd.normalized();
                match upd.op {
                    UpdateOp::Insert => {
                        reference.insert(key);
                    }
                    UpdateOp::Delete => {
                        reference.remove(&key);
                    }
                }
            }
            store.apply_batch(&batch);
        }
        let expect =
            build_undirected_csr(32, &reference.iter().copied().collect::<Vec<_>>());
        assert_eq!(store.view().to_csr(), expect, "overlaid view replays the stream");
        store.compact();
        assert_eq!(store.view().to_csr(), expect, "compaction preserves the edge set");
        validate::check_invariants(&store.view().to_csr()).unwrap();
    }
}
