//! Edge-update batches and per-epoch delta overlays — the write side of
//! the live-mutation subsystem (DESIGN.md §Mutation).
//!
//! A served graph is not frozen: edges arrive while queries run. The
//! Pathfinder's write asymmetry (*remote writes don't migrate; MSPs do
//! memory-side accumulation*, paper §II–III) makes streaming ingest cheap:
//! an update lands as an unconditional remote write into the destination
//! vertex's **delta log** plus an MSP read-modify-write that splices the
//! log head — no thread ever migrates. The host-side image of that log is
//! a [`DeltaOverlay`]: per-vertex *sorted* insert/delete lists built from
//! one batched [`EdgeUpdate`] stream. Overlays stack in epoch order on top
//! of an immutable base CSR ([`crate::graph::store::GraphStore`]) and are
//! merged away by compaction through the same sorted-merge routine
//! ([`merge_neighbors`]) the CSR builder uses — one copy of the dedup
//! logic, so the builder invariant (sorted, deduplicated, self-loop-free
//! edge blocks) cannot drift from the compaction invariant.

use crate::graph::view::GraphView;
use crate::util::rng::SplitMix64;

/// What one update does to an undirected edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateOp {
    /// Add the edge (a no-op if it is already present).
    Insert,
    /// Remove the edge (a no-op if it is absent).
    Delete,
}

/// One undirected edge update. Applied symmetrically: inserting (u, v)
/// inserts both directed arcs, mirroring the builder's undirected closure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeUpdate {
    pub u: u32,
    pub v: u32,
    pub op: UpdateOp,
}

impl EdgeUpdate {
    pub fn insert(u: u32, v: u32) -> Self {
        EdgeUpdate { u, v, op: UpdateOp::Insert }
    }

    pub fn delete(u: u32, v: u32) -> Self {
        EdgeUpdate { u, v, op: UpdateOp::Delete }
    }

    /// Canonical (min, max) endpoint order of the undirected edge.
    pub fn normalized(&self) -> (u32, u32) {
        if self.u <= self.v {
            (self.u, self.v)
        } else {
            (self.v, self.u)
        }
    }
}

/// Append to `out` the sorted, deduplicated union of `base` and `inserts`,
/// minus every value present in `deletes`.
///
/// This is the **one shared sorted-merge/dedup routine** of the graph
/// layer: [`crate::graph::builder::build_undirected_csr`] builds every
/// edge block through it (so the builder *itself* guarantees the
/// sorted+deduped invariant `graph::validate` checks), [`GraphView`]
/// resolves overlaid neighbor lists with it, and
/// [`crate::graph::store::GraphStore`] compaction folds drained overlays
/// into the new base with it.
///
/// `base` and `inserts` must each be sorted (duplicates allowed — they are
/// collapsed); `deletes` must be sorted. Output order is strictly
/// ascending within this call, independent of whatever `out` already
/// holds (callers append row after row).
pub fn merge_neighbors(base: &[u32], inserts: &[u32], deletes: &[u32], out: &mut Vec<u32>) {
    debug_assert!(base.windows(2).all(|w| w[0] <= w[1]), "base not sorted");
    debug_assert!(inserts.windows(2).all(|w| w[0] <= w[1]), "inserts not sorted");
    debug_assert!(deletes.windows(2).all(|w| w[0] <= w[1]), "deletes not sorted");
    let (mut i, mut j) = (0usize, 0usize);
    let mut last: Option<u32> = None;
    while i < base.len() || j < inserts.len() {
        let x = match (base.get(i), inserts.get(j)) {
            (Some(&a), Some(&b)) if a <= b => {
                i += 1;
                a
            }
            (Some(&a), None) => {
                i += 1;
                a
            }
            (_, Some(&b)) => {
                j += 1;
                b
            }
            (None, None) => unreachable!("loop condition"),
        };
        if last == Some(x) {
            continue; // collapse duplicates (within and across inputs)
        }
        last = Some(x);
        if deletes.binary_search(&x).is_err() {
            out.push(x);
        }
    }
}

/// Per-vertex delta of one vertex in one overlay: sorted insert and delete
/// neighbor lists (disjoint — a batch's net effect is one or the other).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VertexDelta {
    pub inserts: Vec<u32>,
    pub deletes: Vec<u32>,
}

/// One epoch's worth of edge updates, indexed per vertex — the host-side
/// image of the Pathfinder's per-vertex memory-side delta logs.
///
/// Overlays hold the batch's **net effect against the view they were
/// applied to** ([`crate::graph::store::GraphStore::apply_batch`] filters
/// redundant inserts/deletes), so `inserts`/`deletes` counts are exact
/// directed-arc deltas and stacking overlays in epoch order reproduces the
/// exact edge set of any epoch.
#[derive(Debug, Clone, Default)]
pub struct DeltaOverlay {
    per_vertex: std::collections::HashMap<u32, VertexDelta>,
    /// Directed arcs inserted by this overlay (2x the undirected count).
    inserts: usize,
    /// Directed arcs deleted by this overlay.
    deletes: usize,
}

impl DeltaOverlay {
    /// Build from a list of *effective*, normalized undirected edges.
    /// Both directions of each edge are recorded; per-vertex lists come
    /// out sorted and deduplicated.
    pub fn from_effective(inserts: &[(u32, u32)], deletes: &[(u32, u32)]) -> Self {
        let mut ov = DeltaOverlay::default();
        for &(u, v) in inserts {
            debug_assert!(u != v, "self loop in overlay");
            ov.per_vertex.entry(u).or_default().inserts.push(v);
            ov.per_vertex.entry(v).or_default().inserts.push(u);
            ov.inserts += 2;
        }
        for &(u, v) in deletes {
            debug_assert!(u != v, "self loop in overlay");
            ov.per_vertex.entry(u).or_default().deletes.push(v);
            ov.per_vertex.entry(v).or_default().deletes.push(u);
            ov.deletes += 2;
        }
        for d in ov.per_vertex.values_mut() {
            d.inserts.sort_unstable();
            d.inserts.dedup();
            d.deletes.sort_unstable();
            d.deletes.dedup();
        }
        ov
    }

    /// Whether this overlay changes vertex `v`'s neighbor list.
    #[inline]
    pub fn touches(&self, v: u32) -> bool {
        self.per_vertex.contains_key(&v)
    }

    /// Sorted neighbors inserted at `v` (empty if untouched).
    #[inline]
    pub fn inserts_of(&self, v: u32) -> &[u32] {
        self.per_vertex.get(&v).map(|d| d.inserts.as_slice()).unwrap_or(&[])
    }

    /// Sorted neighbors deleted at `v` (empty if untouched).
    #[inline]
    pub fn deletes_of(&self, v: u32) -> &[u32] {
        self.per_vertex.get(&v).map(|d| d.deletes.as_slice()).unwrap_or(&[])
    }

    /// Directed arcs this overlay inserts.
    pub fn inserted_arcs(&self) -> usize {
        self.inserts
    }

    /// Directed arcs this overlay deletes.
    pub fn deleted_arcs(&self) -> usize {
        self.deletes
    }

    /// True when the overlay changes nothing.
    pub fn is_empty(&self) -> bool {
        self.per_vertex.is_empty()
    }

    /// Number of vertices whose neighbor lists this overlay touches.
    pub fn touched_vertices(&self) -> usize {
        self.per_vertex.len()
    }
}

/// Generate one reproducible update batch against `view`: `count` updates,
/// a `delete_fraction` share of which remove a *currently present* edge
/// (sampled as a random neighbor of a random non-isolated vertex, with
/// bounded retries), the rest inserting a random non-self-loop pair.
///
/// All randomness flows from `rng` — the same seeded generator state
/// yields the same stream, which is what makes `serve --mutate` runs
/// reproducible end to end (the service forks this stream from its config
/// seed and surfaces both in the report header).
pub fn random_batch(
    view: GraphView<'_>,
    count: usize,
    delete_fraction: f64,
    rng: &mut SplitMix64,
) -> Vec<EdgeUpdate> {
    let n = view.n() as u64;
    assert!(n >= 2, "need at least two vertices to mutate");
    let mut scratch = crate::graph::view::NeighborScratch::default();
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if rng.next_f64() < delete_fraction {
            // Try to find an existing edge to delete (bounded retries so a
            // near-empty graph degrades to inserts instead of spinning).
            let mut found = None;
            for _ in 0..8 {
                let u = rng.gen_range(n) as u32;
                let nbrs = view.neighbors(u, &mut scratch);
                if !nbrs.is_empty() {
                    let v = nbrs[rng.gen_range(nbrs.len() as u64) as usize];
                    found = Some(EdgeUpdate::delete(u, v));
                    break;
                }
            }
            if let Some(upd) = found {
                out.push(upd);
                continue;
            }
        }
        loop {
            let u = rng.gen_range(n) as u32;
            let v = rng.gen_range(n) as u32;
            if u != v {
                out.push(EdgeUpdate::insert(u, v));
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;

    #[test]
    fn merge_unions_and_dedups() {
        let mut out = Vec::new();
        merge_neighbors(&[1, 3, 3, 5], &[2, 3, 9, 9], &[], &mut out);
        assert_eq!(out, vec![1, 2, 3, 5, 9]);
    }

    #[test]
    fn merge_applies_deletes() {
        let mut out = Vec::new();
        merge_neighbors(&[1, 3, 5], &[2, 7], &[3, 7, 8], &mut out);
        assert_eq!(out, vec![1, 2, 5]);
    }

    #[test]
    fn merge_appends_without_cross_row_dedup() {
        // Two rows ending/starting on the same value must both keep it.
        let mut out = Vec::new();
        merge_neighbors(&[4, 5], &[], &[], &mut out);
        merge_neighbors(&[5, 6], &[], &[], &mut out);
        assert_eq!(out, vec![4, 5, 5, 6]);
    }

    #[test]
    fn merge_empty_inputs() {
        let mut out = Vec::new();
        merge_neighbors(&[], &[], &[], &mut out);
        assert!(out.is_empty());
        merge_neighbors(&[], &[2, 2], &[], &mut out);
        assert_eq!(out, vec![2]);
    }

    #[test]
    fn overlay_indexes_both_directions() {
        let ov = DeltaOverlay::from_effective(&[(1, 4), (1, 2)], &[(3, 5)]);
        assert_eq!(ov.inserts_of(1), &[2, 4]);
        assert_eq!(ov.inserts_of(4), &[1]);
        assert_eq!(ov.deletes_of(3), &[5]);
        assert_eq!(ov.deletes_of(5), &[3]);
        assert!(ov.touches(2) && !ov.touches(0));
        assert_eq!(ov.inserted_arcs(), 4);
        assert_eq!(ov.deleted_arcs(), 2);
        assert!(!ov.is_empty());
        assert_eq!(ov.touched_vertices(), 5);
    }

    #[test]
    fn random_batch_is_reproducible_and_valid() {
        let g = build_undirected_csr(64, &(0..63u32).map(|i| (i, i + 1)).collect::<Vec<_>>());
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        let ba = random_batch(g.view(), 50, 0.3, &mut a);
        let bb = random_batch(g.view(), 50, 0.3, &mut b);
        assert_eq!(ba, bb);
        assert_eq!(ba.len(), 50);
        for upd in &ba {
            assert_ne!(upd.u, upd.v, "no self loops");
            assert!((upd.u as usize) < 64 && (upd.v as usize) < 64);
            if upd.op == UpdateOp::Delete {
                // Deletes target an edge present in the sampled view.
                assert!(g.neighbors(upd.u).binary_search(&upd.v).is_ok());
            }
        }
        let mut c = SplitMix64::new(43);
        assert_ne!(random_batch(g.view(), 50, 0.3, &mut c), ba, "seeds differ");
    }

    #[test]
    fn delete_heavy_batch_on_sparse_graph_degrades_to_inserts() {
        let g = build_undirected_csr(8, &[]);
        let mut rng = SplitMix64::new(1);
        let batch = random_batch(g.view(), 20, 1.0, &mut rng);
        assert_eq!(batch.len(), 20);
        assert!(batch.iter().all(|u| u.op == UpdateOp::Insert), "nothing to delete");
    }
}
