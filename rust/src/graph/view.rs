//! `GraphView` — the one read abstraction every analysis and oracle runs
//! against (DESIGN.md §Mutation).
//!
//! A view is a borrowed snapshot: an immutable base [`Csr`] plus zero or
//! more epoch-ordered [`DeltaOverlay`]s. Reads resolve through the shared
//! sorted-merge routine ([`crate::graph::delta::merge_neighbors`]), folding
//! overlays in epoch order so a delete in epoch 3 of an edge inserted in
//! epoch 2 behaves exactly like replaying the update stream.
//!
//! **Zero-overhead fast path:** a view with no overlays (or none touching
//! the queried vertex) hands out the raw CSR slice — no copy, no merge, no
//! allocation — so every existing demand vector is bit-identical when
//! mutation is off. The CI bench gate pins this down
//! (`ci/BENCH_baseline.json` strict metrics).

use crate::graph::csr::Csr;
use crate::graph::delta::{merge_neighbors, DeltaOverlay};
use std::sync::Arc;

/// Reusable merge buffers for overlaid neighbor resolution. Analyses carry
/// one across their whole traversal so the overlay slow path allocates at
/// most twice per query, not per vertex.
#[derive(Debug, Default)]
pub struct NeighborScratch {
    a: Vec<u32>,
    b: Vec<u32>,
}

/// A borrowed snapshot of the graph at one epoch: base CSR + the overlays
/// applied up to (and including) that epoch, oldest first.
///
/// `Copy`: two references — pass it by value everywhere.
#[derive(Debug, Clone, Copy)]
pub struct GraphView<'a> {
    base: &'a Csr,
    overlays: &'a [Arc<DeltaOverlay>],
}

impl<'a> GraphView<'a> {
    /// A view of a bare CSR: the fast path, bit-identical to reading the
    /// CSR directly.
    pub fn flat(base: &'a Csr) -> Self {
        GraphView { base, overlays: &[] }
    }

    /// A view with overlays stacked on `base`, oldest first.
    pub fn overlaid(base: &'a Csr, overlays: &'a [Arc<DeltaOverlay>]) -> Self {
        GraphView { base, overlays }
    }

    /// True when no overlays are stacked (every read is a raw CSR slice).
    pub fn is_flat(&self) -> bool {
        self.overlays.is_empty()
    }

    /// The underlying base CSR (vertex count and striping never change
    /// across epochs — only edge blocks do).
    pub fn base(&self) -> &'a Csr {
        self.base
    }

    /// Overlays stacked on the base, oldest first.
    pub fn overlays(&self) -> &'a [Arc<DeltaOverlay>] {
        self.overlays
    }

    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.base.n()
    }

    /// Neighbor slice of `v` at this view's epoch. Flat views (and views
    /// whose overlays never touch `v`) return the raw CSR edge block; only
    /// a touched vertex pays the overlay fold into `scratch`.
    pub fn neighbors<'s>(&self, v: u32, scratch: &'s mut NeighborScratch) -> &'s [u32]
    where
        'a: 's,
    {
        let base = self.base.neighbors(v);
        if self.overlays.is_empty() || !self.overlays.iter().any(|o| o.touches(v)) {
            return base;
        }
        // Fold overlays in epoch order, ping-ponging between the two
        // scratch buffers; untouched epochs are skipped for free.
        scratch.a.clear();
        scratch.a.extend_from_slice(base);
        for ov in self.overlays {
            if !ov.touches(v) {
                continue;
            }
            scratch.b.clear();
            merge_neighbors(&scratch.a, ov.inserts_of(v), ov.deletes_of(v), &mut scratch.b);
            std::mem::swap(&mut scratch.a, &mut scratch.b);
        }
        &scratch.a
    }

    /// Degree of `v` at this view's epoch. O(1) on the fast path; a
    /// touched vertex pays one overlay fold (allocating internally — use
    /// [`GraphView::neighbors`] with a carried scratch inside hot loops).
    pub fn degree(&self, v: u32) -> usize {
        if self.overlays.is_empty() {
            return self.base.degree(v);
        }
        // neighbors() short-circuits untouched vertices to the raw slice,
        // and an unused NeighborScratch never heap-allocates.
        let mut scratch = NeighborScratch::default();
        self.neighbors(v, &mut scratch).len()
    }

    /// Directed edge count at this view's epoch. O(1) flat; otherwise
    /// derived from the overlays' exact arc deltas.
    pub fn m_directed(&self) -> usize {
        let delta: i64 = self
            .overlays
            .iter()
            .map(|o| o.inserted_arcs() as i64 - o.deleted_arcs() as i64)
            .sum();
        (self.base.m_directed() as i64 + delta) as usize
    }

    /// Bytes of one vertex's edge block in the paper's 64-bit
    /// representation, given its degree at this view.
    #[inline]
    pub fn edge_block_bytes_for(degree: usize) -> u64 {
        degree as u64 * Csr::PAPER_INT_BYTES
    }

    /// Materialize this view into a standalone CSR (compaction, oracles on
    /// exact epoch edge sets, tests). The result satisfies the builder
    /// invariants by construction — every row goes through the shared
    /// sorted-merge routine.
    pub fn to_csr(&self) -> Csr {
        if self.overlays.is_empty() {
            return self.base.clone();
        }
        let n = self.n();
        let mut scratch = NeighborScratch::default();
        let mut offsets = Vec::with_capacity(n + 1);
        offsets.push(0u64);
        let mut targets = Vec::with_capacity(self.m_directed());
        for v in 0..n as u32 {
            targets.extend_from_slice(self.neighbors(v, &mut scratch));
            offsets.push(targets.len() as u64);
        }
        Csr::from_parts(offsets, targets)
    }
}

impl<'a> From<&'a Csr> for GraphView<'a> {
    fn from(base: &'a Csr) -> Self {
        GraphView::flat(base)
    }
}

impl Csr {
    /// This graph as a flat (no-overlay) [`GraphView`].
    pub fn view(&self) -> GraphView<'_> {
        GraphView::flat(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::delta::DeltaOverlay;

    fn path4() -> Csr {
        build_undirected_csr(4, &[(0, 1), (1, 2), (2, 3)])
    }

    #[test]
    fn flat_view_hands_out_raw_slices() {
        let g = path4();
        let v = g.view();
        assert!(v.is_flat());
        let mut scratch = NeighborScratch::default();
        let nbrs = v.neighbors(1, &mut scratch);
        // Zero-overhead: the returned slice IS the CSR's edge block.
        assert_eq!(nbrs.as_ptr(), g.neighbors(1).as_ptr());
        assert_eq!(nbrs, &[0, 2]);
        assert_eq!(v.degree(1), 2);
        assert_eq!(v.m_directed(), g.m_directed());
        assert_eq!(v.to_csr(), g);
    }

    #[test]
    fn overlay_inserts_and_deletes_resolve() {
        let g = path4();
        let ov = [Arc::new(DeltaOverlay::from_effective(&[(0, 3)], &[(1, 2)]))];
        let v = GraphView::overlaid(&g, &ov);
        assert!(!v.is_flat());
        let mut s = NeighborScratch::default();
        assert_eq!(v.neighbors(0, &mut s), &[1, 3]);
        assert_eq!(v.neighbors(1, &mut s), &[0]);
        assert_eq!(v.neighbors(2, &mut s), &[3]);
        assert_eq!(v.neighbors(3, &mut s), &[0, 2]);
        assert_eq!(v.degree(3), 2);
        assert_eq!(v.m_directed(), g.m_directed()); // +2 arcs, -2 arcs
        crate::graph::validate::check_invariants(&v.to_csr()).unwrap();
    }

    #[test]
    fn untouched_vertices_stay_on_the_fast_path() {
        let g = path4();
        let ov = [Arc::new(DeltaOverlay::from_effective(&[(0, 2)], &[]))];
        let v = GraphView::overlaid(&g, &ov);
        let mut s = NeighborScratch::default();
        // Vertex 3 is untouched: raw slice again, even with overlays.
        assert_eq!(v.neighbors(3, &mut s).as_ptr(), g.neighbors(3).as_ptr());
    }

    #[test]
    fn later_overlay_overrides_earlier() {
        let g = path4();
        // Epoch 1 inserts 0-3; epoch 2 deletes it; epoch 3 re-inserts.
        let ovs = [
            Arc::new(DeltaOverlay::from_effective(&[(0, 3)], &[])),
            Arc::new(DeltaOverlay::from_effective(&[], &[(0, 3)])),
            Arc::new(DeltaOverlay::from_effective(&[(0, 3)], &[])),
        ];
        let mut s = NeighborScratch::default();
        let at = |k: usize, v: u32, s: &mut NeighborScratch| {
            GraphView::overlaid(&g, &ovs[..k]).neighbors(v, s).to_vec()
        };
        assert_eq!(at(1, 0, &mut s), vec![1, 3]);
        assert_eq!(at(2, 0, &mut s), vec![1]);
        assert_eq!(at(3, 0, &mut s), vec![1, 3]);
        assert_eq!(at(3, 3, &mut s), vec![0, 2]);
    }
}
