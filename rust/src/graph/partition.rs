//! Vertex partitioner for the sharded fleet (DESIGN.md §Fleet).
//!
//! A [`Partition`] splits an n-vertex graph across `shards` machines by
//! assigning every vertex exactly one *owner* shard. Each shard holds the
//! full adjacency rows of its owned vertices as a sub-CSR (global vertex
//! ids, unowned rows empty — so shard-local traversal needs no id
//! remapping), and edges whose endpoint lives on another shard are *cut
//! arcs*: traversals discover them locally but must ship the frontier
//! candidate over the fleet interconnect (priced by
//! [`crate::sim::demand::PhaseDemand::interconnect_bytes`]).
//!
//! Two strategies, selected by `serve --fleet ...,partition=hash|balanced`:
//!
//! * [`PartitionStrategy::Hash`] — stateless multiplicative hash of the
//!   vertex id. Placement is independent of the graph, so mutation never
//!   moves a vertex; edge balance is whatever the degree distribution
//!   gives (power-law graphs skew).
//! * [`PartitionStrategy::Balanced`] — greedy longest-processing-time
//!   assignment by descending degree: each vertex goes to the currently
//!   lightest shard (by owned arcs). Classic LPT bound: the final
//!   max−min arc spread is at most the maximum degree, so shards stay
//!   within one hub vertex of each other.
//!
//! Ownership is computed from the *base* graph and stays fixed across
//! epochs — mutation batches route to the owner of their endpoints, they
//! never re-shard (see `coordinator::fleet`).

use anyhow::Result;

use crate::graph::csr::Csr;

/// How vertices are assigned to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Stateless multiplicative hash of the vertex id.
    Hash,
    /// Greedy degree-balanced (LPT) assignment minimizing arc spread.
    Balanced,
}

impl PartitionStrategy {
    /// Parse the `partition=` value of `serve --fleet`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "hash" => Ok(PartitionStrategy::Hash),
            "balanced" => Ok(PartitionStrategy::Balanced),
            other => anyhow::bail!("unknown partition strategy {other:?} (want hash|balanced)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PartitionStrategy::Hash => "hash",
            PartitionStrategy::Balanced => "balanced",
        }
    }
}

/// A vertex→shard assignment plus per-shard sub-CSRs and cut accounting.
#[derive(Debug, Clone)]
pub struct Partition {
    pub strategy: PartitionStrategy,
    pub shards: usize,
    /// Owner shard of every vertex.
    owner: Vec<u32>,
    /// Per-shard sub-CSR: global vertex ids, owned rows = the full global
    /// adjacency row, unowned rows empty.
    shard_graphs: Vec<Csr>,
    /// Directed arcs owned by each shard (sum of owned degrees).
    shard_arcs: Vec<usize>,
    /// Directed arcs leaving each shard (owned tail, foreign head).
    cut_arcs: Vec<usize>,
}

impl Partition {
    /// Partition `g` into `shards` shards.
    pub fn build(g: &Csr, shards: usize, strategy: PartitionStrategy) -> Self {
        assert!(shards > 0, "need at least one shard");
        let n = g.n();
        let owner = match strategy {
            PartitionStrategy::Hash => {
                (0..n as u32).map(|v| Self::hash_owner(v, shards)).collect::<Vec<u32>>()
            }
            PartitionStrategy::Balanced => {
                // LPT: place heaviest vertices first, each on the shard
                // with the fewest owned arcs so far (ties: lowest shard).
                let mut order: Vec<u32> = (0..n as u32).collect();
                order.sort_by_key(|&v| (std::cmp::Reverse(g.degree(v)), v));
                let mut owner = vec![0u32; n];
                let mut load = vec![0usize; shards];
                for v in order {
                    let s = (0..shards).min_by_key(|&s| (load[s], s)).unwrap();
                    owner[v as usize] = s as u32;
                    load[s] += g.degree(v);
                }
                owner
            }
        };
        let mut shard_arcs = vec![0usize; shards];
        let mut cut_arcs = vec![0usize; shards];
        let mut shard_graphs = Vec::with_capacity(shards);
        for s in 0..shards {
            let mut offsets = Vec::with_capacity(n + 1);
            let mut targets = Vec::new();
            offsets.push(0u64);
            for v in 0..n as u32 {
                if owner[v as usize] == s as u32 {
                    let row = g.neighbors(v);
                    targets.extend_from_slice(row);
                    shard_arcs[s] += row.len();
                    cut_arcs[s] += row.iter().filter(|&&w| owner[w as usize] != s as u32).count();
                }
                offsets.push(targets.len() as u64);
            }
            shard_graphs.push(Csr::from_parts(offsets, targets));
        }
        Partition { strategy, shards, owner, shard_graphs, shard_arcs, cut_arcs }
    }

    /// Stateless hash ownership of one vertex (the Hash strategy's rule,
    /// exposed so tests can pin it).
    #[inline]
    pub fn hash_owner(v: u32, shards: usize) -> u32 {
        let x = (v as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((x >> 32) % shards as u64) as u32
    }

    /// Owner shard of vertex `v`.
    #[inline]
    pub fn owner_of(&self, v: u32) -> usize {
        self.owner[v as usize] as usize
    }

    /// Sub-CSR of one shard (global ids; unowned rows empty).
    pub fn shard_graph(&self, shard: usize) -> &Csr {
        &self.shard_graphs[shard]
    }

    /// Directed arcs owned by one shard.
    pub fn shard_arcs(&self, shard: usize) -> usize {
        self.shard_arcs[shard]
    }

    /// Directed arcs leaving one shard for another.
    pub fn cut_arcs(&self, shard: usize) -> usize {
        self.cut_arcs[shard]
    }

    /// Fraction of all directed arcs that cross shards (0 for one shard).
    pub fn cut_fraction(&self) -> f64 {
        let total: usize = self.shard_arcs.iter().sum();
        if total == 0 {
            return 0.0;
        }
        self.cut_arcs.iter().sum::<usize>() as f64 / total as f64
    }

    /// Max/min owned-arc ratio across shards (∞ if some shard owns no
    /// arcs); the balance figure the Balanced strategy bounds.
    pub fn max_min_arc_ratio(&self) -> f64 {
        let max = *self.shard_arcs.iter().max().unwrap() as f64;
        let min = *self.shard_arcs.iter().min().unwrap() as f64;
        max / min
    }

    /// Largest absolute owned-arc spread across shards (the LPT bound:
    /// ≤ max degree for the Balanced strategy).
    pub fn arc_spread(&self) -> usize {
        let max = *self.shard_arcs.iter().max().unwrap();
        let min = *self.shard_arcs.iter().min().unwrap();
        max - min
    }

    /// Partition invariants against the source graph: every vertex owned
    /// by exactly one in-range shard, every owned row identical to the
    /// global row, every unowned row empty, arcs conserved (no edge lost
    /// or duplicated across shards), and cut accounting consistent.
    pub fn check_invariants(&self, g: &Csr) -> Result<()> {
        anyhow::ensure!(self.owner.len() == g.n(), "owner map covers every vertex");
        for v in 0..g.n() as u32 {
            let s = self.owner[v as usize] as usize;
            anyhow::ensure!(s < self.shards, "vertex {v} owned by out-of-range shard {s}");
            for (t, sub) in self.shard_graphs.iter().enumerate() {
                let row = sub.neighbors(v);
                if t == s {
                    anyhow::ensure!(
                        row == g.neighbors(v),
                        "shard {t} stores a wrong row for its owned vertex {v}"
                    );
                } else {
                    anyhow::ensure!(
                        row.is_empty(),
                        "vertex {v} has a row on non-owner shard {t}"
                    );
                }
            }
        }
        let arcs: usize = self.shard_arcs.iter().sum();
        anyhow::ensure!(
            arcs == g.m_directed() as usize,
            "arcs not conserved: shards hold {arcs}, graph has {}",
            g.m_directed()
        );
        for s in 0..self.shards {
            anyhow::ensure!(
                self.shard_graphs[s].m_directed() as usize == self.shard_arcs[s],
                "shard {s} arc ledger disagrees with its sub-CSR"
            );
            anyhow::ensure!(self.cut_arcs[s] <= self.shard_arcs[s], "cut exceeds owned");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;

    fn star_plus_path() -> Csr {
        // Vertex 0 is a hub (degree 6), the rest a sparse path.
        let mut edges: Vec<(u32, u32)> = (1..7u32).map(|v| (0, v)).collect();
        edges.extend((7..11u32).map(|v| (v, v + 1)));
        build_undirected_csr(12, &edges)
    }

    #[test]
    fn both_strategies_satisfy_invariants() {
        let g = star_plus_path();
        for strategy in [PartitionStrategy::Hash, PartitionStrategy::Balanced] {
            for shards in [1, 2, 3, 5] {
                let p = Partition::build(&g, shards, strategy);
                p.check_invariants(&g).unwrap();
            }
        }
    }

    #[test]
    fn single_shard_owns_everything_with_no_cut() {
        let g = star_plus_path();
        let p = Partition::build(&g, 1, PartitionStrategy::Balanced);
        assert_eq!(p.cut_fraction(), 0.0);
        assert_eq!(p.shard_arcs(0), g.m_directed() as usize);
        assert_eq!(p.shard_graph(0), &g);
    }

    #[test]
    fn balanced_spread_is_bounded_by_max_degree() {
        let g = star_plus_path();
        let p = Partition::build(&g, 3, PartitionStrategy::Balanced);
        assert!(p.arc_spread() <= g.max_degree(), "LPT bound");
    }

    #[test]
    fn hash_ownership_is_stateless() {
        let g = star_plus_path();
        let p = Partition::build(&g, 4, PartitionStrategy::Hash);
        for v in 0..g.n() as u32 {
            assert_eq!(p.owner_of(v) as u32, Partition::hash_owner(v, 4));
        }
    }

    #[test]
    fn strategy_parses() {
        assert_eq!(PartitionStrategy::parse("hash").unwrap(), PartitionStrategy::Hash);
        assert_eq!(PartitionStrategy::parse("balanced").unwrap(), PartitionStrategy::Balanced);
        assert!(PartitionStrategy::parse("range").is_err());
        assert_eq!(PartitionStrategy::Balanced.label(), "balanced");
    }
}
