//! Graph substrate: Graph500/R-MAT generation, the paper's loose-sparse-row
//! striped storage (§IV-A), binary I/O, and validation.
//!
//! The paper stores the vertex array striped across nodes via the view-2
//! address mode (vertex v on node v mod N) with each vertex's edge block
//! co-located on the same node; [`layout::StripedLayout`] reproduces that
//! placement and is what the simulator charges memory traffic against.
//!
//! Served graphs are live: [`store::GraphStore`] holds an immutable base
//! CSR plus per-epoch [`delta::DeltaOverlay`]s behind the [`view::GraphView`]
//! read abstraction (DESIGN.md §Mutation) — queries pin the epoch current
//! at admission, compaction folds drained overlays back into a flat base.
//!
//! A fleet shards the graph: [`partition::Partition`] assigns every vertex
//! one owner machine (hash or degree-balanced) with per-shard sub-CSRs and
//! cut-arc accounting (DESIGN.md §Fleet).

pub mod builder;
pub mod csr;
pub mod delta;
pub mod io;
pub mod layout;
pub mod partition;
pub mod rmat;
pub mod sample;
pub mod store;
pub mod validate;
pub mod view;

pub use builder::build_undirected_csr;
pub use csr::Csr;
pub use delta::{merge_neighbors, DeltaOverlay, EdgeUpdate, UpdateOp};
pub use layout::StripedLayout;
pub use partition::{Partition, PartitionStrategy};
pub use rmat::Rmat;
pub use store::GraphStore;
pub use view::{GraphView, NeighborScratch};
