//! Binary graph I/O: a small versioned container for CSR graphs so
//! experiments can reuse generated graphs ("Both are loaded before any
//! timings", §II).

use std::io::{Read, Write};
use std::path::Path;

use super::csr::Csr;

const MAGIC: &[u8; 8] = b"PFQCSR01";

/// Save a CSR graph to a binary file.
pub fn save_csr(g: &Csr, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(g.n() as u64).to_le_bytes())?;
    f.write_all(&(g.m_directed() as u64).to_le_bytes())?;
    for &o in g.offsets() {
        f.write_all(&o.to_le_bytes())?;
    }
    for &t in g.targets() {
        f.write_all(&t.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Load a CSR graph from a binary file.
pub fn load_csr(path: &Path) -> anyhow::Result<Csr> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {}", path.display());
    let n = read_u64(&mut f)? as usize;
    let m = read_u64(&mut f)? as usize;
    let mut offsets = Vec::with_capacity(n + 1);
    for _ in 0..=n {
        offsets.push(read_u64(&mut f)?);
    }
    let mut targets = Vec::with_capacity(m);
    let mut buf = [0u8; 4];
    for _ in 0..m {
        f.read_exact(&mut buf)?;
        targets.push(u32::from_le_bytes(buf));
    }
    Ok(Csr::from_parts(offsets, targets))
}

fn read_u64<R: Read>(r: &mut R) -> anyhow::Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;

    #[test]
    fn round_trip() {
        let g = build_undirected_csr(6, &[(0, 1), (1, 2), (3, 4), (4, 5), (0, 5)]);
        let dir = std::env::temp_dir().join("pfq_io_test");
        let path = dir.join("g.csr");
        save_csr(&g, &path).unwrap();
        let back = load_csr(&path).unwrap();
        assert_eq!(g, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("pfq_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.csr");
        std::fs::write(&path, b"not a graph").unwrap();
        assert!(load_csr(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
