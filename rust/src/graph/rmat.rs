//! Graph500 / R-MAT recursive edge generator (Chakrabarti et al. 2004).
//!
//! Matches the paper's dataset recipe (§IV-A): R-MAT with the Graph500
//! parameters (a=0.57, b=0.19, c=0.19, d=0.05), scale 25 / edge-factor 16 in
//! the paper, then de-duplicated and closed under edge reversal so the
//! directed representation holds both (i,j) and (j,i). Vertex ids are
//! scrambled with a fixed permutation like the Graph500 reference code so
//! low ids are not artificially high-degree.

use crate::config::workload::GraphConfig;
use crate::util::parallel;
use crate::util::rng::SplitMix64;

/// R-MAT edge-list generator.
#[derive(Debug, Clone)]
pub struct Rmat {
    cfg: GraphConfig,
}

impl Rmat {
    pub fn new(cfg: GraphConfig) -> Self {
        cfg.validate().expect("invalid graph config");
        Rmat { cfg }
    }

    /// Generate the raw (possibly duplicated, possibly self-looped)
    /// directed edge list of `edge_factor * 2^scale` edges.
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let m = self.cfg.n_edges_target() as usize;
        let scale = self.cfg.scale;
        let (a, b, c) = (self.cfg.a, self.cfg.b, self.cfg.c);
        let seed = self.cfg.seed;

        // Generate in parallel chunks, each with a forked RNG stream so the
        // result is independent of thread scheduling.
        let chunk = 1 << 16;
        let n_chunks = m.div_ceil(chunk);
        parallel::par_map_range(n_chunks, |ci| {
            let mut rng = SplitMix64::new(seed).fork(ci as u64 + 1);
            let lo = ci * chunk;
            let hi = (lo + chunk).min(m);
            (lo..hi)
                .map(|_| {
                    let (u, v) = Self::one_edge(&mut rng, scale, a, b, c);
                    (scramble(u, scale), scramble(v, scale))
                })
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect()
    }

    fn one_edge(rng: &mut SplitMix64, scale: u32, a: f64, b: f64, c: f64) -> (u32, u32) {
        let mut u = 0u32;
        let mut v = 0u32;
        for _ in 0..scale {
            let r = rng.next_f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        (u, v)
    }
}

/// Invertible vertex-id scramble within [0, 2^scale): multiply by an odd
/// constant mod 2^scale then xor-fold, like Graph500's id permutation.
fn scramble(v: u32, scale: u32) -> u32 {
    let mask = (1u64 << scale) - 1;
    let x = (v as u64).wrapping_mul(0x9E3D_79B9 | 1) & mask;
    ((x ^ (x >> (scale / 2 + 1))) & mask) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(scale: u32) -> GraphConfig {
        GraphConfig { scale, edge_factor: 8, ..Default::default() }
    }

    #[test]
    fn deterministic_for_seed() {
        let g1 = Rmat::new(tiny_cfg(10)).edges();
        let g2 = Rmat::new(tiny_cfg(10)).edges();
        assert_eq!(g1, g2);
    }

    #[test]
    fn seeds_change_output() {
        let mut cfg = tiny_cfg(10);
        cfg.seed = 999;
        assert_ne!(Rmat::new(cfg).edges(), Rmat::new(tiny_cfg(10)).edges());
    }

    #[test]
    fn edge_count_and_range() {
        let cfg = tiny_cfg(10);
        let edges = Rmat::new(cfg.clone()).edges();
        assert_eq!(edges.len() as u64, cfg.n_edges_target());
        let n = cfg.n_vertices() as u32;
        assert!(edges.iter().all(|&(u, v)| u < n && v < n));
    }

    #[test]
    fn skewed_degree_distribution() {
        // R-MAT must be skewed: the max out-degree should far exceed the mean.
        let cfg = tiny_cfg(12);
        let edges = Rmat::new(cfg.clone()).edges();
        let n = cfg.n_vertices() as usize;
        let mut deg = vec![0u32; n];
        for &(u, _) in &edges {
            deg[u as usize] += 1;
        }
        let max = *deg.iter().max().unwrap() as f64;
        let mean = edges.len() as f64 / n as f64;
        assert!(max > 8.0 * mean, "max {max} vs mean {mean}: not skewed");
    }

    #[test]
    fn scramble_is_injective() {
        let scale = 10;
        let n = 1u32 << scale;
        let mut seen = vec![false; n as usize];
        for v in 0..n {
            let s = scramble(v, scale);
            assert!(s < n);
            assert!(!seen[s as usize], "collision at {v}");
            seen[s as usize] = true;
        }
    }
}
