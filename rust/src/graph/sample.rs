//! BFS source selection: "The source vertices for BFS tests are
//! reproducibly pseudorandomly generated" (§IV-A). Like Graph500, sources
//! must have non-zero degree (a BFS from an isolated vertex is trivial);
//! sources within one experiment are unique.

use super::csr::Csr;
use crate::util::rng::SplitMix64;

/// Pick `k` distinct non-isolated source vertices, reproducibly.
pub fn bfs_sources(g: &Csr, k: usize, seed: u64) -> Vec<u32> {
    let mut rng = SplitMix64::new(seed);
    let n = g.n() as u64;
    let mut chosen = std::collections::HashSet::with_capacity(k);
    let mut out = Vec::with_capacity(k);
    let mut attempts = 0u64;
    while out.len() < k {
        attempts += 1;
        assert!(
            attempts < 1000 * k as u64 + 10_000,
            "could not find {k} non-isolated sources; graph too sparse"
        );
        let v = rng.gen_range(n) as u32;
        if g.degree(v) > 0 && chosen.insert(v) {
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::build_undirected_csr;

    fn g() -> Csr {
        build_undirected_csr(100, &(0..99u32).map(|i| (i, i + 1)).collect::<Vec<_>>())
    }

    #[test]
    fn reproducible() {
        assert_eq!(bfs_sources(&g(), 10, 7), bfs_sources(&g(), 10, 7));
    }

    #[test]
    fn unique_and_non_isolated() {
        let graph = build_undirected_csr(100, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let src = bfs_sources(&graph, 5, 3);
        let set: std::collections::HashSet<_> = src.iter().collect();
        assert_eq!(set.len(), 5);
        assert!(src.iter().all(|&s| graph.degree(s) > 0));
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(bfs_sources(&g(), 20, 1), bfs_sources(&g(), 20, 2));
    }
}
