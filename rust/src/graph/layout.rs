//! Striped data placement (paper §II view-2 + §IV-A).
//!
//! "View two ... stripes 64-bit elements across nodes. For an address p on
//! node n, p+8 is on node n+1" — so vertex v's record lives on node
//! v mod nodes, and "the edge block is stored on the same node as the
//! vertex's entry". Within a node, consecutive locally-resident elements
//! rotate across the 8 NCDRAM channels; edge blocks start on a
//! pseudo-random channel (allocation-dependent in hardware; deterministic
//! hash here).

/// Placement of graph data across nodes and memory channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripedLayout {
    pub nodes: usize,
    pub channels_per_node: usize,
}

impl StripedLayout {
    pub fn new(nodes: usize, channels_per_node: usize) -> Self {
        assert!(nodes > 0 && channels_per_node > 0);
        StripedLayout { nodes, channels_per_node }
    }

    /// Node holding vertex v's record and its edge block (view-2 striping).
    #[inline]
    pub fn node_of(&self, v: u32) -> usize {
        v as usize % self.nodes
    }

    /// Channel (within its node) holding vertex v's 8-byte record: local
    /// element index v / nodes rotates across channels.
    #[inline]
    pub fn channel_of(&self, v: u32) -> usize {
        (v as usize / self.nodes) % self.channels_per_node
    }

    /// Flat (node, channel) -> global channel index.
    #[inline]
    pub fn flat_channel(&self, node: usize, channel: usize) -> usize {
        node * self.channels_per_node + channel
    }

    /// Global channel index of vertex v's record.
    #[inline]
    pub fn flat_channel_of(&self, v: u32) -> usize {
        self.flat_channel(self.node_of(v), self.channel_of(v))
    }

    /// Channel where vertex v's edge block starts (deterministic hash
    /// standing in for the allocator's placement).
    #[inline]
    pub fn edge_block_channel(&self, v: u32) -> usize {
        let x = (v as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((x >> 33) % self.channels_per_node as u64) as usize
    }

    /// Total channels in the machine.
    pub fn total_channels(&self) -> usize {
        self.nodes * self.channels_per_node
    }

    /// Number of vertices of an n-vertex graph resident on `node`.
    pub fn vertices_on_node(&self, n: usize, node: usize) -> usize {
        debug_assert!(node < self.nodes);
        n / self.nodes + usize::from(node < n % self.nodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_node_placement() {
        let l = StripedLayout::new(8, 8);
        // "vertex 0 and its neighbor array is on node 0, vertex 1 and its
        // neighbors on node 1, and so on."
        assert_eq!(l.node_of(0), 0);
        assert_eq!(l.node_of(1), 1);
        assert_eq!(l.node_of(7), 7);
        assert_eq!(l.node_of(8), 0);
    }

    #[test]
    fn channel_rotation_within_node() {
        let l = StripedLayout::new(8, 8);
        // Consecutive local elements (v, v+8) rotate channels.
        assert_eq!(l.channel_of(0), 0);
        assert_eq!(l.channel_of(8), 1);
        assert_eq!(l.channel_of(8 * 8), 0);
    }

    #[test]
    fn vertices_on_node_partition() {
        let l = StripedLayout::new(8, 8);
        let n = 1003;
        let total: usize = (0..8).map(|nd| l.vertices_on_node(n, nd)).sum();
        assert_eq!(total, n);
        // Balanced to within one.
        let counts: Vec<_> = (0..8).map(|nd| l.vertices_on_node(n, nd)).collect();
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1);
    }

    #[test]
    fn flat_channel_bijective() {
        let l = StripedLayout::new(4, 8);
        let mut seen = std::collections::HashSet::new();
        for node in 0..4 {
            for ch in 0..8 {
                assert!(seen.insert(l.flat_channel(node, ch)));
            }
        }
        assert_eq!(seen.len(), l.total_channels());
    }

    #[test]
    fn edge_block_channels_spread() {
        let l = StripedLayout::new(8, 8);
        let mut hist = vec![0usize; 8];
        for v in 0..8000u32 {
            hist[l.edge_block_channel(v)] += 1;
        }
        // Roughly uniform: no channel should get more than 2x the mean.
        assert!(hist.iter().all(|&h| h < 2000), "{hist:?}");
    }
}
