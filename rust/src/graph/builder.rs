//! Edge-list -> CSR construction with the paper's dataset hygiene:
//! undirected closure, duplicate removal, self-loop removal (§IV-A: "After
//! ensuring the represented graph is undirected and removing duplicate
//! edges").

use super::csr::Csr;
use super::delta::merge_neighbors;
use crate::util::parallel;

/// Build the deduplicated, self-loop-free, symmetric CSR from a raw
/// directed edge list over vertices [0, n).
///
/// Every edge block is emitted through the graph layer's one shared
/// sorted-merge/dedup routine ([`merge_neighbors`] — also used by overlay
/// reads and [`crate::graph::store::GraphStore`] compaction), so the
/// builder *itself* guarantees the sorted+deduped invariant that
/// [`crate::graph::validate::check_invariants`] checks: duplicate raw
/// edges cannot slip through any builder path, and the invariant cannot
/// drift between freshly-built and compacted graphs.
pub fn build_undirected_csr(n: usize, raw_edges: &[(u32, u32)]) -> Csr {
    // Symmetrize: keep both directions of every edge.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(raw_edges.len() * 2);
    for &(u, v) in raw_edges {
        if u != v {
            edges.push((u, v));
            edges.push((v, u));
        }
    }
    // Sort, then build each vertex's edge block via the shared merge
    // routine (which collapses duplicates within the sorted row).
    parallel::par_sort_unstable(&mut edges);

    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0u64);
    let mut targets: Vec<u32> = Vec::with_capacity(edges.len());
    let mut row: Vec<u32> = Vec::new();
    let mut k = 0usize;
    for u in 0..n as u32 {
        row.clear();
        while k < edges.len() && edges[k].0 == u {
            row.push(edges[k].1);
            k += 1;
        }
        merge_neighbors(&row, &[], &[], &mut targets);
        offsets.push(targets.len() as u64);
    }
    assert_eq!(k, edges.len(), "edge endpoint out of range [0, {n})");
    let g = Csr::from_parts(offsets, targets);
    debug_assert!(super::validate::check_invariants(&g).is_ok());
    g
}

/// Count undirected edges of a symmetric CSR (directed / 2).
pub fn undirected_edge_count(g: &Csr) -> usize {
    debug_assert_eq!(g.m_directed() % 2, 0, "graph not symmetric");
    g.m_directed() / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_and_symmetrizes() {
        // Duplicates, self-loop, one direction only.
        let edges = vec![(0, 1), (0, 1), (1, 0), (2, 2), (1, 3)];
        let g = build_undirected_csr(4, &edges);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0, 3]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        assert_eq!(g.neighbors(3), &[1]);
        assert_eq!(undirected_edge_count(&g), 2);
    }

    #[test]
    fn symmetric_invariant() {
        let edges = vec![(0, 3), (3, 1), (2, 0), (1, 2)];
        let g = build_undirected_csr(4, &edges);
        for (u, v) in g.edges().collect::<Vec<_>>() {
            assert!(g.neighbors(v).contains(&u), "missing reverse of ({u},{v})");
        }
    }

    #[test]
    fn neighbor_lists_sorted() {
        let edges = vec![(0, 3), (0, 1), (0, 2)];
        let g = build_undirected_csr(4, &edges);
        assert_eq!(g.neighbors(0), &[1, 2, 3]);
    }

    #[test]
    fn empty_graph() {
        let g = build_undirected_csr(5, &[]);
        assert_eq!(g.n(), 5);
        assert_eq!(g.m_directed(), 0);
    }

    /// Bugfix guard: the builder must guarantee the sorted+deduped/no-self-
    /// loop invariant itself — adversarial inputs (duplicates in both
    /// directions, repeated self loops, repeated edges across rows) must
    /// come out invariant-clean, same as delta compaction output.
    #[test]
    fn adversarial_duplicates_cannot_slip_through() {
        let edges = vec![
            (0, 1),
            (1, 0),
            (0, 1),
            (1, 0),
            (1, 1),
            (1, 1),
            (2, 1),
            (1, 2),
            (2, 3),
            (3, 2),
            (2, 3),
        ];
        let g = build_undirected_csr(4, &edges);
        crate::graph::validate::check_invariants(&g).unwrap();
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(undirected_edge_count(&g), 3);
    }
}
