//! Edge-list -> CSR construction with the paper's dataset hygiene:
//! undirected closure, duplicate removal, self-loop removal (§IV-A: "After
//! ensuring the represented graph is undirected and removing duplicate
//! edges").

use super::csr::Csr;
use crate::util::parallel;

/// Build the deduplicated, self-loop-free, symmetric CSR from a raw
/// directed edge list over vertices [0, n).
pub fn build_undirected_csr(n: usize, raw_edges: &[(u32, u32)]) -> Csr {
    // Symmetrize: keep both directions of every edge.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(raw_edges.len() * 2);
    for &(u, v) in raw_edges {
        if u != v {
            edges.push((u, v));
            edges.push((v, u));
        }
    }
    // Sort + dedup gives dedup'd, neighbor-sorted edge blocks.
    parallel::par_sort_unstable(&mut edges);
    edges.dedup();

    let mut offsets = vec![0u64; n + 1];
    for &(u, _) in &edges {
        offsets[u as usize + 1] += 1;
    }
    for i in 0..n {
        offsets[i + 1] += offsets[i];
    }
    let targets: Vec<u32> = edges.iter().map(|&(_, v)| v).collect();
    Csr::from_parts(offsets, targets)
}

/// Count undirected edges of a symmetric CSR (directed / 2).
pub fn undirected_edge_count(g: &Csr) -> usize {
    debug_assert_eq!(g.m_directed() % 2, 0, "graph not symmetric");
    g.m_directed() / 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedups_and_symmetrizes() {
        // Duplicates, self-loop, one direction only.
        let edges = vec![(0, 1), (0, 1), (1, 0), (2, 2), (1, 3)];
        let g = build_undirected_csr(4, &edges);
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(1), &[0, 3]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        assert_eq!(g.neighbors(3), &[1]);
        assert_eq!(undirected_edge_count(&g), 2);
    }

    #[test]
    fn symmetric_invariant() {
        let edges = vec![(0, 3), (3, 1), (2, 0), (1, 2)];
        let g = build_undirected_csr(4, &edges);
        for (u, v) in g.edges().collect::<Vec<_>>() {
            assert!(g.neighbors(v).contains(&u), "missing reverse of ({u},{v})");
        }
    }

    #[test]
    fn neighbor_lists_sorted() {
        let edges = vec![(0, 3), (0, 1), (0, 2)];
        let g = build_undirected_csr(4, &edges);
        assert_eq!(g.neighbors(0), &[1, 2, 3]);
    }

    #[test]
    fn empty_graph() {
        let g = build_undirected_csr(5, &[]);
        assert_eq!(g.n(), 5);
        assert_eq!(g.m_directed(), 0);
    }
}
