//! Loose-sparse-row graph storage (paper §IV-A).
//!
//! "The vertex records are stored in a dense array, and each record points
//! to an edge block ... the edge block is an array of neighbor vertices."
//! On the host this is a standard CSR; vertex ids are u32 in memory for
//! cache efficiency, but the *timing model* charges 8 bytes per integer as
//! on the Pathfinder ("All integers are 64 bits wide"), see
//! [`Csr::PAPER_INT_BYTES`].

/// Compressed sparse row directed graph (representing an undirected graph
/// by holding both (i,j) and (j,i)).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// Row offsets, length n+1.
    offsets: Vec<u64>,
    /// Concatenated neighbor lists ("edge blocks").
    targets: Vec<u32>,
}

impl Csr {
    /// Width of one integer in the paper's representation (timing model).
    pub const PAPER_INT_BYTES: u64 = 8;

    /// Build from row offsets + targets. Panics if malformed.
    pub fn from_parts(offsets: Vec<u64>, targets: Vec<u32>) -> Self {
        assert!(!offsets.is_empty(), "offsets must have n+1 entries");
        assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "offsets not monotone");
        assert_eq!(*offsets.last().unwrap() as usize, targets.len());
        let n = offsets.len() - 1;
        assert!(targets.iter().all(|&t| (t as usize) < n), "target out of range");
        Csr { offsets, targets }
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of directed edges (2x the undirected edge count).
    pub fn m_directed(&self) -> usize {
        self.targets.len()
    }

    /// Out-degree of a vertex.
    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Neighbor slice ("edge block") of a vertex.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    /// Iterate all directed edges (u, v).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.n() as u32).flat_map(move |u| self.neighbors(u).iter().map(move |&v| (u, v)))
    }

    /// Raw offsets (for I/O and the simulator's layout math).
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Raw targets.
    pub fn targets(&self) -> &[u32] {
        &self.targets
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.n() as u32).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Bytes of one vertex's edge block in the paper's 64-bit representation.
    pub fn edge_block_bytes(&self, v: u32) -> u64 {
        self.degree(v) as u64 * Self::PAPER_INT_BYTES
    }

    /// Dense 0/1 adjacency in row-major f32, for the GraphBLAS baseline
    /// engine. Only sensible for small n (the baseline's fixed artifact
    /// shape); panics if n exceeds `max_n`.
    pub fn dense_adjacency_f32(&self, max_n: usize) -> Vec<f32> {
        let n = self.n();
        assert!(
            n <= max_n,
            "dense adjacency requested for n={n} > cap {max_n}; use a smaller graph"
        );
        let mut a = vec![0.0f32; n * n];
        for u in 0..n as u32 {
            for &v in self.neighbors(u) {
                a[u as usize * n + v as usize] = 1.0;
            }
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Csr {
        // 0 - 1 - 2 undirected
        Csr::from_parts(vec![0, 1, 3, 4], vec![1, 0, 2, 1])
    }

    #[test]
    fn basic_shape() {
        let g = path3();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m_directed(), 4);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn edges_iterator() {
        let g = path3();
        let e: Vec<_> = g.edges().collect();
        assert_eq!(e, vec![(0, 1), (1, 0), (1, 2), (2, 1)]);
    }

    #[test]
    fn dense_adjacency() {
        let g = path3();
        let a = g.dense_adjacency_f32(8);
        assert_eq!(a.len(), 9);
        assert_eq!(a[0 * 3 + 1], 1.0);
        assert_eq!(a[1 * 3 + 0], 1.0);
        assert_eq!(a[0 * 3 + 2], 0.0);
    }

    #[test]
    #[should_panic(expected = "offsets not monotone")]
    fn rejects_bad_offsets() {
        Csr::from_parts(vec![0, 2, 1], vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "target out of range")]
    fn rejects_bad_targets() {
        Csr::from_parts(vec![0, 1], vec![5]);
    }

    #[test]
    fn edge_block_bytes_are_64bit() {
        let g = path3();
        assert_eq!(g.edge_block_bytes(1), 16); // 2 neighbors x 8 B
    }
}
