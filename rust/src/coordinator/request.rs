//! A scheduled unit of work: one [`Analysis`] plus scheduling metadata.
//!
//! The coordinator, planner, metrics and service all traffic in
//! [`QueryRequest`]s. The analysis says *what* to compute; the request
//! adds *when* it arrives, which priority class it belongs to, and an
//! optional latency deadline — the knobs a serving deployment schedules
//! and reports on. All three are threaded into the engine's
//! [`crate::sim::flow::QuerySpec`] by
//! [`crate::coordinator::Coordinator::prepare`], where admission orders
//! the wait queue by priority, sheds expired deadlines, and accounts the
//! analysis's declared context bytes.

use crate::alg::Analysis;
use std::sync::Arc;

/// Scheduling priority class (re-exported from the engine, which orders
/// its wait queue by it: `Interactive < Standard < Batch`).
pub use crate::sim::flow::Priority;

/// One analysis submitted for execution, with scheduling metadata.
#[derive(Debug, Clone)]
pub struct QueryRequest {
    /// The analysis to run.
    pub analysis: Arc<dyn Analysis>,
    /// Simulated arrival time (ns); 0 = present at batch start.
    pub arrival_ns: f64,
    /// Priority class.
    pub priority: Priority,
    /// Optional end-to-end latency budget (ns, measured from arrival).
    pub deadline_ns: Option<f64>,
}

impl QueryRequest {
    /// Wrap a concrete analysis with default metadata (arrival 0,
    /// [`Priority::Standard`], no deadline).
    pub fn new<A: Analysis + 'static>(analysis: A) -> Self {
        Self::from_arc(Arc::new(analysis))
    }

    /// Wrap an already-shared analysis with default metadata.
    pub fn from_arc(analysis: Arc<dyn Analysis>) -> Self {
        QueryRequest { analysis, arrival_ns: 0.0, priority: Priority::default(), deadline_ns: None }
    }

    /// Set the arrival time (ns).
    pub fn at(mut self, arrival_ns: f64) -> Self {
        self.arrival_ns = arrival_ns;
        self
    }

    /// Set the priority class.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set a latency deadline (ns from arrival).
    pub fn with_deadline_ns(mut self, deadline_ns: f64) -> Self {
        self.deadline_ns = Some(deadline_ns);
        self
    }

    /// The analysis's class label.
    pub fn label(&self) -> &'static str {
        self.analysis.label()
    }
}

impl std::fmt::Display for QueryRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.analysis.describe())
    }
}

/// Distinct labels in order of first appearance — the canonical class
/// ordering shared by per-class reports
/// ([`crate::coordinator::metrics::RunReport::labels`]) and the
/// sequential baseline
/// ([`crate::coordinator::planner::sequential_mix_order`]).
pub fn distinct_labels(labels: impl Iterator<Item = &'static str>) -> Vec<&'static str> {
    let mut out: Vec<&'static str> = Vec::new();
    for l in labels {
        if !out.contains(&l) {
            out.push(l);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::{Bfs, Cc};

    #[test]
    fn defaults_and_builders() {
        let r = QueryRequest::new(Bfs { src: 42 });
        assert_eq!(r.arrival_ns, 0.0);
        assert_eq!(r.priority, Priority::Standard);
        assert!(r.deadline_ns.is_none());
        assert_eq!(r.label(), "bfs");
        assert_eq!(r.to_string(), "bfs(src=42)");

        let r = QueryRequest::new(Cc)
            .at(1e9)
            .with_priority(Priority::Interactive)
            .with_deadline_ns(5e9);
        assert_eq!(r.arrival_ns, 1e9);
        assert_eq!(r.priority, Priority::Interactive);
        assert_eq!(r.deadline_ns, Some(5e9));
        assert_eq!(r.to_string(), "cc");
    }

    #[test]
    fn clone_shares_the_analysis() {
        let r = QueryRequest::new(Bfs { src: 1 });
        let c = r.clone();
        assert!(Arc::ptr_eq(&r.analysis, &c.analysis));
    }

    #[test]
    fn priority_orders_interactive_first() {
        assert!(Priority::Interactive < Priority::Standard);
        assert!(Priority::Standard < Priority::Batch);
    }
}
