//! Thread-context memory accounting (paper §IV-B).
//!
//! "Running 256 concurrent queries on eight nodes exhausted the memory used
//! for thread contexts." Each in-flight query reserves stack/context space
//! on every node; the ledger tracks reservations and refuses admissions
//! that would not fit, so overload degrades into rejection (or queueing,
//! via [`crate::sim::flow::Admission`]) instead of the paper's crash.

use crate::config::machine::MachineConfig;
use crate::sim::flow::{Admission, OnFull};

/// Why an admission was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContextExhausted {
    pub in_flight: usize,
    pub capacity: usize,
}

impl std::fmt::Display for ContextExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "thread-context memory exhausted: {} queries in flight, capacity {}",
            self.in_flight, self.capacity
        )
    }
}

impl std::error::Error for ContextExhausted {}

/// Per-machine context-memory ledger.
#[derive(Debug, Clone)]
pub struct ContextLedger {
    capacity: usize,
    in_flight: usize,
    /// High-water mark (diagnostics).
    peak: usize,
    /// Total refused admissions.
    refusals: usize,
}

impl ContextLedger {
    /// Build from a machine config: capacity is how many per-query context
    /// reservations fit in the per-node context memory.
    pub fn new(cfg: &MachineConfig) -> Self {
        ContextLedger {
            capacity: cfg.max_concurrent_queries(),
            in_flight: 0,
            peak: 0,
            refusals: 0,
        }
    }

    /// Build with an explicit capacity (tests, what-if runs).
    pub fn with_capacity(capacity: usize) -> Self {
        ContextLedger { capacity, in_flight: 0, peak: 0, refusals: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn refusals(&self) -> usize {
        self.refusals
    }

    /// Reserve context memory for one query.
    pub fn admit(&mut self) -> Result<(), ContextExhausted> {
        if self.in_flight >= self.capacity {
            self.refusals += 1;
            return Err(ContextExhausted { in_flight: self.in_flight, capacity: self.capacity });
        }
        self.in_flight += 1;
        self.peak = self.peak.max(self.in_flight);
        Ok(())
    }

    /// Release one query's reservation.
    pub fn release(&mut self) {
        assert!(self.in_flight > 0, "release without admit");
        self.in_flight -= 1;
    }

    /// Whether `k` queries can run fully concurrently on this machine.
    pub fn fits(&self, k: usize) -> bool {
        k <= self.capacity
    }

    /// The flow-engine admission policy this ledger implies.
    pub fn policy(&self, on_full: OnFull) -> Admission {
        Admission { max_in_flight: Some(self.capacity), on_full }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;

    #[test]
    fn paper_exhaustion_point_on_8_nodes() {
        // 128 concurrent queries ran (§IV-B); 256 exhausted context memory.
        let l = ContextLedger::new(&MachineConfig::pathfinder_8());
        assert!(l.fits(128));
        assert!(!l.fits(256));
    }

    #[test]
    fn full_pathfinder_runs_750() {
        let l = ContextLedger::new(&MachineConfig::pathfinder_32());
        assert!(l.fits(750));
    }

    #[test]
    fn admit_release_cycle() {
        let mut l = ContextLedger::with_capacity(2);
        l.admit().unwrap();
        l.admit().unwrap();
        let err = l.admit().unwrap_err();
        assert_eq!(err.in_flight, 2);
        assert_eq!(l.refusals(), 1);
        l.release();
        l.admit().unwrap();
        assert_eq!(l.peak(), 2);
    }

    #[test]
    #[should_panic(expected = "release without admit")]
    fn release_underflow_panics() {
        ContextLedger::with_capacity(1).release();
    }

    #[test]
    fn policy_carries_capacity() {
        let l = ContextLedger::with_capacity(7);
        let p = l.policy(OnFull::Queue);
        assert_eq!(p.max_in_flight, Some(7));
        assert_eq!(p.on_full, OnFull::Queue);
    }
}
