//! Thread-context memory accounting (paper §IV-B).
//!
//! "Running 256 concurrent queries on eight nodes exhausted the memory used
//! for thread contexts." Each in-flight query reserves stack/context space
//! on every node; the byte ledger tracks the **bytes** each query reserves
//! (an [`crate::alg::Analysis`] may declare a non-default footprint) and
//! refuses admissions that would not fit, so overload degrades into a
//! typed rejection (or priority-ordered queueing/shedding, via
//! [`crate::sim::flow::Admission`]) instead of the paper's crash.
//!
//! The ledger itself lives in [`crate::sim::ledger`] because the flow
//! engine is what admits against and releases into it during a run
//! (`FlowSim::run_admitted`); the coordinator builds it from the machine
//! config ([`crate::coordinator::Coordinator::ledger`]) and uses it to
//! pre-check declared footprints — a query larger than the whole machine
//! is refused up front with the typed [`ContextExhausted`] error.
//!
//! Accounting is exact: the in-flight set's actual reserved bytes are
//! summed, rather than dividing total capacity by the batch's fattest
//! declared footprint (the conservative pre-byte-accounting heuristic),
//! so one fat query no longer shrinks the whole machine for everyone.

pub use crate::sim::ledger::{ContextExhausted, ContextLedger};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;

    #[test]
    fn paper_exhaustion_point_on_8_nodes() {
        // 128 concurrent queries ran (§IV-B); 256 exhausted context memory.
        let l = ContextLedger::new(&MachineConfig::pathfinder_8());
        assert!(l.fits(128));
        assert!(!l.fits(256));
    }

    #[test]
    fn full_pathfinder_runs_750() {
        let l = ContextLedger::new(&MachineConfig::pathfinder_32());
        assert!(l.fits(750));
    }

    #[test]
    fn capacity_queries_matches_machine_config() {
        let cfg = MachineConfig::pathfinder_8();
        let l = ContextLedger::new(&cfg);
        assert_eq!(l.capacity_queries(), cfg.max_concurrent_queries());
    }
}
