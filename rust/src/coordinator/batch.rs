//! The coordinator batcher: coalesce compatible queued requests into
//! fused multi-source engine queries (DESIGN.md §Batching).
//!
//! The fusion rule: two requests fuse iff they declare the same `Some`
//! [`Analysis::batch_key`], were prepared against the **same graph
//! epoch**, arrive within [`BatchConfig::window_ns`] of the group's first
//! member, and the group stays within [`BatchConfig::width`] (≤
//! [`MAX_BATCH_SOURCES`]). Requests whose analysis declares no batch key
//! pass through untouched, one spec each — batching off is byte-identical
//! to the pre-batching coordinator.
//!
//! A fused group becomes ONE engine query ([`BatchedAnalysis`]):
//!
//! * **arrival** = the last member's arrival (the batcher waits, at most
//!   `window`, for the group to fill);
//! * **priority** = the best (lowest-ordinal) member class — a batch
//!   carrying one Interactive member is Interactive work; cross-priority
//!   fusion trades the slower members' class up, never the faster's down;
//! * **deadline** = the tightest member budget re-based to the fused
//!   arrival (`min over members of (member arrival + deadline) − fused
//!   arrival`), so admission sheds the batch no later than it would have
//!   shed its most impatient member;
//! * **context bytes** = Σ member footprints (fusing shares the sweep,
//!   not the members' per-query state).
//!
//! Per-member accounting survives fusion: the plan keeps an original →
//! fused index map, and [`crate::coordinator::RunReport::from_flow_grouped`]
//! fans the fused timing back out so every member request keeps its own
//! arrival, latency, deadline and SLO record.

use crate::alg::msbfs::{BatchedAnalysis, MAX_BATCH_SOURCES};
use crate::alg::Analysis;
use crate::coordinator::request::QueryRequest;
use std::sync::Arc;

/// Configuration of the batcher (`serve --batch width=W,window=T`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchConfig {
    /// Maximum fused batch width (1..=[`MAX_BATCH_SOURCES`]).
    pub width: usize,
    /// Maximum spread (ns) between a group's first and last member
    /// arrival: how long the batcher will hold a group open to fill it.
    pub window_ns: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        // Width 16 on a 1 ms window: wide enough to matter on the paper's
        // query rates, short enough that the held-back head query's extra
        // wait stays in interactive territory.
        BatchConfig { width: 16, window_ns: 1e6 }
    }
}

impl BatchConfig {
    /// Parse `width=W[,window=T]` (window in **seconds**, like the other
    /// CLI time knobs); an empty spec takes the defaults.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut cfg = BatchConfig::default();
        for piece in spec.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (key, value) = piece
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("batch spec piece {piece:?} is not key=value"))?;
            let value = value.trim();
            match key.trim() {
                "width" => {
                    cfg.width = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("batch width={value:?} is not a count"))?
                }
                "window" => {
                    let s: f64 = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("batch window={value:?} is not seconds"))?;
                    cfg.window_ns = s * 1e9;
                }
                other => anyhow::bail!("unknown batch key {other:?} (want width/window)"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (1..=MAX_BATCH_SOURCES).contains(&self.width),
            "batch width must be 1..={MAX_BATCH_SOURCES}, got {}",
            self.width
        );
        anyhow::ensure!(
            self.window_ns.is_finite() && self.window_ns >= 0.0,
            "batch window must be a non-negative time, got {} ns",
            self.window_ns
        );
        Ok(())
    }

    /// Compact spec string for report headers (round-trips through
    /// [`BatchConfig::parse`]).
    pub fn label(&self) -> String {
        format!("width={},window={}", self.width, self.window_ns * 1e-9)
    }
}

/// A batching plan over one request list: the fused request per group plus
/// the original → fused index map the grouped report needs.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    fused: Vec<QueryRequest>,
    /// `group_of[i]` = index into `fused` serving original request `i`.
    group_of: Vec<usize>,
    /// Original indices per fused request (member order = source order).
    groups: Vec<Vec<usize>>,
}

impl BatchPlan {
    /// Plan batches over `requests` in arrival order. `epochs`, when
    /// given, carries the graph epoch each request was admitted against
    /// (one per request); requests at different epochs never fuse. With
    /// `epochs` absent every request shares epoch 0 (the static-graph
    /// paths).
    pub fn build(
        requests: &[QueryRequest],
        epochs: Option<&[u64]>,
        cfg: &BatchConfig,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        if let Some(e) = epochs {
            anyhow::ensure!(
                e.len() == requests.len(),
                "epoch list ({}) does not match request list ({})",
                e.len(),
                requests.len()
            );
        }
        // Scan in arrival order (stable on ties: submission order).
        let mut order: Vec<usize> = (0..requests.len()).collect();
        order.sort_by(|&a, &b| {
            requests[a]
                .arrival_ns
                .partial_cmp(&requests[b].arrival_ns)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });

        // One open group per (batch key, epoch); closed when full, when
        // the window from its head arrival is exceeded, or at end.
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut open: std::collections::HashMap<(String, u64), usize> =
            std::collections::HashMap::new();
        for &i in &order {
            let req = &requests[i];
            let epoch = epochs.map_or(0, |e| e[i]);
            match req.analysis.batch_key() {
                None => groups.push(vec![i]),
                Some(key) => {
                    let slot = open.entry((key, epoch));
                    match slot {
                        std::collections::hash_map::Entry::Occupied(mut o) => {
                            let gi = *o.get();
                            let head = groups[gi][0];
                            let fits = groups[gi].len() < cfg.width
                                && req.arrival_ns - requests[head].arrival_ns <= cfg.window_ns;
                            if fits {
                                groups[gi].push(i);
                            } else {
                                groups.push(vec![i]);
                                o.insert(groups.len() - 1);
                            }
                        }
                        std::collections::hash_map::Entry::Vacant(v) => {
                            groups.push(vec![i]);
                            v.insert(groups.len() - 1);
                        }
                    }
                }
            }
        }
        // Fused specs run in group-open order (= arrival order of heads),
        // matching how a live batcher would dispatch them.
        let mut fused = Vec::with_capacity(groups.len());
        let mut group_of = vec![0usize; requests.len()];
        for (gi, members) in groups.iter().enumerate() {
            for &i in members {
                group_of[i] = gi;
            }
            fused.push(fuse_group(requests, members)?);
        }
        Ok(BatchPlan { fused, group_of, groups })
    }

    /// The fused request list, one engine query per group.
    pub fn fused(&self) -> &[QueryRequest] {
        &self.fused
    }

    /// Original request index → fused request index.
    pub fn group_of(&self) -> &[usize] {
        &self.group_of
    }

    /// Original indices per fused request, in member (= source) order.
    pub fn groups(&self) -> &[Vec<usize>] {
        &self.groups
    }

    /// Number of fused engine queries.
    pub fn len(&self) -> usize {
        self.fused.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fused.is_empty()
    }

    /// Width of the widest fused group.
    pub fn max_width(&self) -> usize {
        self.groups.iter().map(Vec::len).max().unwrap_or(0)
    }
}

/// Fuse one group of request indices into a single [`QueryRequest`]
/// (module docs: arrival = last member, priority = best member, deadline =
/// tightest member re-based). A singleton group passes through as a clone
/// of the original — no wrapper, no demand change.
pub fn fuse_group(requests: &[QueryRequest], members: &[usize]) -> anyhow::Result<QueryRequest> {
    anyhow::ensure!(!members.is_empty(), "cannot fuse an empty group");
    if members.len() == 1 {
        return Ok(requests[members[0]].clone());
    }
    let analyses: Vec<Arc<dyn Analysis>> =
        members.iter().map(|&i| Arc::clone(&requests[i].analysis)).collect();
    let batched = BatchedAnalysis::fuse(analyses)?;
    let arrival_ns =
        members.iter().map(|&i| requests[i].arrival_ns).fold(f64::NEG_INFINITY, f64::max);
    let priority = members.iter().map(|&i| requests[i].priority).min().expect("non-empty");
    let deadline_ns = members
        .iter()
        .filter_map(|&i| {
            let r = &requests[i];
            r.deadline_ns.map(|d| (r.arrival_ns + d - arrival_ns).max(0.0))
        })
        .fold(None, |acc: Option<f64>, d| Some(acc.map_or(d, |a| a.min(d))));
    let mut req = QueryRequest::from_arc(Arc::new(batched)).at(arrival_ns).with_priority(priority);
    if let Some(d) = deadline_ns {
        req = req.with_deadline_ns(d);
    }
    Ok(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::{Bfs, Cc};
    use crate::coordinator::request::Priority;

    fn bfs_at(src: u32, arrival_ns: f64) -> QueryRequest {
        QueryRequest::new(Bfs { src }).at(arrival_ns)
    }

    #[test]
    fn config_parses_and_round_trips() {
        let c = BatchConfig::parse("width=8, window=0.002").unwrap();
        assert_eq!(c.width, 8);
        assert_eq!(c.window_ns, 2e6);
        assert_eq!(c.label(), "width=8,window=0.002");
        let d = BatchConfig::parse("").unwrap();
        assert_eq!(d, BatchConfig::default());
        assert!(BatchConfig::parse("width=0").is_err());
        assert!(BatchConfig::parse("width=65").is_err());
        assert!(BatchConfig::parse("window=-1").is_err());
        assert!(BatchConfig::parse("depth=3").is_err());
        assert!(BatchConfig::parse("width").is_err());
    }

    #[test]
    fn same_key_same_epoch_requests_fuse_up_to_width() {
        let reqs: Vec<QueryRequest> = (0..5).map(|s| bfs_at(s, s as f64)).collect();
        let cfg = BatchConfig { width: 4, window_ns: 1e6 };
        let plan = BatchPlan::build(&reqs, None, &cfg).unwrap();
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.groups()[0], vec![0, 1, 2, 3]);
        assert_eq!(plan.groups()[1], vec![4]);
        assert_eq!(plan.group_of(), &[0, 0, 0, 0, 1]);
        assert_eq!(plan.max_width(), 4);
        // The fused request schedules at the LAST member's arrival.
        assert_eq!(plan.fused()[0].arrival_ns, 3.0);
        assert_eq!(plan.fused()[0].label(), "msbfs");
        // The trailing singleton passes through unwrapped.
        assert_eq!(plan.fused()[1].label(), "bfs");
    }

    #[test]
    fn window_closes_a_group() {
        let reqs =
            vec![bfs_at(0, 0.0), bfs_at(1, 5e5), bfs_at(2, 2e6), bfs_at(3, 2.1e6)];
        let cfg = BatchConfig { width: 16, window_ns: 1e6 };
        let plan = BatchPlan::build(&reqs, None, &cfg).unwrap();
        assert_eq!(plan.groups(), &[vec![0, 1], vec![2, 3]]);
    }

    #[test]
    fn unbatchable_and_cross_epoch_requests_stay_solo() {
        let reqs = vec![
            bfs_at(0, 0.0),
            QueryRequest::new(Cc).at(1.0),
            bfs_at(1, 2.0),
            bfs_at(2, 3.0),
        ];
        // Epochs: the two batchable BFS land on different epochs.
        let plan = BatchPlan::build(
            &reqs,
            Some(&[0, 0, 1, 1]),
            &BatchConfig::default(),
        )
        .unwrap();
        // bfs@epoch0 solo, cc solo, the two bfs@epoch1 fuse.
        assert_eq!(plan.groups(), &[vec![0], vec![1], vec![2, 3]]);
        assert_eq!(plan.fused()[1].label(), "cc");
        assert_eq!(plan.fused()[2].label(), "msbfs");
    }

    #[test]
    fn fused_priority_and_deadline_take_the_tightest_member() {
        let reqs = vec![
            bfs_at(0, 0.0).with_priority(Priority::Batch).with_deadline_ns(5e6),
            bfs_at(1, 1e5).with_priority(Priority::Interactive),
            bfs_at(2, 2e5).with_deadline_ns(3e6),
        ];
        let fused = fuse_group(&reqs, &[0, 1, 2]).unwrap();
        assert_eq!(fused.arrival_ns, 2e5);
        assert_eq!(fused.priority, Priority::Interactive);
        // Member budgets re-based to the fused arrival: min(0 + 5e6,
        // 2e5 + 3e6) − 2e5 = 3e6.
        assert_eq!(fused.deadline_ns, Some(3e6));
    }

    #[test]
    fn plan_rejects_mismatched_epoch_list() {
        let reqs = vec![bfs_at(0, 0.0)];
        assert!(BatchPlan::build(&reqs, Some(&[0, 0]), &BatchConfig::default()).is_err());
    }

    #[test]
    fn arrival_order_not_submission_order_drives_grouping() {
        // Submitted out of order: the scan still groups by arrival.
        let reqs = vec![bfs_at(0, 2e6), bfs_at(1, 0.0), bfs_at(2, 1e5)];
        let cfg = BatchConfig { width: 16, window_ns: 1e6 };
        let plan = BatchPlan::build(&reqs, None, &cfg).unwrap();
        assert_eq!(plan.groups(), &[vec![1, 2], vec![0]]);
        assert_eq!(plan.group_of(), &[1, 0, 0]);
    }
}
