//! The fleet routing layer: `serve --fleet` (DESIGN.md §Fleet).
//!
//! A [`Fleet`] binds the three sharded-cluster pieces together:
//!
//! * a [`Partition`] (vertex → owner shard, per-shard sub-CSRs, cut-arc
//!   accounting — [`crate::graph::partition`]);
//! * a [`Cluster`] (`shards x replicas` chassis flattened into one
//!   simulatable machine — [`crate::sim::cluster`]);
//! * the per-query **routing + demand models** below, which decide which
//!   fleet members a query touches and price the cross-shard traffic on
//!   the fleet interconnect ([`PhaseDemand::interconnect_bytes`]).
//!
//! Three demand models cover every workload class:
//!
//! * **Rooted traversals** ([`Analysis::source_vertex`] = `Some`): the
//!   fleet runs the level-synchronous traversal explicitly
//!   ([`Fleet::traversal_phases`]). Each frontier vertex expands on its
//!   *owner* shard's chassis exactly like the single-machine tuned BFS
//!   (same migrations, record reads, edge-block streams, unconditional
//!   level writes); an edge whose head lives on **another shard** ships
//!   its frontier candidate over the interconnect instead of the
//!   intra-machine fabric — 16 bytes at the expanding node, priced per
//!   level, so every level with cross-shard discovery also pays the
//!   interconnect round-trip floor. SSSP's bucket refinements and k-hop's
//!   depth cap collapse into the same level structure (the fleet prices
//!   the full expansion — conservative for k-hop).
//! * **Whole-graph analyses** (`source_vertex` = `None`): the base
//!   machine's own demand phases are **scattered** across shards
//!   proportionally to owned arcs ([`Fleet::scatter`]), each shard's
//!   slice embedded on its chassis, plus per-phase interconnect traffic
//!   for the shard's cut arcs (16 bytes each, spread over phases by
//!   channel-op weight). This is a deliberate fluid approximation: totals
//!   are conserved exactly, placement is per-shard exact, per-channel
//!   skew within a shard follows the base model.
//! * **Mutation batches** ([`Fleet::ingest_phase`]): the primary replica
//!   applies each update direction at the destination's owner chassis
//!   (the single-machine memory-side ingest rule), cross-shard endpoints
//!   paying interconnect instead of fabric; every further replica then
//!   receives the record over the **ordered log** (interconnect bytes
//!   from the primary) and splices it memory-side. One log, applied
//!   everywhere, is what keeps all replicas of a shard in the same epoch
//!   sequence — [`ReplicaSet`] is that invariant made executable, and the
//!   fleet-vs-single-node equivalence property tests pin it.
//!
//! **Read replicas**: query `id` is served by replica set `id mod R`
//! ([`Fleet::replica_of`]) — hot query classes spread across full fleet
//! copies while every answer stays bound to its pinned epoch (replicas
//! apply the same ordered log, so the same epoch means the same graph).
//!
//! There is **no demand cache** in the fleet path: routing makes demand
//! genuinely per-query (the replica assignment depends on the query id),
//! so the rotation-equivariance shortcut of the single-machine
//! coordinator does not apply.
//!
//! [`PhaseDemand::interconnect_bytes`]: crate::sim::demand::PhaseDemand

use anyhow::Result;

use super::request::QueryRequest;
use crate::alg::analysis::Analysis;
use crate::config::machine::MachineConfig;
use crate::graph::csr::Csr;
use crate::graph::delta::EdgeUpdate;
use crate::graph::partition::{Partition, PartitionStrategy};
use crate::graph::store::GraphStore;
use crate::graph::view::{GraphView, NeighborScratch};
use crate::sim::cluster::Cluster;
use crate::sim::demand::{DemandBuilder, PhaseDemand};
use crate::sim::flow::QuerySpec;
use crate::sim::machine::Machine;

/// Bytes per cross-shard frontier candidate / log record half-edge — the
/// same 16-byte message the single-machine models charge the fabric for.
const INTERCONNECT_MSG_BYTES: f64 = 16.0;

/// Configuration of `serve --fleet nodes=N,replicas=R,partition=...`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetConfig {
    /// Shard count (`nodes=` in the CLI spec: fleet machines holding
    /// distinct graph shards).
    pub shards: usize,
    /// Full fleet copies (`replicas=`): each adds one more chassis per
    /// shard serving the same ordered update log.
    pub replicas: usize,
    /// Vertex partitioning strategy (`partition=hash|balanced`).
    pub strategy: PartitionStrategy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { shards: 4, replicas: 1, strategy: PartitionStrategy::Hash }
    }
}

impl FleetConfig {
    /// Parse `nodes=N[,replicas=R][,partition=hash|balanced]` (the CLI
    /// `serve --fleet` argument). Omitted keys keep defaults.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut cfg = FleetConfig::default();
        for piece in spec.split(',') {
            let piece = piece.trim();
            if piece.is_empty() {
                continue;
            }
            let (key, value) = piece
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fleet spec piece {piece:?} is not key=value"))?;
            let value = value.trim();
            match key.trim() {
                "nodes" => {
                    cfg.shards = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fleet nodes={value:?} is not a count"))?
                }
                "replicas" => {
                    cfg.replicas = value
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fleet replicas={value:?} is not a count"))?
                }
                "partition" => cfg.strategy = PartitionStrategy::parse(value)?,
                other => {
                    anyhow::bail!("unknown fleet key {other:?} (want nodes/replicas/partition)")
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.shards >= 1, "fleet needs at least one shard");
        anyhow::ensure!(self.replicas >= 1, "fleet needs at least one replica");
        Ok(())
    }

    /// Compact spec string for report headers (round-trips through
    /// [`FleetConfig::parse`]).
    pub fn label(&self) -> String {
        format!(
            "nodes={},replicas={},partition={}",
            self.shards,
            self.replicas,
            self.strategy.label()
        )
    }
}

/// A sharded, replicated fleet serving one graph: partition + flattened
/// cluster + the per-query routing/demand models (module docs).
#[derive(Debug)]
pub struct Fleet {
    cfg: FleetConfig,
    partition: Partition,
    cluster: Cluster,
    /// One base chassis, used to compute the demand shapes that
    /// [`Fleet::scatter`] splits across shards.
    base: Machine,
}

impl Fleet {
    /// Shard `g` and build the fleet on copies of the `base` machine.
    pub fn new(g: &Csr, base: &MachineConfig, cfg: FleetConfig) -> Result<Self> {
        cfg.validate()?;
        let partition = Partition::build(g, cfg.shards, cfg.strategy);
        partition.check_invariants(g)?;
        Ok(Fleet {
            cfg,
            partition,
            cluster: Cluster::new(base, cfg.shards, cfg.replicas),
            base: Machine::new(base.clone()),
        })
    }

    /// The flattened fleet machine the flow engine runs against.
    pub fn machine(&self) -> &Machine {
        self.cluster.machine()
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Read-replica routing: query `id` is served by replica set
    /// `id mod R`, spreading hot query classes across fleet copies.
    #[inline]
    pub fn replica_of(&self, query_id: usize) -> usize {
        query_id % self.cfg.replicas
    }

    /// Prepare one request against the fleet: route to a replica set,
    /// price with the rooted-traversal, fused-batch or scatter model, and
    /// bind the admission metadata — the fleet counterpart of
    /// [`crate::coordinator::Coordinator::prepare_one`] (no demand cache;
    /// module docs explain why).
    ///
    /// Routing generalizes from a single source vertex to the analysis's
    /// [`Analysis::source_set`]: a one-element set is the classic rooted
    /// traversal (byte-identical to the pre-batching router), a wider set
    /// is a fused batch priced by [`Fleet::batched_traversal_phases`],
    /// and `None` scatters as before.
    pub fn prepare_one(
        &self,
        view: GraphView<'_>,
        req: &QueryRequest,
        id: usize,
        stripe_offset: usize,
    ) -> QuerySpec {
        let a = req.analysis.as_ref();
        let replica = self.replica_of(id);
        let phases = match a.source_set() {
            Some(srcs) if srcs.len() == 1 => {
                self.traversal_phases(view, srcs[0], replica, stripe_offset)
            }
            Some(srcs) => self.batched_traversal_phases(view, &srcs, replica, stripe_offset),
            None => self.scatter_phases(view, a, replica, stripe_offset),
        };
        QuerySpec {
            id,
            label: a.label(),
            phases,
            arrival_ns: req.arrival_ns,
            priority: req.priority,
            deadline_ns: req.deadline_ns,
            ctx_bytes: a
                .ctx_mem_bytes(view, self.machine())
                .unwrap_or(self.machine().cfg.ctx_bytes_per_query),
        }
    }

    /// Explicit distributed level-synchronous traversal from `src` on
    /// replica set `replica`: the single-machine tuned-BFS charging rule
    /// per frontier vertex, placed on each vertex's owner chassis, with
    /// cross-shard frontier candidates shipped over the fleet
    /// interconnect (module docs). One phase per level, so every level
    /// with cross-shard discovery pays the interconnect round trip — the
    /// per-level frontier-exchange cost the flattening alone would hide.
    pub fn traversal_phases(
        &self,
        view: GraphView<'_>,
        src: u32,
        replica: usize,
        stripe_offset: usize,
    ) -> Vec<PhaseDemand> {
        let m = self.machine();
        let lay = self.cluster.chassis_layout();
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let cfg = &m.cfg;
        let n = view.n();
        if src as usize >= n {
            return vec![PhaseDemand::zero(nodes, channels)];
        }

        let mut seen = vec![false; n];
        seen[src as usize] = true;
        let mut frontier = vec![src];
        let mut phases = Vec::new();
        let mut scratch = NeighborScratch::default();

        while !frontier.is_empty() {
            let mut b = DemandBuilder::new(nodes, channels);
            let mut next = Vec::new();
            let mut ops = 0.0f64;
            for &u in &frontier {
                let su = self.partition.owner_of(u);
                let un = self.cluster.vertex_node(self.cluster.chassis_of(su, replica), u);
                // Worker launch + record read + edge-block stream on the
                // owner chassis, exactly as on one machine (§III).
                b.migration(un, 1.0);
                b.fabric_bytes(un, 64.0);
                b.instructions(un, cfg.spawn_instr);
                b.channel_op(un, lay.channel_of(u), 1.0);
                ops += 1.0;
                let nbrs = view.neighbors(u, &mut scratch);
                let deg = nbrs.len();
                b.stream_bytes(un, GraphView::edge_block_bytes_for(deg) as f64);
                b.instructions(un, deg as f64 * cfg.instr_per_edge);
                for &v in nbrs {
                    let sv = self.partition.owner_of(v);
                    let vn = self.cluster.vertex_node(self.cluster.chassis_of(sv, replica), v);
                    // Unconditional level/parent write at v's home — on
                    // v's OWNER chassis of this replica set.
                    b.channel_op(vn, (lay.channel_of(v) + stripe_offset) % channels, 1.0);
                    ops += 1.0;
                    if sv != su {
                        // Cross-shard frontier candidate: the message
                        // leaves the machine, interconnect not fabric.
                        b.interconnect_bytes(un, INTERCONNECT_MSG_BYTES);
                    } else if vn != un {
                        b.fabric_bytes(un, INTERCONNECT_MSG_BYTES);
                    }
                    if !seen[v as usize] {
                        seen[v as usize] = true;
                        next.push(v);
                    }
                }
            }
            b.parallelism(ops.min(contexts_total));
            phases.push(b.finish());
            frontier = next;
        }
        phases
    }

    /// Distributed form of the fused multi-source sweep
    /// ([`crate::alg::msbfs`]): one level-synchronous bit-parallel
    /// traversal over the whole batch, placed on each vertex's owner
    /// chassis of replica set `replica`. Per union-frontier vertex the
    /// batch pays ONE worker launch / record read / edge-block stream;
    /// per scanned edge one MSP RMW ORs the frontier word into the
    /// head's — shipped over the fleet interconnect when the edge crosses
    /// shards, the intra-machine fabric otherwise; per newly-set
    /// `(source, vertex)` bit one node-local MSP `remote_min` relaxation
    /// in that member's stripe-rotated frame. A width-1 batch routes
    /// through [`Fleet::traversal_phases`] instead (the
    /// [`Fleet::prepare_one`] dispatch), keeping the classic path
    /// byte-identical.
    pub fn batched_traversal_phases(
        &self,
        view: GraphView<'_>,
        sources: &[u32],
        replica: usize,
        stripe_offset: usize,
    ) -> Vec<PhaseDemand> {
        let m = self.machine();
        let lay = self.cluster.chassis_layout();
        let nodes = m.nodes();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (nodes * m.cfg.contexts_per_node()) as f64;
        let cfg = &m.cfg;
        let n = view.n();

        let mut seen = vec![0u64; n];
        let mut frontier_mask = vec![0u64; n];
        let mut active: Vec<u32> = Vec::new();
        for (s, &src) in sources.iter().enumerate() {
            if (src as usize) < n {
                seen[src as usize] |= 1u64 << s;
                if frontier_mask[src as usize] == 0 {
                    active.push(src);
                }
                frontier_mask[src as usize] |= 1u64 << s;
            }
        }
        active.sort_unstable();
        if active.is_empty() {
            return vec![PhaseDemand::zero(nodes, channels)];
        }

        let mut phases = Vec::new();
        let mut scratch = NeighborScratch::default();
        while !active.is_empty() {
            let mut b = DemandBuilder::new(nodes, channels);
            let mut next_mask = vec![0u64; n];
            let mut touched: Vec<u32> = Vec::new();
            let mut ops = 0.0f64;
            for &u in &active {
                let su = self.partition.owner_of(u);
                let un = self.cluster.vertex_node(self.cluster.chassis_of(su, replica), u);
                // One launch + record/frontier-word read + edge-block
                // stream for the whole batch, on u's owner chassis.
                b.migration(un, 1.0);
                b.fabric_bytes(un, 64.0);
                b.instructions(un, cfg.spawn_instr);
                b.channel_op(un, lay.channel_of(u), 1.0);
                ops += 1.0;
                let fmask = frontier_mask[u as usize];
                let nbrs = view.neighbors(u, &mut scratch);
                let deg = nbrs.len();
                b.stream_bytes(un, GraphView::edge_block_bytes_for(deg) as f64);
                b.instructions(un, deg as f64 * cfg.instr_per_edge);
                for &v in nbrs {
                    let sv = self.partition.owner_of(v);
                    let vn = self.cluster.vertex_node(self.cluster.chassis_of(sv, replica), v);
                    // One MSP RMW carries the whole batch's frontier word.
                    b.msp_op(vn, (lay.channel_of(v) + stripe_offset) % channels, 1.0);
                    ops += 1.0;
                    if sv != su {
                        b.interconnect_bytes(un, INTERCONNECT_MSG_BYTES);
                    } else if vn != un {
                        b.fabric_bytes(un, INTERCONNECT_MSG_BYTES);
                    }
                    let new = fmask & !seen[v as usize];
                    if new != 0 {
                        if next_mask[v as usize] == 0 {
                            touched.push(v);
                        }
                        next_mask[v as usize] |= new;
                        seen[v as usize] |= new;
                        let vc = lay.channel_of(v);
                        let mut bits = new;
                        while bits != 0 {
                            let s = bits.trailing_zeros() as usize;
                            bits &= bits - 1;
                            // Per-(source, vertex) relaxation, node-local
                            // at v's home (the discovery is resolved
                            // where the frontier word lives).
                            b.msp_op(vn, (vc + stripe_offset + s) % channels, 1.0);
                            ops += 1.0;
                        }
                    }
                }
            }
            b.parallelism(ops.min(contexts_total));
            phases.push(b.finish());
            touched.sort_unstable();
            active = touched;
            std::mem::swap(&mut frontier_mask, &mut next_mask);
        }
        phases
    }

    /// Price a whole-graph analysis by scattering its base-machine demand
    /// across shards (module docs: arc-share split + cut-arc
    /// interconnect).
    pub fn scatter_phases(
        &self,
        view: GraphView<'_>,
        a: &dyn Analysis,
        replica: usize,
        stripe_offset: usize,
    ) -> Vec<PhaseDemand> {
        self.scatter(&a.phases(view, &self.base, stripe_offset), replica)
    }

    /// Embed base-chassis demand phases into the fleet: shard `s` runs
    /// the fraction `owned_arcs(s)/total_arcs` of every per-node quantity
    /// on its replica-`replica` chassis, plus `16 B x cut_arcs(s)` of
    /// interconnect traffic for the whole query, spread over phases by
    /// channel-op weight. With one shard this is the identity embedding
    /// (zero cut, factor 1), which the tests pin.
    pub fn scatter(&self, base_phases: &[PhaseDemand], replica: usize) -> Vec<PhaseDemand> {
        let npc = self.cluster.nodes_per_chassis();
        let cpn = self.machine().cfg.channels_per_node;
        let fleet_nodes = self.machine().nodes();
        let shards = self.cfg.shards;
        let total_arcs: usize = (0..shards).map(|s| self.partition.shard_arcs(s)).sum();
        let total_ops: f64 = base_phases.iter().map(|p| p.total_channel_ops()).sum();
        base_phases
            .iter()
            .map(|p| {
                debug_assert_eq!(p.nodes(), npc, "base phases come from one chassis");
                let w = if total_ops > 0.0 {
                    p.total_channel_ops() / total_ops
                } else {
                    1.0 / base_phases.len() as f64
                };
                let mut out = PhaseDemand::zero(fleet_nodes, cpn);
                out.serial_hops = p.serial_hops;
                out.issue_efficiency = p.issue_efficiency;
                out.parallelism = p.parallelism;
                for s in 0..shards {
                    let f = if total_arcs > 0 {
                        self.partition.shard_arcs(s) as f64 / total_arcs as f64
                    } else {
                        1.0 / shards as f64
                    };
                    let cut = INTERCONNECT_MSG_BYTES * self.partition.cut_arcs(s) as f64 * w
                        / npc as f64;
                    let base_node = self.cluster.chassis_of(s, replica) * npc;
                    for bn in 0..npc {
                        let fnode = base_node + bn;
                        out.channel_ops[fnode] += p.channel_ops[bn] * f;
                        out.max_channel_ops[fnode] = p.max_channel_ops[bn] * f;
                        out.stream_bytes[fnode] += p.stream_bytes[bn] * f;
                        out.instructions[fnode] += p.instructions[bn] * f;
                        out.fabric_bytes[fnode] += p.fabric_bytes[bn] * f;
                        out.migrations[fnode] += p.migrations[bn] * f;
                        out.msp_ops[fnode] += p.msp_ops[bn] * f;
                        out.interconnect_bytes[fnode] += cut;
                        for ch in 0..cpn {
                            out.per_channel_ops[fnode * cpn + ch] +=
                                p.per_channel_ops[bn * cpn + ch] * f;
                        }
                    }
                }
                out
            })
            .collect()
    }

    /// Demand of fanning one update batch out through the ordered log
    /// (module docs): primary apply at each destination's owner chassis
    /// (cross-shard endpoints pay interconnect instead of fabric), then
    /// one log shipment + memory-side splice per further replica.
    pub fn ingest_phase(&self, updates: &[EdgeUpdate]) -> PhaseDemand {
        let m = self.machine();
        let lay = self.cluster.chassis_layout();
        let channels = m.cfg.channels_per_node;
        let contexts_total = (m.nodes() * m.cfg.contexts_per_node()) as f64;
        let mut b = DemandBuilder::new(m.nodes(), channels);
        let mut ops = 0.0f64;
        for upd in updates {
            for (src, dst) in [(upd.u, upd.v), (upd.v, upd.u)] {
                let ss = self.partition.owner_of(src);
                let sd = self.partition.owner_of(dst);
                let dc = lay.channel_of(dst);
                let sn = self.cluster.vertex_node(self.cluster.chassis_of(ss, 0), src);
                let dn0 = self.cluster.vertex_node(self.cluster.chassis_of(sd, 0), dst);
                // Primary apply: unconditional remote write + MSP log
                // splice at dst's home, like single-machine ingest.
                b.channel_op(dn0, dc, 1.0);
                b.msp_op(dn0, dc, 1.0);
                ops += 2.0;
                b.instructions(sn, m.cfg.instr_per_edge);
                if ss != sd {
                    b.interconnect_bytes(sn, 2.0 * INTERCONNECT_MSG_BYTES);
                } else if dn0 != sn {
                    b.fabric_bytes(sn, 2.0 * INTERCONNECT_MSG_BYTES);
                }
                // Ordered-log shipping: every further replica of dst's
                // shard receives the record and splices it memory-side.
                for r in 1..self.cfg.replicas {
                    let dnr = self.cluster.vertex_node(self.cluster.chassis_of(sd, r), dst);
                    b.interconnect_bytes(dn0, 2.0 * INTERCONNECT_MSG_BYTES);
                    b.channel_op(dnr, dc, 1.0);
                    b.msp_op(dnr, dc, 1.0);
                    ops += 2.0;
                }
            }
        }
        if ops > 0.0 {
            b.parallelism(ops.min(contexts_total));
            b.issue_efficiency(1.0);
        }
        b.finish()
    }

    /// Fleet section of a service report: per-shard channel utilization
    /// over `duration_ns` (summed across the shard's replicas) plus total
    /// interconnect bytes, computed from the executed specs.
    pub fn stats(&self, specs: &[QuerySpec], duration_ns: f64) -> FleetStats {
        let m = self.machine();
        let npc = self.cluster.nodes_per_chassis();
        let shards = self.cfg.shards;
        let mut shard_ops = vec![0.0f64; shards];
        let mut interconnect = 0.0f64;
        for spec in specs {
            for p in &spec.phases {
                interconnect += p.total_interconnect_bytes();
                for node in 0..p.nodes() {
                    shard_ops[(node / npc) % shards] += p.channel_ops[node];
                }
            }
        }
        let shard_util = (0..shards)
            .map(|s| {
                let cap: f64 = (0..self.cfg.replicas)
                    .flat_map(|r| self.cluster.node_range(self.cluster.chassis_of(s, r)))
                    .map(|node| m.channel_op_rate(node))
                    .sum();
                if duration_ns > 0.0 && cap > 0.0 {
                    shard_ops[s] / (cap * duration_ns * 1e-9)
                } else {
                    0.0
                }
            })
            .collect();
        FleetStats {
            shards,
            replicas: self.cfg.replicas,
            strategy: self.cfg.strategy.label(),
            cut_fraction: self.partition.cut_fraction(),
            interconnect_bytes: interconnect,
            shard_util,
        }
    }
}

/// Fleet section of a [`crate::coordinator::ServiceReport`].
#[derive(Debug, Clone)]
pub struct FleetStats {
    pub shards: usize,
    pub replicas: usize,
    /// Partition strategy label ("hash" / "balanced").
    pub strategy: &'static str,
    /// Fraction of directed arcs crossing shards.
    pub cut_fraction: f64,
    /// Total bytes all queries pushed over the fleet interconnect.
    pub interconnect_bytes: f64,
    /// Per-shard channel utilization over the service duration (all
    /// replicas of the shard pooled).
    pub shard_util: Vec<f64>,
}

impl FleetStats {
    /// Operator-facing summary lines (README's `serve --fleet` block
    /// mirrors this shape).
    pub fn lines(&self) -> String {
        let util = self
            .shard_util
            .iter()
            .enumerate()
            .map(|(s, u)| format!("s{s} {:.0}%", 100.0 * u))
            .collect::<Vec<_>>()
            .join("  ");
        format!(
            "fleet: {} shards x {} replicas ({}), cut {:.1}%, interconnect {}\n  shard util: {}",
            self.shards,
            self.replicas,
            self.strategy,
            100.0 * self.cut_fraction,
            format_bytes(self.interconnect_bytes),
            util
        )
    }
}

fn format_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{:.0} B", b)
    }
}

/// Every replica of every shard as live epoch stores fed by ONE ordered
/// update log — the replication invariant of DESIGN.md §Fleet made
/// executable. Each store holds its shard's sub-CSR (global ids, unowned
/// rows empty) and applies every batch **filtered to updates with an
/// owned endpoint**; empty filtered batches still apply, so epoch
/// numbering stays globally aligned across all `shards x replicas`
/// stores. The theorem the property tests pin: at every epoch, every
/// owned row of every replica equals the global single-node store's row
/// — same epoch, same answers, regardless of shard or replica count.
#[derive(Debug)]
pub struct ReplicaSet {
    partition: Partition,
    replicas: usize,
    /// Replica-major: `stores[replica * shards + shard]`.
    stores: Vec<GraphStore>,
}

impl ReplicaSet {
    pub fn new(partition: Partition, replicas: usize) -> Self {
        assert!(replicas > 0, "need at least one replica");
        let stores = (0..replicas)
            .flat_map(|_| {
                (0..partition.shards).map(|s| GraphStore::new(partition.shard_graph(s)))
            })
            .collect();
        ReplicaSet { partition, replicas, stores }
    }

    pub fn shards(&self) -> usize {
        self.partition.shards
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    pub fn store(&self, shard: usize, replica: usize) -> &GraphStore {
        &self.stores[replica * self.partition.shards + shard]
    }

    pub fn store_mut(&mut self, shard: usize, replica: usize) -> &mut GraphStore {
        &mut self.stores[replica * self.partition.shards + shard]
    }

    fn n(&self) -> usize {
        self.partition.shard_graph(0).n()
    }

    /// Apply one batch through the ordered log to every store (filtered
    /// per shard, module docs). Returns the new epoch, identical across
    /// all stores by construction — asserted, not assumed.
    pub fn apply_batch(&mut self, updates: &[EdgeUpdate]) -> u64 {
        let shards = self.partition.shards;
        let n = self.n() as u32;
        let mut epoch = None;
        for r in 0..self.replicas {
            for s in 0..shards {
                let filtered: Vec<EdgeUpdate> = updates
                    .iter()
                    .filter(|upd| {
                        // Out-of-range endpoints reach no shard; the
                        // global store counts them invalid, and invalid
                        // updates touch no row either way.
                        (upd.u < n && self.partition.owner_of(upd.u) == s)
                            || (upd.v < n && self.partition.owner_of(upd.v) == s)
                    })
                    .copied()
                    .collect();
                let stats = self.stores[r * shards + s].apply_batch(&filtered);
                match epoch {
                    None => epoch = Some(stats.epoch),
                    Some(e) => assert_eq!(e, stats.epoch, "replica log out of step"),
                }
            }
        }
        epoch.expect("at least one store")
    }

    /// Materialize the fleet-wide graph at `epoch` as replica `replica`
    /// sees it: row `v` comes from `v`'s owner store. Equal to the global
    /// single-node store's materialization at the same epoch — the
    /// equivalence property tests compare exactly this.
    pub fn materialize(&self, epoch: u64, replica: usize) -> Result<Csr> {
        let shards = self.partition.shards;
        let views: Vec<GraphView<'_>> = (0..shards)
            .map(|s| self.store(s, replica).view_at(epoch))
            .collect::<Result<_>>()?;
        let n = self.n();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut targets = Vec::new();
        let mut scratch = NeighborScratch::default();
        offsets.push(0u64);
        for v in 0..n as u32 {
            let view = &views[self.partition.owner_of(v)];
            targets.extend_from_slice(view.neighbors(v, &mut scratch));
            offsets.push(targets.len() as u64);
        }
        Ok(Csr::from_parts(offsets, targets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alg::bfs::bfs_run_offset;
    use crate::alg::cc::Cc;
    use crate::graph::builder::build_undirected_csr;

    fn ring_with_hub(n: u32) -> Csr {
        let mut edges: Vec<(u32, u32)> = (0..n).map(|v| (v, (v + 1) % n)).collect();
        edges.extend((2..n).step_by(3).map(|v| (0, v)));
        build_undirected_csr(n as usize, &edges)
    }

    fn fleet(shards: usize, replicas: usize, g: &Csr) -> Fleet {
        let cfg = FleetConfig {
            shards,
            replicas,
            strategy: PartitionStrategy::Balanced,
        };
        Fleet::new(g, &MachineConfig::pathfinder_8(), cfg).unwrap()
    }

    #[test]
    fn parse_and_validate() {
        let c = FleetConfig::parse("nodes=4, replicas=2, partition=balanced").unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.replicas, 2);
        assert_eq!(c.strategy, PartitionStrategy::Balanced);
        assert_eq!(c.label(), "nodes=4,replicas=2,partition=balanced");
        // Defaults survive partial specs.
        let c = FleetConfig::parse("nodes=2").unwrap();
        assert_eq!(c.replicas, 1);
        assert_eq!(c.strategy, PartitionStrategy::Hash);
        assert!(FleetConfig::parse("nodes=0").is_err());
        assert!(FleetConfig::parse("replicas=0").is_err());
        assert!(FleetConfig::parse("partition=range").is_err());
        assert!(FleetConfig::parse("chassis=4").is_err());
        assert!(FleetConfig::parse("nodes").is_err());
    }

    /// A 1x1 fleet IS the single machine: every demand model degenerates
    /// to its single-machine counterpart exactly.
    #[test]
    fn fleet_of_one_is_the_single_machine() {
        let g = ring_with_hub(24);
        let f = fleet(1, 1, &g);
        let m = f.machine();
        assert_eq!(m.nodes(), 8);
        // Rooted traversal == the tuned BFS demand, phase by phase.
        let fleet_phases = f.traversal_phases(g.view(), 3, 0, 5);
        let solo = bfs_run_offset(g.view(), m, 3, 5);
        assert_eq!(fleet_phases, solo.phases);
        // Scatter == identity embedding of the base phases.
        let base = Cc.phases(g.view(), m, 2);
        assert_eq!(f.scatter(&base, 0), base);
        // Ingest == the single-machine memory-side ingest model.
        let upd = vec![EdgeUpdate::insert(1, 9), EdgeUpdate::delete(0, 1)];
        assert_eq!(f.ingest_phase(&upd), PhaseDemand::ingest_batch(m, &upd));
    }

    /// A 1x1 fleet's fused sweep IS the single-machine multi-source
    /// kernel, phase by phase — and `prepare_one` routes a fused batch to
    /// it through `source_set()` while width-1 work keeps the old path.
    #[test]
    fn fleet_of_one_batched_sweep_matches_msbfs() {
        use crate::alg::msbfs::{msbfs_run_offset, BatchedAnalysis};
        use std::sync::Arc;

        let g = ring_with_hub(24);
        let f = fleet(1, 1, &g);
        let m = f.machine();
        let sources = [3u32, 11, 0];
        let fleet_phases = f.batched_traversal_phases(g.view(), &sources, 0, 5);
        let solo = msbfs_run_offset(g.view(), m, &sources, 5);
        assert_eq!(fleet_phases, solo.phases);
        // Routing: a fused batch request is priced by the batched sweep.
        let members: Vec<Arc<dyn Analysis>> = sources
            .iter()
            .map(|&s| Arc::new(crate::alg::bfs::Bfs { src: s }) as Arc<dyn Analysis>)
            .collect();
        let req = QueryRequest::from_arc(Arc::new(BatchedAnalysis::fuse(members).unwrap()));
        let spec = f.prepare_one(g.view(), &req, 0, 5);
        assert_eq!(spec.label, "msbfs");
        assert_eq!(spec.phases, solo.phases);
        assert_eq!(spec.ctx_bytes, 3 * m.cfg.ctx_bytes_per_query);
    }

    /// On a sharded fleet the fused sweep ships cross-shard frontier
    /// words over the interconnect — and pays migrations for the UNION
    /// frontier, not per member.
    #[test]
    fn batched_sweep_shards_pay_interconnect_once_per_edge() {
        let g = ring_with_hub(24);
        let f = fleet(3, 1, &g);
        let sources = [0u32, 5, 9, 13];
        let phases = f.batched_traversal_phases(g.view(), &sources, 0, 0);
        let migs: f64 = phases.iter().map(|p| p.total_migrations()).sum();
        let indiv: f64 = sources
            .iter()
            .map(|&s| {
                f.traversal_phases(g.view(), s, 0, 0)
                    .iter()
                    .map(|p| p.total_migrations())
                    .sum::<f64>()
            })
            .sum();
        assert!(migs < indiv, "fused {migs} vs independent {indiv}");
        let inter: f64 = phases.iter().map(|p| p.total_interconnect_bytes()).sum();
        assert!(inter > 0.0, "a cut ring must ship frontier words");
    }

    #[test]
    fn cross_shard_traversal_ships_frontier_over_the_interconnect() {
        let g = ring_with_hub(24);
        let f = fleet(3, 1, &g);
        let phases = f.traversal_phases(g.view(), 0, 0, 0);
        // Totals conserved: one record read per reached vertex + one
        // write per scanned edge, exactly like one machine.
        let solo = bfs_run_offset(g.view(), &Machine::new(MachineConfig::pathfinder_8()), 0, 0);
        let fleet_ops: f64 = phases.iter().map(|p| p.total_channel_ops()).sum();
        let solo_ops: f64 = solo.phases.iter().map(|p| p.total_channel_ops()).sum();
        assert_eq!(fleet_ops, solo_ops);
        let migs: f64 = phases.iter().map(|p| p.total_migrations()).sum();
        assert_eq!(migs, solo.reached() as f64);
        // Cross-shard edges pay interconnect, 16 B per scanned edge whose
        // endpoints have different owners.
        let p = f.partition();
        let cross: f64 = (0..g.n() as u32)
            .filter(|&v| solo.levels[v as usize] != -1)
            .map(|u| {
                g.neighbors(u)
                    .iter()
                    .filter(|&&v| p.owner_of(v) != p.owner_of(u))
                    .count() as f64
            })
            .sum();
        assert!(cross > 0.0, "partition must actually cut this graph");
        let inter: f64 = phases.iter().map(|p| p.total_interconnect_bytes()).sum();
        assert_eq!(inter, 16.0 * cross);
    }

    #[test]
    fn replica_routing_places_demand_on_the_routed_copy() {
        let g = ring_with_hub(24);
        let f = fleet(2, 2, &g);
        let npc = f.cluster().nodes_per_chassis();
        let first_copy = 2 * npc; // replica 0 = chassis 0..2 = nodes 0..16
        for (id, expect_second) in [(0usize, false), (1usize, true), (2usize, false)] {
            let req = QueryRequest::new(crate::alg::bfs::Bfs { src: 0 });
            let spec = f.prepare_one(g.view(), &req, id, id);
            let on_second: f64 = spec
                .phases
                .iter()
                .flat_map(|p| p.channel_ops[first_copy..].iter())
                .sum();
            let on_first: f64 = spec
                .phases
                .iter()
                .flat_map(|p| p.channel_ops[..first_copy].iter())
                .sum();
            if expect_second {
                assert!(on_second > 0.0 && on_first == 0.0, "id {id} routes to replica 1");
            } else {
                assert!(on_first > 0.0 && on_second == 0.0, "id {id} routes to replica 0");
            }
        }
    }

    #[test]
    fn scatter_conserves_totals_and_charges_cut_arcs() {
        let g = ring_with_hub(24);
        let f = fleet(3, 1, &g);
        let m = Machine::new(MachineConfig::pathfinder_8());
        let base = Cc.phases(g.view(), &m, 0);
        let scattered = f.scatter(&base, 0);
        assert_eq!(scattered.len(), base.len());
        let sum = |ps: &[PhaseDemand], sel: fn(&PhaseDemand) -> f64| -> f64 {
            ps.iter().map(sel).sum()
        };
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(close(
            sum(&scattered, |p| p.total_channel_ops()),
            sum(&base, |p| p.total_channel_ops())
        ));
        assert!(close(
            sum(&scattered, |p| p.stream_bytes.iter().sum()),
            sum(&base, |p| p.stream_bytes.iter().sum())
        ));
        assert!(close(
            sum(&scattered, |p| p.total_instructions()),
            sum(&base, |p| p.total_instructions())
        ));
        // Whole-query interconnect = 16 B per cut arc.
        let cut: usize = (0..3).map(|s| f.partition().cut_arcs(s)).sum();
        assert!(cut > 0);
        assert!(close(
            sum(&scattered, |p| p.total_interconnect_bytes()),
            16.0 * cut as f64
        ));
    }

    #[test]
    fn ingest_fans_out_through_the_ordered_log() {
        let g = ring_with_hub(24);
        let f = fleet(2, 2, &g);
        let p = f.partition();
        // One intra-shard and one cross-shard update (by construction).
        let (mut same, mut cross) = (None, None);
        'outer: for u in 0..24u32 {
            for v in (u + 1)..24 {
                if same.is_none() && p.owner_of(u) == p.owner_of(v) {
                    same = Some(EdgeUpdate::insert(u, v));
                } else if cross.is_none() && p.owner_of(u) != p.owner_of(v) {
                    cross = Some(EdgeUpdate::insert(u, v));
                }
                if same.is_some() && cross.is_some() {
                    break 'outer;
                }
            }
        }
        let updates = vec![same.unwrap(), cross.unwrap()];
        let d = f.ingest_phase(&updates);
        // Write + MSP per direction, applied at BOTH replicas.
        assert_eq!(d.total_channel_ops(), 2.0 * 2.0 * 2.0 * 2.0);
        assert_eq!(d.msp_ops.iter().sum::<f64>(), 2.0 * 2.0 * 2.0);
        // Interconnect: the cross-shard update's two primary applies
        // (32 B each) + log shipping of every direction to replica 1
        // (4 directions x 32 B).
        assert_eq!(d.total_interconnect_bytes(), 2.0 * 32.0 + 4.0 * 32.0);
        assert_eq!(d.total_migrations(), 0.0, "ingest never migrates");
        assert_eq!(d.issue_efficiency, Some(1.0));
    }

    #[test]
    fn replica_set_tracks_the_global_store_at_every_epoch() {
        let g = ring_with_hub(24);
        let part = Partition::build(&g, 3, PartitionStrategy::Hash);
        let mut rs = ReplicaSet::new(part, 2);
        let mut global = GraphStore::new(&g);
        let batches: Vec<Vec<EdgeUpdate>> = vec![
            vec![EdgeUpdate::insert(0, 7), EdgeUpdate::delete(0, 1)],
            vec![],
            vec![EdgeUpdate::delete(0, 7), EdgeUpdate::insert(5, 19), EdgeUpdate::insert(5, 19)],
        ];
        for b in &batches {
            let e = rs.apply_batch(b);
            assert_eq!(e, global.apply_batch(b).epoch);
        }
        for epoch in 0..=batches.len() as u64 {
            let want = global.view_at(epoch).unwrap().to_csr();
            for r in 0..2 {
                assert_eq!(
                    rs.materialize(epoch, r).unwrap(),
                    want,
                    "epoch {epoch} replica {r}"
                );
            }
        }
    }

    #[test]
    fn stats_report_shard_utilization_and_interconnect() {
        let g = ring_with_hub(24);
        let f = fleet(4, 1, &g);
        let m = f.machine();
        // One phase drawing half of every channel for 1 ms + a known
        // interconnect volume.
        let p = PhaseDemand::uniform_fleet_load(m, 0.5, 1e6, 1e6);
        let inter = p.total_interconnect_bytes();
        let spec = QuerySpec {
            id: 0,
            label: "bfs",
            phases: vec![p],
            arrival_ns: 0.0,
            priority: crate::sim::flow::Priority::Interactive,
            deadline_ns: None,
            ctx_bytes: 0,
        };
        let s = f.stats(&[spec], 2e6);
        assert_eq!(s.shards, 4);
        assert_eq!(s.shard_util.len(), 4);
        for u in &s.shard_util {
            // Half capacity over half the window = 25%.
            assert!((u - 0.25).abs() < 1e-9, "util {u}");
        }
        assert_eq!(s.interconnect_bytes, inter);
        let lines = s.lines();
        assert!(lines.starts_with("fleet: 4 shards x 1 replicas (balanced)"), "{lines}");
        assert!(lines.contains("shard util: s0 25%"), "{lines}");
    }
}
