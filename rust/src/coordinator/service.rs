//! Long-running service facade: the "web-accessible graph database" shape
//! the paper motivates (§I), on top of the coordinator.
//!
//! Queries arrive over simulated time as a Poisson stream whose class mix
//! is a declarative [`WorkloadSpec`] — weighted analysis classes resolved
//! through the [`crate::alg::AnalysisRegistry`] or supplied as factories —
//! admission control bounds in-flight work at the machine's thread-context
//! capacity, and the report carries per-class latency quantiles
//! (p50/p95/p99), throughput, rejection/queueing behavior and channel
//! utilization — everything an operator would watch on a dashboard.

use crate::alg::{Analysis, AnalysisFactory, AnalysisRegistry};
use crate::config::scenario::ScenarioSpec;
use crate::coordinator::batch::{self, BatchConfig, BatchPlan};
use crate::coordinator::fleet::{Fleet, FleetConfig, FleetStats};
use crate::coordinator::mutation::{
    CompactionFold, IngestBatch, MutationConfig, MutationStats, COMPACT_LABEL, MUTATE_LABEL,
};
use crate::coordinator::request::{Priority, QueryRequest};
use crate::graph::csr::Csr;
use crate::graph::delta::random_batch;
use crate::graph::store::GraphStore;
use crate::sim::flow::{OnFull, QuerySpec, ShareWeights};
use crate::sim::machine::Machine;
use crate::sim::preempt::PreemptPolicy;
use crate::sim::trace::{TraceBuffer, TraceEvent};
use crate::util::rng::SplitMix64;
use crate::util::stats::Quantiles;
use std::sync::Arc;

use super::planner::arrival_times;
use super::scenario::{ScenarioMap, ScenarioStats};
use super::scheduler::{Coordinator, Policy};
use super::telemetry::TelemetryConfig;
use crate::util::json::Json;

/// One weighted analysis class of a service workload.
#[derive(Clone)]
pub struct WorkloadClass {
    /// Class label (for reports; matches the analyses the factory builds).
    pub label: &'static str,
    /// Relative arrival weight (need not sum to 1 across classes).
    pub weight: f64,
    /// Priority the class's requests carry.
    pub priority: Priority,
    /// Latency SLO: the report checks the class's p99 against this target
    /// (s) and turns the quantiles into a pass/fail signal.
    pub slo_p99_s: Option<f64>,
    /// Per-request deadline (s from arrival). Queries whose deadline
    /// expires while queued are shed by admission.
    pub deadline_s: Option<f64>,
    factory: AnalysisFactory,
}

impl WorkloadClass {
    /// A class from an explicit factory.
    pub fn new(label: &'static str, weight: f64, factory: AnalysisFactory) -> Self {
        WorkloadClass {
            label,
            weight,
            priority: Priority::default(),
            slo_p99_s: None,
            deadline_s: None,
            factory,
        }
    }

    /// A class resolved from a registry by label.
    pub fn from_registry(
        registry: &AnalysisRegistry,
        label: &str,
        weight: f64,
    ) -> anyhow::Result<Self> {
        let (label, factory) = registry
            .factory(label)
            .ok_or_else(|| anyhow::anyhow!("unknown analysis class {label:?}"))?;
        Ok(Self::new(label, weight, factory))
    }

    /// Set the priority the class's requests carry.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set a p99 latency SLO (s) the service report checks.
    pub fn with_slo_p99_s(mut self, slo_p99_s: f64) -> Self {
        self.slo_p99_s = Some(slo_p99_s);
        self
    }

    /// Set a per-request deadline (s from arrival); expired queued
    /// requests are shed.
    pub fn with_deadline_s(mut self, deadline_s: f64) -> Self {
        self.deadline_s = Some(deadline_s);
        self
    }

    /// Build one instance rooted at `src`.
    pub fn build(&self, src: u32) -> Arc<dyn Analysis> {
        (self.factory)(src)
    }
}

impl std::fmt::Debug for WorkloadClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkloadClass")
            .field("label", &self.label)
            .field("weight", &self.weight)
            .field("priority", &self.priority)
            .field("slo_p99_s", &self.slo_p99_s)
            .field("deadline_s", &self.deadline_s)
            .finish()
    }
}

/// A distribution over priority classes: arrivals are assigned a priority
/// sampled from these weights, overriding each workload class's default
/// priority. The CLI `serve --priority-mix interactive=0.2,standard=0.6,
/// batch=0.2` knob parses into this.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriorityMix {
    pub interactive: f64,
    pub standard: f64,
    pub batch: f64,
}

impl Default for PriorityMix {
    fn default() -> Self {
        PriorityMix { interactive: 0.0, standard: 1.0, batch: 0.0 }
    }
}

impl PriorityMix {
    /// Parse `class=weight,...` (e.g. `interactive=0.2,standard=0.6,
    /// batch=0.2`); omitted classes get weight 0.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut mix = PriorityMix { interactive: 0.0, standard: 0.0, batch: 0.0 };
        for (class, weight) in crate::util::cli::parse_kv_f64_list(spec, "priority mix")? {
            match class {
                "interactive" => mix.interactive = weight,
                "standard" => mix.standard = weight,
                "batch" => mix.batch = weight,
                other => anyhow::bail!(
                    "unknown priority class {other:?} (want interactive/standard/batch)"
                ),
            }
        }
        mix.validate()?;
        Ok(mix)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.interactive >= 0.0 && self.standard >= 0.0 && self.batch >= 0.0,
            "priority weights must be non-negative"
        );
        anyhow::ensure!(
            self.interactive + self.standard + self.batch > 0.0,
            "total priority weight must be positive"
        );
        Ok(())
    }

    /// Sample one priority class in proportion to the weights.
    pub fn pick(&self, rng: &mut SplitMix64) -> Priority {
        let total = self.interactive + self.standard + self.batch;
        let x = rng.next_f64() * total;
        if x < self.interactive {
            Priority::Interactive
        } else if x < self.interactive + self.standard {
            Priority::Standard
        } else {
            Priority::Batch
        }
    }
}

/// A declarative mixed workload: weighted analysis classes.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub classes: Vec<WorkloadClass>,
}

impl WorkloadSpec {
    pub fn new(classes: Vec<WorkloadClass>) -> Self {
        WorkloadSpec { classes }
    }

    /// The classic paper mix: BFS with a CC fraction.
    pub fn bfs_cc(cc_fraction: f64) -> Self {
        let reg = AnalysisRegistry::builtin();
        WorkloadSpec::new(vec![
            WorkloadClass::from_registry(&reg, "bfs", 1.0 - cc_fraction).expect("builtin"),
            WorkloadClass::from_registry(&reg, "cc", cc_fraction).expect("builtin"),
        ])
    }

    /// The four traversal-shaped classes: mostly interactive short
    /// queries (BFS, k-hop), some SSSP, a CC trickle. The interactive
    /// k-hop class carries a p99 SLO the report checks. (The full
    /// catalog, including the analytic kernels, is
    /// [`WorkloadSpec::six_class`].)
    pub fn four_class() -> Self {
        let reg = AnalysisRegistry::builtin();
        WorkloadSpec::new(vec![
            WorkloadClass::from_registry(&reg, "bfs", 0.5).expect("builtin"),
            WorkloadClass::from_registry(&reg, "khop", 0.25)
                .expect("builtin")
                .with_priority(Priority::Interactive)
                .with_slo_p99_s(0.05),
            WorkloadClass::from_registry(&reg, "sssp", 0.15).expect("builtin"),
            WorkloadClass::from_registry(&reg, "cc", 0.1)
                .expect("builtin")
                .with_priority(Priority::Batch),
        ])
    }

    /// Every shipped analysis in one mix: the [`WorkloadSpec::four_class`]
    /// traversal classes plus the two whole-graph analytic kernels —
    /// PageRank and triangle counting — as Batch-class background work
    /// (both are demand-cacheable, so a stream of them costs one
    /// functional execution each). The interactive k-hop class keeps its
    /// p99 SLO; BFS gets a generous one so the summary shows a
    /// multi-class SLO section.
    pub fn six_class() -> Self {
        let reg = AnalysisRegistry::builtin();
        WorkloadSpec::new(vec![
            WorkloadClass::from_registry(&reg, "bfs", 0.35)
                .expect("builtin")
                .with_slo_p99_s(0.5),
            WorkloadClass::from_registry(&reg, "khop", 0.25)
                .expect("builtin")
                .with_priority(Priority::Interactive)
                .with_slo_p99_s(0.05),
            WorkloadClass::from_registry(&reg, "sssp", 0.15).expect("builtin"),
            WorkloadClass::from_registry(&reg, "cc", 0.1)
                .expect("builtin")
                .with_priority(Priority::Batch),
            WorkloadClass::from_registry(&reg, "pagerank", 0.1)
                .expect("builtin")
                .with_priority(Priority::Batch),
            WorkloadClass::from_registry(&reg, "tricount", 0.05)
                .expect("builtin")
                .with_priority(Priority::Batch),
        ])
    }

    /// Parse a `label=weight,label=weight,...` spec against a registry,
    /// e.g. `bfs=0.6,cc=0.1,sssp=0.2,khop=0.1`.
    pub fn parse(spec: &str, registry: &AnalysisRegistry) -> anyhow::Result<Self> {
        let classes = crate::util::cli::parse_kv_f64_list(spec, "workload mix")?
            .into_iter()
            .map(|(label, weight)| WorkloadClass::from_registry(registry, label, weight))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let spec = WorkloadSpec::new(classes);
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.classes.is_empty(), "workload needs at least one class");
        anyhow::ensure!(
            self.classes.iter().all(|c| c.weight >= 0.0),
            "class weights must be non-negative"
        );
        anyhow::ensure!(self.total_weight() > 0.0, "total class weight must be positive");
        for c in &self.classes {
            // Reports key on Analysis::label(); a mismatched class label
            // would silently vanish from the per-class latency lines.
            let built = c.build(0).label();
            anyhow::ensure!(
                built == c.label,
                "workload class labeled {:?} builds analyses labeled {built:?}",
                c.label
            );
        }
        Ok(())
    }

    pub fn total_weight(&self) -> f64 {
        self.classes.iter().map(|c| c.weight).sum()
    }

    /// Sample one class in proportion to the weights.
    pub fn pick(&self, rng: &mut SplitMix64) -> &WorkloadClass {
        let mut x = rng.next_f64() * self.total_weight();
        for c in &self.classes {
            if x < c.weight {
                return c;
            }
            x -= c.weight;
        }
        self.classes.last().expect("validated non-empty")
    }
}

/// Where `--trace` writes its artifacts (DESIGN.md §Observability).
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Chrome trace-event JSON output path (Perfetto-openable); the
    /// machine-readable telemetry lands next to it as
    /// `<stem>.telemetry.json`.
    pub path: std::path::PathBuf,
    /// Telemetry sample interval (simulated ns); 0 = auto (span/256).
    pub sample_ns: f64,
}

impl TraceSpec {
    pub fn new(path: impl Into<std::path::PathBuf>) -> Self {
        TraceSpec { path: path.into(), sample_ns: 0.0 }
    }

    /// Parse the CLI form `PATH[,sample=NS]` (NS = simulated nanoseconds
    /// between telemetry samples; omitted = auto).
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut parts = spec.split(',');
        let path = parts
            .next()
            .filter(|p| !p.is_empty())
            .ok_or_else(|| anyhow::anyhow!("--trace needs an output path"))?;
        let mut out = TraceSpec::new(path);
        for part in parts {
            match part.split_once('=') {
                Some(("sample", ns)) => {
                    out.sample_ns = ns
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--trace sample={ns:?} is not a number"))?;
                    anyhow::ensure!(
                        out.sample_ns > 0.0,
                        "--trace sample interval must be positive"
                    );
                }
                _ => anyhow::bail!("unknown --trace option {part:?} (want PATH[,sample=NS])"),
            }
        }
        Ok(out)
    }
}

/// Service workload description.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Total queries to serve.
    pub queries: usize,
    /// Mean arrival rate (queries/s of simulated time).
    pub arrival_rate_per_s: f64,
    /// The class mix arrivals are drawn from.
    pub workload: WorkloadSpec,
    /// What to do when thread-context memory is full.
    pub on_full: OnFull,
    /// When set, each arrival's priority is sampled from this distribution
    /// instead of taken from its workload class.
    pub priority_mix: Option<PriorityMix>,
    /// Fair-share weights dividing bandwidth among running queries by
    /// priority class (`serve --weights interactive=4,standard=2,batch=1`;
    /// flat = plain max-min).
    pub weights: ShareWeights,
    /// Checkpoint preemption of running Batch work under Interactive
    /// pressure (`serve --preempt`; None = disabled).
    pub preempt: Option<PreemptPolicy>,
    /// Streaming edge-update lane (`serve --mutate rate=R,batch=B`):
    /// update batches arrive as Batch-class work alongside queries, each
    /// advancing the graph store one epoch (None = static graph, the
    /// byte-identical fast path).
    pub mutation: Option<MutationConfig>,
    /// Sharded multi-chassis serving (`serve --fleet nodes=N,replicas=R,
    /// partition=hash|balanced`): the graph is partitioned across N
    /// shards, replicated R times, queries routed per
    /// [`crate::coordinator::fleet`] and run on the flattened cluster
    /// machine (None = single machine, the byte-identical fast path).
    pub fleet: Option<FleetConfig>,
    /// Multi-source batching (`serve --batch [width=W,window=T]`):
    /// compatible same-epoch arrivals fuse into one shared edge sweep
    /// while each keeps its own latency/SLO record (DESIGN.md §Batching);
    /// None = every query runs solo, the byte-identical fast path.
    pub batch: Option<BatchConfig>,
    /// Query-lifecycle tracing (`serve --trace out.json[,sample=NS]`):
    /// record every engine scheduling event plus coordinator spans and
    /// export Chrome trace JSON + machine-readable telemetry (None = no
    /// tracing, the zero-cost [`crate::sim::trace::NullSink`] path).
    pub trace: Option<TraceSpec>,
    /// Open-loop multi-stream scenario (`serve --scenario <file|name>`,
    /// docs/SCENARIOS.md). When set, the arrival timeline is compiled
    /// from the scenario's per-tenant streams — `queries`,
    /// `arrival_rate_per_s`, `workload` and `priority_mix` are ignored;
    /// everything else (on_full, weights, preempt, mutation, fleet,
    /// batch, trace) composes as usual.
    pub scenario: Option<ScenarioSpec>,
    /// RNG seed (arrivals, sources, query classes, priorities; the
    /// mutation stream forks an independent sub-stream from it).
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            queries: 256,
            arrival_rate_per_s: 100.0,
            workload: WorkloadSpec::bfs_cc(0.1),
            on_full: OnFull::Queue,
            priority_mix: None,
            weights: ShareWeights::flat(),
            preempt: None,
            mutation: None,
            fleet: None,
            batch: None,
            trace: None,
            scenario: None,
            seed: 0x5E21,
        }
    }
}

/// Chainable builders: the optional sub-configs (priority mix, weights,
/// preemption, mutation, fleet, batching) compose without struct-literal
/// field soup — `ServiceConfig::default().with_queries(64).with_preempt
/// (PreemptPolicy::default())` reads like the CLI flags it mirrors.
impl ServiceConfig {
    pub fn with_queries(mut self, queries: usize) -> Self {
        self.queries = queries;
        self
    }

    pub fn with_arrival_rate_per_s(mut self, rate: f64) -> Self {
        self.arrival_rate_per_s = rate;
        self
    }

    pub fn with_workload(mut self, workload: WorkloadSpec) -> Self {
        self.workload = workload;
        self
    }

    pub fn with_on_full(mut self, on_full: OnFull) -> Self {
        self.on_full = on_full;
        self
    }

    pub fn with_priority_mix(mut self, mix: PriorityMix) -> Self {
        self.priority_mix = Some(mix);
        self
    }

    pub fn with_weights(mut self, weights: ShareWeights) -> Self {
        self.weights = weights;
        self
    }

    pub fn with_preempt(mut self, preempt: PreemptPolicy) -> Self {
        self.preempt = Some(preempt);
        self
    }

    pub fn with_mutation(mut self, mutation: MutationConfig) -> Self {
        self.mutation = Some(mutation);
        self
    }

    pub fn with_fleet(mut self, fleet: FleetConfig) -> Self {
        self.fleet = Some(fleet);
        self
    }

    pub fn with_batch(mut self, batch: BatchConfig) -> Self {
        self.batch = Some(batch);
        self
    }

    pub fn with_trace(mut self, trace: TraceSpec) -> Self {
        self.trace = Some(trace);
        self
    }

    pub fn with_scenario(mut self, scenario: ScenarioSpec) -> Self {
        self.scenario = Some(scenario);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Per-class SLO verdict in a service report.
#[derive(Debug, Clone)]
pub struct SloOutcome {
    pub label: String,
    /// The class's declared p99 target (s).
    pub target_p99_s: f64,
    /// Measured p99 (s); None if the class completed nothing.
    pub actual_p99_s: Option<f64>,
    /// True iff the class completed queries and its p99 met the target.
    pub pass: bool,
}

/// Operator-facing service run summary.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub served: usize,
    /// Queries rejected at arrival.
    pub rejected: usize,
    /// Queries shed from the wait queue (deadline expiry or overload).
    pub shed: usize,
    /// Queries checkpoint-parked at least once (all resumed and served;
    /// counted inside `served` too).
    pub preempted: usize,
    /// Wall (simulated) duration from first arrival to last completion (s).
    pub duration_s: f64,
    /// Completed queries per second.
    pub throughput_qps: f64,
    /// Latency quantile summary per class (s), in first-appearance order.
    pub class_latency: Vec<(String, Quantiles)>,
    /// SLO pass/fail per class that declared a p99 target.
    pub slo: Vec<SloOutcome>,
    /// Per-priority-class admission summary (waits, sheds, rejections).
    pub priority: Vec<crate::coordinator::metrics::PriorityStats>,
    /// Per-priority-class completed count + latency quantiles (as
    /// *admitted*, i.e. after any aging promotion) — the data behind the
    /// BENCH schema-2 `class_matrix` row [`ServiceReport::to_json`]
    /// emits. None quantiles = the class completed nothing.
    pub priority_latency: Vec<(Priority, usize, Option<Quantiles>)>,
    /// Peak simultaneous in-flight queries.
    pub peak_concurrency: usize,
    /// Mean channel utilization over the run.
    pub channel_utilization: f64,
    /// The seed the run was generated from (reproduce with `--seed`).
    pub seed: u64,
    /// Mutation-lane summary (epochs, compactions, update throughput);
    /// None for a static-graph run.
    pub mutation: Option<MutationStats>,
    /// Fleet summary (per-shard utilization, interconnect bytes); None
    /// for a single-machine run.
    pub fleet: Option<FleetStats>,
    /// Per-stream scenario outcomes (arrivals, sheds, per-stream seeds,
    /// SLO verdicts); None unless the run was driven by
    /// [`ServiceConfig::scenario`].
    pub scenario: Option<ScenarioStats>,
}

impl ServiceReport {
    /// Latency quantiles of one class, if it completed any queries.
    pub fn class(&self, label: &str) -> Option<&Quantiles> {
        self.class_latency.iter().find(|(l, _)| l == label).map(|(_, q)| q)
    }

    /// SLO verdict of one class, if it declared a target.
    pub fn slo_of(&self, label: &str) -> Option<&SloOutcome> {
        self.slo.iter().find(|s| s.label == label)
    }

    /// All declared SLOs passed (vacuously true with none declared).
    pub fn slos_pass(&self) -> bool {
        self.slo.iter().all(|s| s.pass)
    }

    /// Render a compact operator summary: per-class p50/p95/p99 with SLO
    /// verdicts, plus per-priority waits and shed/reject counts.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "served {} (rejected {}, shed {}, preempted {}) in {:.2}s — {:.1} q/s, \
             peak {} in flight, channel util {:.0}%, seed {:#x}",
            self.served,
            self.rejected,
            self.shed,
            self.preempted,
            self.duration_s,
            self.throughput_qps,
            self.peak_concurrency,
            self.channel_utilization * 100.0,
            self.seed,
        );
        if let Some(f) = &self.fleet {
            out.push_str(&format!("\n  {}", f.lines()));
        }
        if let Some(m) = &self.mutation {
            out.push_str(&format!("\n  {}", m.line()));
        }
        for (label, q) in &self.class_latency {
            out.push_str(&format!("\n  {:>5}: {}", label, q.latency_line()));
            if let Some(s) = self.slo_of(label) {
                out.push_str(&format!(
                    " | SLO p99<={:.3}s: {}",
                    s.target_p99_s,
                    if s.pass { "PASS" } else { "FAIL" }
                ));
            }
        }
        for s in &self.priority {
            out.push_str(&format!("\n  {}", s.line()));
        }
        if let Some(sc) = &self.scenario {
            for st in &sc.streams {
                out.push_str(&format!("\n  {}", st.line()));
            }
        }
        out
    }

    /// Machine-readable report (`serve --report-json`): run identity and
    /// counts, per-label latency quantiles, SLO verdicts, the scenario
    /// stream table, and a BENCH schema-2 compatible `class_matrix` row
    /// keyed `serve/<scenario>` (or `serve` for flat runs) — the exact
    /// cell shape the flow_sim bench writes, so CI can splice scenario
    /// rows into BENCH_pr.json without translation.
    pub fn to_json(&self) -> Json {
        let cell = |n: usize, q: &Option<Quantiles>| match q {
            None => Json::Null,
            Some(q) => Json::obj(vec![
                ("n", Json::num(n as f64)),
                ("p50_s", Json::num(q.q50)),
                ("p95_s", Json::num(q.q95)),
                ("p99_s", Json::num(q.q99)),
            ]),
        };
        let row = Json::Obj(
            self.priority_latency
                .iter()
                .map(|(p, n, q)| {
                    (crate::config::scenario::priority_name(*p).to_string(), cell(*n, q))
                })
                .collect(),
        );
        let key = match &self.scenario {
            Some(sc) => format!("serve/{}", sc.name),
            None => "serve".to_string(),
        };
        Json::obj(vec![
            ("schema", Json::num(2.0)),
            ("kind", Json::str("serve-report")),
            ("seed", Json::str(format!("{:#x}", self.seed))),
            ("served", Json::num(self.served as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("preempted", Json::num(self.preempted as f64)),
            ("duration_s", Json::num(self.duration_s)),
            ("throughput_qps", Json::num(self.throughput_qps)),
            ("peak_concurrency", Json::num(self.peak_concurrency as f64)),
            ("channel_utilization", Json::num(self.channel_utilization)),
            (
                "class_latency",
                Json::Obj(
                    self.class_latency
                        .iter()
                        .map(|(l, q)| {
                            (
                                l.clone(),
                                Json::obj(vec![
                                    ("p50_s", Json::num(q.q50)),
                                    ("p95_s", Json::num(q.q95)),
                                    ("p99_s", Json::num(q.q99)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "slo",
                Json::arr(self.slo.iter().map(|s| {
                    Json::obj(vec![
                        ("label", Json::str(s.label.clone())),
                        ("target_p99_s", Json::num(s.target_p99_s)),
                        (
                            "actual_p99_s",
                            s.actual_p99_s.map_or(Json::Null, Json::num),
                        ),
                        ("pass", Json::Bool(s.pass)),
                    ])
                })),
            ),
            ("class_matrix", Json::Obj([(key, row)].into_iter().collect())),
            (
                "scenario",
                self.scenario.as_ref().map_or(Json::Null, |sc| sc.to_json()),
            ),
        ])
    }
}

/// The service: owns a coordinator and serves arrival streams.
pub struct GraphService<'g> {
    coord: Coordinator<'g>,
}

impl<'g> GraphService<'g> {
    pub fn new(g: &'g Csr, machine: Machine) -> Self {
        GraphService { coord: Coordinator::new(g, machine) }
    }

    pub fn coordinator(&self) -> &Coordinator<'g> {
        &self.coord
    }

    /// Serve a synthetic arrival stream described by `cfg`. With
    /// [`ServiceConfig::mutation`] set, update batches stream in alongside
    /// the queries (see [`GraphService::serve_mutating`]); with
    /// [`ServiceConfig::fleet`] set, queries are routed across the
    /// sharded/replicated fleet (see [`GraphService::serve_fleet`]);
    /// otherwise the graph is static and served by one machine — the
    /// byte-identical fast path.
    pub fn serve(&self, cfg: &ServiceConfig) -> anyhow::Result<ServiceReport> {
        anyhow::ensure!(
            cfg.scenario.is_some() || cfg.queries > 0,
            "need at least one query"
        );
        cfg.workload.validate()?;
        cfg.weights.validate()?;
        if let Some(spec) = &cfg.scenario {
            spec.validate()?;
        }
        if let Some(mix) = &cfg.priority_mix {
            mix.validate()?;
        }
        if let Some(fcfg) = &cfg.fleet {
            fcfg.validate()?;
        }
        if let Some(bcfg) = &cfg.batch {
            bcfg.validate()?;
        }
        if let Some(mcfg) = &cfg.mutation {
            mcfg.validate()?;
            return self.serve_mutating(cfg, mcfg);
        }
        if cfg.fleet.is_some() {
            return self.serve_fleet(cfg);
        }
        let (requests, arrivals, smap) = self.build_query_stream(cfg)?;

        let policy = Policy::ConcurrentAdmitted {
            on_full: cfg.on_full,
            weights: cfg.weights,
            preempt: cfg.preempt,
        };
        let mut tracer = cfg.trace.as_ref().map(|_| TraceBuffer::new());
        let mut coord_events: Vec<TraceEvent> = Vec::new();
        let report = match &cfg.batch {
            // Static graph = one epoch: every compatible request is a
            // fusion candidate, capped only by the width/window budget.
            Some(bcfg) => {
                let plan = BatchPlan::build(&requests, None, bcfg)?;
                let specs = self.coord.prepare(self.coord.view(), 0, plan.fused(), 0);
                if tracer.is_some() {
                    fusion_events(plan.group_of(), plan.fused(), &mut coord_events);
                }
                match tracer.as_mut() {
                    Some(buf) => self.coord.run_specs_grouped_traced(
                        &requests,
                        plan.group_of(),
                        plan.fused(),
                        &specs,
                        policy,
                        buf,
                    )?,
                    None => self.coord.run_specs_grouped(
                        &requests,
                        plan.group_of(),
                        plan.fused(),
                        &specs,
                        policy,
                    )?,
                }
            }
            None => match tracer.as_mut() {
                Some(buf) => {
                    let specs = self.coord.prepare(self.coord.view(), 0, &requests, 0);
                    let identity: Vec<usize> = (0..requests.len()).collect();
                    self.coord
                        .run_specs_grouped_traced(&requests, &identity, &requests, &specs, policy, buf)?
                }
                None => self.coord.run(&requests, policy)?,
            },
        };

        let first_arrival = arrivals.first().copied().unwrap_or(0.0) * 1e-9;
        let out = self.build_report(cfg, &report, first_arrival, None, smap.as_ref());
        if let Some(mut buf) = tracer {
            buf.events.extend(coord_events);
            self.export_trace(cfg, &buf, self.coord.machine())?;
        }
        Ok(out)
    }

    /// Build the fleet router when [`ServiceConfig::fleet`] is set:
    /// partition the served graph and stand up the `shards x replicas`
    /// cluster machine from copies of this service's base machine.
    fn build_fleet(&self, cfg: &ServiceConfig) -> anyhow::Result<Option<Fleet>> {
        match cfg.fleet {
            None => Ok(None),
            Some(fcfg) => {
                Ok(Some(Fleet::new(self.coord.graph(), &self.coord.machine().cfg, fcfg)?))
            }
        }
    }

    /// The static-graph fleet path (`serve --fleet` without `--mutate`):
    /// the same seeded query stream as the single-machine path, each
    /// request routed to its replica set and priced by the fleet demand
    /// models, then run through the usual admission/weights/preemption
    /// machinery on the flattened cluster machine. The report gains a
    /// [`FleetStats`] section (per-shard utilization, interconnect bytes).
    fn serve_fleet(&self, cfg: &ServiceConfig) -> anyhow::Result<ServiceReport> {
        let fleet = self.build_fleet(cfg)?.expect("fleet config present");
        let (requests, arrivals, smap) = self.build_query_stream(cfg)?;
        let view = self.coord.view();
        // Batching composes with the fleet: the plan fuses compatible
        // arrivals exactly as on one machine, and each fused request is
        // priced by the fleet's shared-sweep demand model
        // ([`Fleet::batched_traversal_phases`] via `source_set`).
        let plan = match &cfg.batch {
            Some(bcfg) => Some(BatchPlan::build(&requests, None, bcfg)?),
            None => None,
        };
        let to_prepare: &[QueryRequest] = plan.as_ref().map_or(&requests, |p| p.fused());
        let specs: Vec<QuerySpec> = to_prepare
            .iter()
            .enumerate()
            .map(|(id, req)| fleet.prepare_one(view, req, id, id))
            .collect();
        let fleet_coord = Coordinator::new(self.coord.graph(), fleet.machine().clone());
        let policy = Policy::ConcurrentAdmitted {
            on_full: cfg.on_full,
            weights: cfg.weights,
            preempt: cfg.preempt,
        };
        let mut tracer = cfg.trace.as_ref().map(|_| TraceBuffer::new());
        let mut coord_events: Vec<TraceEvent> = Vec::new();
        if tracer.is_some() {
            if let Some(p) = &plan {
                fusion_events(p.group_of(), p.fused(), &mut coord_events);
            }
            route_events(&fleet, to_prepare, &mut coord_events);
        }
        let report = match (&plan, tracer.as_mut()) {
            (Some(p), Some(buf)) => fleet_coord.run_specs_grouped_traced(
                &requests,
                p.group_of(),
                p.fused(),
                &specs,
                policy,
                buf,
            )?,
            (Some(p), None) => {
                fleet_coord.run_specs_grouped(&requests, p.group_of(), p.fused(), &specs, policy)?
            }
            (None, Some(buf)) => {
                let identity: Vec<usize> = (0..requests.len()).collect();
                fleet_coord
                    .run_specs_grouped_traced(&requests, &identity, &requests, &specs, policy, buf)?
            }
            (None, None) => fleet_coord.run_specs(&requests, &specs, policy)?,
        };
        let first_arrival = arrivals.first().copied().unwrap_or(0.0) * 1e-9;
        let mut out = self.build_report(cfg, &report, first_arrival, None, smap.as_ref());
        out.fleet = Some(fleet.stats(&specs, out.duration_s * 1e9));
        if let Some(mut buf) = tracer {
            buf.events.extend(coord_events);
            self.export_trace(cfg, &buf, fleet.machine())?;
        }
        Ok(out)
    }

    /// The mixed query+update lane (DESIGN.md §Mutation). The timeline
    /// merges the query stream with a Poisson stream of update batches:
    ///
    /// * a **batch arrival** applies its updates to the epoch store (new
    ///   epoch) and submits an [`IngestBatch`] request — Batch-class work
    ///   carrying the memory-side ingest demand through the same
    ///   admission/weights/preemption machinery as queries;
    /// * a **query arrival** pins the epoch current at that instant and is
    ///   prepared against that exact snapshot, so a running traversal
    ///   never sees a half-applied (or later) batch.
    ///
    /// After the engine runs, completions are replayed against the store:
    /// each query unpins its epoch at its finish time and the store
    /// compacts whenever [`MutationConfig::compact_every`] overlays drain
    /// — never retiring a pinned epoch. (The store applies every batch at
    /// its arrival — the data plane; admission models the *bandwidth* of
    /// ingest, so a shed batch's cost leaves the timeline while its edges
    /// still land, as a retry loop would eventually achieve.)
    ///
    /// Compaction is not free bookkeeping: each fold streams the old base
    /// and the drained overlays through the memory side
    /// ([`crate::sim::demand::PhaseDemand::compaction_fold`]). Fold
    /// instants depend on query finish times, so the timeline runs once
    /// without them to find the instants, then re-runs with each fold
    /// submitted as a Batch-class [`CompactionFold`] at the moment its
    /// drain threshold was crossed — one fixed-point iteration; the
    /// store's data plane is identical either way.
    ///
    /// With [`ServiceConfig::fleet`] set, the same merged timeline runs on
    /// the flattened cluster machine: queries are routed/priced by the
    /// fleet demand models, each update batch fans out through the ordered
    /// log ([`Fleet::ingest_phase`]), and folds cover every replica's copy
    /// of the base.
    ///
    /// With [`ServiceConfig::batch`] set, consecutive compatible query
    /// arrivals within one epoch fuse into a single multi-source sweep
    /// (DESIGN.md §Batching); an update batch always closes the open
    /// fusion group first, so a fused sweep never spans epochs.
    fn serve_mutating(
        &self,
        cfg: &ServiceConfig,
        mcfg: &MutationConfig,
    ) -> anyhow::Result<ServiceReport> {
        /// Runaway guard: a mis-set rate cannot explode the timeline.
        const MAX_BATCHES: usize = 16_384;

        let g = self.coord.graph();
        let fleet = self.build_fleet(cfg)?;
        let fleet_coord = fleet.as_ref().map(|f| Coordinator::new(g, f.machine().clone()));
        let policy = || Policy::ConcurrentAdmitted {
            on_full: cfg.on_full,
            weights: cfg.weights,
            preempt: cfg.preempt,
        };
        // Coordinator-level events (epoch applies, compaction folds, batch
        // fusion, shard routing) collect separately from the engine buffer:
        // the fold fixed-point below may discard the first engine run, and
        // these events must survive that re-run.
        let mut tracer = cfg.trace.as_ref().map(|_| TraceBuffer::new());
        let mut coord_events: Vec<TraceEvent> = Vec::new();
        // One shared generator with the static path: the query stream for
        // a given seed is draw-for-draw the same with or without mutation.
        let (query_requests, arrivals, smap) = self.build_query_stream(cfg)?;

        // The mutation stream forks an independent, surfaceable seed: one
        // number in the report reproduces the whole run.
        let mutation_seed = SplitMix64::new(cfg.seed).next_u64() ^ 0x6D75_7461_7465; // "mutate"
        let mut mstream = SplitMix64::new(mutation_seed);
        let mut content_rng = mstream.fork(1);
        let span_ns = arrivals.last().copied().unwrap_or(0.0);
        let mut batch_arrivals = Vec::new();
        let mut t = 0.0f64;
        loop {
            let u = mstream.next_f64().max(1e-12);
            t += -u.ln() / mcfg.rate_batches_per_s * 1e9;
            if t >= span_ns || batch_arrivals.len() >= MAX_BATCHES {
                break;
            }
            batch_arrivals.push(t);
        }
        if batch_arrivals.len() >= MAX_BATCHES {
            // Say so out loud: the tail of the run serves a frozen graph,
            // and throughput numbers describe the truncated stream.
            eprintln!(
                "serve --mutate: batch stream truncated at {MAX_BATCHES} batches \
                 ({:.0} batches/s over a {:.3}s span exceeds the runaway guard); \
                 the remainder of the run mutates nothing",
                mcfg.rate_batches_per_s,
                span_ns * 1e-9
            );
        }
        if batch_arrivals.is_empty() {
            // A lane with zero batches would be a static run in disguise;
            // land one mid-stream so the epoch machinery is exercised.
            batch_arrivals.push(span_ns * 0.5);
        }

        /// Close the open fusion group: fuse its members into one engine
        /// request, price it against the group's pinned snapshot, and map
        /// every member onto the new spec. A singleton group is the member
        /// itself, unwrapped — so with batching off (effective width 1)
        /// this lane is byte-identical to the historical per-query loop.
        #[allow(clippy::too_many_arguments)]
        fn flush_group(
            pending: &mut Vec<usize>,
            epoch: u64,
            store: &GraphStore<'_>,
            coord: &Coordinator<'_>,
            fleet: Option<&Fleet>,
            requests: &[QueryRequest],
            fused: &mut Vec<QueryRequest>,
            group_of: &mut [usize],
            specs: &mut Vec<QuerySpec>,
        ) -> anyhow::Result<()> {
            if pending.is_empty() {
                return Ok(());
            }
            let sid = specs.len();
            let freq = batch::fuse_group(requests, pending)?;
            let spec = match fleet {
                Some(f) => f.prepare_one(store.view(), &freq, sid, sid),
                None => coord.prepare_one(store.view(), epoch, &freq, sid, sid),
            };
            for &i in pending.iter() {
                group_of[i] = sid;
            }
            fused.push(freq);
            specs.push(spec);
            pending.clear();
            Ok(())
        }

        // Merge the two sorted timelines; at equal instants the batch goes
        // first, so "the epoch current at admission" includes it.
        //
        // With the batcher on, consecutive compatible query arrivals (same
        // batch key, same pinned epoch, within the width/window budget)
        // buffer in `pending` and flush as ONE fused spec; an update batch
        // always flushes first, since it advances the epoch and later
        // queries must not fuse across it. `requests` keeps one entry per
        // ORIGINAL arrival (queries, ingest batches, folds); `fused` and
        // `specs` are what the engine runs, 1:1; `group_of` maps originals
        // to their spec so every member keeps its own record.
        let bcfg = cfg.batch.unwrap_or(BatchConfig { width: 1, window_ns: 0.0 });
        let mut store = GraphStore::new(g);
        let total = query_requests.len() + batch_arrivals.len();
        let mut requests: Vec<QueryRequest> = Vec::with_capacity(total);
        let mut fused: Vec<QueryRequest> = Vec::with_capacity(total);
        let mut group_of: Vec<usize> = Vec::with_capacity(total);
        let mut specs = Vec::with_capacity(total);
        let mut pinned: Vec<(usize, u64)> = Vec::new();
        let mut pending: Vec<usize> = Vec::new();
        let mut pending_epoch = 0u64;
        let mut pending_key: Option<String> = None;
        let mut pending_head_ns = 0.0f64;
        let (mut updates_total, mut inserted, mut deleted, mut redundant) = (0usize, 0, 0, 0);
        let (mut qi, mut bi) = (0usize, 0usize);
        while qi < query_requests.len() || bi < batch_arrivals.len() {
            let take_batch = bi < batch_arrivals.len()
                && (qi >= query_requests.len() || batch_arrivals[bi] <= arrivals[qi]);
            if take_batch {
                // The epoch is about to advance: close the open group
                // against the snapshot its members actually pinned.
                flush_group(
                    &mut pending,
                    pending_epoch,
                    &store,
                    &self.coord,
                    fleet.as_ref(),
                    &requests,
                    &mut fused,
                    &mut group_of,
                    &mut specs,
                )?;
                let updates = Arc::new(random_batch(
                    store.view(),
                    mcfg.batch,
                    mcfg.delete_fraction,
                    &mut content_rng,
                ));
                let bs = store.apply_batch(&updates);
                if tracer.is_some() {
                    coord_events.push(TraceEvent::EpochApply {
                        t_ns: batch_arrivals[bi],
                        epoch: bs.epoch,
                        updates: updates.len(),
                    });
                }
                updates_total += updates.len();
                inserted += bs.inserted;
                deleted += bs.deleted;
                redundant += bs.redundant;
                let req = QueryRequest::from_arc(Arc::new(IngestBatch::new(
                    Arc::clone(&updates),
                    bs.epoch,
                )))
                .at(batch_arrivals[bi])
                .with_priority(Priority::Batch);
                let sid = specs.len();
                let spec = match &fleet {
                    // Fleet ingest: fan the batch out through the ordered
                    // log (primary apply + per-replica shipment/splice).
                    Some(f) => QuerySpec {
                        id: sid,
                        label: MUTATE_LABEL,
                        phases: vec![f.ingest_phase(&updates)],
                        arrival_ns: req.arrival_ns,
                        priority: req.priority,
                        deadline_ns: req.deadline_ns,
                        ctx_bytes: f.machine().cfg.ctx_bytes_per_query,
                    },
                    None => self.coord.prepare_one(store.view(), bs.epoch, &req, sid, sid),
                };
                group_of.push(sid);
                requests.push(req.clone());
                fused.push(req);
                specs.push(spec);
                bi += 1;
            } else {
                let epoch = store.pin();
                let req = query_requests[qi].clone();
                let key = req.analysis.batch_key();
                let idx = requests.len();
                let joins = !pending.is_empty()
                    && key.is_some()
                    && key == pending_key
                    && epoch == pending_epoch
                    && pending.len() < bcfg.width
                    && req.arrival_ns - pending_head_ns <= bcfg.window_ns;
                if !joins {
                    flush_group(
                        &mut pending,
                        pending_epoch,
                        &store,
                        &self.coord,
                        fleet.as_ref(),
                        &requests,
                        &mut fused,
                        &mut group_of,
                        &mut specs,
                    )?;
                    pending_epoch = epoch;
                    pending_key = key;
                    pending_head_ns = req.arrival_ns;
                }
                pinned.push((idx, epoch));
                requests.push(req);
                // Placeholder until the group closes and its spec exists.
                group_of.push(usize::MAX);
                pending.push(idx);
                qi += 1;
            }
        }
        flush_group(
            &mut pending,
            pending_epoch,
            &store,
            &self.coord,
            fleet.as_ref(),
            &requests,
            &mut fused,
            &mut group_of,
            &mut specs,
        )?;
        debug_assert!(group_of.iter().all(|&gi| gi != usize::MAX));

        if tracer.is_some() {
            fusion_events(&group_of, &fused, &mut coord_events);
            if let Some(f) = &fleet {
                route_events(f, &fused, &mut coord_events);
            }
        }
        let report = match (&fleet_coord, tracer.as_mut()) {
            (Some(c), Some(buf)) => {
                c.run_specs_grouped_traced(&requests, &group_of, &fused, &specs, policy(), buf)?
            }
            (Some(c), None) => {
                c.run_specs_grouped(&requests, &group_of, &fused, &specs, policy())?
            }
            (None, Some(buf)) => self.coord.run_specs_grouped_traced(
                &requests,
                &group_of,
                &fused,
                &specs,
                policy(),
                buf,
            )?,
            (None, None) => {
                self.coord.run_specs_grouped(&requests, &group_of, &fused, &specs, policy())?
            }
        };

        // Replay completions: unpin each query's epoch when it finished
        // (at arrival for work that never ran) and compact whenever the
        // drained prefix reaches the threshold, recording each fold's
        // instant and volume for the demand pass below.
        let mut unpins: Vec<(f64, u64)> = pinned
            .iter()
            .map(|&(id, epoch)| {
                let r = &report.records[id];
                let t = if r.finish_s.is_finite() { r.finish_s } else { r.arrival_s };
                (t, epoch)
            })
            .collect();
        unpins.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (mut compactions, mut folded) = (0usize, 0usize);
        // (instant s, old base arcs, drained arc records, new base epoch)
        let mut folds: Vec<(f64, usize, usize, u64)> = Vec::new();
        let mut base_arcs = g.m_directed();
        let mut fold_at = |store: &mut GraphStore, t: f64| {
            let cs = store.compact();
            folds.push((t, base_arcs, cs.drained, cs.base_epoch));
            base_arcs = store.view_at(cs.base_epoch).expect("fresh base is live").m_directed();
            folded += cs.drained;
            compactions += 1;
        };
        for &(t, epoch) in &unpins {
            store.unpin(epoch);
            if store.drainable_overlays() >= mcfg.compact_every {
                fold_at(&mut store, t);
            }
        }
        if store.drainable_overlays() > 0 {
            fold_at(&mut store, report.makespan_s);
        }

        // Account the folds: re-run the timeline with each compaction
        // submitted as Batch-class work at the instant the replay
        // triggered it (method docs). With R fleet replicas every copy of
        // the shard folds its own base, so the volume scales by R.
        if tracer.is_some() {
            for &(t_s, _, drained, epoch) in &folds {
                coord_events.push(TraceEvent::Compaction { t_ns: t_s * 1e9, epoch, drained });
            }
        }
        let report = if folds.is_empty() {
            report
        } else {
            let scale = fleet.as_ref().map_or(1, |f| f.config().replicas);
            for &(t_s, arcs, drained, epoch) in &folds {
                let sid = specs.len();
                let req = QueryRequest::from_arc(Arc::new(CompactionFold::new(
                    g.n(),
                    arcs * scale,
                    drained * scale,
                    epoch,
                )))
                .at(t_s * 1e9)
                .with_priority(Priority::Batch);
                let spec = match &fleet_coord {
                    Some(c) => c.prepare_one(store.view(), epoch, &req, sid, sid),
                    None => self.coord.prepare_one(store.view(), epoch, &req, sid, sid),
                };
                group_of.push(sid);
                requests.push(req.clone());
                fused.push(req);
                specs.push(spec);
            }
            // The fold-accounting run IS the reported run: restart the
            // engine trace so the artifact matches the final timeline.
            if let Some(buf) = tracer.as_mut() {
                buf.events.clear();
            }
            match (&fleet_coord, tracer.as_mut()) {
                (Some(c), Some(buf)) => {
                    c.run_specs_grouped_traced(&requests, &group_of, &fused, &specs, policy(), buf)?
                }
                (Some(c), None) => {
                    c.run_specs_grouped(&requests, &group_of, &fused, &specs, policy())?
                }
                (None, Some(buf)) => self.coord.run_specs_grouped_traced(
                    &requests,
                    &group_of,
                    &fused,
                    &specs,
                    policy(),
                    buf,
                )?,
                (None, None) => {
                    self.coord.run_specs_grouped(&requests, &group_of, &fused, &specs, policy())?
                }
            }
        };

        // Both lists are non-empty here (queries > 0 is enforced; an empty
        // batch stream got a fallback batch above).
        let first_arrival_ns = batch_arrivals[0].min(arrivals[0]);
        let mut out = self.build_report(cfg, &report, first_arrival_ns * 1e-9, None, smap.as_ref());
        // One duration for the whole report: the update throughput shares
        // build_report's denominator by construction.
        out.mutation = Some(MutationStats {
            seed: mutation_seed,
            batches: batch_arrivals.len(),
            updates: updates_total,
            inserted,
            deleted,
            redundant,
            compactions,
            overlays_compacted: folded,
            final_overlays: store.live_overlays(),
            update_throughput_per_s: updates_total as f64 / out.duration_s,
            batch_latency: report.latency_quantiles(Some(MUTATE_LABEL)),
        });
        if let Some(f) = &fleet {
            out.fleet = Some(f.stats(&specs, out.duration_s * 1e9));
        }
        if let Some(mut buf) = tracer {
            buf.events.extend(coord_events);
            let machine = fleet.as_ref().map_or(self.coord.machine(), |f| f.machine());
            self.export_trace(cfg, &buf, machine)?;
        }
        Ok(out)
    }

    /// Write the trace artifacts for a finished traced run: Chrome trace
    /// JSON at the configured path and `<stem>.telemetry.json` beside it.
    /// `machine` is the machine the run actually executed on — its
    /// chassis layout drives the per-chassis utilization series (a fleet
    /// run passes the flattened cluster machine, whose
    /// `nodes_per_chassis` is one fleet member).
    fn export_trace(
        &self,
        cfg: &ServiceConfig,
        buf: &TraceBuffer,
        machine: &Machine,
    ) -> anyhow::Result<()> {
        let spec = cfg.trace.as_ref().expect("trace config present");
        let tcfg = TelemetryConfig::default()
            .with_sample_ns(spec.sample_ns)
            .with_chassis(machine.cfg.nodes_per_chassis, machine.cfg.nodes);
        super::telemetry::export(buf, &tcfg, &spec.path)?;
        Ok(())
    }

    /// Generate the seeded query stream: sources, Poisson arrivals, and
    /// per-query class/priority/deadline draws, in arrival order. The ONE
    /// generator all serve paths (static, fleet, mutating) use — the
    /// mutation lane's determinism contract ("same seed, same query
    /// stream") depends on them consuming the rng draw-for-draw
    /// identically, so there is exactly one copy of this code. With
    /// [`ServiceConfig::scenario`] set, the flat Poisson generator is
    /// replaced wholesale by the scenario compiler
    /// ([`super::scenario::compile`]) and the returned map ties every
    /// request back to its tenant stream for per-stream reporting.
    fn build_query_stream(
        &self,
        cfg: &ServiceConfig,
    ) -> anyhow::Result<(Vec<QueryRequest>, Vec<f64>, Option<ScenarioMap>)> {
        let g = self.coord.graph();
        if let Some(spec) = &cfg.scenario {
            let tl =
                super::scenario::compile(g, &AnalysisRegistry::builtin(), spec, cfg.seed)?;
            return Ok((tl.requests, tl.arrivals, Some(tl.map)));
        }
        let mut rng = SplitMix64::new(cfg.seed);
        let sources = crate::graph::sample::bfs_sources(g, cfg.queries, rng.next_u64());
        let arrivals = arrival_times(cfg.queries, cfg.arrival_rate_per_s, rng.next_u64());
        let requests: Vec<QueryRequest> = sources
            .into_iter()
            .zip(&arrivals)
            .map(|(src, &arrival)| {
                let class = cfg.workload.pick(&mut rng);
                let priority = match &cfg.priority_mix {
                    Some(mix) => mix.pick(&mut rng),
                    None => class.priority,
                };
                let mut req = QueryRequest::from_arc(class.build(src))
                    .at(arrival)
                    .with_priority(priority);
                if let Some(d) = class.deadline_s {
                    req = req.with_deadline_ns(d * 1e9);
                }
                req
            })
            .collect();
        Ok((requests, arrivals, None))
    }

    /// Assemble the operator report. `served`/`rejected`/`shed`/
    /// `preempted` and throughput count *queries* (the mutate lane reports
    /// through [`MutationStats`] and its own `"mutate"` class row).
    fn build_report(
        &self,
        cfg: &ServiceConfig,
        report: &crate::coordinator::metrics::RunReport,
        first_arrival_s: f64,
        mutation: Option<MutationStats>,
        smap: Option<&ScenarioMap>,
    ) -> ServiceReport {
        let duration_s = (report.makespan_s - first_arrival_s).max(f64::MIN_POSITIVE);
        let queries = || {
            report
                .records
                .iter()
                .filter(|r| r.label != MUTATE_LABEL && r.label != COMPACT_LABEL)
        };
        let served = queries().filter(|r| r.completed()).count();
        let priority_latency: Vec<(Priority, usize, Option<Quantiles>)> =
            [Priority::Interactive, Priority::Standard, Priority::Batch]
                .into_iter()
                .map(|p| {
                    let xs: Vec<f64> = queries()
                        .filter(|r| r.completed() && r.admitted_as == p)
                        .map(|r| r.latency_s)
                        .collect();
                    (p, xs.len(), Quantiles::try_from_samples(&xs))
                })
                .collect();
        // The k-th query record is the k-th compiled scenario request in
        // every serve path: mutation/compaction lanes carry their own
        // labels (filtered above) and queries keep submission order.
        let scenario = match (&cfg.scenario, smap) {
            (Some(spec), Some(map)) => {
                let recs: Vec<&crate::coordinator::metrics::QueryRecord> = queries().collect();
                Some(ScenarioStats::from_records(spec, map, &recs))
            }
            _ => None,
        };
        let class_latency: Vec<(String, Quantiles)> = report
            .per_class_quantiles()
            .into_iter()
            .map(|(l, q)| (l.to_string(), q))
            .collect();
        let slo = cfg
            .workload
            .classes
            .iter()
            .filter_map(|c| {
                let target = c.slo_p99_s?;
                let actual = class_latency
                    .iter()
                    .find(|(l, _)| l == c.label)
                    .map(|(_, q)| q.q99);
                Some(SloOutcome {
                    label: c.label.to_string(),
                    target_p99_s: target,
                    actual_p99_s: actual,
                    pass: actual.is_some_and(|a| a <= target),
                })
            })
            .collect();
        ServiceReport {
            served,
            rejected: queries().filter(|r| r.rejected()).count(),
            shed: queries().filter(|r| r.shed()).count(),
            preempted: queries().filter(|r| r.preempted()).count(),
            duration_s,
            throughput_qps: served as f64 / duration_s,
            class_latency,
            slo,
            priority: report.priority_stats(),
            priority_latency,
            peak_concurrency: report.peak_concurrency,
            channel_utilization: report.mean_channel_utilization,
            seed: cfg.seed,
            mutation,
            fleet: None,
            scenario,
        }
    }
}

/// One [`TraceEvent::BatchFuse`] per spec that actually coalesced members
/// (width >= 2), stamped at the fused arrival. `group_of[i]` names the
/// spec serving original request `i`, exactly as the scheduler consumes
/// it.
fn fusion_events(group_of: &[usize], fused: &[QueryRequest], out: &mut Vec<TraceEvent>) {
    let mut width = vec![0usize; fused.len()];
    for &gi in group_of {
        width[gi] += 1;
    }
    for (sid, req) in fused.iter().enumerate() {
        if width[sid] >= 2 {
            out.push(TraceEvent::BatchFuse {
                t_ns: req.arrival_ns,
                id: sid,
                width: width[sid],
                label: req.analysis.label(),
            });
        }
    }
}

/// One [`TraceEvent::ShardRoute`] per rooted engine query: the home shard
/// of its (first) source and the replica set `id mod R` serving it.
/// Scatter analyses (and the ingest/fold lanes) span every shard and get
/// no routing event.
fn route_events(fleet: &Fleet, fused: &[QueryRequest], out: &mut Vec<TraceEvent>) {
    for (sid, req) in fused.iter().enumerate() {
        if let Some(src) = req.analysis.source_set().and_then(|s| s.first().copied()) {
            out.push(TraceEvent::ShardRoute {
                t_ns: req.arrival_ns,
                id: sid,
                shard: fleet.partition().owner_of(src),
                replica: fleet.replica_of(sid),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn g() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    #[test]
    fn serves_mixed_stream() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 40,
            workload: WorkloadSpec::bfs_cc(0.2),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 40);
        assert_eq!(rep.rejected, 0);
        assert!(rep.class("bfs").is_some());
        assert!(rep.class("cc").is_some());
        assert!(rep.throughput_qps > 0.0);
        assert!(!rep.summary().is_empty());
    }

    #[test]
    fn four_class_stream_reports_every_class() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 80,
            workload: WorkloadSpec::four_class(),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 80);
        for label in ["bfs", "khop", "sssp", "cc"] {
            let q = rep.class(label).unwrap_or_else(|| panic!("missing class {label}"));
            assert!(q.q0 <= q.q50 && q.q50 <= q.q95 && q.q95 <= q.q99 && q.q99 <= q.q100);
        }
        // The summary surfaces p95/p99 per class.
        let s = rep.summary();
        assert!(s.contains("p95") && s.contains("p99"), "{s}");
    }

    #[test]
    fn workload_spec_parses_against_registry() {
        let reg = crate::alg::AnalysisRegistry::builtin();
        let spec = WorkloadSpec::parse(
            "bfs=0.5, cc=0.1, sssp=0.15, khop=0.1, pagerank=0.1, tricount=0.05",
            &reg,
        )
        .unwrap();
        assert_eq!(spec.classes.len(), 6);
        assert!((spec.total_weight() - 1.0).abs() < 1e-12);
        assert!(WorkloadSpec::parse("betweenness=1.0", &reg).is_err());
        assert!(WorkloadSpec::parse("bfs", &reg).is_err());
        assert!(WorkloadSpec::parse("", &reg).is_err());
    }

    #[test]
    fn mismatched_class_label_is_rejected() {
        let spec = WorkloadSpec::new(vec![WorkloadClass::new(
            "fast-bfs",
            1.0,
            Arc::new(|src| -> Arc<dyn Analysis> { Arc::new(crate::alg::Bfs { src }) }),
        )]);
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("fast-bfs") && err.contains("bfs"), "{err}");
    }

    #[test]
    fn weighted_pick_tracks_weights() {
        let spec = WorkloadSpec::bfs_cc(0.25);
        let mut rng = SplitMix64::new(7);
        let mut cc = 0usize;
        const N: usize = 4000;
        for _ in 0..N {
            if spec.pick(&mut rng).label == "cc" {
                cc += 1;
            }
        }
        let frac = cc as f64 / N as f64;
        assert!((frac - 0.25).abs() < 0.05, "cc fraction {frac}");
    }

    #[test]
    fn overload_rejects_when_configured() {
        let g = g();
        let mut cfg_m = MachineConfig::pathfinder_8();
        cfg_m.ctx_mem_per_node_bytes = 16 << 20; // capacity 8
        let svc = GraphService::new(&g, Machine::new(cfg_m));
        let cfg = ServiceConfig {
            queries: 64,
            arrival_rate_per_s: 1.0e6, // effectively simultaneous
            workload: WorkloadSpec::bfs_cc(0.0),
            on_full: OnFull::Reject,
            seed: 3,
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert!(rep.rejected > 0, "overload should reject");
        assert_eq!(rep.served + rep.rejected, 64);
        assert!(rep.peak_concurrency <= 8);
    }

    #[test]
    fn queueing_serves_everything_eventually() {
        let g = g();
        let mut cfg_m = MachineConfig::pathfinder_8();
        cfg_m.ctx_mem_per_node_bytes = 16 << 20;
        let svc = GraphService::new(&g, Machine::new(cfg_m));
        let cfg = ServiceConfig {
            queries: 64,
            arrival_rate_per_s: 1.0e6,
            workload: WorkloadSpec::bfs_cc(0.0),
            on_full: OnFull::Queue,
            seed: 3,
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 64);
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.shed, 0);
    }

    /// `--priority-mix`: sampled priorities override class priorities and
    /// show up in the per-priority report.
    #[test]
    fn priority_mix_overrides_class_priorities() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 60,
            workload: WorkloadSpec::bfs_cc(0.0),
            priority_mix: Some(PriorityMix { interactive: 0.3, standard: 0.4, batch: 0.3 }),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 60);
        assert_eq!(rep.priority.len(), 3, "all three classes sampled: {:?}", rep.priority);
        let submitted: usize = rep.priority.iter().map(|s| s.submitted).sum();
        assert_eq!(submitted, 60);
        let s = rep.summary();
        assert!(s.contains("[interactive]") && s.contains("[batch]"), "{s}");
    }

    #[test]
    fn priority_mix_parses_and_validates() {
        let m = PriorityMix::parse("interactive=0.2, standard=0.6, batch=0.2").unwrap();
        assert!((m.interactive - 0.2).abs() < 1e-12);
        assert!((m.batch - 0.2).abs() < 1e-12);
        let m = PriorityMix::parse("batch=1.0").unwrap();
        assert_eq!(m.standard, 0.0);
        assert!(PriorityMix::parse("realtime=1.0").is_err());
        assert!(PriorityMix::parse("interactive=-1").is_err());
        assert!(PriorityMix::parse("").is_err());
        let mut rng = SplitMix64::new(1);
        let only_batch = PriorityMix::parse("batch=2.0").unwrap();
        assert_eq!(only_batch.pick(&mut rng), Priority::Batch);
    }

    /// Per-class SLO: a generous target passes under light load; an
    /// impossible target fails — and the verdict appears in the summary.
    #[test]
    fn slo_verdicts_reported_per_class() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let reg = crate::alg::AnalysisRegistry::builtin();
        let workload = WorkloadSpec::new(vec![
            WorkloadClass::from_registry(&reg, "bfs", 0.8)
                .unwrap()
                .with_slo_p99_s(1e6), // generous: passes
            WorkloadClass::from_registry(&reg, "cc", 0.2)
                .unwrap()
                .with_slo_p99_s(1e-12), // impossible: fails
        ]);
        let cfg = ServiceConfig { queries: 40, workload, ..Default::default() };
        let rep = svc.serve(&cfg).unwrap();
        assert!(rep.slo_of("bfs").unwrap().pass);
        assert!(!rep.slo_of("cc").unwrap().pass);
        assert!(!rep.slos_pass());
        let s = rep.summary();
        assert!(s.contains("PASS") && s.contains("FAIL"), "{s}");
    }

    /// Class deadlines flow into admission: under heavy overload with a
    /// tight deadline, queued queries expire and are shed.
    #[test]
    fn class_deadline_sheds_expired_queued_queries() {
        let g = g();
        let mut cfg_m = MachineConfig::pathfinder_8();
        cfg_m.ctx_mem_per_node_bytes = 16 << 20; // capacity 8
        let svc = GraphService::new(&g, Machine::new(cfg_m));
        let reg = crate::alg::AnalysisRegistry::builtin();
        let workload = WorkloadSpec::new(vec![WorkloadClass::from_registry(&reg, "bfs", 1.0)
            .unwrap()
            .with_deadline_s(1e-6)]); // 1 µs: expires while queued
        let cfg = ServiceConfig {
            queries: 64,
            arrival_rate_per_s: 1.0e6,
            workload,
            on_full: OnFull::Queue,
            seed: 3,
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert!(rep.shed > 0, "tight deadlines must shed queued work");
        assert_eq!(rep.served + rep.shed + rep.rejected, 64);
    }

    /// `--weights` + `--preempt` flow through the service: under a
    /// saturating burst with Batch work in the mix, the weighted+preempt
    /// configuration serves Interactive work with a p99 no worse than
    /// plain max-min, parks Batch queries at checkpoints, and still
    /// serves every query.
    #[test]
    fn weights_and_preempt_flow_through_service() {
        let g = g();
        let mut cfg_m = MachineConfig::pathfinder_8();
        cfg_m.ctx_mem_per_node_bytes = 16 << 20; // capacity 8: forces queueing
        let svc = GraphService::new(&g, Machine::new(cfg_m));
        let base_cfg = ServiceConfig {
            queries: 96,
            arrival_rate_per_s: 1.0e6, // effectively simultaneous burst
            workload: WorkloadSpec::bfs_cc(0.0),
            on_full: OnFull::Queue,
            priority_mix: Some(PriorityMix { interactive: 0.25, standard: 0.25, batch: 0.5 }),
            seed: 9,
            ..Default::default()
        };
        let plain = svc.serve(&base_cfg).unwrap();
        assert_eq!(plain.preempted, 0, "preemption defaults off");

        let cfg = ServiceConfig {
            weights: ShareWeights::priority_weighted(),
            preempt: Some(PreemptPolicy::default()),
            ..base_cfg
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 96, "queueing still serves everything");
        assert!(rep.preempted > 0, "batch work must park under this burst");
        assert!(rep.summary().contains("preempted"), "{}", rep.summary());
        let p99 = |r: &ServiceReport| {
            r.priority
                .iter()
                .find(|s| s.priority == Priority::Interactive)
                .and_then(|s| s.latency.as_ref())
                .map(|q| q.q99)
                .expect("interactive latencies")
        };
        assert!(
            p99(&rep) <= p99(&plain),
            "weighted+preempt interactive p99 {} must not exceed plain {}",
            p99(&rep),
            p99(&plain)
        );
        // Only Batch work is ever parked.
        for s in &rep.priority {
            if s.priority != Priority::Batch {
                assert_eq!(s.preempted, 0, "{:?} must not be preempted", s.priority);
            }
        }
    }

    #[test]
    fn reproducible_given_seed() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig { queries: 20, ..Default::default() };
        let a = svc.serve(&cfg).unwrap();
        let b = svc.serve(&cfg).unwrap();
        assert_eq!(a.duration_s, b.duration_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.seed, cfg.seed, "seed surfaces in the report");
        assert!(a.summary().contains("seed"), "{}", a.summary());
        assert!(a.mutation.is_none(), "static run has no mutation section");
    }

    /// Acceptance (DESIGN.md §Mutation): a `serve --mutate` mixed run
    /// completes end to end — queries all served, the `mutate` class
    /// reported alongside query p50/p95/p99, update throughput, epoch
    /// count and compaction stats in the summary — and is reproducible
    /// from its seed.
    #[test]
    fn mutation_lane_serves_mixed_stream_end_to_end() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 24,
            arrival_rate_per_s: 200.0,
            workload: WorkloadSpec::bfs_cc(0.2),
            mutation: Some(crate::coordinator::mutation::MutationConfig {
                rate_batches_per_s: 100.0,
                batch: 16,
                delete_fraction: 0.2,
                compact_every: 2,
            }),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 24, "every query served (mutate lane not counted)");
        let m = rep.mutation.as_ref().expect("mutation stats present");
        assert!(m.batches >= 1);
        assert_eq!(m.updates, m.batches * 16);
        assert!(m.inserted > 0, "{m:?}");
        assert!(m.update_throughput_per_s > 0.0);
        // Every overlay is eventually folded back into a flat base.
        assert!(m.compactions >= 1);
        assert_eq!(m.overlays_compacted, m.batches);
        assert_eq!(m.final_overlays, 0);
        // The mutate lane reports per class like any workload class.
        assert!(rep.class("mutate").is_some(), "mutate class latency row");
        assert!(rep.class("bfs").is_some() && rep.class("cc").is_some());
        assert!(m.batch_latency.is_some());
        // Ingest is Batch-class work under the existing priority machinery.
        let batch_stats = rep
            .priority
            .iter()
            .find(|s| s.priority == Priority::Batch)
            .expect("batch class present");
        assert!(batch_stats.submitted >= m.batches);
        let s = rep.summary();
        assert!(s.contains("mutation:") && s.contains("compactions"), "{s}");
        // Reproducible end to end.
        let rep2 = svc.serve(&cfg).unwrap();
        assert_eq!(rep.duration_s, rep2.duration_s);
        assert_eq!(rep.mutation.as_ref().unwrap().inserted, m.inserted);
        assert_eq!(rep.mutation.as_ref().unwrap().seed, m.seed);
    }

    /// Compaction demand (DESIGN.md §Mutation): whenever the mutate lane
    /// folds overlays, the folds appear as Batch-class `compact` work in
    /// the timeline — with their own class latency row — and never count
    /// as queries.
    #[test]
    fn compaction_folds_appear_as_batch_class_work() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 24,
            arrival_rate_per_s: 200.0,
            workload: WorkloadSpec::bfs_cc(0.2),
            mutation: Some(crate::coordinator::mutation::MutationConfig {
                rate_batches_per_s: 100.0,
                batch: 16,
                delete_fraction: 0.2,
                compact_every: 2,
            }),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        let m = rep.mutation.as_ref().expect("mutation stats present");
        assert!(m.compactions >= 1, "this workload must compact");
        assert_eq!(rep.served, 24, "folds are not queries");
        assert!(rep.class("compact").is_some(), "fold latency row present");
        // Folds ride the Batch lane alongside the ingest batches.
        let batch = rep
            .priority
            .iter()
            .find(|s| s.priority == Priority::Batch)
            .expect("batch class present");
        assert!(batch.submitted >= m.batches + m.compactions);
    }

    /// Acceptance (DESIGN.md §Fleet): `serve --fleet nodes=4,
    /// partition=balanced` runs end to end — every query served, the
    /// report carrying per-shard utilization and the interconnect bytes
    /// the cross-shard routing generated — and is reproducible.
    #[test]
    fn fleet_serves_mixed_stream_end_to_end() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 32,
            workload: WorkloadSpec::bfs_cc(0.2),
            fleet: Some(FleetConfig::parse("nodes=4,partition=balanced").unwrap()),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 32);
        assert_eq!(rep.rejected, 0);
        assert!(rep.class("bfs").is_some() && rep.class("cc").is_some());
        let f = rep.fleet.as_ref().expect("fleet stats present");
        assert_eq!(f.shards, 4);
        assert_eq!(f.replicas, 1);
        assert_eq!(f.strategy, "balanced");
        assert_eq!(f.shard_util.len(), 4);
        assert!(f.interconnect_bytes > 0.0, "an rmat cut at 4 shards ships frontier");
        let s = rep.summary();
        assert!(s.contains("fleet: 4 shards x 1 replicas (balanced)"), "{s}");
        assert!(s.contains("shard util: s0"), "{s}");
        let rep2 = svc.serve(&cfg).unwrap();
        assert_eq!(rep.duration_s, rep2.duration_s, "fleet serving is deterministic");
    }

    /// `--fleet` composes with `--mutate`: every update batch fans out
    /// through the ordered log (interconnect traffic from log shipping at
    /// replicas=2), folds cover every replica's copy, and the query and
    /// mutation accounting match the single-machine lane's shape.
    #[test]
    fn fleet_mutation_lane_fans_out_through_the_ordered_log() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 16,
            arrival_rate_per_s: 200.0,
            workload: WorkloadSpec::bfs_cc(0.2),
            mutation: Some(crate::coordinator::mutation::MutationConfig {
                rate_batches_per_s: 100.0,
                batch: 16,
                delete_fraction: 0.2,
                compact_every: 2,
            }),
            fleet: Some(FleetConfig::parse("nodes=2,replicas=2").unwrap()),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 16, "mutate/compact lanes not counted as queries");
        let m = rep.mutation.as_ref().expect("mutation stats present");
        assert!(m.batches >= 1);
        assert!(m.compactions >= 1);
        assert_eq!(m.final_overlays, 0);
        let f = rep.fleet.as_ref().expect("fleet stats present");
        assert_eq!((f.shards, f.replicas), (2, 2));
        assert!(f.interconnect_bytes > 0.0, "log shipping to replica 1");
        assert!(rep.class("mutate").is_some() && rep.class("compact").is_some());
        let s = rep.summary();
        assert!(s.contains("fleet:") && s.contains("mutation:"), "{s}");
    }

    /// The query stream for a given seed is identical with and without the
    /// mutation lane (the mutation stream is forked, not interleaved), and
    /// a static-graph serve is unchanged by the mutation code path.
    #[test]
    fn mutation_stream_is_forked_not_interleaved() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let static_cfg = ServiceConfig { queries: 16, ..Default::default() };
        let plain = svc.serve(&static_cfg).unwrap();
        let mutate_cfg = ServiceConfig {
            queries: 16,
            mutation: Some(crate::coordinator::mutation::MutationConfig {
                rate_batches_per_s: 40.0,
                batch: 8,
                delete_fraction: 0.0,
                compact_every: 4,
            }),
            ..static_cfg.clone()
        };
        let mutated = svc.serve(&mutate_cfg).unwrap();
        // Same query classes in the same proportions: the class draws come
        // from the same rng positions.
        let count = |r: &ServiceReport, label: &str| {
            r.class_latency.iter().filter(|(l, _)| l == label).count()
        };
        assert_eq!(count(&plain, "bfs"), count(&mutated, "bfs"));
        assert_eq!(plain.served, mutated.served);
    }

    /// `serve --batch`: fusing a burst of same-kind traversals into
    /// shared sweeps serves the same stream faster (every member still
    /// keeps its own record), and a width-1 batcher is indistinguishable
    /// from no batcher at all — the singleton groups unwrap.
    #[test]
    fn batching_fuses_the_static_path_and_speeds_it_up() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let base = ServiceConfig {
            queries: 32,
            arrival_rate_per_s: 1.0e6, // effectively simultaneous burst
            workload: WorkloadSpec::bfs_cc(0.0),
            seed: 11,
            ..Default::default()
        };
        let plain = svc.serve(&base).unwrap();
        let batched = svc
            .serve(&base.clone().with_batch(BatchConfig { width: 16, window_ns: 1e9 }))
            .unwrap();
        assert_eq!(batched.served, 32, "every member keeps its own record");
        assert_eq!(batched.rejected + batched.shed, 0);
        let q50 = |r: &ServiceReport| r.class("bfs").expect("bfs class").q50;
        assert!(
            q50(&batched) < q50(&plain),
            "fused sweeps must beat 32-way solo contention: {} vs {}",
            q50(&batched),
            q50(&plain)
        );
        assert!(batched.duration_s < plain.duration_s);

        let solo =
            svc.serve(&base.clone().with_batch(BatchConfig { width: 1, window_ns: 1e9 })).unwrap();
        assert_eq!(solo.duration_s, plain.duration_s);
        assert_eq!(
            solo.class("bfs").unwrap().q100,
            plain.class("bfs").unwrap().q100,
            "width-1 batching is the unbatched path"
        );
    }

    /// `--batch` composes with `--fleet`: the plan fuses exactly as on a
    /// single machine while each fused sweep is priced with cross-shard
    /// frontier exchange, and the whole stream still gets served.
    #[test]
    fn batching_composes_with_fleet_routing() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let base = ServiceConfig {
            queries: 32,
            arrival_rate_per_s: 1.0e6,
            workload: WorkloadSpec::bfs_cc(0.0),
            fleet: Some(FleetConfig::parse("nodes=4,partition=balanced").unwrap()),
            seed: 11,
            ..Default::default()
        };
        let plain = svc.serve(&base).unwrap();
        let batched = svc
            .serve(&base.clone().with_batch(BatchConfig { width: 16, window_ns: 1e9 }))
            .unwrap();
        assert_eq!(batched.served, 32);
        let f = batched.fleet.as_ref().expect("fleet stats present");
        assert_eq!(f.shards, 4);
        assert!(f.interconnect_bytes > 0.0, "fused sweeps still ship frontier");
        assert!(
            batched.duration_s < plain.duration_s,
            "shared sweeps finish the burst sooner: {} vs {}",
            batched.duration_s,
            plain.duration_s
        );
    }

    /// `--batch` composes with `--mutate`: an update batch closes the
    /// open fusion group, so fused sweeps never span an epoch boundary,
    /// and the mutation/compaction accounting keeps its shape.
    #[test]
    fn batching_composes_with_mutation_epochs() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig {
            queries: 24,
            arrival_rate_per_s: 200.0,
            workload: WorkloadSpec::bfs_cc(0.2),
            mutation: Some(crate::coordinator::mutation::MutationConfig {
                rate_batches_per_s: 100.0,
                batch: 16,
                delete_fraction: 0.2,
                compact_every: 2,
            }),
            batch: Some(BatchConfig { width: 8, window_ns: 1e9 }),
            ..Default::default()
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 24, "per-member records survive fusion");
        let m = rep.mutation.as_ref().expect("mutation stats present");
        assert!(m.batches >= 1);
        assert_eq!(m.final_overlays, 0, "every overlay still folds");
        assert!(rep.class("mutate").is_some());
        let rep2 = svc.serve(&cfg).unwrap();
        assert_eq!(rep.duration_s, rep2.duration_s, "batched mutate lane is deterministic");
    }

    /// The `with_*` builders cover every optional sub-config and chain
    /// into a config that serves.
    #[test]
    fn service_config_builder_matches_literal() {
        let built = ServiceConfig::default()
            .with_queries(12)
            .with_arrival_rate_per_s(50.0)
            .with_workload(WorkloadSpec::bfs_cc(0.0))
            .with_on_full(OnFull::Reject)
            .with_priority_mix(PriorityMix { interactive: 0.5, standard: 0.25, batch: 0.25 })
            .with_weights(ShareWeights::priority_weighted())
            .with_preempt(PreemptPolicy::default())
            .with_batch(BatchConfig::default())
            .with_seed(7);
        assert_eq!(built.queries, 12);
        assert_eq!(built.arrival_rate_per_s, 50.0);
        assert!(matches!(built.on_full, OnFull::Reject));
        assert!(built.priority_mix.is_some() && built.preempt.is_some());
        assert_eq!(built.batch, Some(BatchConfig::default()));
        assert_eq!(built.seed, 7);
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let rep = svc.serve(&built).unwrap();
        assert_eq!(rep.served + rep.rejected + rep.shed, 12);
    }

    #[test]
    fn trace_spec_parses_path_and_sample() {
        let t = TraceSpec::parse("out.json").unwrap();
        assert_eq!(t.path, std::path::PathBuf::from("out.json"));
        assert_eq!(t.sample_ns, 0.0);
        let t = TraceSpec::parse("/tmp/x.json,sample=5e6").unwrap();
        assert_eq!(t.sample_ns, 5e6);
        assert!(TraceSpec::parse("").is_err());
        assert!(TraceSpec::parse("x.json,sample=-1").is_err());
        assert!(TraceSpec::parse("x.json,bogus=1").is_err());
    }

    /// The ISSUE 9 acceptance scenario: `serve --fleet --batch --mutate
    /// --preempt --trace` writes a Chrome trace covering the whole query
    /// lifecycle (>= 8 event kinds, including coordinator-level batch
    /// fusion, epoch applies and shard routing) plus a telemetry sidecar
    /// with non-empty utilization and queue-depth series — and tracing
    /// changes nothing about the run itself.
    #[test]
    fn full_stack_traced_serve_exports_artifacts() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let dir = std::env::temp_dir()
            .join(format!("pfq-trace-test-{}", std::process::id()));
        let path = dir.join("out.json");
        let base = ServiceConfig {
            queries: 48,
            arrival_rate_per_s: 2000.0,
            workload: WorkloadSpec::bfs_cc(0.1),
            priority_mix: Some(PriorityMix { interactive: 0.3, standard: 0.4, batch: 0.3 }),
            weights: ShareWeights::priority_weighted(),
            preempt: Some(PreemptPolicy::default()),
            mutation: Some(crate::coordinator::mutation::MutationConfig {
                rate_batches_per_s: 200.0,
                batch: 16,
                delete_fraction: 0.2,
                compact_every: 2,
            }),
            fleet: Some(FleetConfig::parse("nodes=2,replicas=2").unwrap()),
            batch: Some(BatchConfig { width: 8, window_ns: 1e9 }),
            seed: 3,
            ..Default::default()
        };
        let untraced = svc.serve(&base).unwrap();
        let traced = svc.serve(&base.clone().with_trace(TraceSpec::new(&path))).unwrap();
        // Observation only: the traced run is the same run.
        assert_eq!(traced.served, untraced.served);
        assert_eq!(traced.duration_s, untraced.duration_s);
        assert_eq!(traced.peak_concurrency, untraced.peak_concurrency);

        let doc = crate::util::json::Json::parse_file(&path).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let tel = crate::util::json::Json::parse_file(
            &crate::coordinator::telemetry::telemetry_path(&path),
        )
        .unwrap();
        let counts = tel.get("event_counts").unwrap();
        let kinds = [
            "arrival",
            "admit",
            "phase_start",
            "phase_end",
            "finish",
            "solve",
            "batch_fuse",
            "epoch_apply",
            "shard_route",
            "compaction",
        ];
        let present: Vec<&str> =
            kinds.iter().copied().filter(|k| counts.get_opt(k).is_some()).collect();
        assert!(
            present.len() >= 8,
            "want >= 8 lifecycle event kinds, got {present:?}"
        );
        let series = tel.get("series").unwrap();
        assert!(
            !series.get("t_ns").unwrap().as_arr().unwrap().is_empty(),
            "sampled time axis present"
        );
        assert!(series
            .get("queue_depth")
            .unwrap()
            .get("interactive")
            .unwrap()
            .as_arr()
            .unwrap()
            .len()
            .eq(&series.get("t_ns").unwrap().as_arr().unwrap().len()));
        assert!(series.get("chassis_utilization").unwrap().get("chassis_0").is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }
}
