//! Long-running service facade: the "web-accessible graph database" shape
//! the paper motivates (§I), on top of the coordinator.
//!
//! Queries arrive over simulated time (a Poisson stream of BFS with a CC
//! fraction), admission control bounds in-flight work at the machine's
//! thread-context capacity, and the report carries per-class latency,
//! throughput, rejection/queueing behavior and channel utilization —
//! everything an operator would watch on a dashboard.

use crate::alg::Query;
use crate::graph::csr::Csr;
use crate::sim::flow::OnFull;
use crate::sim::machine::Machine;
use crate::util::rng::SplitMix64;
use crate::util::stats::Quantiles;

use super::planner::arrival_times;
use super::scheduler::{Coordinator, Policy};

/// Service workload description.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Total queries to serve.
    pub queries: usize,
    /// Mean arrival rate (queries/s of simulated time).
    pub arrival_rate_per_s: f64,
    /// Fraction of arrivals that are CC evaluations (rest are BFS).
    pub cc_fraction: f64,
    /// What to do when thread-context memory is full.
    pub on_full: OnFull,
    /// RNG seed (arrivals, sources, query classes).
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            queries: 256,
            arrival_rate_per_s: 100.0,
            cc_fraction: 0.1,
            on_full: OnFull::Queue,
            seed: 0x5E21,
        }
    }
}

/// Operator-facing service run summary.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub served: usize,
    pub rejected: usize,
    /// Wall (simulated) duration from first arrival to last completion (s).
    pub duration_s: f64,
    /// Completed queries per second.
    pub throughput_qps: f64,
    /// Latency five-number summary per class (s).
    pub bfs_latency: Option<Quantiles>,
    pub cc_latency: Option<Quantiles>,
    /// Peak simultaneous in-flight queries.
    pub peak_concurrency: usize,
    /// Mean channel utilization over the run.
    pub channel_utilization: f64,
}

impl ServiceReport {
    /// Render a compact operator summary.
    pub fn summary(&self) -> String {
        let fmt_q = |q: &Option<Quantiles>| match q {
            Some(q) => format!(
                "p0={:.3}s p50={:.3}s p100={:.3}s",
                q.q0, q.q50, q.q100
            ),
            None => "n/a".into(),
        };
        format!(
            "served {} (rejected {}) in {:.2}s — {:.1} q/s, peak {} in flight, \
             channel util {:.0}%\n  bfs: {}\n  cc:  {}",
            self.served,
            self.rejected,
            self.duration_s,
            self.throughput_qps,
            self.peak_concurrency,
            self.channel_utilization * 100.0,
            fmt_q(&self.bfs_latency),
            fmt_q(&self.cc_latency),
        )
    }
}

/// The service: owns a coordinator and serves arrival streams.
pub struct GraphService<'g> {
    coord: Coordinator<'g>,
}

impl<'g> GraphService<'g> {
    pub fn new(g: &'g Csr, machine: Machine) -> Self {
        GraphService { coord: Coordinator::new(g, machine) }
    }

    pub fn coordinator(&self) -> &Coordinator<'g> {
        &self.coord
    }

    /// Serve a synthetic arrival stream described by `cfg`.
    pub fn serve(&self, cfg: &ServiceConfig) -> anyhow::Result<ServiceReport> {
        anyhow::ensure!(cfg.queries > 0, "need at least one query");
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.cc_fraction),
            "cc_fraction must be in [0, 1]"
        );
        let g = self.coord.graph();
        let mut rng = SplitMix64::new(cfg.seed);
        let sources =
            crate::graph::sample::bfs_sources(g, cfg.queries, rng.next_u64());
        let queries: Vec<Query> = sources
            .into_iter()
            .map(|src| {
                if rng.next_f64() < cfg.cc_fraction {
                    Query::Cc
                } else {
                    Query::Bfs { src }
                }
            })
            .collect();
        let arrivals = arrival_times(cfg.queries, cfg.arrival_rate_per_s, rng.next_u64());

        let specs = self.coord.prepare_with_arrivals(&queries, Some(&arrivals));
        let report = self.coord.run_specs(
            &queries,
            &specs,
            Policy::ConcurrentAdmitted { on_full: cfg.on_full },
        )?;

        let first_arrival = arrivals.first().copied().unwrap_or(0.0) * 1e-9;
        let duration_s = (report.makespan_s - first_arrival).max(f64::MIN_POSITIVE);
        Ok(ServiceReport {
            served: report.completed(),
            rejected: report.rejections(),
            duration_s,
            throughput_qps: report.completed() as f64 / duration_s,
            bfs_latency: report.latency_quantiles(Some("bfs")),
            cc_latency: report.latency_quantiles(Some("cc")),
            peak_concurrency: report.peak_concurrency,
            channel_utilization: report.mean_channel_utilization,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::machine::MachineConfig;
    use crate::config::workload::GraphConfig;
    use crate::graph::builder::build_undirected_csr;
    use crate::graph::rmat::Rmat;

    fn g() -> Csr {
        let r = Rmat::new(GraphConfig::with_scale(10));
        build_undirected_csr(1 << 10, &r.edges())
    }

    #[test]
    fn serves_mixed_stream() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig { queries: 40, cc_fraction: 0.2, ..Default::default() };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 40);
        assert_eq!(rep.rejected, 0);
        assert!(rep.bfs_latency.is_some());
        assert!(rep.cc_latency.is_some());
        assert!(rep.throughput_qps > 0.0);
        assert!(!rep.summary().is_empty());
    }

    #[test]
    fn overload_rejects_when_configured() {
        let g = g();
        let mut cfg_m = MachineConfig::pathfinder_8();
        cfg_m.ctx_mem_per_node_bytes = 16 << 20; // capacity 8
        let svc = GraphService::new(&g, Machine::new(cfg_m));
        let cfg = ServiceConfig {
            queries: 64,
            arrival_rate_per_s: 1.0e6, // effectively simultaneous
            cc_fraction: 0.0,
            on_full: OnFull::Reject,
            seed: 3,
        };
        let rep = svc.serve(&cfg).unwrap();
        assert!(rep.rejected > 0, "overload should reject");
        assert_eq!(rep.served + rep.rejected, 64);
        assert!(rep.peak_concurrency <= 8);
    }

    #[test]
    fn queueing_serves_everything_eventually() {
        let g = g();
        let mut cfg_m = MachineConfig::pathfinder_8();
        cfg_m.ctx_mem_per_node_bytes = 16 << 20;
        let svc = GraphService::new(&g, Machine::new(cfg_m));
        let cfg = ServiceConfig {
            queries: 64,
            arrival_rate_per_s: 1.0e6,
            cc_fraction: 0.0,
            on_full: OnFull::Queue,
            seed: 3,
        };
        let rep = svc.serve(&cfg).unwrap();
        assert_eq!(rep.served, 64);
        assert_eq!(rep.rejected, 0);
    }

    #[test]
    fn reproducible_given_seed() {
        let g = g();
        let svc = GraphService::new(&g, Machine::new(MachineConfig::pathfinder_8()));
        let cfg = ServiceConfig { queries: 20, ..Default::default() };
        let a = svc.serve(&cfg).unwrap();
        let b = svc.serve(&cfg).unwrap();
        assert_eq!(a.duration_s, b.duration_s);
        assert_eq!(a.served, b.served);
    }
}
