//! The concurrent-query coordinator — the serving layer of the paper.
//!
//! The paper's experiments submit batches of queries either **sequentially**
//! (one finishes before the next starts) or **concurrently** (all at once,
//! "without any explicit scheduling or allocation of resources", §I) and
//! compare end-to-end times. This module is the system around that,
//! workload-open through [`crate::alg::Analysis`] and [`QueryRequest`]:
//!
//! * [`request`] — a [`QueryRequest`] bundles an analysis with scheduling
//!   metadata (arrival time, priority class, optional deadline);
//! * [`planner`] — turns workload descriptions (query counts, class
//!   mixes, arrival processes) into concrete request lists;
//! * [`admission`] — byte-exact thread-context memory accounting; the
//!   §IV-B 256-queries-on-8-nodes exhaustion becomes a typed rejection, a
//!   priority-ordered wait, or overload shedding (Batch work first);
//! * [`batch`] — the batcher (`serve --batch`): compatible queued
//!   requests (same [`crate::alg::Analysis::batch_key`], same epoch,
//!   within a width/window budget) fuse into ONE multi-source engine
//!   query sharing a single edge sweep ([`crate::alg::msbfs`]), while
//!   every member keeps its own latency/SLO record (DESIGN.md §Batching);
//! * [`scheduler`] — executes a request batch under a policy (sequential /
//!   concurrent / capped-concurrent) on the flow engine, caching and
//!   rotating demand per analysis kind where instances are identical;
//!   admitted execution can divide bandwidth by priority-class weights
//!   ([`ShareWeights`]) and checkpoint-preempt Batch work under
//!   Interactive pressure ([`PreemptPolicy`], DESIGN.md §Scheduling);
//! * [`metrics`] — per-query records, per-class quantiles (Table I),
//!   improvement percentages (Fig. 4), utilization counters;
//! * [`service`] — a long-running service facade: queries arrive over
//!   (simulated) time from a declarative [`WorkloadSpec`], are admitted or
//!   rejected, and per-class latency is tracked — what a web-accessible
//!   graph database deployment of the Pathfinder would look like (§I);
//! * [`mutation`] — the live-graph ingest lane (`serve --mutate`): update
//!   batches advance the epoch store and compete for channel bandwidth as
//!   Batch-class [`IngestBatch`] work, while queries pin the epoch current
//!   at admission (DESIGN.md §Mutation);
//! * [`fleet`] — the sharded multi-chassis routing layer (`serve
//!   --fleet`): a partitioned graph served by `shards x replicas` fleet
//!   members, rooted traversals priced with explicit per-level cross-shard
//!   frontier exchange on the fleet interconnect, update batches fanned
//!   out through one ordered log so every replica of a shard agrees per
//!   epoch (DESIGN.md §Fleet);
//! * [`scenario`] — the open-loop load harness (`serve --scenario`):
//!   compiles a declarative [`crate::config::scenario::ScenarioSpec`] —
//!   per-tenant streams with their own arrival process (constant /
//!   diurnal / bursty / ramp), mix, priority, SLO and deadline — into one
//!   merged deterministic timeline served through the paths above
//!   (docs/SCENARIOS.md);
//! * [`telemetry`] — the observability layer (`--trace`): replays the
//!   engine's [`crate::sim::trace::TraceBuffer`] into sampled
//!   time-series (per-chassis utilization, queue depth per class,
//!   context bytes in flight), per-class latency quantiles, and two
//!   artifacts — Perfetto-openable Chrome trace-event JSON plus a
//!   machine-readable `*.telemetry.json` (DESIGN.md §Observability).

pub mod admission;
pub mod batch;
pub mod fleet;
pub mod metrics;
pub mod mutation;
pub mod planner;
pub mod request;
pub mod scenario;
pub mod scheduler;
pub mod service;
pub mod telemetry;

pub use admission::{ContextExhausted, ContextLedger};
pub use batch::{BatchConfig, BatchPlan};
pub use crate::sim::flow::ShareWeights;
pub use fleet::{Fleet, FleetConfig, FleetStats, ReplicaSet};
pub use crate::sim::preempt::PreemptPolicy;
pub use metrics::{ImprovementRow, Outcome, PriorityStats, QueryRecord, RunReport};
pub use mutation::{
    CompactionFold, IngestBatch, MutationConfig, MutationStats, COMPACT_LABEL, MUTATE_LABEL,
};
pub use planner::{arrival_times, bfs_queries, mix_queries};
pub use request::{Priority, QueryRequest};
pub use scenario::{compile as compile_scenario, ScenarioStats, StreamStats};
pub use scheduler::{Coordinator, Policy};
pub use service::{
    GraphService, PriorityMix, ServiceConfig, ServiceReport, SloOutcome, TraceSpec,
    WorkloadClass, WorkloadSpec,
};
pub use telemetry::{Telemetry, TelemetryConfig};
