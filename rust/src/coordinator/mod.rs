//! The concurrent-query coordinator — the serving layer of the paper.
//!
//! The paper's experiments submit batches of queries either **sequentially**
//! (one finishes before the next starts) or **concurrently** (all at once,
//! "without any explicit scheduling or allocation of resources", §I) and
//! compare end-to-end times. This module is the system around that:
//!
//! * [`planner`] — turns workload descriptions (query counts, BFS/CC
//!   mixes, arrival processes) into concrete query lists;
//! * [`admission`] — thread-context memory accounting; the §IV-B
//!   256-queries-on-8-nodes exhaustion becomes a graceful rejection or a
//!   FIFO wait;
//! * [`scheduler`] — executes a query list under a policy (sequential /
//!   concurrent / capped-concurrent) on the flow engine, caching and
//!   rotating demand where queries are identical;
//! * [`metrics`] — per-query records, per-label quantiles (Table I),
//!   improvement percentages (Fig. 4), utilization counters;
//! * [`service`] — a long-running service facade: queries arrive over
//!   (simulated) time, are admitted or rejected, and per-class latency is
//!   tracked — what a web-accessible graph database deployment of the
//!   Pathfinder would look like (§I).

pub mod admission;
pub mod metrics;
pub mod planner;
pub mod scheduler;
pub mod service;

pub use admission::ContextLedger;
pub use metrics::{ImprovementRow, QueryRecord, RunReport};
pub use planner::{arrival_times, bfs_queries, mix_queries};
pub use scheduler::{Coordinator, Policy};
pub use service::{GraphService, ServiceConfig, ServiceReport};
